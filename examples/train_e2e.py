"""End-to-end fault-tolerant training run.

Trains a reduced Mamba2 config with the full production stack — synthetic
sharded loader, AdamW, remat, atomic checkpoints, the FT driver with an
injected mid-run failure — and verifies the loss curve survives the
restart. Use --full for the real mamba2-130m config on capable hardware.

  PYTHONPATH=src python examples/train_e2e.py --steps 60
"""
import argparse
import shutil

import jax

from repro.configs import get_config, get_smoke_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import SyntheticLoader
from repro.ft.driver import FTConfig, TrainDriver
from repro.models.params import init_params, param_count
from repro.models.transformer import model_specs
from repro.optim.adamw import init_opt_state
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_e2e")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt, ignore_errors=True)

    cfg = (get_config if args.full else get_smoke_config)("mamba2_130m")
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=5,
                     total_steps=args.steps, remat_policy="none")
    params = init_params(jax.random.PRNGKey(0), model_specs(cfg))
    opt = init_opt_state(params)
    print(f"training {cfg.name}: {param_count(model_specs(cfg)):,} params")

    raw = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))

    crash = {"armed": True}

    def step_fn(state, batch):
        if crash["armed"] and state[1].step >= args.steps // 2:
            crash["armed"] = False
            raise RuntimeError("injected node failure at midpoint")
        p, o = state
        p, o, m = raw(p, o, batch)
        return (p, o), m

    driver = TrainDriver(step_fn, FTConfig(checkpoint_dir=args.ckpt,
                                           checkpoint_every=10))
    loader = SyntheticLoader(cfg, args.batch, args.seq)
    state, logs = driver.run((params, opt), loader, num_steps=args.steps)
    losses = [float(m["loss"]) for m in logs]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(logs)} steps "
          f"(retries={driver.stats.retries} — survived the injected failure)")
    assert losses[-1] < losses[0]
    print("OK")


if __name__ == "__main__":
    main()
