"""Reproduce the paper's core fairness experiments in the simulator.

Runs §V-B validation scenarios (protection, donation, upper bound,
thrashing) and prints the numbers next to the paper's claims.

  PYTHONPATH=src python examples/fair_tiering_sim.py
"""
from repro.configs.base import TieringConfig
from repro.core.simulator import simulate
from repro.core.workloads import microbenchmark, thrasher


def main():
    base = dict(n_tenants=3, n_fast_pages=1024, n_slow_pages=512,
                lower_protection=(320, 320, 320), upper_bound=(0, 0, 0))

    print("— §V-B2 lower protection (footprints 120/90/90GB, prot 80GB) —")
    r = simulate(TieringConfig(**base),
                 [microbenchmark(480), microbenchmark(360),
                  microbenchmark(360)], 250)
    gb = r.fast_usage[-25:].mean(0) / 4
    spill = r.slow_usage[-25:].mean(0) / 4
    print(f"  converged local: {gb.round(0)} GB (paper: 80 each)")
    print(f"  spilled to CXL:  {spill.round(0)} GB (paper: 40/10/10)\n")

    print("— §V-B3 donation (B, C under protection; A receives) —")
    r = simulate(TieringConfig(**base),
                 [microbenchmark(480), microbenchmark(280, arrival=40),
                  microbenchmark(280, arrival=40)], 250)
    print(f"  A's local = {r.fast_usage[-25:, 0].mean() / 4:.0f} GB "
          f"(> 80 GB protection: donation is work-conserving)")
    print(f"  B/C demotions in steady state: "
          f"{int(r.demotions[-100:, 1:].sum())} (donors fully protected)\n")

    print("— §V-B4 upper bound (A capped at 80GB despite free memory) —")
    r = simulate(TieringConfig(**{**base, 'upper_bound': (320, 0, 0)}),
                 [microbenchmark(480), microbenchmark(160),
                  microbenchmark(160)], 150)
    print(f"  A's max local: {r.fast_usage[-25:, 0].max() / 4:.0f} GB "
          f"(bound 80)\n")

    print("— §V-B5 thrashing mitigation —")
    tenants = [thrasher(400, fast_share=16), microbenchmark(200),
               microbenchmark(200)]
    cfg = TieringConfig(n_tenants=3, n_fast_pages=1024, n_slow_pages=512,
                        lower_protection=(0, 256, 256), upper_bound=(16, 0, 0),
                        migration_cost=0.0003, t_resident=10, r_thrashing=8.0,
                        controller_period=15)
    on = simulate(cfg, tenants, 300)
    off = simulate(cfg.with_(enable_thrash_mitigation=False), tenants, 300)
    w = slice(200, 300)
    print(f"  thrasher migrations: "
          f"{(off.promotions[w, 0] + off.demotions[w, 0]).mean():.0f}/tick -> "
          f"{(on.promotions[w, 0] + on.demotions[w, 0]).mean():.0f}/tick")
    gain = (on.mean_throughput(w)[1:].sum()
            / off.mean_throughput(w)[1:].sum() - 1)
    print(f"  neighbor throughput: +{gain:.1%} (paper: +7%)")


if __name__ == "__main__":
    main()
