"""End-to-end driver (the paper's kind: serving): multi-tenant batched
decode with the Equilibria-tiered paged KV cache.

Four tenants share a small LM server; tenant 0 gets an upper bound (the
capacity-planning case, §IV-B) and the others get lower protections. The
compiled serve step runs attention over the two-tier paged cache, feeds
per-page attention mass into the hotness tracker, and migrates pages under
the fairness policy — all on-device. Per-tenant cgroup-style counters are
printed every 16 steps.

  PYTHONPATH=src python examples/multi_tenant_serving.py [--steps 96]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import TieringConfig
from repro.models.params import init_params
from repro.models.transformer import model_specs
from repro.serve.decode import build_serve_step, init_serve_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=96)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--arch", default="llama32_1b")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    tcfg = TieringConfig(
        n_tenants=4, page_tokens=4, thrash_table_slots=256,
        lower_protection=(0, 8, 8, 8),       # tenants 1-3 protected
        upper_bound=(6, 0, 0, 0))            # tenant 0 capacity-capped
    params = init_params(jax.random.PRNGKey(0), model_specs(cfg))
    state = init_serve_state(cfg, tcfg, args.batch, args.steps)
    step = jax.jit(build_serve_step(cfg, tcfg, args.batch, args.steps))

    tokens = jnp.ones((args.batch, 1), jnp.int32)
    t0 = time.time()
    for i in range(args.steps):
        logits, state = step(params, state, tokens)
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if (i + 1) % 16 == 0:
            kv = state["kv"]
            ten = np.asarray(kv.tenant)
            fp = np.asarray(kv.fast_page >= 0).sum(1)
            sp = np.asarray(kv.slow_page >= 0).sum(1)
            fast = [int(fp[ten == t].sum()) for t in range(4)]
            slow = [int(sp[ten == t].sum()) for t in range(4)]
            c = kv.counters
            print(f"step {i + 1:3d}: fast={fast} slow={slow} "
                  f"promote={np.asarray(c.promotions).tolist()} "
                  f"demote={np.asarray(c.demotions).tolist()}")
    dt = time.time() - t0
    print(f"\n{args.batch * args.steps} tokens in {dt:.1f}s; tenant 0 stayed "
          f"under its 6-page bound; protected tenants kept their share.")


if __name__ == "__main__":
    main()
