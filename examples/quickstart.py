"""Quickstart: the Equilibria fairness policy in 60 seconds.

Runs two colocated tenants through the tiering engine — once under the TPP
baseline (system-level hotness, no fairness) and once under Equilibria —
and shows the launch-order unfairness the paper opens with (§III-F), then a
tiny model forward/train step to show the ML substrate.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_smoke_config
from repro.configs.base import TieringConfig, TrainConfig
from repro.core.simulator import simulate
from repro.core.workloads import microbenchmark
from repro.data.pipeline import synthetic_batch
from repro.models.params import init_params
from repro.models.transformer import model_specs
from repro.optim.adamw import init_opt_state
from repro.train.step import make_train_step


def tiering_demo():
    print("=== Equilibria vs TPP: launch-order fairness (paper §III-F) ===")
    cfg = TieringConfig(n_tenants=2, n_fast_pages=512, n_slow_pages=512,
                        lower_protection=(256, 256), upper_bound=(0, 0))
    tenants = [microbenchmark(300), microbenchmark(300, arrival=30)]
    for mode in ("tpp", "equilibria"):
        r = simulate(cfg, tenants, 250, mode=mode)
        thr = r.mean_throughput()
        gap = 1 - thr[1] / thr[0]
        print(f"  {mode:11s}: tenantA={thr[0]:7.1f}  lateB={thr[1]:7.1f}  "
              f"late-tenant penalty = {gap:.1%}")
    print("  -> Equilibria's lower protection erases the launch-order tax.\n")


def model_demo():
    print("=== substrate: one train step on a reduced qwen3 config ===")
    cfg = get_smoke_config("qwen3_32b")
    params = init_params(jax.random.PRNGKey(0), model_specs(cfg))
    opt = init_opt_state(params)
    tc = TrainConfig(remat_policy="none", warmup_steps=1)
    step = jax.jit(make_train_step(cfg, tc))
    batch = synthetic_batch(cfg, 2, 32, kind="train")
    for i in range(3):
        params, opt, m = step(params, opt, batch)
        print(f"  step {i}: loss={float(m['loss']):.4f} "
              f"grad_norm={float(m['grad_norm']):.3f}")


if __name__ == "__main__":
    tiering_demo()
    model_demo()
