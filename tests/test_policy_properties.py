"""Hypothesis property tests on the fairness policy invariants (Eq.1/Eq.2,
thrash table) and on engine-level conservation laws."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import TieringConfig
from repro.core import policy as P
from repro.core.state import TenantPolicy
from repro.core.simulator import simulate
from repro.core.workloads import TenantWorkload, microbenchmark

CFG = TieringConfig()


def _policy(prot, bound):
    return TenantPolicy(jnp.asarray(prot, jnp.int32),
                        jnp.asarray(bound, jnp.int32))


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 2000), min_size=1, max_size=8),
       st.lists(st.integers(0, 2000), min_size=1, max_size=8))
def test_eq1_invariants(usage, prot):
    n = min(len(usage), len(prot))
    usage, prot = usage[:n], prot[:n]
    pol = _policy(prot, [0] * n)
    u = jnp.asarray(usage, jnp.int32)
    d = P.eq1_demotion_scan(u, u, pol, jnp.asarray(True))
    d = np.asarray(d)
    # never negative; zero for tenants at/below protection; bounded by usage
    assert (d >= 0).all()
    for i in range(n):
        if usage[i] <= prot[i]:
            assert d[i] == 0
        assert d[i] <= usage[i] + 1e-6
    # monotone in overage: more usage (same protection) => >= scan
    d2 = P.eq1_demotion_scan(u + 100, u + 100, pol, jnp.asarray(True))
    assert (np.asarray(d2) >= d - 1e-6).all()
    # not contended => no demotion pressure
    d3 = P.eq1_demotion_scan(u, u, pol, jnp.asarray(False))
    assert (np.asarray(d3) == 0).all()


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(1, 4000), min_size=1, max_size=8),
       st.lists(st.integers(1, 4000), min_size=1, max_size=8))
def test_eq2_invariants(usage, prot):
    n = min(len(usage), len(prot))
    usage, prot = usage[:n], prot[:n]
    pol = _policy(prot, [0] * n)
    u = jnp.asarray(usage, jnp.int32)
    p_base = jnp.full((n,), 256.0)
    p, throttled = P.eq2_promotion_scan(p_base, u, pol, jnp.asarray(True), CFG)
    p = np.asarray(p)
    # floor of 1/16 of base; never exceeds base
    assert (p >= 256.0 / 16 - 1e-6).all()
    assert (p <= 256.0 + 1e-6).all()
    for i in range(n):
        if usage[i] <= prot[i]:
            assert p[i] == 256.0           # under protection: unthrottled
        else:
            # paper's examples: 1% overage -> ~96%, 10% -> ~68%
            ratio = prot[i] / usage[i]
            expect = max(min(ratio ** 4, 1.0), 1.0 / 16)
            np.testing.assert_allclose(p[i] / 256.0, expect, rtol=1e-5)


def test_eq2_paper_quoted_values():
    """§IV-E: 96% at 1% overage; 68% at 10% overage; floor 1/16."""
    pol = _policy([1000], [0])
    for over, expect in [(1.01, 0.961), (1.10, 0.683)]:
        p, _ = P.eq2_promotion_scan(jnp.array([256.0]),
                                    jnp.array([int(1000 * over)]), pol,
                                    jnp.asarray(True), CFG)
        np.testing.assert_allclose(float(p[0]) / 256.0, expect, atol=0.005)
    p, _ = P.eq2_promotion_scan(jnp.array([256.0]), jnp.array([100000]), pol,
                                jnp.asarray(True), CFG)
    assert abs(float(p[0]) / 256.0 - 1.0 / 16) < 1e-6


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000_000))
def test_thrash_table_roundtrip(page):
    from repro.core.state import ThrashTable
    slots = 64
    table = ThrashTable(page=jnp.full((slots,), -1, jnp.int32),
                        tick=jnp.zeros((slots,), jnp.int32))
    t = jnp.asarray(5, jnp.int32)
    pages = jnp.asarray([page], jnp.int32)
    mask = jnp.asarray([True])
    table = P.thrash_record_promotions(table, pages, mask, t)
    # demotion shortly after -> exactly one thrash event for the owner
    hits = P.thrash_check_demotions(table, pages, mask,
                                    jnp.asarray([1], jnp.int32),
                                    t + 2, CFG, 4)
    assert hits.tolist() == [0, 1, 0, 0]
    # after t_resident, no event
    hits2 = P.thrash_check_demotions(table, pages, mask,
                                     jnp.asarray([1], jnp.int32),
                                     t + CFG.t_resident + 1, CFG, 4)
    assert hits2.tolist() == [0, 0, 0, 0]


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(60, 240), min_size=2, max_size=4),
       st.integers(0, 3))
def test_engine_conservation_properties(footprints, late_idx):
    """Capacity never exceeded; usage equals live footprint; counters sane."""
    n = len(footprints)
    cfg = TieringConfig(
        n_tenants=n, n_fast_pages=256, n_slow_pages=512,
        lower_protection=tuple(256 // n for _ in range(n)),
        upper_bound=(0,) * n)
    tenants = [microbenchmark(f, arrival=(20 if i == late_idx % n else 0))
               for i, f in enumerate(footprints)]
    r = simulate(cfg, tenants, 80, mode="equilibria", k_max=64)
    fast_total = r.fast_usage.sum(axis=1)
    assert (fast_total <= 256).all()                 # capacity invariant
    # after ramp, fast+slow == footprint for every tenant
    for i, f in enumerate(footprints):
        total = r.fast_usage[-1, i] + r.slow_usage[-1, i]
        assert total == f, (i, total, f)
    assert (r.promotions >= 0).all() and (r.demotions >= 0).all()
    # thrash counter is monotone
    assert (np.diff(r.thrash_events, axis=0) >= 0).all()
