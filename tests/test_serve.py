"""Serving-path tests: tiered paged decode must equal the full-sequence
forward bit-for-bit(ish, f32) even while Equilibria migrates pages."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import TieringConfig
from repro.models.params import init_params
from repro.models.transformer import encode_frames, model_forward, model_specs
from repro.serve.decode import (build_serve_step, compute_cross_kv,
                                init_serve_state)

from conftest import arch_params

KEY = jax.random.PRNGKey(0)
TCFG = TieringConfig(n_tenants=2, page_tokens=4, thrash_table_slots=64,
                     lower_protection=(2, 2), upper_bound=(3, 3))


def _decode_all(cfg, params, state, toks, tcfg=TCFG):
    step = jax.jit(build_serve_step(cfg, tcfg, toks.shape[0], toks.shape[1]))
    outs = []
    for i in range(toks.shape[1]):
        logits, state = step(params, state, toks[:, i:i + 1])
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1), state


@pytest.mark.parametrize("arch", arch_params())
def test_decode_matches_forward_with_migrations(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32",
                              param_dtype="float32")
    if cfg.moe is not None:
        # exact decode/forward equivalence needs drop-free capacity
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = init_params(KEY, model_specs(cfg))
    B, steps = 2, 24
    batch = {"tokens": jax.random.randint(KEY, (B, steps), 0, cfg.vocab_size)}
    state = init_serve_state(cfg, TCFG, B, steps)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(7), (B, cfg.num_image_tokens, cfg.d_model)) * 0.1
        ck, cv = compute_cross_kv(params, cfg, batch["image_embeds"])
        state["cross_k"], state["cross_v"] = ck, cv
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(7), (B, cfg.encoder_seq, cfg.d_model)) * 0.1
        enc = encode_frames(params, batch["frames"], cfg, remat="none")
        ck, cv = compute_cross_kv(params, cfg, enc)
        state["cross_k"], state["cross_v"] = ck, cv

    serve_logits, state = _decode_all(cfg, params, state, batch["tokens"])
    ref_logits, _ = model_forward(params, batch, cfg, remat="none")
    err = float(jnp.abs(serve_logits - ref_logits).max())
    assert err < 1e-3, err
    if "kv" in state:
        kv = state["kv"]
        # tight bounds forced real tier activity
        assert int((kv.slow_page >= 0).sum()) > 0
        assert int(kv.seq_len[0]) == steps


def test_swa_ring_wrap_correct():
    cfg = dataclasses.replace(get_smoke_config("h2o_danube_3_4b"),
                              dtype="float32", param_dtype="float32")
    params = init_params(KEY, model_specs(cfg))
    B, steps = 2, 96    # window=32, page=4: ring wraps multiple times
    toks = jax.random.randint(KEY, (B, steps), 0, cfg.vocab_size)
    state = init_serve_state(cfg, TCFG, B, steps)
    serve_logits, _ = _decode_all(cfg, params, state, toks)
    ref_logits, _ = model_forward(params, {"tokens": toks}, cfg, remat="none")
    assert float(jnp.abs(serve_logits - ref_logits).max()) < 1e-3


def test_fairness_counters_on_serving_path():
    """Equilibria inside serve_step: counters move, protections respected."""
    cfg = dataclasses.replace(get_smoke_config("llama32_1b"), dtype="float32")
    params = init_params(KEY, model_specs(cfg))
    B, steps = 8, 40
    tcfg = TieringConfig(n_tenants=2, page_tokens=4, thrash_table_slots=64,
                         lower_protection=(12, 12), upper_bound=(0, 0))
    toks = jax.random.randint(KEY, (B, steps), 0, cfg.vocab_size)
    state = init_serve_state(cfg, tcfg, B, steps)
    _, state = _decode_all(cfg, params, state, toks, tcfg=tcfg)
    kv = state["kv"]
    assert int(kv.counters.allocations.sum()) == B * (steps // 4)
    assert int(kv.t) == steps


def test_unrolled_inplace_decode_matches_scan_path():
    """The unrolled (in-place pool update) decode path used by the dry-run
    must equal the scan path bit-for-bit."""
    from repro.models.unroll import set_unroll
    cfg = dataclasses.replace(get_smoke_config("qwen3_32b"), dtype="float32",
                              param_dtype="float32")
    params = init_params(KEY, model_specs(cfg))
    B, steps = 2, 16
    toks = jax.random.randint(KEY, (B, steps), 0, cfg.vocab_size)
    outs = {}
    for unroll in (False, True):
        set_unroll(unroll)
        try:
            state = init_serve_state(cfg, TCFG, B, steps)
            step = jax.jit(build_serve_step(cfg, TCFG, B, steps))
            got = []
            for i in range(steps):
                logits, state = step(params, state, toks[:, i:i + 1])
                got.append(logits[:, 0])
            outs[unroll] = jnp.stack(got, axis=1)
        finally:
            set_unroll(False)
    assert float(jnp.abs(outs[True] - outs[False]).max()) < 1e-5


def test_tpp_mode_on_serving_path():
    cfg = dataclasses.replace(get_smoke_config("llama32_1b"), dtype="float32")
    params = init_params(KEY, model_specs(cfg))
    B, steps = 4, 16
    toks = jax.random.randint(KEY, (B, steps), 0, cfg.vocab_size)
    state = init_serve_state(cfg, TCFG, B, steps)
    step = jax.jit(build_serve_step(cfg, TCFG, B, steps, mode="tpp"))
    for i in range(steps):
        logits, state = step(params, state, toks[:, i:i + 1])
    ref, _ = model_forward(params, {"tokens": toks}, cfg, remat="none")
    assert float(jnp.abs(logits[:, 0] - ref[:, -1]).max()) < 1e-3
