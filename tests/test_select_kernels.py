"""Interpret-mode equivalence suite for the Pallas selection-core kernels
(kernels/select, kernels/migrate.commit_moves) and the kernel-backed
strategies (select.pallas_static_strategy / pallas_dynamic_strategy).

Three layers, all bit-exact (integer outputs array_equal, f32 outputs
bitwise — the kernels are compare-only / integer-associative, and the
float perf model stays on the shared jnp path):

  1. kernel vs ref oracle: seeded properties over random shapes, scores
     with ties and -inf, zero quotas, k saturation, ring overflow.
  2. strategy vs the jnp "batched" strategy: contiguous and permuted
     static owners, dynamic owners with FREE-sentinel holes.
  3. whole simulation: run_engine / simulate_churn / hotness providers
     with impl="pallas_interpret" vs "batched", every SimResult field
     (including the decoded migration event ring) compared bitwise.

Property cases run under hypothesis when available, else the seeded
fallback (tests/proputil.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proputil import seeded_property
from repro.configs.base import TieringConfig
from repro.core import select as S
from repro.core.engine import run_engine
from repro.core.hotness import SketchSpec
from repro.core.simulator import churn_roster_config, simulate, simulate_churn
from repro.core.workloads import (build_trace, ci_like, microbenchmark,
                                  poisson_churn)
from repro.kernels.migrate.ops import commit_moves, migrate_pages
from repro.kernels.select.ops import seg_reduce, seg_sums, seg_topk
from repro.obs.trace import MigrationRing, ring_record


# ------------------------------------------------------ kernel-level refs ----
def _topk_case(rng):
    # shapes drawn from a small fixed set so compiled kernels are reused
    # across property cases (each new shape is a fresh interpret trace)
    T = int(rng.choice([1, 4, 9]))
    Sn = int(rng.choice([1, 7, 64, 130]))
    if rng.random() < 0.5:      # integer scores force tie-break agreement
        score = rng.integers(-4, 4, (T, Sn)).astype(np.float32)
    else:
        score = rng.standard_normal((T, Sn)).astype(np.float32)
    score[rng.random((T, Sn)) < 0.1] = -np.inf
    valid = rng.random((T, Sn)) < rng.choice([0.3, 0.8, 1.0])
    quotas = rng.integers(0, Sn + 3, T).astype(np.int32)
    quotas[rng.integers(0, T)] = 0
    k = int(rng.choice([1, 5, Sn + 2]))
    return jnp.asarray(score), jnp.asarray(valid), jnp.asarray(quotas), k


@seeded_property(n_fallback=16)
def test_seg_topk_interpret_bit_exact(seed):
    rng = np.random.default_rng(seed)
    score, valid, quotas, k = _topk_case(rng)
    br = int(rng.choice([2, 8]))
    ref = seg_topk(score, valid, quotas, k, impl="ref")
    out = seg_topk(score, valid, quotas, k, impl="pallas_interpret",
                   block_rows=br)
    for name, r, o in zip(("cols", "take", "counts"), ref, out):
        np.testing.assert_array_equal(np.asarray(o), np.asarray(r),
                                      err_msg=name)


@seeded_property(n_fallback=16)
def test_seg_reduce_interpret_bit_exact(seed):
    rng = np.random.default_rng(seed)
    T = int(rng.choice([1, 5, 8]))
    Sn = int(rng.choice([1, 64, 200]))
    x = jnp.asarray(rng.integers(-8, 8, (T, Sn)).astype(np.int32))
    valid = jnp.asarray(rng.random((T, Sn)) < rng.choice([0.0, 0.5, 1.0]))
    br = int(rng.choice([2, 8]))
    rs, rp = seg_reduce(x, valid, impl="ref")
    os_, op = seg_reduce(x, valid, impl="pallas_interpret", block_rows=br)
    np.testing.assert_array_equal(np.asarray(os_), np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(op), np.asarray(rp))
    np.testing.assert_array_equal(
        np.asarray(seg_sums(x, valid, impl="pallas_interpret", block_rows=br)),
        np.asarray(seg_sums(x, valid, impl="ref")))


@seeded_property(n_fallback=16)
def test_commit_moves_interpret_bit_exact(seed):
    """Fused tier scatter + ring append: interpret == ref == the tick's
    original ring_record + drop-scatter composition, including ring
    overflow (N > capacity keeps the newest C) and sentinel-L lanes."""
    rng = np.random.default_rng(seed)
    L = int(rng.choice([8, 48]))
    C = int(rng.choice([1, 4, 8]))
    N = int(rng.choice([1, 16, 33]))
    tier = jnp.asarray(rng.integers(0, 2, L).astype(np.int32))
    data = jnp.asarray(rng.integers(-5, 5, (C, 5)).astype(np.int32))
    head = jnp.asarray(np.int32(rng.integers(0, 3 * C)))
    take_np = rng.random(N) < 0.5
    pages_np = np.where(take_np, rng.integers(0, L, N), L).astype(np.int32)
    pages, take = jnp.asarray(pages_np), jnp.asarray(take_np)
    tenants = jnp.asarray(rng.integers(0, 7, N).astype(np.int32))
    hot = jnp.asarray(rng.standard_normal(N).astype(np.float32))
    t = jnp.asarray(np.int32(rng.integers(0, 100)))
    direction = int(rng.integers(0, 2))
    to_tier = int(rng.integers(0, 2))
    ref = commit_moves(tier, data, head, pages, take, tenants, hot, t,
                       direction=direction, to_tier=to_tier, impl="ref")
    out = commit_moves(tier, data, head, pages, take, tenants, hot, t,
                       direction=direction, to_tier=to_tier,
                       impl="pallas_interpret")
    for name, r, o in zip(("tier", "ring_data", "head"), ref, out):
        np.testing.assert_array_equal(np.asarray(o), np.asarray(r),
                                      err_msg=name)
    # and vs the unfused jnp composition the tick core originally ran
    ring2 = ring_record(MigrationRing(data=data, head=head), take, pages,
                        tenants, hot, direction, t)
    tier2 = tier.at[jnp.where(take, pages, L)].set(to_tier, mode="drop")
    np.testing.assert_array_equal(np.asarray(ref[0]), np.asarray(tier2))
    np.testing.assert_array_equal(np.asarray(ref[1]), np.asarray(ring2.data))
    np.testing.assert_array_equal(np.asarray(ref[2]), np.asarray(ring2.head))


@seeded_property(n_fallback=8)
def test_migrate_page_block_variants(seed):
    """migrate_pages tiling is parameterized: every page_block (including
    non-divisors, which the kernel rounds down) matches the ref."""
    rng = np.random.default_rng(seed)
    l = int(rng.choice([1, 4, 6]))
    b = int(rng.choice([1, 4]))
    msrc, mdst = int(rng.choice([2, 5])), int(rng.choice([2, 5]))
    src = jnp.asarray(rng.standard_normal((l, b, msrc, 2, 2, 8)), jnp.float32)
    dstn = rng.standard_normal((l, b, mdst, 2, 2, 8)).astype(np.float32)
    si = jnp.asarray(rng.integers(0, msrc, b), jnp.int32)
    di = jnp.asarray(rng.integers(0, mdst, b), jnp.int32)
    sel = jnp.asarray(rng.integers(0, 2, b).astype(bool))
    ref = migrate_pages(src, jnp.asarray(dstn), si, di, sel, impl="ref")
    for pb in (1, 3, 8):        # dst_pool is donated: fresh array per call
        out = migrate_pages(src, jnp.asarray(dstn), si, di, sel,
                            impl="pallas_interpret", page_block=pb)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref),
                                      err_msg=f"page_block={pb}")


# --------------------------------------------------------------- edge pins ----
def test_seg_topk_zero_quota_and_all_invalid():
    score = jnp.asarray(np.ones((2, 16), np.float32))
    valid = jnp.asarray(np.array([[True] * 16, [False] * 16]))
    quotas = jnp.asarray(np.array([0, 16], np.int32))
    cols, take, counts = seg_topk(score, valid, quotas, 8,
                                  impl="pallas_interpret")
    assert not np.asarray(take).any()
    np.testing.assert_array_equal(np.asarray(counts), [0, 0])
    np.testing.assert_array_equal(np.asarray(cols), np.full((2, 8), 16))


def test_seg_topk_tie_break_lowest_index():
    """Duplicate scores resolve to the lowest column (lax.top_k order)."""
    score = jnp.asarray(np.zeros((1, 32), np.float32))
    valid = jnp.asarray(np.ones((1, 32), bool))
    cols, take, counts = seg_topk(score, valid, jnp.asarray([4]), 8,
                                  impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(cols)[0, :4], [0, 1, 2, 3])
    np.testing.assert_array_equal(np.asarray(take)[0],
                                  [True] * 4 + [False] * 4)
    assert int(counts[0]) == 4


def test_seg_topk_saturation():
    """quota > eligible -> counts == eligible; quota > k -> counts == k."""
    score = jnp.asarray(np.arange(12, dtype=np.float32)[None])
    valid = jnp.asarray((np.arange(12) % 2 == 0)[None])   # 6 eligible
    _, _, counts = seg_topk(score, valid, jnp.asarray([100]), 12,
                            impl="pallas_interpret")
    assert int(counts[0]) == 6
    _, take, counts = seg_topk(score, jnp.asarray(np.ones((1, 12), bool)),
                               jnp.asarray([100]), 5,
                               impl="pallas_interpret")
    assert int(counts[0]) == 5 and int(np.asarray(take).sum()) == 5


def test_commit_moves_all_sentinel_is_noop():
    """A fully-untaken compact stream writes neither tier nor ring."""
    tier = jnp.asarray(np.zeros(8, np.int32))
    data = jnp.asarray(np.full((4, 5), -1, np.int32))
    out = commit_moves(tier, data, jnp.asarray(np.int32(0)),
                       jnp.asarray(np.full(6, 8, np.int32)),
                       jnp.asarray(np.zeros(6, bool)),
                       jnp.asarray(np.zeros(6, np.int32)),
                       jnp.asarray(np.zeros(6, np.float32)),
                       jnp.asarray(np.int32(3)), direction=1, to_tier=1,
                       impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(out[0]), np.zeros(8))
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(data))
    assert int(out[2]) == 0


# ---------------------------------------------------------- strategy level ----
def _strategy_select_case(rng, T, owner):
    L = owner.shape[0]
    score = (rng.integers(-3, 3, L) if rng.random() < 0.5
             else rng.standard_normal(L)).astype(np.float32)
    active = rng.random(L) < rng.choice([0.3, 0.8, 1.0])
    quotas = rng.integers(0, L // max(T, 1) + 4, T).astype(np.int32)
    quotas[rng.integers(0, T)] = 0
    return jnp.asarray(score), jnp.asarray(active), jnp.asarray(quotas)


@seeded_property(n_fallback=10)
def test_static_strategy_contiguous_bit_exact(seed):
    rng = np.random.default_rng(seed)
    T = int(rng.choice([1, 3, 6]))
    counts = rng.choice([4, 17, 29], T)
    owner = np.repeat(np.arange(T), counts).astype(np.int32)
    k_max = int(rng.choice([3, 16, 64]))
    score, active, quotas = _strategy_select_case(rng, T, owner)
    base = S.static_strategy(owner, T, k_max, impl="batched")
    kern = S.static_strategy(owner, T, k_max, impl="pallas_interpret")
    a = base.select(score, jnp.asarray(owner), active, quotas)
    b = kern.select(score, jnp.asarray(owner), active, quotas)
    np.testing.assert_array_equal(np.asarray(b.mask), np.asarray(a.mask))
    np.testing.assert_array_equal(np.asarray(b.counts), np.asarray(a.counts))
    # the compact stream is consistent with the mask
    L = owner.shape[0]
    flat = np.where(np.asarray(b.take), np.asarray(b.pages), L).ravel()
    mask = np.zeros(L + 1, bool)
    mask[flat] = True
    np.testing.assert_array_equal(mask[:L], np.asarray(a.mask))
    # fused reductions agree with the jnp strategy
    xi = jnp.asarray(rng.integers(0, 5, L).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(kern.by_tenant(xi, jnp.asarray(owner))),
        np.asarray(base.by_tenant(xi, jnp.asarray(owner))))
    new = jnp.asarray(rng.random(L) < 0.4)
    ra, ca = kern.alloc_stats(new, jnp.asarray(owner))
    rr = base.alloc_ranks(new, jnp.asarray(owner))
    np.testing.assert_array_equal(
        np.asarray(ra)[np.asarray(new)], np.asarray(rr)[np.asarray(new)])
    np.testing.assert_array_equal(
        np.asarray(ca), np.asarray(base.by_tenant(
            new.astype(jnp.int32), jnp.asarray(owner))))


@seeded_property(n_fallback=8)
def test_static_strategy_permuted_bit_exact(seed):
    """Arbitrary owner permutations: mask-only selections stay bit-equal."""
    rng = np.random.default_rng(seed)
    T = int(rng.choice([1, 3, 6]))
    L = int(rng.choice([24, 61]))
    owner = rng.integers(0, T, L).astype(np.int32)
    score, active, quotas = _strategy_select_case(rng, T, owner)
    base = S.static_strategy(owner, T, 16, impl="batched")
    kern = S.static_strategy(owner, T, 16, impl="pallas_interpret")
    a = base.select(score, jnp.asarray(owner), active, quotas)
    b = kern.select(score, jnp.asarray(owner), active, quotas)
    if S.plan_layout(owner, T) is None:     # genuinely non-contiguous
        assert b.pages is None  # mask-only, like the jnp generic path
    np.testing.assert_array_equal(np.asarray(b.mask), np.asarray(a.mask))
    np.testing.assert_array_equal(np.asarray(b.counts), np.asarray(a.counts))
    xi = jnp.asarray(rng.integers(0, 5, L).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(kern.by_tenant(xi, jnp.asarray(owner))),
        np.asarray(base.by_tenant(xi, jnp.asarray(owner))))


@seeded_property(n_fallback=8)
def test_dynamic_strategy_holes_bit_exact(seed):
    """Runtime owner vectors with FREE-sentinel (owner == T) holes."""
    rng = np.random.default_rng(seed)
    T = int(rng.choice([1, 3, 5]))
    L = int(rng.choice([16, 57]))
    owner = rng.integers(0, T + 1, L).astype(np.int32)   # T = free pool
    score, active, quotas = _strategy_select_case(rng, T, owner)
    base = S.dynamic_strategy(T, 16, impl="batched")
    kern = S.dynamic_strategy(T, 16, impl="pallas_interpret")
    a = base.select(score, jnp.asarray(owner), active, quotas)
    b = kern.select(score, jnp.asarray(owner), active, quotas)
    np.testing.assert_array_equal(np.asarray(b.mask), np.asarray(a.mask))
    np.testing.assert_array_equal(np.asarray(b.counts), np.asarray(a.counts))


# ----------------------------------------------------------- whole engine ----
def _assert_simresult_equal(a, b):
    for f in ("fast_usage", "slow_usage", "promotions", "demotions",
              "throughput", "latency", "promo_scale", "thrash_events",
              "attempted", "pool_free"):
        x, y = getattr(a, f), getattr(b, f)
        if x is None or y is None:
            assert x is None and y is None, f
            continue
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x),
                                      err_msg=f)
    assert a.migrations_dropped == b.migrations_dropped
    np.testing.assert_array_equal(b.migrations, a.migrations)
    assert set(a.tier_stats) == set(b.tier_stats)
    for k in a.tier_stats:
        np.testing.assert_array_equal(np.asarray(b.tier_stats[k]),
                                      np.asarray(a.tier_stats[k]), err_msg=k)


def _small_static():
    cfg = TieringConfig(n_tenants=3, n_fast_pages=256, n_slow_pages=256,
                        lower_protection=(96, 96, 0),
                        upper_bound=(0, 120, 0))
    tenants = [microbenchmark(150), microbenchmark(140, arrival=10),
               ci_like(120, phase_len=20)]
    return cfg, tenants


@pytest.mark.parametrize("mode", ["equilibria", "tpp", "memtis", "static"])
def test_engine_pallas_interpret_matches_batched(mode):
    """Whole-trace equivalence on all four policy modes: every TickOutput
    field of the kernel tick is bit-equal to the jnp tick — floats
    included (the perf model runs the same jnp ops in both)."""
    cfg, tenants = _small_static()
    owner, acc, alive = build_trace(tenants, 40)
    _, a = run_engine(cfg, owner, acc, alive, mode=mode, k_max=64,
                      impl="batched")
    _, b = run_engine(cfg, owner, acc, alive, mode=mode, k_max=64,
                      impl="pallas_interpret")
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(b, f)),
                                      np.asarray(getattr(a, f)), err_msg=f)


@pytest.mark.parametrize("impl", ["jnp", "pallas_ref"])
def test_engine_impl_aliases_match_batched(impl):
    """impl="jnp" is the batched path verbatim; impl="pallas_ref" runs the
    kernel algorithm through its compiled jnp oracle (the CPU/GPU fast
    path) and must also be bit-exact."""
    cfg, tenants = _small_static()
    owner, acc, alive = build_trace(tenants, 10)
    _, a = run_engine(cfg, owner, acc, alive, k_max=32, impl="batched")
    _, b = run_engine(cfg, owner, acc, alive, k_max=32, impl=impl)
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(b, f)),
                                      np.asarray(getattr(a, f)), err_msg=f)


def test_churn_pallas_interpret_matches_batched():
    """Dynamic-ownership engine (runtime owner vector, free pool, lifecycle
    events) through the kernel strategy: full SimResult bitwise equal,
    migration event ring included."""
    slots = poisson_churn(n_slots=4, ticks=60, seed=3)
    cfg = churn_roster_config(slots)
    a = simulate_churn(cfg, slots, 60, mode="equilibria", k_max=32,
                       impl="batched")
    b = simulate_churn(cfg, slots, 60, mode="equilibria", k_max=32,
                       impl="pallas_interpret")
    _assert_simresult_equal(a, b)


def test_hotness_sketch_pallas_matches_batched():
    """Sketch-provider compact streams (provider buffer width, not the
    strategy rowspace) flow through the commit_moves kernel bit-exactly —
    pins the lane-tenant derivation in the strategy's move hook."""
    cfg, tenants = _small_static()
    spec = SketchSpec(depth=2, width=1024, n_cand=16, n_cold=16, probe=256)
    a = simulate(cfg, tenants, 25, k_max=32, impl="batched", hotness=spec)
    b = simulate(cfg, tenants, 25, k_max=32, impl="pallas_interpret",
                 hotness=spec)
    _assert_simresult_equal(a, b)
