"""Per-kernel validation: Pallas (interpret=True, kernel body on CPU) vs the
pure-jnp ref.py oracle, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ------------------------------------------------------- flash attention ----
@pytest.mark.parametrize("b,h,kh,sq,skv,d", [
    (2, 4, 2, 128, 128, 64),
    (1, 8, 8, 64, 64, 32),
    (2, 2, 1, 64, 256, 64),      # decode-ish: short q, long kv
    (1, 4, 2, 256, 256, 48),     # non-128 head dim (pad path)
])
@pytest.mark.parametrize("dtype", [
    jnp.float32, pytest.param(jnp.bfloat16, marks=pytest.mark.slow)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention(b, h, kh, sq, skv, d, dtype, causal, window):
    from repro.kernels.flash_attention.ops import flash_attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, kh, skv, d), dtype)
    v = jax.random.normal(ks[2], (b, kh, skv, d), dtype)
    ref = flash_attention(q, k, v, causal=causal, window=window, impl="ref")
    out = flash_attention(q, k, v, causal=causal, window=window,
                          impl="pallas_interpret", block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


# ------------------------------------------------------ tiered attention ----
@pytest.mark.parametrize("b,h,kh,d,mf,ms,pt", [
    (2, 8, 4, 64, 16, 8, 8),
    (3, 4, 4, 32, 8, 8, 4),
    (2, 16, 2, 64, 16, 16, 8),
])
@pytest.mark.parametrize("dtype", [
    jnp.float32, pytest.param(jnp.bfloat16, marks=pytest.mark.slow)])
@pytest.mark.parametrize("window", [None, 40])
def test_tiered_attention(b, h, kh, d, mf, ms, pt, dtype, window):
    from repro.kernels.tiered_attention.ops import tiered_attention
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, 1, h, d), dtype)
    fk = jax.random.normal(ks[1], (b, mf, pt, kh, d), dtype)
    fv = jax.random.normal(ks[2], (b, mf, pt, kh, d), dtype)
    sk = jax.random.normal(ks[3], (b, ms, pt, kh, d), dtype)
    sv = jax.random.normal(ks[4], (b, ms, pt, kh, d), dtype)
    fp = jnp.where(jnp.arange(mf)[None] < mf - 2,
                   jnp.arange(mf)[None].repeat(b, 0), -1)
    sp = jnp.where(jnp.arange(ms)[None] < ms - 1,
                   (mf - 2 + jnp.arange(ms))[None].repeat(b, 0), -1)
    seq_len = jnp.full((b,), (mf - 2 + ms - 1) * pt - 3, jnp.int32)
    ref = tiered_attention(q, fk, fv, sk, sv, fp, sp, seq_len,
                           window=window, impl="ref")
    out = tiered_attention(q, fk, fv, sk, sv, fp, sp, seq_len,
                           window=window, impl="pallas_interpret",
                           page_block=4)
    for r, o in zip(ref, out):
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32),
                                   atol=_tol(dtype), rtol=1e-2)


def test_tiered_attention_matches_serving_path():
    """Kernel ref == the XLA function used inside serve_step."""
    from repro.kernels.tiered_attention.ops import tiered_attention
    from repro.memtier.kvcache import tiered_paged_attention
    b, h, kh, d, mf, ms, pt = 2, 8, 4, 32, 8, 8, 4
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    fk = jax.random.normal(ks[1], (b, mf, pt, kh, d), jnp.float32)
    fv = jax.random.normal(ks[2], (b, mf, pt, kh, d), jnp.float32)
    sk = jax.random.normal(ks[3], (b, ms, pt, kh, d), jnp.float32)
    sv = jax.random.normal(ks[4], (b, ms, pt, kh, d), jnp.float32)
    fp = jnp.tile(jnp.arange(mf)[None], (b, 1))
    sp = jnp.where(jnp.arange(ms)[None] < ms - 2,
                   (mf + jnp.arange(ms))[None].repeat(b, 0), -1)
    seq_len = jnp.full((b,), (mf + ms - 2) * pt - 1, jnp.int32)
    out_k, mf_k, ms_k = tiered_attention(q, fk, fv, sk, sv, fp, sp, seq_len,
                                         impl="ref")
    # serving path uses token-validity masks built from the same metadata
    tok_f = fp[:, :, None] * pt + jnp.arange(pt)[None, None]
    okf = (fp >= 0)[:, :, None] & (tok_f <= seq_len[:, None, None])
    tok_s = sp[:, :, None] * pt + jnp.arange(pt)[None, None]
    oks = (sp >= 0)[:, :, None] & (tok_s <= seq_len[:, None, None])
    out_s, mf_s, ms_s = tiered_paged_attention(q, fk, fv, sk, sv, okf, oks)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_s), atol=2e-5)
    np.testing.assert_allclose(np.asarray(mf_k), np.asarray(mf_s), atol=2e-5)
    np.testing.assert_allclose(np.asarray(ms_k), np.asarray(ms_s), atol=2e-5)


# --------------------------------------------------------------- migrate ----
@pytest.mark.parametrize("l,b,msrc,mdst,pt,kh,d", [
    (2, 4, 6, 5, 4, 2, 16), (1, 8, 4, 4, 8, 1, 32), (3, 2, 8, 8, 2, 4, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_migrate(l, b, msrc, mdst, pt, kh, d, dtype):
    from repro.kernels.migrate.ops import migrate_pages
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.normal(size=(l, b, msrc, pt, kh, d)), dtype)
    dstn = rng.normal(size=(l, b, mdst, pt, kh, d)).astype(np.float32)
    si = jnp.asarray(rng.integers(0, msrc, b), jnp.int32)
    di = jnp.asarray(rng.integers(0, mdst, b), jnp.int32)
    sel = jnp.asarray(rng.integers(0, 2, b).astype(bool))
    ref = migrate_pages(src, jnp.asarray(dstn, dtype), si, di, sel, impl="ref")
    out = migrate_pages(src, jnp.asarray(dstn, dtype), si, di, sel,
                        impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(ref, np.float32))


# -------------------------------------------------------------- ssd scan ----
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 64, 3, 16, 8, 16), (1, 128, 2, 32, 16, 32), (2, 32, 4, 8, 8, 8)])
@pytest.mark.parametrize("dtype", [
    jnp.float32, pytest.param(jnp.bfloat16, marks=pytest.mark.slow)])
def test_ssd_scan(b, s, h, p, n, chunk, dtype):
    from repro.kernels.ssd_scan.ops import ssd_scan
    from repro.models.ssm import ssd_recurrent_ref
    ks = jax.random.split(KEY, 4)
    x = (jax.random.normal(ks[0], (b, s, h, p)) * 0.5).astype(dtype)
    a = (-jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.3).astype(jnp.float32)
    bb = (jax.random.normal(ks[2], (b, s, h, n)) * 0.5).astype(dtype)
    cc = (jax.random.normal(ks[3], (b, s, h, n)) * 0.5).astype(dtype)
    y_ref, h_ref = ssd_recurrent_ref(x.astype(jnp.float32), a,
                                     bb.astype(jnp.float32),
                                     cc.astype(jnp.float32))
    y, hf = ssd_scan(x, a, bb, cc, chunk=chunk, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=5 * _tol(dtype), rtol=5e-2)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h_ref),
                               atol=5 * _tol(dtype), rtol=5e-2)
