"""Dynamic-ownership subsystem tests (core/churn.py).

Pins the tentpole properties: lifecycle events are scan data (one jaxpr
serves any churn schedule), page-count conservation holds under arbitrary
generated schedules, departed tenants own nothing, slot reuse resets
controller state, policy re-partitioning respects capacity, and the
pathology detectors tolerate mid-window departures.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from proputil import seeded_property

from repro.configs.base import TieringConfig
from repro.core import policy as P
from repro.core.churn import (ChurnSchedule, churn_events, make_churn_tick,
                              run_churn_engine)
from repro.core.simulator import CHURN_PRESETS, simulate_churn, simulate_preset
from repro.core.state import TenantPolicy, init_state
from repro.core.workloads import (ChurnSlot, TenantWorkload,
                                  build_churn_schedule, cache_like, web_like)
from repro.obs import pathology as PATH

# ------------------------------------------------- shared compiled runner ----
# One fixed-shape runner for the property suite: hypothesis/fallback examples
# vary only the schedule *data*, so jax compiles the scan exactly once.
_T, _S, _L, _TICKS = 4, 24, 160, 24
_RUNNER = {}


def _runner():
    if not _RUNNER:
        cfg = TieringConfig(n_tenants=_T, n_fast_pages=48, n_slow_pages=112,
                            lower_protection=(12, 12, 0, 0),
                            upper_bound=(0, 20, 0, 0))
        tick = make_churn_tick(cfg, _L, mode="equilibria", k_max=32)
        _RUNNER.update(
            cfg=cfg,
            run=jax.jit(lambda s, r, w: jax.lax.scan(tick, s, (r, w))),
            state=init_state(cfg, _L))
    return _RUNNER


def _random_schedule(seed: int) -> ChurnSchedule:
    """Adversarial lifecycle schedule: per-slot on/off phases with the
    footprint resized randomly every tick while resident."""
    rng = np.random.default_rng(seed)
    want = np.zeros((_TICKS, _T), np.int32)
    for i in range(_T):
        t = int(rng.integers(0, 6))
        while t < _TICKS:
            on = int(rng.integers(1, 12))
            for k in range(t, min(t + on, _TICKS)):
                want[k, i] = int(rng.integers(1, _S + 1))
            t += on + int(rng.integers(1, 8))
    rates = (rng.random((_TICKS, _T, _S)) * 5.0).astype(np.float32)
    rates[rng.random(rates.shape) < 0.3] = 0.0
    return ChurnSchedule(want=want, rates=rates)


@seeded_property(n_fallback=20, max_examples=40)
def test_conservation_under_generated_lifecycles(seed):
    """Across any generated lifecycle schedule: fast + slow + free == L
    every tick, a tenant's footprint tracks its target exactly (the pool
    covers the roster here), departed tenants own zero pages, and the final
    owner vector is consistent with the per-tenant counts."""
    r = _runner()
    sched = _random_schedule(seed)
    final, outs = r["run"](r["state"], jnp.asarray(sched.rates),
                           jnp.asarray(sched.want))
    fast = np.asarray(outs.fast_usage)
    slow = np.asarray(outs.slow_usage)
    pool = np.asarray(outs.pool_free)
    owned = fast + slow
    # conservation: every page is fast, slow, or free — nothing leaks
    np.testing.assert_array_equal(fast.sum(1) + slow.sum(1) + pool, _L)
    # sum(want) <= L here, so grant/reclaim settle footprints exactly
    np.testing.assert_array_equal(owned, sched.want)
    assert (owned[sched.want == 0] == 0).all()
    assert (fast >= 0).all() and (slow >= 0).all()
    # final owner vector agrees with the counters; no page has an owner
    # outside [0, T] and every owned page belongs to an active tenant
    owner = np.asarray(final.owner)
    assert owner.min() >= 0 and owner.max() <= _T
    np.testing.assert_array_equal(np.bincount(owner, minlength=_T + 1)[:_T],
                                  owned[-1])
    active_final = sched.want[-1] > 0
    assert active_final[owner[owner < _T]].all()
    # thrash counters stay monotone through churn
    assert (np.diff(np.asarray(outs.thrash_events), axis=0) >= 0).all()


def test_oversubscribed_pool_truncates_in_slot_order():
    """When the roster asks for more pages than the host has, grants are
    truncated in slot-priority order and conservation still holds."""
    cfg = TieringConfig(n_tenants=3, n_fast_pages=16, n_slow_pages=16,
                        lower_protection=(), upper_bound=())
    L = 32
    want = np.tile(np.array([[20, 20, 20]], np.int32), (6, 1))
    rates = np.full((6, 3, 20), 1.0, np.float32)
    final, outs = run_churn_engine(cfg, ChurnSchedule(want, rates),
                                   n_pages=L)
    owned = np.asarray(outs.fast_usage) + np.asarray(outs.slow_usage)
    assert (owned <= want).all()
    np.testing.assert_array_equal(owned[-1], [20, 12, 0])   # slot priority
    np.testing.assert_array_equal(
        owned.sum(1) + np.asarray(outs.pool_free), L)


def test_churn16_preset_acceptance():
    """The churn16 preset schedules >= 50 arrival/departure events, all
    served by one compiled tick, with conservation and clean departures."""
    ticks = 240
    cfg, slots = CHURN_PRESETS["churn16"]()
    sched = build_churn_schedule(slots, ticks)
    arrivals, departures = churn_events(sched.want)
    assert arrivals + departures >= 50, (arrivals, departures)
    r = simulate_preset("churn16", ticks=ticks)
    L = cfg.n_fast_pages + cfg.n_slow_pages
    np.testing.assert_array_equal(
        r.fast_usage.sum(1) + r.slow_usage.sum(1) + r.pool_free, L)
    owned = r.fast_usage + r.slow_usage
    assert (owned[~r.active] == 0).all()
    assert (owned <= sched.want).all()
    assert (np.diff(r.thrash_events, axis=0) >= 0).all()


def test_jaxpr_constant_in_churn_events():
    """Lifecycle events are data, not structure: the tick jaxpr built for a
    zero-churn schedule and for a 100+-event schedule are identical."""
    r = _runner()
    cfg = r["cfg"]
    tick = make_churn_tick(cfg, _L, mode="equilibria", k_max=32)
    quiet = ChurnSchedule(np.full((_TICKS, _T), 8, np.int32),
                          np.ones((_TICKS, _T, _S), np.float32))
    stormy = _random_schedule(3)
    assert churn_events(quiet.want)[0] + churn_events(quiet.want)[1] == _T
    a, d = churn_events(stormy.want)
    assert a + d > _T

    from repro.analysis.constancy import assert_jaxpr_constant

    def build(sched):
        return tick, (r["state"], (jnp.asarray(sched.rates[0]),
                                   jnp.asarray(sched.want[0])))

    assert_jaxpr_constant(build, (quiet, stormy),
                          label="churn tick: event schedule")


def test_lifecycle_grant_release_depart():
    """Deterministic walk: arrival grants + allocates, shrink releases the
    coldest pages, departure returns everything to the pool."""
    cfg = TieringConfig(n_tenants=2, n_fast_pages=16, n_slow_pages=16)
    L = 32
    want = np.array([[4, 0], [4, 6], [2, 6], [0, 6]], np.int32)
    rates = np.zeros((4, 2, 8), np.float32)
    rates[:, 0, :2] = 4.0            # slot0 ranks 0-1 hot
    rates[:, 0, 2:4] = 0.1           # slot0 ranks 2-3 cold
    rates[:, 1, :6] = 1.0
    final, outs = run_churn_engine(cfg, ChurnSchedule(want, rates),
                                   n_pages=L)
    owned = np.asarray(outs.fast_usage) + np.asarray(outs.slow_usage)
    np.testing.assert_array_equal(owned, want)       # targets hit every tick
    np.testing.assert_array_equal(np.asarray(outs.pool_free),
                                  [28, 22, 24, 26])
    c = jax.tree_util.tree_map(np.asarray, final.counters)
    np.testing.assert_array_equal(c.allocations, [4, 6])
    np.testing.assert_array_equal(c.reclaims, [4, 0])   # 2 (shrink) + 2 (depart)
    owner = np.asarray(final.owner)
    # slot0 (pages 0-3) fully reclaimed; the shrink released its two cold
    # pages (tenant-local ranks 2,3 = physical 2,3) first
    assert (owner[:4] == 2).all()                    # FREE sentinel == T == 2
    np.testing.assert_array_equal(owner[4:10], [1] * 6)


def test_slot_reuse_resets_controller_state():
    """A fresh arrival in a previously-used slot starts with clean
    controller state (promo_scale back to 1, steady/mitigation cleared)."""
    cfg = TieringConfig(n_tenants=2, n_fast_pages=16, n_slow_pages=16)
    tick = make_churn_tick(cfg, 32)
    state = init_state(cfg, 32)
    state = state._replace(promo_scale=jnp.asarray([0.25, 0.5]),
                           steady=jnp.asarray([True, True]),
                           mitigated_prev=jnp.asarray([True, True]))
    rates = jnp.ones((2, 8), jnp.float32)
    new_state, _ = tick(state, (rates, jnp.asarray([8, 0], jnp.int32)))
    assert float(new_state.promo_scale[0]) == 1.0    # arrived: reset
    assert float(new_state.promo_scale[1]) == 0.5    # untouched
    assert not bool(new_state.steady[0])
    assert not bool(new_state.mitigated_prev[0])


def test_repartition_policy():
    base = TenantPolicy(jnp.asarray([100, 100, 50], jnp.int32),
                        jnp.asarray([0, 120, 60], jnp.int32))
    # all active, capacity ample: unchanged
    pol = P.repartition_policy(base, jnp.asarray([True, True, True]), 400)
    np.testing.assert_array_equal(np.asarray(pol.lower_protection),
                                  [100, 100, 50])
    np.testing.assert_array_equal(np.asarray(pol.upper_bound), [0, 120, 60])
    # departure drops both knobs; remaining fit => unscaled
    pol = P.repartition_policy(base, jnp.asarray([True, False, True]), 400)
    np.testing.assert_array_equal(np.asarray(pol.lower_protection),
                                  [100, 0, 50])
    np.testing.assert_array_equal(np.asarray(pol.upper_bound), [0, 0, 60])
    # oversubscribed: proportional scale-down, never exceeding capacity
    pol = P.repartition_policy(base, jnp.asarray([True, False, True]), 100)
    prot = np.asarray(pol.lower_protection)
    np.testing.assert_array_equal(prot, [66, 0, 33])
    assert prot.sum() <= 100
    # weights bias the squeeze toward heavy slots (and never exceed the ask)
    pol = P.repartition_policy(base, jnp.asarray([True, False, True]), 100,
                               weights=jnp.asarray([1.0, 1.0, 3.0]))
    prot = np.asarray(pol.lower_protection)
    np.testing.assert_array_equal(prot, [40, 0, 50])
    assert prot.sum() <= 100


# ----------------------------------- churn-aware pathology detectors ----
def _departure_telemetry():
    """Tenant 0 is squeezed below protection with real demand, then departs
    at tick 75 — inside the detectors' steady window [50, 100)."""
    ticks, T = 100, 2
    fast = np.zeros((ticks, T))
    slow = np.zeros((ticks, T))
    attempted = np.zeros((ticks, T))
    promotions = np.zeros((ticks, T))
    active = np.ones((ticks, T), bool)
    fast[:75, 0] = 10
    slow[:75, 0] = 50                 # footprint 60 >= protection 50
    attempted[:75, 0] = 5             # sustained promotion demand
    active[75:, 0] = False
    fast[:, 1] = 40
    return fast, slow, attempted, promotions, active


def test_departed_tenant_is_not_a_protection_violation():
    fast, slow, attempted, _, active = _departure_telemetry()
    # roster-blind view misreads the truncated window as a violation...
    assert PATH.detect_protection_violation(fast, slow, (50, 0),
                                            attempted=attempted)
    # ...the churn-aware view knows tenant 0 departed mid-window
    assert PATH.detect_protection_violation(fast, slow, (50, 0),
                                            attempted=attempted,
                                            active=active) == []


def test_departed_tenant_is_not_a_promotion_stall():
    _, _, attempted, promotions, active = _departure_telemetry()
    assert PATH.detect_promotion_stall(attempted, promotions)
    assert PATH.detect_promotion_stall(attempted, promotions,
                                       active=active) == []


def test_departed_thrasher_still_caught():
    """Chronic thrashing is history: a thrasher that departed mid-window is
    still reported — and the roster actually *recovers* it. Roster-blind,
    the post-departure zero-rate windows dilute the bad-window fraction
    below threshold (a churn false negative); judged only over the windows
    the tenant fully resided in, it is flagged."""
    ticks, T = 160, 2
    thrash = np.zeros((ticks, T))
    active = np.ones((ticks, T), bool)
    thrash[:, 0] = np.minimum(np.arange(ticks), 100) * 5.0   # departs @100
    active[100:, 0] = False
    # steady window [80, 160): windows 80-100 (thrashing), 100-120, 120-140
    # (flat) -> diluted to 1/3 bad roster-blind, under the 0.5 threshold
    assert PATH.detect_chronic_thrashing(thrash) == []
    found = PATH.detect_chronic_thrashing(thrash, active=active)
    assert [p.tenant for p in found] == [0]


def test_cold_tenant_stays_exempt():
    """A tenant below protection with zero demand is not a violation —
    with or without the churn roster."""
    fast, slow, *_ = _departure_telemetry()
    attempted = np.zeros_like(fast)
    assert PATH.detect_protection_violation(fast, slow, (50, 0),
                                            attempted=attempted,
                                            demotions=np.zeros_like(fast)) == []


def test_churn_run_detectors_tolerate_departure():
    """End-to-end: a protected tenant with live demand departs mid-window in
    a churn run; the SimResult-integrated detectors stay silent for it."""
    slots = [
        ChurnSlot(web_like(48), [(0, 150)]),          # departs mid-window
        ChurnSlot(cache_like(64), [(0, 960)]),
        ChurnSlot(cache_like(64), [(2, 960)]),
    ]
    cfg = TieringConfig(n_tenants=3, n_fast_pages=64, n_slow_pages=176,
                        lower_protection=(24, 24, 24), upper_bound=())
    r = simulate_churn(cfg, slots, 200)
    assert r.active is not None and not r.active[-1, 0]
    for p in r.pathologies():
        assert not (p.tenant == 0
                    and p.kind in ("protection_violation",
                                   "promotion_stall")), str(p)
