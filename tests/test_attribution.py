"""Slowdown attribution ledger (obs/attribution.py) + counterfactual
baselines (obs/counterfactual.py) + the mergeable stall sketch
(obs/sketch.py).

The load-bearing contract is conservation: every tick, the ledger's five
components sum to the total modeled stall in *integer* accounting, and the
cumulative total matches the counter identity
``attempted_promotions - promotions + reclaims`` bit-exact — across every
policy mode (including tpp, whose global promotion selection can hand a
tenant more than its per-tenant quota cascade), both engines, and the
chunked fleet rollout. Counterfactual interference (isolated-minus-stacked
fast-hit delta) must be non-negative on clean hosts and strictly positive
for victims of an injected thrasher. Sketch percentiles follow the
``hist_percentile`` lower-edge spec, are exact in the integer linear
range, and merge losslessly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TieringConfig
from repro.core.churn import make_churn_tick, run_churn_engine
from repro.core.engine import run_engine
from repro.core.state import init_state
from repro.core.workloads import (ChurnSlot, build_churn_schedule,
                                  build_trace, cache_like, spark_like,
                                  suggest_churn_policy, thrasher, web_like)
from repro.obs import sketch as SK
from repro.obs.attribution import (COMPONENTS, AttribSignals,
                                   attribution_components,
                                   attribution_conserved,
                                   attribution_summary, fast_hit_fraction,
                                   make_attribution)
from repro.obs.counterfactual import counterfactual_run, isolate_schedules

_TICKS = 100


def _pressured(noisy: bool = False, ticks: int = _TICKS):
    """4 tenants oversubscribing a 64-page fast tier ~2.2x."""
    slots = [ChurnSlot(web_like(40), [(0, ticks)]),
             ChurnSlot(cache_like(40), [(0, ticks)]),
             ChurnSlot(spark_like(32), [(4, ticks)]),
             ChurnSlot(thrasher(32, fast_share=10), [(ticks // 5, ticks)])
             if noisy else
             ChurnSlot(web_like(32), [(ticks // 5, ticks)])]
    prot, bound = suggest_churn_policy(slots)
    cfg = TieringConfig(n_tenants=4, n_fast_pages=64, n_slow_pages=128,
                        lower_protection=prot, upper_bound=bound, p_base=16)
    return cfg, build_churn_schedule(slots, ticks)


# ----------------------------------------------------------- conservation ----
@pytest.mark.parametrize("mode", ["equilibria", "tpp", "memtis", "static"])
def test_conservation_every_mode(mode):
    cfg, sched = _pressured(noisy=True)
    spec = make_attribution(cfg.n_tenants, cfg.lat_fast)
    final, _ = run_churn_engine(cfg, sched, mode=mode, k_max=32, attrib=spec)
    att = final.attrib
    comp = np.asarray(att.comp, np.int64)
    total = np.asarray(att.total, np.int64)
    c = final.counters
    ident = (np.asarray(c.attempted_promotions, np.int64)
             - np.asarray(c.promotions, np.int64)
             + np.asarray(c.reclaims, np.int64))
    assert (comp >= 0).all(), mode
    assert (comp.sum(axis=-1) == total).all(), mode
    assert (total == ident).all(), mode
    assert attribution_conserved(att, c)
    assert total.sum() > 0, "pressured host must accumulate stall"


def test_conservation_static_engine():
    cfg = TieringConfig(n_tenants=3, n_fast_pages=24, n_slow_pages=60,
                        lower_protection=(4, 4, 0), upper_bound=(0, 0, 10),
                        p_base=8)
    owner, accesses, alive = build_trace(
        [web_like(24), cache_like(24), thrasher(24, fast_share=8)], 80)
    spec = make_attribution(3, cfg.lat_fast)
    final, _ = run_engine(cfg, owner, accesses, alive, k_max=16, attrib=spec)
    assert attribution_conserved(final.attrib, final.counters)
    assert int(np.asarray(final.attrib.total).sum()) > 0


def test_no_throttle_ablation_zeroes_component():
    cfg, sched = _pressured(noisy=True)
    cfg = cfg.with_(enable_promo_throttle=False)
    spec = make_attribution(cfg.n_tenants, cfg.lat_fast)
    final, _ = run_churn_engine(cfg, sched, k_max=32, attrib=spec)
    comp = np.asarray(final.attrib.comp)
    assert (comp[:, COMPONENTS.index("throttled")] == 0).all()
    assert attribution_conserved(final.attrib, final.counters)


def test_components_unit_decomposition():
    sig = AttribSignals(
        cand=jnp.asarray([10, 6, 4]), promoted=jnp.asarray([2, 6, 6]),
        quota_base=jnp.asarray([8, 6, 4]), quota_eq2=jnp.asarray([5, 6, 4]),
        quota_mit=jnp.asarray([3, 6, 4]), freed=jnp.asarray([1, 0, 2]),
        a_fast=jnp.zeros(3), a_slow=jnp.zeros(3), latency=jnp.ones(3))
    comp = np.asarray(attribution_components(sig))
    # tenant 0: hot 2, throttled 3, mitigated 2, reclaim 1, contention 1
    assert comp[0].tolist() == [2, 3, 2, 1, 1]
    # tenant 1: everything promoted, nothing deferred
    assert comp[1].tolist() == [0, 0, 0, 0, 0]
    # tenant 2: tpp-style global selection spill (promoted > quota_mit):
    # the negative spill folds into hot_resident, contention floors at 0
    assert comp[2].tolist() == [-2, 0, 0, 2, 0]
    assert (comp.sum(axis=-1) == np.asarray(
        sig.cand - sig.promoted + sig.freed)).all()


def test_summary_rejects_batched_state():
    from repro.obs.attribution import init_attribution
    spec = make_attribution(2)
    att = jax.tree_util.tree_map(lambda x: jnp.stack([x, x]),
                                 init_attribution(spec))
    with pytest.raises(ValueError):
        attribution_summary(spec, att)


def test_tick_jaxpr_constant_in_horizon_and_tenants():
    from repro.analysis.constancy import assert_jaxpr_constant
    from repro.obs.streaming import make_detector

    def build(p):
        ticks, T = p
        cfg = TieringConfig(n_tenants=T, n_fast_pages=16, n_slow_pages=24,
                            lower_protection=(3, 3), upper_bound=(0, 6))
        det = make_detector(ticks, T, cfg.lower_protection)
        att = make_attribution(T, cfg.lat_fast)
        tick = make_churn_tick(cfg, 40, k_max=16, detector=det, attrib=att)
        state = init_state(cfg, 40, detector=det, attrib=att)
        return tick, (state, (jnp.zeros((T, 8), jnp.float32),
                              jnp.zeros((T,), jnp.int32)))

    # horizon and tenant count are data: same eqn count AND primitive mix
    assert_jaxpr_constant(build, [(50, 3), (500, 3), (50, 6)],
                          label="attributed tick: horizon/tenants")


# ---------------------------------------------------------- fleet rollout ----
def _demo_roll(ticks=80, hosts=2, **kw):
    from repro.obs.dashboard import demo_fleet
    return demo_fleet(hosts=hosts, ticks=ticks, chunk=40, **kw)


def test_fleet_rollout_attribution_accessors():
    cfg, roll = _demo_roll()
    H, T = roll.n_hosts, cfg.n_tenants
    comp = roll.attribution_components()
    assert comp.shape == (H, T, len(COMPONENTS))
    assert roll.attribution_totals().shape == (H, T)
    fhit = roll.fast_hit_fraction()
    assert fhit.shape == (H, T) and (fhit >= 0).all() and (fhit <= 1).all()
    assert roll.attribution_conserved()
    rup = roll.attribution_rollup()
    assert rup["conserved"] is True
    assert rup["stall_units_total"] == int(comp.sum())
    assert abs(sum(rup["component_shares"].values()) - 1.0) < 1e-9 \
        or rup["stall_units_total"] == 0
    p50, p95, p99 = roll.stall_percentiles((0.5, 0.95, 0.99))
    assert p50 <= p95 <= p99


def test_fleet_rollout_attrib_false_raises():
    from repro.core.workloads import build_churn_schedule
    from repro.obs.fleet import fleet_rollout, stack_schedules
    cfg, sched = _pressured()
    want, rates = stack_schedules([sched, sched])
    roll = fleet_rollout(cfg, want, rates, 40, chunk=20, k_max=16,
                         attrib=False, detect=False)
    assert roll.final_state.attrib is None
    with pytest.raises(ValueError):
        roll.attribution_totals()


def test_chunked_rollout_chunk_invariant():
    """The ledger riding the donated carry must not depend on chunking."""
    from repro.obs.fleet import fleet_rollout, stack_schedules
    cfg, sched = _pressured(noisy=True, ticks=80)
    want, rates = stack_schedules([sched, sched])
    rolls = [fleet_rollout(cfg, want, rates, 80, chunk=c, k_max=16,
                           detect=False) for c in (20, 80)]
    a, b = (r.final_state.attrib for r in rolls)
    assert (np.asarray(a.comp) == np.asarray(b.comp)).all()
    assert (np.asarray(a.total) == np.asarray(b.total)).all()
    assert (np.asarray(a.sketch) == np.asarray(b.sketch)).all()


# -------------------------------------------------------- counterfactuals ----
def test_isolate_schedules_masks_other_tenants():
    _, sched = _pressured()
    want_iso, rates_iso = isolate_schedules(sched)
    T = sched.want.shape[1]
    for i in range(T):
        assert (want_iso[i][:, i] == sched.want[:, i]).all()
        others = [j for j in range(T) if j != i]
        assert (want_iso[i][:, others] == 0).all()
        assert (rates_iso[i][:, others] == 0).all()


def test_counterfactual_clean_nonnegative():
    cfg, sched = _pressured(noisy=False, ticks=80)
    res = counterfactual_run(cfg, sched, k_max=32)
    assert res.active.all()
    assert (res.interference >= -1e-6).all()
    assert attribution_conserved(res.stacked_state.attrib,
                                 res.stacked_state.counters)


def test_counterfactual_noisy_victim_positive():
    cfg_c, sched_c = _pressured(noisy=False, ticks=80)
    cfg_n, sched_n = _pressured(noisy=True, ticks=80)
    clean = counterfactual_run(cfg_c, sched_c, k_max=32)
    noisy = counterfactual_run(cfg_n, sched_n, k_max=32)
    delta = noisy.interference - clean.interference
    victim = int(np.argmax(delta))
    assert noisy.interference[victim] > 0.01
    assert delta[victim] > 0.05
    s = noisy.summary()
    assert s["active_tenants"] == 4
    assert s["max_interference"] >= noisy.interference[victim] - 1e-9


def test_fast_hit_fraction_empty_is_one():
    spec = make_attribution(3)
    from repro.obs.attribution import init_attribution
    att = init_attribution(spec)
    assert (fast_hit_fraction(att) == 1.0).all()


# ------------------------------------------------------------ stall sketch ----
def test_sketch_exact_in_linear_range():
    values = np.array([0, 1, 1, 5, 17, 100, 127] * 3)
    counts = SK.sketch_add(SK.init_sketch(), jnp.asarray(values, jnp.float32))
    assert int(SK.sketch_count(counts)) == values.size
    for q in (0.1, 0.5, 0.9, 0.99):
        exact = np.sort(values)[min(int(np.ceil(q * values.size)) - 1,
                                    values.size - 1)]
        assert int(SK.sketch_percentile(counts, q)) == int(exact), q


def test_sketch_merge_equals_pooled():
    rng = np.random.default_rng(3)
    vals = rng.integers(0, 4000, size=(8, 64)).astype(np.float32)
    batched = jax.vmap(SK.sketch_add)(SK.init_sketch((8,)),
                                      jnp.asarray(vals))
    pooled = SK.sketch_add(SK.init_sketch(), jnp.asarray(vals.reshape(-1)))
    assert (np.asarray(SK.sketch_merge(batched))
            == np.asarray(pooled, np.int64)).all()


def test_sketch_rank_error_bound():
    from benchmarks.attribution import _sketch_rank_error
    assert _sketch_rank_error(n_hosts=16, per_host=256) <= 0.02


def test_sketch_edges_and_empty():
    edges = np.asarray(SK.sketch_edges())
    assert edges.shape == (SK.SKETCH_BUCKETS + 1,)
    assert (np.diff(edges) > 0).all()
    assert (edges[:SK.N_LINEAR] == np.arange(SK.N_LINEAR)).all()
    assert float(SK.sketch_percentile(SK.init_sketch(), 0.99)) == 0.0


def test_sketch_weighted_add():
    counts = SK.sketch_add(SK.init_sketch(), jnp.asarray([3.0, 3.0, 900.0]),
                           weights=jnp.asarray([2, 3, 4], jnp.int32))
    assert int(SK.sketch_count(counts)) == 9
    assert int(np.asarray(counts)[3]) == 5
