"""Property suite for the sketch-provider structures (core/cms.py), via
the seeded-property shim (tests/proputil.py):

  * count-min estimates are one-sided: estimate >= true count (collisions
    only ever inflate; integer-valued f32 counts make the bound exact)
  * decay is monotone: aging never raises a bucket or an estimate
  * merge is associative and commutative on integer-valued counts
  * top-N extraction is best-first and, under collision-free hashing,
    contains the true argmax page
"""
import jax.numpy as jnp
import numpy as np
from proputil import seeded_property

from repro.core import cms as CM

N_PAGES = 512


def _stream(rng, n_pages=N_PAGES):
    n = int(rng.integers(8, 64))
    pages = rng.integers(0, n_pages, n).astype(np.int32)
    amounts = rng.integers(1, 16, n).astype(np.float32)   # integer-valued
    valid = rng.random(n) < 0.9
    return pages, amounts, valid


@seeded_property()
def test_cms_estimate_one_sided(seed):
    rng = np.random.default_rng(seed)
    p = CM.cms_params(depth=int(rng.integers(1, 4)), width=256,
                      decay=1.0, seed=int(rng.integers(0, 16)))
    cms = CM.make_cms(p)
    true = np.zeros(N_PAGES, np.float64)
    for _ in range(int(rng.integers(1, 4))):
        pages, amounts, valid = _stream(rng)
        cms = CM.cms_add(p, cms, jnp.asarray(pages), jnp.asarray(amounts),
                         jnp.asarray(valid))
        np.add.at(true, pages[valid], amounts[valid])
    est = np.asarray(CM.cms_estimate(
        p, cms, jnp.arange(N_PAGES, dtype=jnp.int32)))
    # integer-valued f32 sums are exact, so the bound needs no epsilon
    assert (est >= true).all()


@seeded_property()
def test_cms_decay_monotone(seed):
    rng = np.random.default_rng(seed)
    p = CM.cms_params(depth=2, width=256,
                      decay=float(rng.uniform(0.05, 1.0)),
                      seed=int(rng.integers(0, 16)))
    cms = jnp.asarray(rng.random((p.depth, p.width)).astype(np.float32) * 64)
    once = CM.cms_decay(p, cms)
    twice = CM.cms_decay(p, once)
    assert (np.asarray(once) <= np.asarray(cms)).all()
    assert (np.asarray(twice) <= np.asarray(once)).all()
    pages = jnp.asarray(rng.integers(0, N_PAGES, 32).astype(np.int32))
    assert (np.asarray(CM.cms_estimate(p, once, pages))
            <= np.asarray(CM.cms_estimate(p, cms, pages))).all()


@seeded_property()
def test_cms_merge_associative(seed):
    rng = np.random.default_rng(seed)
    p = CM.cms_params(depth=2, width=128, decay=1.0,
                      seed=int(rng.integers(0, 16)))

    def sketch():
        cms = CM.make_cms(p)
        pages, amounts, valid = _stream(rng)
        return CM.cms_add(p, cms, jnp.asarray(pages), jnp.asarray(amounts),
                          jnp.asarray(valid))

    a, b, c = sketch(), sketch(), sketch()
    left = CM.cms_merge(CM.cms_merge(a, b), c)
    right = CM.cms_merge(a, CM.cms_merge(b, c))
    # integer-valued counts stay exactly representable, so associativity
    # holds bitwise, not just approximately
    assert np.array_equal(np.asarray(left), np.asarray(right))
    assert np.array_equal(np.asarray(CM.cms_merge(a, b)),
                          np.asarray(CM.cms_merge(b, a)))


@seeded_property()
def test_topn_rows_best_first(seed):
    rng = np.random.default_rng(seed)
    T, M = 3, int(rng.integers(8, 48))
    n = int(rng.integers(1, M + 4))
    score = jnp.asarray(rng.random((T, M)).astype(np.float32))
    page = jnp.asarray(rng.integers(0, N_PAGES, (T, M)).astype(np.int32))
    valid = jnp.asarray(rng.random((T, M)) < 0.8)
    pages, vals = CM.topn_rows(score, page, valid, n)
    pages, vals = np.asarray(pages), np.asarray(vals)
    sc, va = np.asarray(score), np.asarray(valid)
    for t in range(T):
        got = vals[t][pages[t] >= 0]
        assert (got[:-1] >= got[1:]).all()          # best first
        if va[t].any():
            assert pages[t][0] == np.asarray(page)[t][
                np.where(va[t], sc[t], -np.inf).argmax()]
            assert (pages[t] >= 0).sum() == min(n, int(va[t].sum()))


@seeded_property()
def test_topn_contains_true_argmax_no_collisions(seed):
    rng = np.random.default_rng(seed)
    p = CM.cms_params(depth=2, width=1024, decay=1.0,
                      seed=int(rng.integers(0, 64)))
    pages = rng.choice(4096, size=32, replace=False).astype(np.int32)
    counts = rng.integers(1, 100, 32).astype(np.float32)
    counts[rng.integers(0, 32)] += 200               # unique argmax
    cms = CM.cms_add(p, CM.make_cms(p), jnp.asarray(pages),
                     jnp.asarray(counts), jnp.ones((32,), bool))
    est = np.asarray(CM.cms_estimate(p, cms, jnp.asarray(pages)))
    assert (est >= counts).all()
    h = np.asarray(CM.cms_hash(p, jnp.asarray(pages)))
    if any(np.unique(h[d]).size == pages.size for d in range(p.depth)):
        # some row is injective on this page set, so min-over-rows is
        # exact and ranking by estimate recovers the true argmax
        assert np.array_equal(est, counts)
        top, _ = CM.topn_rows(jnp.asarray(est)[None, :],
                              jnp.asarray(pages)[None, :],
                              jnp.ones((1, 32), bool), 8)
        assert pages[counts.argmax()] in np.asarray(top)[0]
