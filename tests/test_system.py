"""End-to-end behaviour tests: the paper's §V-B validation experiments run
against the tiering engine, Equilibria vs the TPP baseline."""
import numpy as np
import pytest

from repro.configs.base import TieringConfig
from repro.core.simulator import PRESETS, simulate, simulate_preset
from repro.core.workloads import TenantWorkload, microbenchmark, thrasher


def _cfg(**kw):
    base = dict(n_tenants=3, n_fast_pages=1024, n_slow_pages=512,
                lower_protection=(320, 320, 320), upper_bound=(0, 0, 0))
    base.update(kw)
    return TieringConfig(**base)


class TestValidation:
    """Paper §V-B: the five functionality validations."""

    def test_local_memory_preferred_when_uncontended(self):
        # footprints 480+160+160 < 1024: everyone fully fast-tier (§V-B1)
        cfg = _cfg()
        r = simulate(cfg, [microbenchmark(480), microbenchmark(160),
                           microbenchmark(160)], 120, mode="equilibria")
        assert (r.slow_usage[-1] == 0).all()
        assert r.fast_usage[-1].tolist() == [480, 160, 160]

    def test_lower_protection_enforced(self):
        # 480/360/360 footprints, 320 protection: converge to ~protection (§V-B2)
        cfg = _cfg()
        r = simulate(cfg, [microbenchmark(480), microbenchmark(360),
                           microbenchmark(360)], 250, mode="equilibria")
        final = r.fast_usage[-25:].mean(0)
        assert final[0] >= 320 - 8          # A keeps its protection
        assert abs(final[1] - final[2]) <= 8  # B and C symmetric
        # A pushed down toward protection, B/C keep at least protection
        assert final[0] <= 400
        assert final[1] >= 312 and final[2] >= 312

    def test_unused_protection_donated(self):
        # B, C under protection; A overshoots and receives the donation (§V-B3)
        cfg = _cfg()
        r = simulate(cfg, [microbenchmark(480), microbenchmark(280, arrival=40),
                           microbenchmark(280, arrival=40)], 250,
                     mode="equilibria")
        final = r.fast_usage[-25:].mean(0)
        assert final[1] >= 275 and final[2] >= 275   # fully resident (<=prot)
        assert final[0] > 320 + 20                   # donation received
        # donors are never demoted (exempt under protection)
        assert r.demotions[-100:, 1].sum() == 0
        assert r.demotions[-100:, 2].sum() == 0

    def test_upper_bound_enforced(self):
        # ample free fast tier, but A capped at 320 pages (§V-B4)
        cfg = _cfg(upper_bound=(320, 0, 0))
        r = simulate(cfg, [microbenchmark(480), microbenchmark(160),
                           microbenchmark(160)], 150, mode="equilibria")
        assert r.fast_usage[-25:, 0].max() <= 320
        assert r.slow_usage[-1, 0] >= 150            # spilled
        # B, C unaffected
        assert r.fast_usage[-1, 1] == 160

    def test_thrashing_mitigated(self):
        # thrasher capped at 24 fast pages; two normal tenants (§V-B5)
        # (thrash thresholds rescaled to simulator ticks: the paper's are
        # wall-clock rates on a 5s controller period)
        tenants = [thrasher(400, fast_share=16),
                   microbenchmark(200), microbenchmark(200)]
        cfg = _cfg(upper_bound=(16, 0, 0), lower_protection=(0, 256, 256),
                   migration_cost=0.002, t_resident=10, r_thrashing=8.0,
                   controller_period=15)
        on = simulate(cfg, tenants, 300, mode="equilibria")
        off = simulate(cfg.with_(enable_thrash_mitigation=False), tenants,
                       300, mode="equilibria")
        w = slice(200, 300)
        mig_on = (on.promotions[w, 0] + on.demotions[w, 0]).mean()
        mig_off = (off.promotions[w, 0] + off.demotions[w, 0]).mean()
        assert mig_on < mig_off * 0.6, (mig_on, mig_off)  # migrations cut
        # neighbors throughput improves with mitigation
        thr_on = on.mean_throughput(w)[1:].sum()
        thr_off = off.mean_throughput(w)[1:].sum()
        assert thr_on > thr_off
        # promotion rate of the thrasher was halved at least once
        assert (on.promo_scale[:, 0] < 1.0).any()


class TestFairnessVsTPP:
    """Paper §III-F: the failure modes of unfair tiering."""

    def test_hotness_unfairness_under_tpp(self):
        cfg = TieringConfig(n_tenants=2, n_fast_pages=512, n_slow_pages=512,
                            lower_protection=(256, 256), upper_bound=(0, 0))
        tenants = [microbenchmark(400, hotness=2.0),
                   microbenchmark(400, hotness=1.0)]
        tpp = simulate(cfg, tenants, 200, mode="tpp")
        eq = simulate(cfg, tenants, 200, mode="equilibria")
        # TPP: hot tenant hoards local memory (Fig. 3)
        assert tpp.fast_usage[-1, 0] > 1.8 * tpp.fast_usage[-1, 1]
        # Equilibria: both keep >= ~protection
        assert eq.fast_usage[-1, 0] >= 240 and eq.fast_usage[-1, 1] >= 240

    def test_launch_order_unfairness_under_tpp(self):
        cfg = TieringConfig(n_tenants=2, n_fast_pages=512, n_slow_pages=512,
                            lower_protection=(256, 256), upper_bound=(0, 0))
        tenants = [microbenchmark(300), microbenchmark(300, arrival=30)]
        tpp = simulate(cfg, tenants, 250, mode="tpp")
        eq = simulate(cfg, tenants, 250, mode="equilibria")
        gap_tpp = 1 - tpp.mean_throughput()[1] / tpp.mean_throughput()[0]
        gap_eq = abs(1 - eq.mean_throughput()[1] / eq.mean_throughput()[0])
        assert gap_tpp > 0.15          # paper: late tenant ~28% slower
        assert gap_eq < 0.10           # Equilibria equalizes

    def test_memtis_mode_upper_limit_only(self):
        cfg = TieringConfig(n_tenants=2, n_fast_pages=512, n_slow_pages=512,
                            lower_protection=(0, 0), upper_bound=(200, 0))
        tenants = [microbenchmark(400), microbenchmark(300, arrival=20)]
        r = simulate(cfg, tenants, 150, mode="memtis")
        assert r.fast_usage[-25:, 0].max() <= 200

    def test_static_mode_never_migrates(self):
        cfg = _cfg()
        r = simulate(cfg, [microbenchmark(480), microbenchmark(360),
                           microbenchmark(360)], 100, mode="static")
        assert r.promotions.sum() == 0 and r.demotions.sum() == 0


class TestStackedScenario:
    """§V at-scale deployment shape: many heterogeneous cgroups per host."""

    def test_stacked16_preset(self):
        cfg, tenants = PRESETS["stacked16"]()
        assert cfg.n_tenants == len(tenants) == 16
        r = simulate_preset("stacked16", ticks=120, k_max=64)
        assert r.fast_usage.shape[1] == 16
        # capacity invariant under the full heterogeneous stack
        assert (r.fast_usage.sum(axis=1) <= cfg.n_fast_pages).all()
        # every tenant got memory; protected tenants hold their hot share
        assert (r.fast_usage[-1] + r.slow_usage[-1] > 0).all()
        prot = np.asarray(cfg.lower_protection)
        final = r.fast_usage[-20:].mean(0)
        protected = prot > 0
        assert (final[protected] >= prot[protected] * 0.75).all()
        # obs rides along at T=16
        assert r.tier_stats is not None
        assert r.tier_stats["resid_hist"].shape[0] == 16


class TestObservability:
    """Paper §IV-C: per-tenant tier observability counters."""

    def test_counters_populated(self):
        import jax.numpy as jnp
        from repro.core.engine import run_engine
        from repro.core.state import tier_stat
        from repro.core.workloads import build_trace
        cfg = _cfg()
        tenants = [microbenchmark(480), microbenchmark(360),
                   microbenchmark(360)]
        owner, acc, alive = build_trace(tenants, 150)
        final, outs = run_engine(cfg.with_(n_tenants=3), owner, acc, alive)
        owner_oh = jnp.asarray(
            (owner[None, :] == np.arange(3)[:, None]).astype(np.float32))
        stat = tier_stat(final, owner_oh)
        assert (np.asarray(stat["pgalloc"]) > 0).all()
        assert np.asarray(stat["pgpromote_attempted"]).sum() >= \
            np.asarray(stat["pgpromote"]).sum()
        assert (np.asarray(stat["local_usage_bytes"]) > 0).all()
