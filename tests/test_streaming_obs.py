"""Streaming pathology detection (obs/streaming.py) + telemetry exporters.

The differential contract: the streaming detectors — windowed state
machines folded tick-by-tick inside the compiled engine — must agree with
the offline trace detectors (obs/pathology.py) when fed the same runs.
Integer-counter detectors (chronic thrashing, protection violation,
promotion stall) agree exactly; noisy neighbor replaces f64 trace means
with running f32 sums (documented <= 5% tolerance, exact on every scenario
pinned here). Three acceptance scenarios: a clean mixed fleet (both
silent), an injected noisy thrasher on a churned host (both flag it,
nobody else), and a churned thrasher through the single-host engine.

Also pinned: jaxpr size constant in horizon, detector boundary conditions
(departure exactly at a window edge, single-tick windows, steady_frac 0/1,
mid-window arrival gating), the unified histogram-percentile spec, and the
exporter validators (Chrome trace + Prometheus text exposition).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TieringConfig
from repro.core.churn import make_churn_tick, run_churn_engine
from repro.core.state import init_state
from repro.core.workloads import (ChurnSlot, build_churn_schedule,
                                  cache_like, spark_like, thrasher, web_like)
from repro.obs import pathology as PA
from repro.obs.export import (chrome_trace, fleet_exposition,
                              rollout_exposition, validate_chrome_trace,
                              validate_exposition)
from repro.obs.fleet import (fleet_rollout, mixed_fleet_hosts,
                             run_mixed_fleet, stack_schedules)
from repro.obs.pathology import detect_all, detect_chronic_thrashing
from repro.obs.stats import bucket_edges, hist_percentile, hist_percentile_j
from repro.obs.streaming import (KINDS, make_detector, run_detector,
                                 streaming_pathologies)
from repro.obs.trace import DIR_DEMOTE, DIR_PROMOTE, EVENT_DTYPE

_TICKS = 160
_FOOT = (32, 40, 40, 24)


def _cfg():
    total = sum(_FOOT)
    return TieringConfig(n_tenants=4, n_fast_pages=int(total * 1.15),
                         n_slow_pages=total,
                         lower_protection=(8, 12, 12, 8),
                         upper_bound=(24, 0, 0, 0), migration_cost=0.005)


def _hosts(noisy_host=None):
    """2 static + 2 churned hosts (the PR-5 fleet scenario)."""
    static_mixes = [
        [web_like(_FOOT[0]), cache_like(_FOOT[1]), spark_like(_FOOT[2]),
         web_like(_FOOT[3])],
        [web_like(_FOOT[0], hot_pages=10), cache_like(_FOOT[1]),
         web_like(_FOOT[2]), cache_like(_FOOT[3])],
    ]
    churned = []
    for seed in (0, 1):
        churned.append([
            ChurnSlot(web_like(_FOOT[0]), [(0, _TICKS)]),
            ChurnSlot(cache_like(_FOOT[1]), [(5, _TICKS)]),
            ChurnSlot(cache_like(_FOOT[2]), [(0, 60 + 10 * seed),
                                             (90, _TICKS)]),
            ChurnSlot(web_like(_FOOT[3]), [(8 * seed, _TICKS)]),
        ])
    hosts = mixed_fleet_hosts(static_mixes, churned, _TICKS)
    if noisy_host is not None:
        hosts[noisy_host][0] = ChurnSlot(thrasher(_FOOT[0], fast_share=12),
                                         [(30, _TICKS)])
    return hosts


def _keyset(pathologies):
    return sorted((p.kind, p.tenant) for p in pathologies)


def _assert_agree(online, offline):
    """Streaming and offline verdicts agree: same (kind, tenant) set, and
    severity/evidence within float tolerance (the noisy detector's running
    f32 sums vs offline f64 means)."""
    assert _keyset(online) == _keyset(offline)
    off = {(p.kind, p.tenant): p for p in offline}
    for p in online:
        q = off[(p.kind, p.tenant)]
        assert p.severity == pytest.approx(q.severity, rel=5e-2)
        for k, v in q.evidence.items():
            assert p.evidence[k] == pytest.approx(v, rel=5e-2, abs=1e-6)


def _offline_from_run(cfg, outs, active):
    return detect_all(
        np.asarray(outs.fast_usage), np.asarray(outs.slow_usage),
        np.asarray(outs.promotions), np.asarray(outs.demotions),
        np.asarray(outs.latency), np.asarray(outs.thrash_events),
        attempted=np.asarray(outs.attempted_promotions),
        lower_protection=tuple(cfg.lower_protection[:cfg.n_tenants]),
        active=active)


# ------------------------------------------- differential: 3 scenarios ----
def test_differential_churned_thrasher_single_host():
    """Scenario: a churned thrasher through the single-host engine. The
    in-tick streamed state, the host-side replay (run_detector on the same
    telemetry), and the offline trace detectors all agree."""
    cfg = _cfg()
    slots = [
        ChurnSlot(thrasher(_FOOT[0], fast_share=12), [(30, _TICKS)]),
        ChurnSlot(cache_like(_FOOT[1]), [(5, _TICKS)]),
        ChurnSlot(cache_like(_FOOT[2]), [(0, 60), (90, _TICKS)]),
        ChurnSlot(web_like(_FOOT[3]), [(0, _TICKS)]),
    ]
    schedule = build_churn_schedule(slots, _TICKS)
    spec = make_detector(_TICKS, 4, cfg.lower_protection)
    final, outs = run_churn_engine(cfg, schedule, k_max=32, detector=spec)

    online = streaming_pathologies(spec, final.det)
    active = np.asarray(schedule.want) > 0
    offline = _offline_from_run(cfg, outs, active)
    assert ("chronic_thrashing", 0) in _keyset(offline)   # non-vacuous
    _assert_agree(online, offline)

    # host-side replay through the same scan update == the in-tick state
    cum = np.asarray(outs.thrash_events)
    replay = run_detector(
        spec, active=active,
        thrash_new=np.diff(cum, axis=0, prepend=np.zeros((1, 4))),
        fast_usage=np.asarray(outs.fast_usage),
        slow_usage=np.asarray(outs.slow_usage),
        attempted=np.asarray(outs.attempted_promotions),
        promotions=np.asarray(outs.promotions),
        demotions=np.asarray(outs.demotions),
        latency=np.asarray(outs.latency))
    for f in replay._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(replay, f)), np.asarray(getattr(final.det, f)),
            rtol=1e-6, err_msg=f)


def test_differential_clean_fleet_silent():
    """Scenario: clean mixed fleet. Offline (run_mixed_fleet, full traces)
    and streaming (fleet_rollout, O(1) trace memory) both stay silent."""
    hosts = _hosts()
    offline = run_mixed_fleet(_cfg(), hosts, _TICKS, k_max=32)
    assert offline.tenants_flagged() == []

    want, rates = stack_schedules(
        [build_churn_schedule(s, _TICKS) for s in hosts])
    roll = fleet_rollout(_cfg(), want, rates, _TICKS, chunk=64, k_max=32)
    assert roll.tenants_flagged() == []
    assert roll.pathology_counts() == {}
    assert roll.pathology_rollup()["hosts_with_pathology"] == 0


def test_differential_noisy_fleet_flagged():
    """Scenario: thrasher injected on churned host 2. Both paths flag
    exactly (host 2, tenant 0) and agree on the per-host verdicts."""
    noisy = 2
    hosts = _hosts(noisy_host=noisy)
    offline = run_mixed_fleet(_cfg(), hosts, _TICKS, k_max=32)
    want, rates = stack_schedules(
        [build_churn_schedule(s, _TICKS) for s in hosts])
    roll = fleet_rollout(_cfg(), want, rates, _TICKS, chunk=64, k_max=32)

    assert (noisy, 0) in roll.tenants_flagged("chronic_thrashing")
    assert roll.tenants_flagged() == offline.tenants_flagged()
    assert roll.pathology_counts() == offline.pathology_counts()
    for h in range(roll.n_hosts):
        _assert_agree(roll.host_pathologies(h), offline.pathologies[h])
    # online-only signals: the flag was raised while the run was live
    k = KINDS.index("chronic_thrashing")
    first = roll.pathology_first_flag()
    assert 0 <= first[noisy, 0, k] < _TICKS
    assert roll.pathology_flag_ticks()[noisy, 0, k] > 0
    # deterministic ordering (satellite: sorted, list not set)
    assert roll.tenants_flagged() == sorted(roll.tenants_flagged())
    assert isinstance(offline.tenants_flagged(), list)


def test_detector_jaxpr_constant_in_horizon():
    """The detector seam adds a fixed number of equations: jaxpr size of the
    detector-carrying tick is identical at a 200-tick and a 10k-tick horizon
    (window geometry is baked in as Python constants, horizon is data)."""
    from repro.analysis.constancy import assert_jaxpr_constant

    cfg = _cfg()
    L = cfg.n_fast_pages + cfg.n_slow_pages
    S = max(_FOOT)

    def build(horizon):
        spec = make_detector(horizon, 4, cfg.lower_protection)
        tick = make_churn_tick(cfg, L, k_max=32, detector=spec)
        state = init_state(cfg, L, detector=spec)
        inp = (jnp.ones((4, S), jnp.float32), jnp.full((4,), 16, jnp.int32))
        return tick, (state, inp)

    assert_jaxpr_constant(build, (200, 10_000),
                          label="detector tick: horizon")

    # and the streamed state itself is O(T): no leaf scales with horizon
    spec = make_detector(10_000, 4, cfg.lower_protection)
    state = init_state(cfg, L, detector=spec)
    for leaf in jax.tree_util.tree_leaves(state.det):
        assert leaf.size <= 4 * len(KINDS)


# ------------------------------------------------ boundary conditions ----
def _synthetic(horizon, T, *, active, thrash_per_tick=None, fast=None,
               slow=None, attempted=None, promotions=None, demotions=None,
               latency=None):
    """[ticks, T] telemetry set with offline/streaming-compatible shapes."""
    z = np.zeros((horizon, T))
    sig = dict(
        active=np.asarray(active, bool),
        thrash_new=z if thrash_per_tick is None else thrash_per_tick,
        fast_usage=z if fast is None else fast,
        slow_usage=z if slow is None else slow,
        attempted=z if attempted is None else attempted,
        promotions=z if promotions is None else promotions,
        demotions=z if demotions is None else demotions,
        latency=np.ones((horizon, T)) if latency is None else latency)
    return {k: np.asarray(v) for k, v in sig.items()}


def _both(spec, sig, lower_protection=()):
    online = streaming_pathologies(spec, run_detector(spec, **sig))
    offline = detect_all(
        sig["fast_usage"], sig["slow_usage"], sig["promotions"],
        sig["demotions"], sig["latency"],
        np.cumsum(sig["thrash_new"], axis=0),
        attempted=sig["attempted"], lower_protection=lower_protection,
        active=sig["active"])
    return online, offline


def test_departure_exactly_at_window_edge():
    """A thrasher departing exactly at a window boundary is still judged
    over the windows it fully resided in — and its final (just-closed)
    window counts, because the closing tick's events belong to it."""
    H, T, W = 80, 2, 20                      # s0=40: windows [40,60),[60,80)
    active = np.ones((H, T), bool)
    active[60:, 0] = False                   # departs exactly at the edge
    ev = np.zeros((H, T))
    ev[:60, 0] = 6                           # 6 events/tick while resident
    sig = _synthetic(H, T, active=active, thrash_per_tick=ev)
    online, offline = _both(make_detector(H, T), sig)
    assert ("chronic_thrashing", 0) in _keyset(offline)
    _assert_agree(online, offline)
    # offline evidence: exactly ONE resident window ([40,60)), all bad
    p = next(p for p in online if p.tenant == 0)
    assert p.evidence["bad_window_frac"] == 1.0

    # current-state detectors (stall) skip the departed tenant: demand that
    # vanished with the tenant is churn, not a stalled promoter
    att = np.zeros((H, T))
    att[:60, 0] = 8                          # heavy demand, zero successes
    sig = _synthetic(H, T, active=active, attempted=att)
    online, offline = _both(make_detector(H, T), sig)
    assert _keyset(online) == _keyset(offline) == []


def test_single_tick_windows():
    """window=1: every steady tick is its own window; a tenant over the
    rate threshold every tick flags, one under it never does."""
    H, T = 40, 2
    active = np.ones((H, T), bool)
    ev = np.zeros((H, T))
    ev[:, 0] = 5                             # > 4.0/window -> every window bad
    ev[:, 1] = 3                             # under threshold -> never bad
    cum = np.cumsum(ev, axis=0)
    offline = detect_chronic_thrashing(cum, window=1, active=active)
    spec = make_detector(H, T, window=1)
    assert spec.window == 1
    online = [p for p in streaming_pathologies(
        spec, run_detector(spec, **_synthetic(H, T, active=active,
                                              thrash_per_tick=ev)))
        if p.kind == "chronic_thrashing"]
    _assert_agree(online, offline)
    assert _keyset(online) == [("chronic_thrashing", 0)]


def test_steady_frac_extremes():
    """steady_frac=0 -> empty steady window, nothing judged, nothing
    crashes; steady_frac=1 -> the whole run is steady and window geometry
    follows the same shrink rule as offline."""
    H, T = 40, 2
    active = np.ones((H, T), bool)
    ev = np.zeros((H, T))
    ev[:, 0] = 6
    sig = _synthetic(H, T, active=active, thrash_per_tick=ev)

    spec0 = make_detector(H, T, steady_frac=0.0)
    assert spec0.n_steady == 0
    assert streaming_pathologies(spec0, run_detector(spec0, **sig)) == []

    spec1 = make_detector(H, T, steady_frac=1.0)
    assert spec1.steady_start == 0 and spec1.n_steady == H
    # 40 fits exactly two 20-tick windows: no shrink (offline rule is <)
    assert spec1.window == 20
    out = streaming_pathologies(spec1, run_detector(spec1, **sig))
    p = next(p for p in out if p.kind == "chronic_thrashing")
    assert p.tenant == 0 and p.evidence["bad_window_frac"] == 1.0
    # one closed window ([0,20), judged at t=20; the run ends before t=40
    # closes the second), holding the events of ticks 1..20
    assert p.evidence["mean_rate"] == pytest.approx(120.0)

    # a horizon that can't fit two windows shrinks: 30 // 4 = 7
    spec_small = make_detector(30, T, steady_frac=1.0)
    assert spec_small.window == 7


def test_mid_window_arrival_gating():
    """A tenant arriving mid-steady-window is gated exactly as offline:
    thrash windows it only partially covers don't count, and the
    protection-violation roster gate (resident >= 50% of steady) skips it
    until it has real residency."""
    H, T = 80, 2                             # s0=40
    active = np.ones((H, T), bool)
    active[:70, 0] = False                   # arrives at t=70: 25% of steady
    fast = np.zeros((H, T))
    slow = np.zeros((H, T))
    att = np.zeros((H, T))
    slow[:, 0] = 10                          # demand covers protection of 8,
    att[:, 0] = 2                            # wants promotion, fast stays 0
    ev = np.zeros((H, T))
    ev[70:, 0] = 6                           # thrashing, but only 10 ticks
    sig = _synthetic(H, T, active=active, thrash_per_tick=ev, fast=fast,
                     slow=slow, attempted=att)
    online, offline = _both(make_detector(H, T, (8, 0)), sig,
                            lower_protection=(8, 0))
    # window [60,80) not fully resident; 25% < 50% residency gates the rest
    assert _keyset(online) == _keyset(offline) == []

    # same signals with an early arrival (t=44: covers window [60,80) fully
    # and 90% of steady): both paths now flag protection violation + stall
    active2 = np.ones((H, T), bool)
    active2[:44, 0] = False
    ev2 = np.zeros((H, T))
    ev2[44:, 0] = 6
    sig2 = _synthetic(H, T, active=active2, thrash_per_tick=ev2, fast=fast,
                      slow=slow, attempted=att)
    online2, offline2 = _both(make_detector(H, T, (8, 0)), sig2,
                              lower_protection=(8, 0))
    assert ("protection_violation", 0) in _keyset(offline2)
    assert ("promotion_stall", 0) in _keyset(offline2)
    _assert_agree(online2, offline2)


# -------------------------------------------------- percentile spec ----
def test_hist_percentile_edge_cases():
    NB = 8
    edges = bucket_edges(NB)
    empty = np.zeros((1, NB), np.int64)
    last = np.zeros((2, NB), np.int64)
    last[:, -1] = 7                          # all mass in the last bucket
    mid = np.zeros((1, NB), np.int64)
    mid[0, 2] = 3
    mid[0, 5] = 1

    for q in (0.0, 0.5, 1.0):
        assert hist_percentile(empty, q)[0] == 0.0
    assert hist_percentile(last, 0.5).tolist() == [edges[-1]] * 2
    assert hist_percentile(last, 1.0).tolist() == [edges[-1]] * 2
    assert hist_percentile(last, 0.0).tolist() == [0.0] * 2   # cum[0] >= 0
    assert hist_percentile(mid, 0.0)[0] == 0.0
    assert hist_percentile(mid, 0.5)[0] == edges[2]
    assert hist_percentile(mid, 1.0)[0] == edges[5]           # last non-empty

    rng = np.random.default_rng(0)
    h = rng.integers(0, 9, size=(16, NB))
    h[3] = 0                                                  # an empty row
    for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0):
        np.testing.assert_array_equal(
            hist_percentile(h, q), np.asarray(hist_percentile_j(h, q)),
            err_msg=f"q={q}")


# ------------------------------------------------------- exporters ----
def _events(rows):
    return np.array(rows, dtype=EVENT_DTYPE)


def test_chrome_trace_span_pairing():
    ev = _events([
        (2, 0, 5, DIR_PROMOTE, 1.5), (4, 0, 5, DIR_DEMOTE, 0.2),   # thrash
        (3, 1, 9, DIR_PROMOTE, 2.0), (50, 1, 9, DIR_DEMOTE, 1.0),  # resident
        (6, 0, 7, DIR_DEMOTE, 0.1),          # promote lost to ring wrap
        (55, 1, 11, DIR_PROMOTE, 3.0),       # never demoted: open at horizon
    ])
    tr = chrome_trace({0: ev}, t_resident=8, horizon=60)
    assert validate_chrome_trace(tr) == 4
    validate_chrome_trace(json.dumps(tr))    # text form round-trips
    by_name = {e["name"]: e for e in tr["traceEvents"] if e["ph"] != "M"}
    assert by_name["thrash"]["args"]["residency_ticks"] == 2
    assert by_name["fast_resident"]["args"]["residency_ticks"] == 47
    assert by_name["fast_resident_open"]["args"]["residency_ticks"] == 5
    assert by_name["demote"]["ph"] == "i"
    assert by_name["thrash"]["pid"] == 0 and by_name["thrash"]["tid"] == 0


def test_chrome_trace_validator_rejects():
    with pytest.raises(ValueError):
        validate_chrome_trace([1, 2, 3])               # not an object
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "X", "name": "a",
                                                "pid": 0, "tid": 0}]})  # no ts
    bad = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 0, "tid": 0, "ts": 10, "dur": 1},
        {"ph": "X", "name": "b", "pid": 0, "tid": 0, "ts": 5, "dur": 1},
    ]}
    with pytest.raises(ValueError, match="monotone"):
        validate_chrome_trace(bad)
    # same timestamps on DIFFERENT tracks are fine
    bad["traceEvents"][1]["tid"] = 1
    assert validate_chrome_trace(bad) == 2


def test_exposition_grammar_and_histograms():
    counters = {"promotions": np.array([[3, 0], [1, 9]])}
    hist = np.zeros((2, 2, 4), np.int64)
    hist[0, 0, 1] = 5
    hist[1, 1, 3] = 2
    flag = np.zeros((2, 2, len(KINDS)), np.int32)
    flag[1, 0, 0] = 7
    first = np.full((2, 2, len(KINDS)), -1, np.int32)
    first[1, 0, 0] = 40
    text = fleet_exposition(counters, resid_hist=hist, flag_ticks=flag,
                            first_flag=first)
    n = validate_exposition(text)
    assert n > 0
    assert ('equilibria_pathology_flag_ticks_total{host="1",tenant="0",'
            'kind="chronic_thrashing"} 7') in text
    # first_flag gauge emitted only for tenants that actually flagged
    assert text.count("first_flag_tick{") == 1
    # histogram: le series cumulative, +Inf present, _count matches
    assert 'le="+Inf"' in text and "_count{" in text


def test_exposition_validator_rejects():
    with pytest.raises(ValueError, match="no TYPE"):
        validate_exposition('undeclared_metric 1\n')
    with pytest.raises(ValueError, match="not a valid sample"):
        validate_exposition('# TYPE m counter\nm{bad-label="x"} 1\n')
    bad_hist = "\n".join([
        "# HELP h x", "# TYPE h histogram",
        'h_bucket{le="1"} 5', 'h_bucket{le="2"} 3',   # not cumulative
        'h_bucket{le="+Inf"} 5', "h_count 5", "h_sum 1"])
    with pytest.raises(ValueError, match="cumulative"):
        validate_exposition(bad_hist)
    no_inf = "\n".join([
        "# HELP h x", "# TYPE h histogram",
        'h_bucket{le="1"} 5', "h_count 5"])
    with pytest.raises(ValueError, match=r"\+Inf"):
        validate_exposition(no_inf)
    mismatch = "\n".join([
        "# HELP h x", "# TYPE h histogram",
        'h_bucket{le="1"} 5', 'h_bucket{le="+Inf"} 5', "h_count 6"])
    with pytest.raises(ValueError, match="_count"):
        validate_exposition(mismatch)


def test_rollout_exposition_end_to_end():
    """A real (tiny) rollout exports valid exposition including the
    pathology counter families."""
    hosts = _hosts()[:2]
    ticks = 40
    want, rates = stack_schedules(
        [build_churn_schedule(s, ticks) for s in hosts])
    roll = fleet_rollout(_cfg(), want, rates, ticks, chunk=16, k_max=32)
    text = rollout_exposition(roll)
    assert validate_exposition(text) > 0
    assert "equilibria_pathology_flag_ticks_total" in text
    assert "equilibria_fast_residency_ticks_bucket" in text
