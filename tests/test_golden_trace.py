"""Golden-trace regression fixtures: small deterministic runs (one static,
one churn) are serialized — cumulative counters, ``tier_stat``-level
summary metrics, and the decoded migration ring — into tests/golden/*.json
and diffed in tier-1, so *silent telemetry drift* (a counter that stops
incrementing, a ring record that changes meaning, a histogram that moves)
fails CI even when no behavioral test notices.

Regeneration (after an intentional behavior change):

    REPRO_REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_golden_trace.py

then commit the updated fixtures with a note on why the telemetry moved.
"""
import json
import os
import pathlib

import numpy as np
import pytest

from repro.configs.base import TieringConfig
from repro.core.simulator import simulate, simulate_churn
from repro.core.workloads import (ChurnSlot, ci_like, microbenchmark,
                                  serverless_bursts, web_like)

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
REGEN = os.environ.get("REPRO_REGEN_GOLDEN") == "1"
RING_HEAD = 40          # decoded migration events pinned from each end


def _static_small():
    cfg = TieringConfig(n_tenants=3, n_fast_pages=128, n_slow_pages=256,
                        lower_protection=(48, 48, 0),
                        upper_bound=(0, 64, 0))
    tenants = [microbenchmark(80), web_like(90, arrival=8),
               ci_like(70, phase_len=16)]
    return simulate(cfg, tenants, 60, k_max=32)


def _churn_small():
    slots = [ChurnSlot(web_like(40), [(0, 80)]),
             ChurnSlot(microbenchmark(32, ramp=3), [(4, 30), (40, 70)]),
             *serverless_bursts(2, 80, footprint=24, seed=3)]
    cfg = TieringConfig(n_tenants=4, n_fast_pages=64, n_slow_pages=120,
                        lower_protection=(16, 8, 0, 0),
                        upper_bound=(0, 24, 0, 0))
    return simulate_churn(cfg, slots, 80, k_max=32)


def _churn16_sketch():
    """The sketch hotness provider on the churn16 preset: pins the
    provider's decisions (and its dense-hot telemetry in the ring) under
    dynamic ownership, so refactors can't silently shift sketch
    semantics."""
    from repro.core.simulator import CHURN_PRESETS
    cfg, slots = CHURN_PRESETS["churn16"]()
    cfg = cfg.with_(n_tenants=len(slots))
    return simulate_churn(cfg, slots, 100, k_max=64, hotness="sketch")


SCENARIOS = {"static_small": _static_small, "churn_small": _churn_small,
             "churn16_sketch": _churn16_sketch}


def _events_to_lists(ev) -> list:
    return [[int(e["tick"]), int(e["tenant"]), int(e["page"]),
             int(e["direction"]), round(float(e["hotness"]), 5)]
            for e in ev]


def _collect(r) -> dict:
    """Everything an operator-facing telemetry surface reports."""
    ts = r.tier_stats
    out = {
        "final_fast_usage": r.fast_usage[-1].tolist(),
        "final_slow_usage": r.slow_usage[-1].tolist(),
        "total_promotions": r.promotions.sum(0).tolist(),
        "total_demotions": r.demotions.sum(0).tolist(),
        "total_attempted": r.attempted.sum(0).tolist(),
        "final_thrash_events": r.thrash_events[-1].tolist(),
        "final_pool_free": int(r.pool_free[-1]),
        "promo_attempts": ts["promo_attempts"].tolist(),
        "promo_success": ts["promo_success"].tolist(),
        "demo_attempts": ts["demo_attempts"].tolist(),
        "demo_success": ts["demo_success"].tolist(),
        "resid_hist": ts["resid_hist"].tolist(),
        "resid_p50": ts["resid_p50"].tolist(),
        "resid_p99": ts["resid_p99"].tolist(),
        "contended_frac": [round(float(x), 6) for x in ts["contended_frac"]],
        "throttled_frac": [round(float(x), 6) for x in ts["throttled_frac"]],
        "below_protection_frac": [round(float(x), 6)
                                  for x in ts["below_protection_frac"]],
        "obs_ticks": int(ts["ticks"]),
        "ring_events_decoded": len(r.migrations),
        "ring_events_dropped": int(r.migrations_dropped),
        "ring_head": _events_to_lists(r.migrations[:RING_HEAD]),
        "ring_tail": _events_to_lists(r.migrations[-RING_HEAD:]),
    }
    return out


def _diff(got, want, path=""):
    """Exact on ints/strings, atol 1e-4 on floats, recursive on containers."""
    if isinstance(want, list):
        assert isinstance(got, list) and len(got) == len(want), \
            f"{path}: length {len(got)} != {len(want)}"
        for i, (g, w) in enumerate(zip(got, want)):
            _diff(g, w, f"{path}[{i}]")
    elif isinstance(want, bool) or isinstance(want, str):
        assert got == want, f"{path}: {got!r} != {want!r}"
    elif isinstance(want, int):
        assert int(got) == want, f"{path}: {got} != {want}"
    elif isinstance(want, float):
        assert abs(float(got) - want) <= 1e-4, f"{path}: {got} != {want}"
    else:
        assert got == want, f"{path}: {got!r} != {want!r}"


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_golden_trace(name):
    got = _collect(SCENARIOS[name]())
    path = GOLDEN_DIR / f"{name}.json"
    if REGEN:
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text(json.dumps(got, indent=1) + "\n")
        return
    assert path.exists(), (
        f"missing golden fixture {path}; generate with "
        f"REPRO_REGEN_GOLDEN=1 python -m pytest {__file__}")
    want = json.loads(path.read_text())
    assert sorted(want) == sorted(got), "telemetry key set drifted"
    for key in sorted(want):
        _diff(got[key], want[key], key)
