"""Unified tick core (core/tick.py): the static engine and the churn engine
are two ownership providers over ONE pipeline.

The keystone regression: a constant tenant roster (everyone arrives at tick
0, fixed footprint, nobody departs) is expressible through BOTH adapters —
as a prebuilt static trace (``run_engine``) and as the degenerate churn
schedule (``run_churn_engine`` with constant ``want``). On that shared
scenario the two paths must agree exactly: the dynamic provider's first-tick
pool grant reproduces the contiguous static layout, tenant-local access
ranks equal physical index order, and every control decision downstream
derives from integer counts the providers compute identically. This test
fails if the engine/churn pipelines ever drift apart again (the drift PR 4
had to re-fix twice is now structurally impossible, and this pins the seam).

Float telemetry (latency/throughput) is compared with a tolerance only
because the contiguous strategy reduces floats via cumsum while the dynamic
strategy scatter-adds — association differs, decisions do not.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import TieringConfig
from repro.core.churn import run_churn_engine
from repro.core.engine import run_engine
from repro.core.tick import dynamic_ownership, make_tick_core
from repro.core.state import init_state
from repro.core.workloads import TenantWorkload, build_churn_schedule, \
    build_trace, ChurnSlot

# constant-roster scenario: ramp=1 (full footprint at age 0), no departures,
# all arrivals at tick 0 — expressible identically through both providers.
# k_max <= every footprint so both selection strategies share the same
# per-tenant take window; thrash_table_slots > L so no same-tick collisions
# (the one documented divergence source between compact/full-lane scatters).
_TENANTS = [
    TenantWorkload(footprint=24, pattern="uniform", hot_rate=4.0,
                   cold_rate=0.0, ramp=1),
    TenantWorkload(footprint=32, pattern="hotcold", hot_frac=0.25,
                   hot_rate=4.0, cold_rate=0.05, ramp=1,
                   rotate_hot_every=9),
    TenantWorkload(footprint=24, pattern="stream", stream_window=6,
                   stream_step=2, hot_rate=3.0, cold_rate=0.05, ramp=1),
]
_TICKS = 48
_K_MAX = 16


def _cfg(**kw):
    base = dict(n_tenants=3, n_fast_pages=40, n_slow_pages=40,
                lower_protection=(8, 8, 0), upper_bound=(0, 16, 12))
    base.update(kw)
    return TieringConfig(**base)


def _run_both(mode: str):
    cfg = _cfg()
    owner, accesses, alive = build_trace(_TENANTS, _TICKS)
    L = owner.shape[0]
    assert alive.all(), "shared scenario must keep every page live"
    final_s, outs_s = run_engine(cfg, owner, accesses, alive, mode=mode,
                                 k_max=_K_MAX)
    slots = [ChurnSlot(w, [(0, _TICKS)]) for w in _TENANTS]
    sched = build_churn_schedule(slots, _TICKS)
    final_c, outs_c = run_churn_engine(cfg, sched, mode=mode, k_max=_K_MAX,
                                       n_pages=L)
    return (final_s, outs_s), (final_c, outs_c)


@pytest.mark.parametrize("mode", ["equilibria", "tpp", "memtis", "static"])
def test_static_and_churn_paths_agree_on_shared_scenario(mode):
    (final_s, outs_s), (final_c, outs_c) = _run_both(mode)
    # integer trajectories: exact equality, every tick
    for name in ("fast_usage", "slow_usage", "promotions", "demotions",
                 "attempted_promotions", "thrash_events", "fast_free",
                 "pool_free"):
        np.testing.assert_array_equal(
            np.asarray(getattr(outs_s, name)),
            np.asarray(getattr(outs_c, name)), err_msg=name)
    # cumulative counters: exact
    cs = jax.tree_util.tree_map(np.asarray, final_s.counters)
    cc = jax.tree_util.tree_map(np.asarray, final_c.counters)
    for name in cs._fields:
        np.testing.assert_array_equal(getattr(cs, name), getattr(cc, name),
                                      err_msg=f"counters.{name}")
    # controller state: exact (thrash mitigation fired identically)
    np.testing.assert_array_equal(np.asarray(final_s.promo_scale),
                                  np.asarray(final_c.promo_scale))
    np.testing.assert_array_equal(np.asarray(final_s.steady),
                                  np.asarray(final_c.steady))
    # physical placement: the degenerate grant reproduces the static layout
    np.testing.assert_array_equal(np.asarray(final_s.tier),
                                  np.asarray(final_c.tier))
    np.testing.assert_array_equal(np.asarray(final_c.owner),
                                  build_trace(_TENANTS, _TICKS)[0])
    # float telemetry: same decisions, association-tolerant comparison
    np.testing.assert_allclose(np.asarray(outs_s.latency),
                               np.asarray(outs_c.latency), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(outs_s.throughput),
                               np.asarray(outs_c.throughput), rtol=1e-4)


def test_shared_scenario_actually_migrates():
    """Guard against vacuous agreement: the shared scenario must exercise
    the regulated pipeline (demotions, promotions, sync path, contention)."""
    (_, outs_s), _ = _run_both("equilibria")
    assert np.asarray(outs_s.promotions).sum() > 0
    assert np.asarray(outs_s.demotions).sum() > 0
    assert np.asarray(outs_s.attempted_promotions).sum() > 0


def test_providers_share_one_pipeline_jaxpr_shape():
    """The two providers produce ticks whose step-2..9 pipeline is the same
    code: mode branches aside, both trace without error and with T-constant
    structure (same eqn count for different tenant data under the dynamic
    provider — lifecycle events are data, not structure)."""
    import jax.numpy as jnp
    cfg = _cfg()
    L = 80
    prov = dynamic_ownership(cfg, L, k_max=_K_MAX)
    tick = make_tick_core(cfg, prov, mode="equilibria", k_max=_K_MAX)
    state = init_state(cfg, L)
    S = 32
    quiet = (jnp.ones((3, S), jnp.float32), jnp.asarray([24, 32, 24], jnp.int32))
    stormy = (jnp.zeros((3, S), jnp.float32), jnp.asarray([0, 5, 0], jnp.int32))
    jx = [str(jax.make_jaxpr(tick)(state, inp)) for inp in (quiet, stormy)]
    assert jx[0] == jx[1]


def test_static_provider_rejects_bad_impl():
    from repro.core.engine import make_tick
    with pytest.raises(AssertionError):
        make_tick(_cfg(), np.zeros(8, np.int32), impl="nope")
