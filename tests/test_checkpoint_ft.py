"""Checkpoint/restore roundtrip, atomic commit, elastic reshape, FT driver."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import sharded as ckpt
from repro.ft.driver import FTConfig, TrainDriver


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"w": jnp.ones((5,), jnp.int32),
                  "scale": jnp.asarray(2.5)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, extra={"note": "x"})
    assert ckpt.latest_step(str(tmp_path)) == 7
    r = ckpt.restore(str(tmp_path), 7, t)
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.restore_extra(str(tmp_path))["note"] == "x"


def test_gc_keeps_latest(tmp_path):
    t = _tree()
    for s in range(6):
        ckpt.save(str(tmp_path), s, t, keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2 and steps[-1] == "step_00000005"


def test_async_checkpointer(tmp_path):
    t = _tree()
    ac = ckpt.AsyncCheckpointer(str(tmp_path))
    ac.save(3, t)
    ac.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_ft_driver_restart_and_straggler(tmp_path):
    """Inject a transient failure; driver restores and completes. A slow step
    is flagged as a straggler."""
    state = {"x": jnp.zeros(())}
    fails = {"armed": True}
    stragglers = []

    def step_fn(s, batch):
        if batch == 13 and fails["armed"]:
            fails["armed"] = False
            raise RuntimeError("injected node failure")
        if batch == 17:
            time.sleep(0.15)
        else:
            time.sleep(0.01)
        return {"x": s["x"] + 1}, {"step_metric": batch}

    cfg = FTConfig(checkpoint_dir=str(tmp_path), checkpoint_every=5,
                   straggler_factor=3.0, heartbeat_file=str(tmp_path / "hb"))
    drv = TrainDriver(step_fn, cfg,
                      on_straggler=lambda s, dt: stragglers.append(s))
    state, logs = drv.run(state, iter(range(100)), num_steps=25)
    assert drv.stats.retries == 1
    assert drv.stats.completed_steps == 25
    assert 17 in stragglers
    assert (tmp_path / "hb").exists()
    assert ckpt.latest_step(str(tmp_path)) is not None


def test_elastic_restore_with_new_sharding(tmp_path):
    """Checkpoint leaves are host arrays; restore re-applies shardings for
    the current (different) topology."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(str(tmp_path), 0, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    r = ckpt.restore(str(tmp_path), 0, t, shardings=sh)
    assert r["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))


def test_resume_from_latest(tmp_path):
    state = {"x": jnp.zeros(())}

    def step_fn(s, batch):
        return {"x": s["x"] + 1}, {}

    cfg = FTConfig(checkpoint_dir=str(tmp_path), checkpoint_every=5)
    drv = TrainDriver(step_fn, cfg)
    state, _ = drv.run(state, iter(range(100)), num_steps=12)
    # "crash": new driver resumes from step 10 checkpoint
    drv2 = TrainDriver(step_fn, cfg)
    restored, start = drv2.maybe_restore({"x": jnp.zeros(())})
    assert start == 10
    assert float(restored["x"]) == 10.0
