"""Property-test shim: run seed-driven properties under hypothesis when it
is installed (shrinking + diverse exploration), or as a seeded-parametrize
fallback otherwise — so the property suites always execute in CI instead of
skipping (the container image does not ship hypothesis).

A property is written as ``def test_x(seed: int)`` where ``seed`` fully
determines the generated case (via ``np.random.default_rng(seed)``).
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


def seeded_property(n_fallback: int = 32, max_examples: int = 100):
    """Decorator: feed the wrapped ``fn(seed)`` either hypothesis-drawn or
    range(n_fallback) seeds."""
    def deco(fn):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=max_examples, deadline=None)(
                given(st.integers(0, 2**32 - 1))(fn))
        return pytest.mark.parametrize("seed", range(n_fallback))(fn)
    return deco
