import os

# Keep smoke tests on the single real CPU device (the dry-run sets its own
# XLA_FLAGS in repro.launch.dryrun, never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)

# One representative arch per family runs in the default suite; the
# duplicate-family archs are `slow` (full sweep: pytest -m "").
CORE_ARCHS = frozenset({
    "llama32_1b",            # dense
    "granite_moe_3b_a800m",  # moe
    "mamba2_130m",           # ssm
    "zamba2_7b",             # hybrid
    "whisper_tiny",          # encdec
    "llama32_vision_90b",    # vlm
})


def arch_params():
    """ARCH_IDS with non-core archs marked slow, for parametrize sweeps."""
    from repro.configs import ARCH_IDS
    return [a if a in CORE_ARCHS else pytest.param(a, marks=pytest.mark.slow)
            for a in ARCH_IDS]
