"""Differential fidelity tests for the hotness-provider seam
(core/hotness.py): each provider's promotion decisions measured against
the exact engine on the same trajectory.

The strongest pin is the full-coverage sketch equivalence: when the probe
budget enumerates every tenant rowspace, the hash windows are injective
and the buffers cover every footprint, the sketch provider's counters,
latency and usage match the exact engine BITWISE over a free run — the
count-min recurrence was written in the exact engine's fma form
specifically to make that hold (see core/hotness.py). Degradations are
then deliberate spec choices (sampled probes, one-tick report delay), and
the paired-tick agreement harness quantifies them.

The wide provider x mode x ownership matrix with wall-times lives in
benchmarks/hotness.py (results/hotness.json); these tests pin semantics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proputil import seeded_property
from repro.analysis.constancy import assert_jaxpr_constant
from repro.analysis.targets import hotness_constancy_sweeps
from repro.configs.base import TieringConfig
from repro.core.churn import make_churn_tick
from repro.core.engine import make_tick
from repro.core.hotness import (HOTNESS_PROVIDERS, SketchSpec, cold_score,
                                init_hotness)
from repro.core.simulator import simulate
from repro.core.state import TIER_FAST, TIER_SLOW, init_state
from repro.core.tick import MODES
from repro.core.workloads import (build_trace, ci_like, microbenchmark,
                                  web_like)

SIM_FIELDS = ("promotions", "demotions", "attempted", "latency",
              "fast_usage", "slow_usage", "thrash_events", "pool_free")


def _small():
    cfg = TieringConfig(n_tenants=3, n_fast_pages=64, n_slow_pages=128,
                        lower_protection=(16, 16, 0), upper_bound=(0, 32, 0))
    tenants = [microbenchmark(40), web_like(48, arrival=8),
               ci_like(36, phase_len=16)]
    return cfg, tenants


def _assert_sim_equal(a, b, fields=SIM_FIELDS):
    for name in fields:
        ga, gb = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        assert np.array_equal(ga, gb), name


# ----------------------------------------------------- cold-score helper ----
@seeded_property(n_fallback=8)
def test_cold_score_formula_pin(seed):
    """The deduped demotion/reclaim ranking is bit-identical to the inline
    formula it replaced at the three historic call sites."""
    rng = np.random.default_rng(seed)
    last = jnp.asarray(rng.integers(0, 200, 256).astype(np.int32))
    hot = jnp.asarray((rng.random(256) * 8).astype(np.float32))
    t = jnp.int32(int(rng.integers(0, 500)))
    want = (t - last).astype(jnp.float32) * 1e3 - hot
    assert np.array_equal(np.asarray(cold_score(t, last, hot)),
                          np.asarray(want))


# ------------------------------------------------------------ equivalence ----
def test_exact_provider_is_the_default():
    """hotness=None and hotness="exact" are the same program."""
    cfg, tenants = _small()
    _assert_sim_equal(simulate(cfg, tenants, 50),
                      simulate(cfg, tenants, 50, hotness="exact"))


def test_sketch_full_coverage_free_running_bitwise():
    """Full-coverage sketch == exact engine, bitwise, over a free run."""
    cfg, tenants = _small()
    _assert_sim_equal(simulate(cfg, tenants, 60),
                      simulate(cfg, tenants, 60, hotness="sketch"))


# ------------------------------------------------- paired-tick agreement ----
def _paired_agreement(cfg, tenants, hotness, ticks, k_max=32,
                      mode="equilibria"):
    """Pooled promotion-set Jaccard: exact advances the trajectory, the
    provider ticks counterfactually from each pre-tick state (carrying its
    own sketch/report state)."""
    owner, accesses, alive = build_trace(tenants, ticks)
    cfg = cfg.with_(n_tenants=len(tenants))
    L = owner.shape[0]
    et = jax.jit(make_tick(cfg, owner, mode, k_max))
    pt = jax.jit(make_tick(cfg, owner, mode, k_max, hotness=hotness))
    hstate = init_hotness(hotness, cfg, L)
    state = init_state(cfg, L, owner=owner)
    acc = jnp.asarray(accesses, jnp.float32)
    alv = jnp.asarray(alive, bool)
    inter = union = 0
    for t in range(ticks):
        before = np.asarray(state.tier)
        ns_e, _ = et(state, (acc[t], alv[t]))
        ns_p, _ = pt(state._replace(hotness=hstate), (acc[t], alv[t]))
        pe = (before == TIER_SLOW) & (np.asarray(ns_e.tier) == TIER_FAST)
        pp = (before == TIER_SLOW) & (np.asarray(ns_p.tier) == TIER_FAST)
        inter += int((pe & pp).sum())
        union += int((pe | pp).sum())
        hstate = ns_p.hotness
        state = ns_e
    return inter / max(union, 1), union


def test_sampled_regime_sketch_agreement_floor():
    """Sparse probing (8 of ~48 lanes per tenant-tick) is a deliberate
    fidelity cliff: agreement drops well below 1 but the provider still
    finds a consistent share of the exact promotions. Pins the harness's
    ability to DISCRIMINATE (full coverage is bitwise; this is not)."""
    cfg, tenants = _small()
    agreement, union = _paired_agreement(cfg, tenants, SketchSpec(probe=24),
                                         ticks=80)
    assert union > 0
    assert 0.2 <= agreement < 1.0, (agreement, union)


def test_neomem_report_is_one_tick_late():
    """The device report reaches the OS pipeline one tick after the
    accesses that built it: first-tick promotions are zero, then the
    pipeline catches up to the exact engine's decisions."""
    cfg = TieringConfig(n_tenants=2, n_fast_pages=16, n_slow_pages=32,
                        lower_protection=(4, 4), upper_bound=(0, 0))
    L = 32
    owner = np.repeat(np.arange(2, dtype=np.int32), 16)
    accs = jnp.full((L,), 4.0, jnp.float32)
    alive = jnp.ones((L,), bool)
    cum = {}
    for prov in (None, "neomem"):
        tick = jax.jit(make_tick(cfg, owner, "equilibria", 8, hotness=prov))
        st = init_state(cfg, L, owner=owner, hotness=prov)
        per_tick = []
        for _ in range(3):
            st, _ = tick(st, (accs, alive))
            per_tick.append(int(np.asarray(st.counters.promotions).sum()))
        cum[prov] = per_tick
    assert cum[None][0] > 0                    # exact promotes immediately
    assert cum["neomem"][0] == 0               # report not delivered yet
    assert cum["neomem"][1] == cum[None][1]    # one tick late, then equal


# -------------------------------------------------- provider/mode matrix ----
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("provider", HOTNESS_PROVIDERS)
def test_provider_mode_matrix_invariants(provider, mode):
    """Every provider x policy mode builds, runs, and preserves the core
    capacity invariant (fast tier never overfilled)."""
    cfg, tenants = _small()
    res = simulate(cfg, tenants, 20, mode=mode, k_max=16, hotness=provider)
    assert (res.fast_usage.sum(axis=1) <= cfg.n_fast_pages).all()
    assert np.isfinite(res.latency).all()


@pytest.mark.parametrize("provider", ("sketch", "neomem"))
def test_provider_dynamic_ownership_runs(provider):
    """Providers compose with ownership-as-state (the churn engine): the
    lazy RowSpace comes from the live owner vector instead of trace-time
    constants."""
    cfg = TieringConfig(n_tenants=3, n_fast_pages=32, n_slow_pages=64,
                        lower_protection=(4, 4, 4), upper_bound=(0, 0, 0))
    L = 96
    tick = jax.jit(make_churn_tick(cfg, L, mode="equilibria", k_max=8,
                                   hotness=provider))
    state = init_state(cfg, L, hotness=provider)
    rates = jnp.full((3, 24), 2.0, jnp.float32)
    want = jnp.array([16, 8, 4], jnp.int32)
    for _ in range(3):
        state, out = tick(state, (rates, want))
    usage = np.asarray(state.tier) == TIER_FAST
    assert usage.sum() <= cfg.n_fast_pages


# -------------------------------------------------------- jaxpr constancy ----
@pytest.mark.parametrize("name", sorted(hotness_constancy_sweeps()))
def test_provider_jaxpr_constancy(name):
    """Provider tick programs stay structurally constant in T, and the
    sketch/neomem candidate paths stay structurally constant in L (the
    graph half of the O(hot set) claim; wall-time is benchmarks/hotness)."""
    build, params = hotness_constancy_sweeps()[name]
    assert_jaxpr_constant(build, params, label=name)
