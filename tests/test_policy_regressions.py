"""Regression tests for hot-path policy fixes:

1. eq2_promotion_scan no longer flags unconfigured tenants (prot=0, bound=0)
   as throttled — the clip factor was 1.0 but obs throttle occupancy read
   ~100% under contention.
2. upper_bound_demotion uses rounded thresholds — truncation made small
   bounds trigger the gentle path early and overshoot the target.
3. thrash_controller recovery waits out the mitigation's own quiet window —
   doubling after a single quiet window bounced a mitigated tenant straight
   back into thrashing each controller period.
"""
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TieringConfig
from repro.core import policy as P
from repro.core.simulator import simulate
from repro.core.state import TenantPolicy, init_state
from repro.core.workloads import microbenchmark

CFG = TieringConfig()


def _policy(prot, bound):
    return TenantPolicy(jnp.asarray(prot, jnp.int32),
                        jnp.asarray(bound, jnp.int32))


# ---------------------------------------------------------------- eq2 ----
class TestEq2UnconfiguredTenants:
    def test_unconfigured_tenant_never_throttled(self):
        pol = _policy([0, 0, 500], [0, 0, 0])
        p_base = jnp.full((3,), 256.0)
        usage = jnp.asarray([800, 1, 600], jnp.int32)
        p, throttled = P.eq2_promotion_scan(p_base, usage, pol,
                                            jnp.asarray(True), CFG)
        # no protection and no bound -> not throttled, full scan rate
        assert throttled.tolist() == [False, False, True]
        np.testing.assert_allclose(np.asarray(p)[:2], [256.0, 256.0])

    def test_bound_only_tenant_still_throttled_near_bound(self):
        pol = _policy([0], [100])
        p, throttled = P.eq2_promotion_scan(
            jnp.array([256.0]), jnp.asarray([96], jnp.int32), pol,
            jnp.asarray(False), CFG)
        assert bool(throttled[0])          # (b): approaching its upper bound
        assert float(p[0]) == 256.0        # factor 1.0 until over the bound
        p2, throttled2 = P.eq2_promotion_scan(
            jnp.array([256.0]), jnp.asarray([110], jnp.int32), pol,
            jnp.asarray(False), CFG)
        assert bool(throttled2[0])
        assert float(p2[0]) < 256.0        # over the bound: ratio^4 bites

    def test_obs_throttle_occupancy_clean_for_unconfigured_fleet(self):
        # heavy contention, but nobody configured protections/bounds:
        # throttled_frac must stay 0 (the obs misreport this PR fixes)
        cfg = TieringConfig(n_tenants=2, n_fast_pages=256, n_slow_pages=512,
                            lower_protection=(0, 0), upper_bound=(0, 0))
        r = simulate(cfg, [microbenchmark(300), microbenchmark(300)], 80,
                     mode="equilibria", k_max=64)
        assert float(np.asarray(r.tier_stats["contended_frac"]).max()) > 0
        np.testing.assert_array_equal(
            np.asarray(r.tier_stats["throttled_frac"]), 0.0)


# -------------------------------------------------- upper-bound rounding ----
class TestUpperBoundRounding:
    def _quota(self, usage, bound):
        q = P.upper_bound_demotion(jnp.asarray([usage], jnp.int32),
                                   _policy([0], [bound]))
        return int(q[0])

    def test_small_bound_no_early_trigger(self):
        # bound=10: 95% is 9.5, so usage 9 must NOT trigger the gentle path
        # (truncated thresholds fired at 9 and demoted toward 8)
        assert self._quota(9, 10) == 0
        # at the bound, demote gently down to round(0.9*10) = 9
        assert self._quota(10, 10) == 1

    def test_tiny_bound_never_demotes_below_bound_range(self):
        for usage in range(0, 4):
            assert self._quota(usage, 3) == 0   # 3 <= bound stays resident
        assert self._quota(4, 3) == 1           # only real overage is shed

    def test_large_bounds_unchanged_semantics(self):
        # bound=1000: near at 950, target 900 — classic gentle behaviour
        assert self._quota(949, 1000) == 0
        assert self._quota(950, 1000) == 50
        assert self._quota(1005, 1000) == 105

    def test_gentle_target_is_90pct(self):
        for bound in (10, 17, 64, 320, 1000):
            near = int(np.ceil(0.95 * bound - 1e-9))
            target = int(round(0.9 * bound))
            for usage in (near - 1, near, bound, bound + 7):
                q = self._quota(usage, bound)
                if usage < near:
                    assert q == max(usage - bound, 0)
                else:
                    assert usage - q == min(usage, target)


# ------------------------------------------------------ controller recovery ----
class TestThrashControllerRecovery:
    def _step(self, state, cfg, events, usage=100):
        """One controller window: bump thrash counter by `events`, run."""
        c = state.counters._replace(
            thrash_events=state.counters.thrash_events + events)
        state = state._replace(counters=c,
                               usage_prev=jnp.asarray([usage], jnp.int32),
                               freed_since=jnp.zeros((1,), jnp.int32))
        out = P.thrash_controller(state, jnp.asarray([usage], jnp.int32), cfg)
        return state._replace(
            promo_scale=out.promo_scale, steady=out.steady, table=out.table,
            thrash_prev=out.thrash_prev, usage_prev=out.usage_prev,
            freed_since=out.freed_since,
            mitigated_prev=out.mitigated_prev), out

    def test_no_recovery_in_mitigation_window(self):
        cfg = TieringConfig(n_tenants=1, r_thrashing=4.0)
        state = init_state(cfg, 16)
        state, out = self._step(state, cfg, events=10)    # thrashing: halve
        assert float(out.promo_scale[0]) == 0.5
        assert bool(out.mitigated_prev[0])
        # quiet window right after the halving: must NOT double back yet
        state, out = self._step(state, cfg, events=0)
        assert float(out.promo_scale[0]) == 0.5
        # a second clean window: now recovery may proceed
        state, out = self._step(state, cfg, events=0)
        assert float(out.promo_scale[0]) == 1.0

    def test_monotone_recovery_after_mitigation(self):
        cfg = TieringConfig(n_tenants=1, r_thrashing=4.0)
        state = init_state(cfg, 16)
        for _ in range(3):                                # drive scale to 1/8
            state, out = self._step(state, cfg, events=10)
        assert float(out.promo_scale[0]) == 0.125
        scales = []
        for _ in range(6):                                # quiet from now on
            state, out = self._step(state, cfg, events=0)
            scales.append(float(out.promo_scale[0]))
        assert scales == sorted(scales)                   # monotone recovery
        assert scales[0] == 0.125                         # no same-window bounce
        assert scales[-1] == 1.0
