"""Exporter validator negative paths + new metric families.

The validators guard the CI exporter smoke, so they must actually reject
malformed artifacts — each rejection case here is a real corruption mode:
non-monotone track timestamps and unpaired B/E spans for Chrome traces;
bad label escapes, non-cumulative histogram buckets, missing ``+Inf``,
``_count`` mismatches and undeclared/duplicate families for Prometheus
text exposition. Also pinned: the attribution/ring metric families emitted
by ``rollout_exposition`` and the serving-path ``kv_exposition``.
"""
import dataclasses

import numpy as np
import pytest

from repro.obs.export import (kv_exposition, prom_lines, rollout_exposition,
                              validate_chrome_trace, validate_exposition)
from repro.obs.trace import init_ring, ring_summary


def _trace(events):
    return {"traceEvents": events}


def _ev(ph="X", ts=0, pid=0, tid=0, name="e", **kw):
    return {"ph": ph, "ts": ts, "pid": pid, "tid": tid, "name": name, **kw}


# ------------------------------------------------------- chrome trace ----
def test_chrome_valid_complete_events():
    n = validate_chrome_trace(_trace([
        {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
         "args": {"name": "host0"}},
        _ev(ts=0, dur=5), _ev(ts=3, dur=1), _ev(ts=3, tid=1, dur=2)]))
    assert n == 3


def test_chrome_rejects_nonmonotone_track():
    with pytest.raises(ValueError, match="not monotone"):
        validate_chrome_trace(_trace([_ev(ts=5, dur=1), _ev(ts=4, dur=1)]))
    # same timestamps on *different* tracks are fine
    assert validate_chrome_trace(_trace([_ev(ts=5, tid=0, dur=1),
                                         _ev(ts=4, tid=1, dur=1)])) == 2


def test_chrome_rejects_negative_dur_and_missing_fields():
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace(_trace([_ev(ts=0, dur=-1)]))
    with pytest.raises(ValueError, match="missing 'ts'"):
        validate_chrome_trace(_trace([{"ph": "X", "pid": 0, "tid": 0,
                                       "name": "e", "dur": 1}]))
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"foo": []})


def test_chrome_balanced_be_spans_pass():
    assert validate_chrome_trace(_trace([
        _ev(ph="B", ts=0), _ev(ph="B", ts=1, name="inner"),
        _ev(ph="E", ts=2), _ev(ph="E", ts=3)])) == 4


def test_chrome_rejects_end_without_begin():
    with pytest.raises(ValueError, match="no open 'B'"):
        validate_chrome_trace(_trace([_ev(ph="E", ts=0)]))
    # B on one track does not open a span on another
    with pytest.raises(ValueError, match="no open 'B'"):
        validate_chrome_trace(_trace([_ev(ph="B", ts=0, tid=0),
                                      _ev(ph="E", ts=1, tid=1)]))


def test_chrome_rejects_unclosed_begin():
    with pytest.raises(ValueError, match="unclosed 'B'"):
        validate_chrome_trace(_trace([_ev(ph="B", ts=0),
                                      _ev(ph="E", ts=1),
                                      _ev(ph="B", ts=2, name="left_open")]))


# --------------------------------------------------- prometheus text ----
_GOOD = """# HELP m_total Things.
# TYPE m_total counter
m_total{host="0",tenant="1"} 3
"""


def test_exposition_valid_passes():
    assert validate_exposition(_GOOD) == 1


def test_exposition_rejects_bad_escape():
    bad = '# HELP m_total T.\n# TYPE m_total counter\n' \
          'm_total{host="a\\qb"} 1\n'
    with pytest.raises(ValueError, match="not a valid sample"):
        validate_exposition(bad)


def test_exposition_accepts_legal_escapes():
    text = "\n".join(prom_lines(
        "m_total", "T.", "counter",
        [({"host": 'a\\b'}, 1.0), ({"host": 'say "hi"\nok'}, 2.0)])) + "\n"
    assert validate_exposition(text) == 2


def test_exposition_rejects_noncumulative_buckets():
    bad = ('# HELP h T.\n# TYPE h histogram\n'
           'h_bucket{le="1"} 5\nh_bucket{le="2"} 3\n'
           'h_bucket{le="+Inf"} 5\nh_count 5\n')
    with pytest.raises(ValueError, match="not cumulative"):
        validate_exposition(bad)


def test_exposition_rejects_missing_inf_bucket():
    bad = ('# HELP h T.\n# TYPE h histogram\n'
           'h_bucket{le="1"} 5\nh_bucket{le="2"} 6\nh_count 6\n')
    with pytest.raises(ValueError, match=r"missing \+Inf"):
        validate_exposition(bad)


def test_exposition_rejects_count_mismatch():
    bad = ('# HELP h T.\n# TYPE h histogram\n'
           'h_bucket{le="1"} 5\nh_bucket{le="+Inf"} 6\nh_count 7\n')
    with pytest.raises(ValueError, match="_count"):
        validate_exposition(bad)


def test_exposition_rejects_undeclared_and_duplicate():
    with pytest.raises(ValueError, match="no TYPE"):
        validate_exposition('m_total 1\n')
    dup = ('# TYPE m_total counter\n# TYPE m_total counter\nm_total 1\n')
    with pytest.raises(ValueError, match="duplicate TYPE"):
        validate_exposition(dup)


# ------------------------------------- attribution / ring families ----
def _small_rollout():
    from repro.obs.dashboard import demo_fleet
    return demo_fleet(hosts=2, ticks=80, chunk=40, noisy=True)


def test_rollout_exposition_attribution_families():
    cfg, roll = _small_rollout()
    text = rollout_exposition(roll)
    assert validate_exposition(text) > 0
    for family in ("equilibria_stall_component_total",
                   "equilibria_stall_units_total",
                   "equilibria_stall_units_per_tick_bucket",
                   "equilibria_stall_units_quantile",
                   "equilibria_ring_events_total",
                   "equilibria_ring_dropped_total"):
        assert family in text, family
    # the exported component series conserve: per (host, tenant), the
    # component samples sum to the stall_units_total sample
    import re
    comp, total = {}, {}
    for line in text.splitlines():
        m = re.match(r'equilibria_stall_(component|units)_total'
                     r'\{host="(\d+)"(?:,tenant="(\d+)")?'
                     r'(?:,component="\w+")?,?\} (\S+)', line)
        if not m:
            continue
        key = (m.group(2), m.group(3))
        if m.group(1) == "component":
            comp[key] = comp.get(key, 0.0) + float(m.group(4))
        else:
            total[key] = float(m.group(4))
    assert comp and comp == total


def test_ring_summary_scalar_and_batched():
    ring = init_ring(8)
    s = ring_summary(ring)
    assert s == {"capacity": 8, "recorded": 0, "retained": 0, "dropped": 0}
    batched = ring._replace(
        data=np.broadcast_to(np.asarray(ring.data), (3, 8, 5)),
        head=np.asarray([2, 8, 13]))
    s = ring_summary(batched)
    assert s["retained"].tolist() == [2, 8, 8]
    assert s["dropped"].tolist() == [0, 0, 5]


def test_kv_and_serve_exposition():
    from repro.configs import get_smoke_config
    from repro.configs.base import TieringConfig
    from repro.memtier.kvcache import init_cache, kv_tier_counters
    from repro.serve.decode import init_serve_state, serve_exposition
    cfg = dataclasses.replace(get_smoke_config("llama32_1b"),
                              dtype="float32", param_dtype="float32")
    tcfg = TieringConfig(n_tenants=2, page_tokens=4, thrash_table_slots=64,
                         lower_protection=(2, 2), upper_bound=(3, 3))
    cache = init_cache(cfg, tcfg, batch=2, seq=16)
    counters = kv_tier_counters(cache)
    assert set(counters) == set(cache.counters._asdict())
    assert all(v.shape == (2,) for v in counters.values())
    text = kv_exposition(cache)
    assert validate_exposition(text) > 0
    assert "equilibria_kv_promotions_total" in text
    assert "equilibria_kv_ring_dropped_total" in text

    state = init_serve_state(cfg, tcfg, 2, 16)
    assert validate_exposition(serve_exposition(state)) > 0
    with pytest.raises(ValueError, match="no tiered KV cache"):
        serve_exposition({"mamba": None})


def test_dashboard_attribution_section():
    from repro.obs.dashboard import render_dashboard
    cfg, roll = _small_rollout()
    md = render_dashboard(roll)
    assert "## Slowdown attribution" in md
    for name in ("hot_resident", "throttled", "mitigated", "reclaim",
                 "contention", "fast-hit", "conserved"):
        assert name in md, name
