"""Observability subsystem tests: residency bucketing, migration-ring
wraparound/decode round-trip, pathology detectors on synthetic traces,
fleet roll-up shapes under vmap, and in-graph collection on both the trace
engine and the KV serving path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TieringConfig
from repro.obs import pathology as PA
from repro.obs import stats as OS
from repro.obs import trace as OT


# ---------------------------------------------------- residency histogram ----
def test_residency_bucketing():
    ages = jnp.asarray([0, 1, 2, 3, 4, 7, 8, 15, 16, 1 << 20])
    buckets = np.asarray(OS.residency_bucket(ages, n_buckets=8))
    assert buckets.tolist() == [0, 0, 1, 1, 2, 2, 3, 3, 4, 7]  # clipped


def test_residency_hist_records_exits_per_tenant():
    stats = OS.init_stats(3, (8,), n_buckets=8)
    owners = jnp.asarray([0, 0, 1, 1, 2, 2, 2, 2], jnp.int32)
    stats = OS.record_fast_entries(
        stats, jnp.asarray([1, 1, 1, 1, 0, 0, 0, 0], bool),
        jnp.asarray(10, jnp.int32))
    # exits at t=13: ages 3 -> bucket 1. page 4 never entered -> not counted
    stats = OS.record_fast_exits(
        stats, jnp.asarray([1, 0, 1, 0, 1, 0, 0, 0], bool), owners,
        jnp.asarray(13, jnp.int32))
    h = np.asarray(stats.resid_hist)
    assert h.sum() == 2
    assert h[0, 1] == 1 and h[1, 1] == 1 and h[2].sum() == 0
    # exited stamps cleared, survivors keep theirs
    assert np.asarray(stats.fast_since).tolist()[:4] == [-1, 10, -1, 10]


def test_stats_summary_percentiles():
    stats = OS.init_stats(1, (4,), n_buckets=8)
    hist = np.zeros((1, 8), np.int32)
    hist[0, 0] = 10   # 10 exits with residency < 2 ticks
    hist[0, 4] = 1    # one long residency (>= 16 ticks)
    stats = stats._replace(resid_hist=jnp.asarray(hist),
                           ticks=jnp.asarray(5, jnp.int32))
    s = OS.stats_summary(stats)
    assert s["resid_p50"][0] == 0
    assert s["resid_p99"][0] == 16


# ------------------------------------------------------- migration ring ----
def test_ring_wraparound_decode_roundtrip():
    ring = OT.init_ring(8)

    @jax.jit
    def push(ring, pages, t):
        mask = pages >= 0
        tenants = pages % 4
        hot = pages.astype(jnp.float32) / 10
        return OT.ring_record(ring, mask, pages, tenants, hot,
                              OT.DIR_PROMOTE, t)

    # 13 events across 3 calls -> 5 oldest overwritten
    ring = push(ring, jnp.asarray([0, 1, 2, 3, 4]), jnp.asarray(1))
    ring = push(ring, jnp.asarray([5, 6, -1, 7, 8]), jnp.asarray(2))
    ring = push(ring, jnp.asarray([9, 10, 11, 12, -1]), jnp.asarray(3))
    events, dropped = OT.decode_ring(ring)
    assert dropped == 5 and len(events) == 8
    assert events["page"].tolist() == [5, 6, 7, 8, 9, 10, 11, 12]
    assert events["tick"].tolist() == [2, 2, 2, 2, 3, 3, 3, 3]
    assert events["tenant"].tolist() == [(p % 4) for p in events["page"]]
    np.testing.assert_allclose(events["hotness"],
                               np.asarray(events["page"]) / 10, rtol=1e-6)


def test_ring_single_call_larger_than_capacity_keeps_newest():
    ring = OT.init_ring(4)
    pages = jnp.arange(10)
    ring = OT.ring_record(ring, jnp.ones((10,), bool), pages, pages % 2,
                          pages.astype(jnp.float32), OT.DIR_PROMOTE,
                          jnp.asarray(1))
    events, dropped = OT.decode_ring(ring)
    assert dropped == 6
    assert events["page"].tolist() == [6, 7, 8, 9]  # newest C, in order


def test_ring_partial_fill_decode():
    ring = OT.init_ring(16)
    ring = OT.ring_record(ring, jnp.asarray([True, False, True]),
                          jnp.asarray([7, 8, 9]), jnp.asarray([0, 1, 2]),
                          jnp.asarray([1.0, 2.0, 3.0]), OT.DIR_DEMOTE,
                          jnp.asarray(4))
    events, dropped = OT.decode_ring(ring)
    assert dropped == 0
    assert events["page"].tolist() == [7, 9]
    assert (events["direction"] == OT.DIR_DEMOTE).all()


# ------------------------------------------------------ pathology logic ----
def _flat(ticks, T, val=0.0):
    return np.full((ticks, T), val)


def test_detect_chronic_thrashing_only_sustained():
    ticks, T = 200, 2
    ev = np.zeros((ticks, T))
    ev[:, 0] = np.arange(ticks) * 10          # tenant0: 10 events/tick forever
    ev[100:120, 1] = np.arange(20) * 10       # tenant1: one 20-tick burst
    ev[120:, 1] = ev[119, 1]
    found = PA.detect_chronic_thrashing(ev, window=20, rate_threshold=4.0)
    assert [p.tenant for p in found] == [0]
    assert found[0].severity >= 1.0


def test_detect_protection_violation_exempts_cold_tenants():
    ticks, T = 120, 3
    fast = _flat(ticks, T, 100.0)
    slow = _flat(ticks, T, 100.0)
    fast[:, 0] = 20                            # tenant0 held below prot=80
    att = _flat(ticks, T, 1.0)                 # everyone wants promotion...
    att[:, 2] = 0
    fast[:, 2] = 20                            # tenant2 below but cold: exempt
    found = PA.detect_protection_violation(fast, slow, [80, 80, 80],
                                           attempted=att,
                                           demotions=_flat(ticks, T))
    assert [p.tenant for p in found] == [0]


def test_detect_noisy_neighbor_needs_dominance_and_degradation():
    ticks, T = 200, 3
    promo = _flat(ticks, T); demo = _flat(ticks, T)
    lat = _flat(ticks, T, 1.0)
    promo[100:, 0] = 50                        # tenant0 dominates migrations
    lat[100:, 1] = 1.5                         # neighbor's latency degrades
    found = PA.detect_noisy_neighbor(promo, demo, lat)
    assert [p.tenant for p in found] == [0]
    # same dominance, no degradation -> silent
    assert PA.detect_noisy_neighbor(promo, demo, _flat(ticks, T, 1.0)) == []


def test_detect_promotion_stall():
    ticks, T = 100, 2
    att = _flat(ticks, T, 5.0)
    promo = _flat(ticks, T, 4.0)
    promo[:, 1] = 0.0                          # tenant1 never succeeds
    found = PA.detect_promotion_stall(att, promo)
    assert [p.tenant for p in found] == [1]
    assert found[0].evidence["success_ratio"] == 0.0


# -------------------------------------------------- engine integration ----
def test_engine_stats_and_ring_collected():
    from repro.core.simulator import simulate
    from repro.core.workloads import microbenchmark
    cfg = TieringConfig(n_tenants=2, n_fast_pages=128, n_slow_pages=256,
                        lower_protection=(48, 48), upper_bound=(0, 0),
                        obs_ring_capacity=256)
    r = simulate(cfg, [microbenchmark(100), microbenchmark(100)], 80,
                 k_max=32)
    s = r.tier_stats
    # every demotion ends a residency -> histogram mass == total demotions
    assert s["resid_hist"].sum() == r.demotions.sum()
    assert (s["promo_success"] <= s["promo_attempts"]).all()
    assert s["ticks"] == 80
    # ring holds promote+demote events, newest-first semantics
    n_mig = int(r.promotions.sum() + r.demotions.sum())
    assert len(r.migrations) == min(n_mig, 256)
    assert r.migrations_dropped == max(n_mig - 256, 0)
    assert (np.diff(r.migrations["tick"]) >= 0).all()
    dirs = set(r.migrations["direction"].tolist())
    assert dirs <= {OT.DIR_PROMOTE, OT.DIR_DEMOTE}


def test_tier_stat_export_includes_obs_fields():
    import jax.numpy as jnp
    from repro.core.engine import run_engine
    from repro.core.state import tier_stat
    from repro.core.workloads import build_trace, microbenchmark
    cfg = TieringConfig(n_tenants=2, n_fast_pages=128, n_slow_pages=256,
                        lower_protection=(48, 48), upper_bound=(0, 0))
    owner, acc, alive = build_trace(
        [microbenchmark(100), microbenchmark(100)], 60)
    final, _ = run_engine(cfg, owner, acc, alive, k_max=32)
    oh = jnp.asarray((owner[None, :] == np.arange(2)[:, None]).astype(np.float32))
    stat = tier_stat(final, oh)
    for key in ("resid_p50", "promo_success_ratio", "contended_frac",
                "throttled_frac", "thrash_rate"):
        assert key in stat, key
        assert np.asarray(stat[key]).shape == (2,)


def test_tier_stat_works_under_jit():
    """tier_stat stays a pure-jnp export usable on traced state."""
    import jax.numpy as jnp
    from repro.core.state import init_state, tier_stat
    cfg = TieringConfig(n_tenants=2)
    state = init_state(cfg, 16)
    oh = jnp.ones((2, 16), jnp.float32) / 2
    stat = jax.jit(lambda s: tier_stat(s, oh))(state)
    assert np.asarray(stat["resid_p50"]).shape == (2,)
    assert np.asarray(stat["demo_success_ratio"]).shape == (2,)


def test_demo_success_ratio_bounded_with_sync_demotions():
    """Step-6b sync upper-bound demotions count as attempts too (ratio <= 1)."""
    from repro.core.simulator import simulate
    from repro.core.workloads import microbenchmark, thrasher
    cfg = TieringConfig(n_tenants=2, n_fast_pages=128, n_slow_pages=256,
                        lower_protection=(0, 48), upper_bound=(12, 0))
    r = simulate(cfg, [thrasher(80, fast_share=12), microbenchmark(80)],
                 120, k_max=32)
    assert (r.tier_stats["demo_success_ratio"] <= 1.0 + 1e-6).all()
    assert r.tier_stats["demo_success"][0] > 0


# ------------------------------------------------------ fleet under vmap ----
def test_fleet_rollup_shapes_and_detection():
    from repro.obs.fleet import (heterogeneous_mixes, inject_noisy_neighbor,
                                 run_fleet)
    H, T, ticks = 4, 3, 120
    cfg = TieringConfig(n_tenants=T, n_fast_pages=256, n_slow_pages=256,
                        lower_protection=(64, 64, 64), upper_bound=(0, 0, 0),
                        migration_cost=0.005, obs_ring_capacity=128)
    mixes = heterogeneous_mixes([80, 80, 64], n_hosts=H, seed=1)
    res = run_fleet(cfg, mixes, ticks, k_max=32)
    for arr in (res.latency, res.throughput, res.fast_usage, res.promotions,
                res.attempted, res.thrash_events):
        assert arr.shape == (H, ticks, T)
    assert len(res.stats) == H
    assert all(s["resid_hist"].shape == (T, cfg.obs_resid_buckets)
               for s in res.stats)
    roll = res.rollup()
    assert roll["hosts"] == H and roll["tenants"] == T
    assert roll["latency_p99"] >= roll["latency_p50"] >= 1.0
    # per-host ring decodes independently
    ev, _ = res.host_migrations(0)
    assert ev.dtype == OT.EVENT_DTYPE
    # an injected noisy neighbor is flagged; this clean fleet is not
    assert res.tenants_flagged() == []
    noisy = run_fleet(
        cfg.with_(upper_bound=(12, 0, 0)),
        inject_noisy_neighbor(mixes, tenant=0, fast_share=12, arrival=40),
        ticks, k_max=32)
    flagged = noisy.tenants_flagged("chronic_thrashing")
    assert flagged and all(t == 0 for _, t in flagged)


# --------------------------------------------------- serving-path stats ----
def test_kv_step_collects_stats_and_ring():
    from repro.configs import get_smoke_config
    from repro.core.state import make_policy
    from repro.memtier import kvcache as KC
    from repro.memtier.tiering import equilibria_kv_step
    cfg = dataclasses.replace(get_smoke_config("llama32_1b"), dtype="float32")
    tcfg = TieringConfig(n_tenants=2, page_tokens=4, thrash_table_slots=64,
                         lower_protection=(2, 2), upper_bound=(3, 3),
                         obs_ring_capacity=64)
    B, seq = 4, 32
    cache = KC.init_cache(cfg, tcfg, B, seq)
    policy = make_policy(tcfg)
    # hand-place hot slow pages so the step promotes
    M = cache.page_tier.shape[1]
    slow_page = cache.slow_page.at[:, 0].set(0)
    page_tier = cache.page_tier.at[:, 0].set(1)
    cache = cache._replace(slow_page=slow_page, page_tier=page_tier,
                           seq_len=jnp.full((B,), 4, jnp.int32))
    B_, Mf = cache.fast_page.shape
    Ms = cache.slow_page.shape[1]
    fast_mass = jnp.zeros((B, Mf), jnp.float32)
    slow_mass = jnp.full((B, Ms), 10.0, jnp.float32)

    step = jax.jit(lambda c: equilibria_kv_step(
        c, fast_mass, slow_mass, tcfg, policy, fast_budget=B * M))
    out = step(cache)
    assert int(out.counters.promotions.sum()) > 0
    s = OS.stats_summary(out.stats)
    assert s["promo_attempts"].sum() >= s["promo_success"].sum() > 0
    events, _ = OT.decode_ring(out.ring)
    assert len(events) == int(out.counters.promotions.sum())
    assert (events["direction"] == OT.DIR_PROMOTE).all()
    # promoted slots carry a residency stamp for later exit accounting
    assert (np.asarray(out.stats.fast_since) >= 0).any()
