"""Equivalence suite: the tenant-batched selection engine (core/select.py,
engine impl="batched") is pinned bit-exactly to the seed's per-tenant
unrolled loops (impl="unrolled") — randomized scores, quotas (zero, partial,
over-supply), masks, and tie cases, for T in {1, 3, 8} — plus trace-time
T-independence of the batched tick's jaxpr."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TieringConfig
from repro.core import select as S
from repro.core.engine import make_tick, run_engine
from repro.core.state import init_state
from repro.core.workloads import build_trace, ci_like, microbenchmark

L = 96  # fixed so every parametrized case reuses one compiled shape per T


def _unrolled_select(score, owner, active, quotas, T, k_cap):
    masks = jnp.asarray((owner[None] == np.arange(T)[:, None]) & active[None])
    return S.select_top_quota_unrolled(jnp.asarray(score), masks,
                                       jnp.asarray(quotas), k_cap)


def _batched_select(score, owner, active, quotas, T, k_cap):
    return S.select_top_quota(jnp.asarray(score), jnp.asarray(owner),
                              jnp.asarray(active), jnp.asarray(quotas), T,
                              k_cap)


@pytest.mark.parametrize("T", [1, 3, 8])
@pytest.mark.parametrize("seed", range(8))
def test_select_randomized_bit_exact(T, seed):
    rng = np.random.default_rng(1000 * T + seed)
    owner = rng.integers(0, T, L).astype(np.int32)
    # half the cases use integer-valued scores so duplicates force the
    # top_k/stable-sort tie-break (lower index wins) to agree
    if seed % 2 == 0:
        score = rng.integers(-4, 4, L).astype(np.float32)
    else:
        score = rng.standard_normal(L).astype(np.float32)
    active = rng.random(L) < rng.choice([0.2, 0.6, 1.0])
    if T >= 3:
        active &= owner != 1          # one tenant fully masked out
    # quotas mix: zero, partial, and over-supply (more than active pages)
    quotas = rng.integers(0, 2 * L, T).astype(np.int32)
    quotas[rng.integers(0, T)] = 0
    k_cap = int(rng.choice([3, 17, L + 8]))
    a = _batched_select(score, owner, active, quotas, T, k_cap)
    b = _unrolled_select(score, owner, active, quotas, T, k_cap)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("T", [1, 3, 8])
@pytest.mark.parametrize("seed", range(8))
def test_select_rows_contiguous_bit_exact(T, seed):
    """The padded-rows strategy (contiguous layouts) vs the unrolled loop."""
    rng = np.random.default_rng(7000 * T + seed)
    counts = rng.integers(0, 2 * L // max(T, 1), T)
    owner = np.repeat(np.arange(T), counts).astype(np.int32)
    Lc = owner.shape[0]
    if Lc == 0:
        owner = np.zeros(1, np.int32)
        Lc = 1
    layout = S.plan_layout(owner, T)
    assert layout is not None
    score = (rng.integers(-3, 3, Lc) if seed % 2 == 0
             else rng.standard_normal(Lc)).astype(np.float32)
    active = rng.random(Lc) < rng.choice([0.3, 1.0])
    quotas = rng.integers(0, Lc + 4, T).astype(np.int32)
    k_cap = int(rng.choice([2, 19, Lc + 8]))
    sel = S.select_top_quota_rows(jnp.asarray(score), jnp.asarray(active),
                                  jnp.asarray(quotas), layout, k_cap)
    masks = (owner[None] == np.arange(T)[:, None]) & active[None]
    ref = S.select_top_quota_unrolled(jnp.asarray(score), jnp.asarray(masks),
                                      jnp.asarray(quotas), k_cap)
    np.testing.assert_array_equal(np.asarray(sel.mask), np.asarray(ref))
    # the compact stream agrees with the mask
    np.testing.assert_array_equal(np.asarray(sel.counts),
                                  masks.astype(np.int64) @ np.asarray(ref))


def test_plan_layout_rejects_non_contiguous():
    assert S.plan_layout(np.array([0, 1, 0, 1], np.int32), 2) is None
    assert S.plan_layout(np.array([1, 1, 0, 0], np.int32), 2) is None
    assert S.plan_layout(np.array([0, 0, 1, 1], np.int32), 2) is not None
    assert S.plan_layout(np.array([0, 0, 2, 2], np.int32), 3) is not None


@pytest.mark.parametrize("T", [1, 3, 8])
def test_allocation_ranks_match_unrolled(T):
    rng = np.random.default_rng(T)
    for seed in range(6):
        owner = rng.integers(0, T, L).astype(np.int32)
        new = rng.random(L) < rng.choice([0.0, 0.3, 1.0])
        ra = S.allocation_ranks(jnp.asarray(new), jnp.asarray(owner), T)
        rb = S.allocation_ranks_unrolled(jnp.asarray(new), jnp.asarray(owner),
                                         T)
        # ranks of non-new pages are unspecified in the batched version
        np.testing.assert_array_equal(np.asarray(ra)[new], np.asarray(rb)[new])


@pytest.mark.parametrize("mode", ["equilibria", "memtis", "tpp"])
def test_engine_batched_matches_unrolled(mode):
    """Whole-tick equivalence over a real trace: every integer output of the
    batched engine is bit-equal to the seed's unrolled engine."""
    cfg = TieringConfig(n_tenants=3, n_fast_pages=256, n_slow_pages=256,
                        lower_protection=(96, 96, 0),
                        upper_bound=(0, 120, 0))
    tenants = [microbenchmark(150), microbenchmark(140, arrival=10),
               ci_like(120, phase_len=20)]
    owner, acc, alive = build_trace(tenants, 80)
    _, a = run_engine(cfg, owner, acc, alive, mode=mode, k_max=64,
                      impl="batched")
    _, b = run_engine(cfg, owner, acc, alive, mode=mode, k_max=64,
                      impl="unrolled")
    for f in ("fast_usage", "slow_usage", "promotions", "demotions",
              "thrash_events", "attempted_promotions", "fast_free",
              "promo_scale"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)
    # float perf model: scatter-add vs matmul reduction order may differ
    np.testing.assert_allclose(np.asarray(a.latency), np.asarray(b.latency),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(a.throughput),
                               np.asarray(b.throughput), rtol=1e-5)


def _tick_build(impl):
    def build(T):
        Lp = 16 * T
        owner = np.arange(Lp, dtype=np.int32) % T
        cfg = TieringConfig(n_tenants=T, n_fast_pages=Lp // 2,
                            lower_protection=(4,) * T, upper_bound=(8,) * T)
        tick = make_tick(cfg, owner, "equilibria", k_max=8, impl=impl)
        state = init_state(cfg, Lp)
        return tick, (state, (jnp.zeros((Lp,), jnp.float32),
                              jnp.ones((Lp,), bool)))
    return build


def test_batched_tick_trace_is_T_independent():
    """The batched tick's jaxpr signature (eqn count + primitive histogram,
    sub-jaxprs included) is identical for T=2 and T=16, with zero top_k
    ops on the equilibria path; the unrolled tick grows."""
    from repro.analysis.constancy import (assert_jaxpr_constant,
                                          sweep_signatures)

    sig = assert_jaxpr_constant(_tick_build("batched"), (2, 16),
                                label="batched tick: tenant count")
    assert sig.histogram().get("top_k", 0) == 0   # equilibria: no top_k ops

    (_, un_small), (_, un_big) = sweep_signatures(
        _tick_build("unrolled"), (2, 16))
    assert un_small != un_big                     # unrolled impl DOES grow
    assert un_big.histogram().get("top_k", 0) > \
        un_small.histogram().get("top_k", 0)
    assert un_big.n_eqns > un_small.n_eqns
