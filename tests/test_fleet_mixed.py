"""Mixed-fleet harness (obs/fleet.py on the unified tick core).

Pins the PR-5 fleet properties: static and churned hosts run side by side
under ONE vmap of the unified dynamic-ownership tick (the host mix is
data, not structure — same jaxpr regardless of mix), a noisy neighbor
injected on a *churned* host is flagged while the clean mixed fleet stays
silent, and the chunked long-horizon rollout (donated carries, schedule
archetypes gathered in-graph, periodic tiling) is bit-equal to the
single-scan execution.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TieringConfig
from repro.core.churn import make_churn_tick
from repro.core.state import init_state, stack_states
from repro.core.workloads import (ChurnSlot, build_churn_schedule,
                                  cache_like, spark_like, thrasher, web_like)
from repro.obs.fleet import (fleet_rollout, mixed_fleet_hosts,
                             run_mixed_fleet, stack_schedules)

_TICKS = 160
# slot footprints shared fleet-wide (slot count must match across hosts;
# footprints need not, but keeping them equal makes the A/B injection clean)
_FOOT = (32, 40, 40, 24)


def _cfg():
    total = sum(_FOOT)
    fast = int(total * 1.15)   # ample fast tier: a clean fleet must be clean
    # slot-0 bound: harmless for the clean web/cache hot sets (~11 pages),
    # the squeeze that turns an injected thrasher into §IV-F churn
    return TieringConfig(n_tenants=4, n_fast_pages=fast, n_slow_pages=total,
                         lower_protection=(8, 12, 12, 8),
                         upper_bound=(24, 0, 0, 0),
                         migration_cost=0.005)


def _hosts(noisy_host=None):
    """2 static + 2 churned hosts, T=4 slots each."""
    static_mixes = [
        [web_like(_FOOT[0]), cache_like(_FOOT[1]), spark_like(_FOOT[2]),
         web_like(_FOOT[3])],
        [web_like(_FOOT[0], hot_pages=10), cache_like(_FOOT[1]),
         web_like(_FOOT[2]), cache_like(_FOOT[3])],
    ]
    churned = []
    for seed in (0, 1):
        churned.append([
            ChurnSlot(web_like(_FOOT[0]), [(0, _TICKS)]),
            ChurnSlot(cache_like(_FOOT[1]), [(5, _TICKS)]),
            # mid-run departure + re-arrival: slot reuse on a live fleet
            ChurnSlot(cache_like(_FOOT[2]), [(0, 60 + 10 * seed),
                                             (90, _TICKS)]),
            ChurnSlot(web_like(_FOOT[3]), [(8 * seed, _TICKS)]),
        ])
    hosts = mixed_fleet_hosts(static_mixes, churned, _TICKS)
    if noisy_host is not None:
        # §V-B5 noisy neighbor on a churned host: promotion-hot pages never
        # re-accessed before demotion, squeezed under slot 0's bound; late
        # arrival leaves the detectors a clean baseline window
        hosts[noisy_host][0] = ChurnSlot(thrasher(_FOOT[0], fast_share=12),
                                         [(30, _TICKS)])
    return hosts


def test_mixed_fleet_clean_is_silent():
    res = run_mixed_fleet(_cfg(), _hosts(), _TICKS, k_max=32)
    assert res.n_hosts == 4
    assert res.latency.shape == (4, _TICKS, 4)
    assert res.tenants_flagged() == [], res.pathology_counts()
    # the churned hosts really churned: slot 2 left and came back
    assert not res.active[2, 70, 2] and res.active[2, 100, 2]
    roll = res.rollup()
    assert roll["hosts_with_pathology"] == 0
    assert roll["latency_p99"] >= roll["latency_p50"] >= 1.0


def test_noisy_neighbor_on_churned_host_is_flagged():
    noisy_host = 2                      # a churned host
    res = run_mixed_fleet(_cfg(), _hosts(noisy_host=noisy_host), _TICKS,
                          k_max=32)
    flagged = res.tenants_flagged("chronic_thrashing")
    assert (noisy_host, 0) in flagged, res.pathology_counts()
    # the injection is host-local: nobody else in the fleet is flagged
    assert {h for h, _ in res.tenants_flagged()} == {noisy_host}
    # per-host in-graph stats saw the churn too
    assert res.stats[noisy_host]["thrash_rate"][0] > 0


def test_fleet_jaxpr_constant_in_host_mix():
    """The unified tick traces once regardless of host mix: an all-static
    fleet and a mixed static+churn fleet produce IDENTICAL vmapped jaxprs
    (the mix lives in the schedule data), and the trace's equation count is
    independent of the host count."""
    cfg = _cfg()
    L = cfg.n_fast_pages + cfg.n_slow_pages
    tick = make_churn_tick(cfg, L, k_max=32)

    from repro.analysis.constancy import assert_jaxpr_constant

    def build(H):
        vt = jax.vmap(tick)
        states = stack_states(init_state(cfg, L), H)
        S = max(_FOOT)
        inp = (jnp.ones((H, 4, S), jnp.float32),
               jnp.full((H, 4), 16, jnp.int32))
        return vt, (states, inp)

    # retrace at the same H is deterministic; doubling H leaves the
    # vmapped program's eqn count and primitive mix untouched
    assert_jaxpr_constant(build, (4, 4, 8),
                          label="vmapped tick: host count")

    # same program, different *data*: all-static vs mixed fleets share the
    # compiled scan — pin by running both through one jitted runner and
    # checking the runner compiled exactly once
    hosts_static = mixed_fleet_hosts(
        [[web_like(f) for f in _FOOT]] * 2, [], 32)
    hosts_mixed = _hosts()
    n_compiles = 0

    def counting_run(s, r, w):
        nonlocal n_compiles
        n_compiles += 1
        return jax.lax.scan(tick, s, (r, w))

    run = jax.jit(jax.vmap(counting_run))
    for hosts in (hosts_static[:2], hosts_mixed[:2]):
        want, rates = stack_schedules(
            [build_churn_schedule(s, 32) for s in hosts])
        S = max(_FOOT)
        pad = np.zeros(rates.shape[:3] + (S - rates.shape[3],), np.float32)
        rates = np.concatenate([rates, pad], axis=3)
        states = stack_states(init_state(cfg, L), 2)
        run(states, jnp.asarray(rates), jnp.asarray(want))
    assert n_compiles == 1


def test_chunked_rollout_matches_single_scan():
    """fleet_rollout chunking (donated carries, periodic schedule tiling)
    is bit-exact: chunk=ticks (one scan) == chunk=7 (chunks + remainder)."""
    cfg = _cfg()
    hosts = _hosts()
    ticks = 30
    want, rates = stack_schedules(
        [build_churn_schedule(s, ticks) for s in hosts])
    runs = [fleet_rollout(cfg, want, rates, ticks, chunk=c, k_max=32)
            for c in (ticks, 7)]
    c0, c1 = (r.counters() for r in runs)
    for name in c0._fields:
        np.testing.assert_array_equal(getattr(c0, name), getattr(c1, name),
                                      err_msg=name)
    np.testing.assert_array_equal(np.asarray(runs[0].final_state.tier),
                                  np.asarray(runs[1].final_state.tier))
    np.testing.assert_array_equal(np.asarray(runs[0].final_state.owner),
                                  np.asarray(runs[1].final_state.owner))
    np.testing.assert_allclose(runs[0].latency_mean, runs[1].latency_mean,
                               rtol=1e-6)
    np.testing.assert_allclose(runs[0].migrations_per_tick,
                               runs[1].migrations_per_tick, rtol=1e-6)


def test_rollout_archetype_tiling_matches_explicit_hosts():
    """host_arch tiling (several hosts sharing one schedule archetype) is
    identical to materializing the schedule per host. Archetype 0 is static
    and archetype 1 churns (departure + re-arrival inside the horizon) so
    the two produce genuinely different counters — a wrong-axis gather in
    the in-graph schedule lookup cannot pass by accident."""
    cfg = _cfg()
    hosts = [_hosts()[0], _hosts()[2]]     # one static, one churned
    ticks = 100                            # covers depart@60 / re-arrive@90
    want, rates = stack_schedules(
        [build_churn_schedule(s, ticks) for s in hosts])
    tiled = fleet_rollout(cfg, want, rates, ticks,
                          host_arch=np.array([0, 1, 0, 1]), chunk=32,
                          k_max=32)
    explicit = fleet_rollout(cfg, want[[0, 1, 0, 1]], rates[[0, 1, 0, 1]],
                             ticks, chunk=32, k_max=32)
    ce, ct = explicit.counters(), tiled.counters()
    for name in ct._fields:
        np.testing.assert_array_equal(getattr(ct, name), getattr(ce, name),
                                      err_msg=name)
    # non-vacuous: the archetypes disagree (the churned host reclaimed)
    assert not np.array_equal(ct.reclaims[0], ct.reclaims[1])
    assert not np.array_equal(ct.allocations[0], ct.allocations[1])


@pytest.mark.slow
def test_rollout_pmap_shard_path_matches():
    """With >1 device the rollout shards hosts via pmap; results are
    bit-equal to the vmap path. Exercised in a subprocess with forced host
    devices (jax is already initialized single-device in this process)."""
    script = textwrap.dedent("""
        import numpy as np
        from repro.configs.base import TieringConfig
        from repro.core.workloads import (build_churn_schedule,
                                          as_churn_slots, web_like,
                                          cache_like)
        from repro.obs.fleet import fleet_rollout, stack_schedules
        import jax
        assert jax.local_device_count() == 2, jax.local_device_count()
        ticks = 20
        hosts = [as_churn_slots([web_like(8), cache_like(10)], ticks),
                 as_churn_slots([cache_like(8), web_like(10)], ticks)]
        cfg = TieringConfig(n_tenants=2, n_fast_pages=12, n_slow_pages=20,
                            lower_protection=(3, 3), upper_bound=(0, 6))
        want, rates = stack_schedules(
            [build_churn_schedule(s, ticks) for s in hosts])
        ha = np.array([0, 1, 0, 1])
        a = fleet_rollout(cfg, want, rates, ticks, host_arch=ha, chunk=8,
                          k_max=8, shard=True)
        b = fleet_rollout(cfg, want, rates, ticks, host_arch=ha, chunk=8,
                          k_max=8, shard=False)
        assert a.sharded and not b.sharded
        ca, cb = a.counters(), b.counters()
        for name in ca._fields:
            np.testing.assert_array_equal(getattr(ca, name),
                                          getattr(cb, name), err_msg=name)
        np.testing.assert_array_equal(np.asarray(a.final_state.tier),
                                      np.asarray(b.final_state.tier))
        print("SHARD_OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARD_OK" in out.stdout
