"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step on CPU, asserting shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import synthetic_batch
from repro.models.params import init_params, param_count
from repro.models.transformer import model_forward, model_specs
from repro.optim.adamw import init_opt_state
from repro.train.step import make_train_step

from conftest import arch_params

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", arch_params())
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = init_params(KEY, model_specs(cfg))
    B, S = 2, 16
    batch = synthetic_batch(cfg, B, S, kind="prefill")
    logits, aux = model_forward(params, batch, cfg, remat="none")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", arch_params())
def test_train_step_decreases_loss_and_finite(arch):
    cfg = get_smoke_config(arch)
    tc = TrainConfig(learning_rate=5e-3, warmup_steps=1, total_steps=20,
                     remat_policy="none", grad_clip=1.0)
    params = init_params(KEY, model_specs(cfg))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, tc))
    batch = synthetic_batch(cfg, 2, 16, kind="train")
    losses = []
    for _ in range(4):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]  # overfits one batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full (assigned) configs expose the exact published dimensions."""
    cfg = get_config(arch)
    specs = model_specs(cfg)
    n = param_count(specs)
    expected_range = {
        "mixtral_8x22b": (130e9, 150e9),
        "granite_moe_3b_a800m": (3.0e9, 3.6e9),
        "qwen3_32b": (30e9, 35e9),
        "codeqwen15_7b": (7e9, 9e9),
        "h2o_danube_3_4b": (3.5e9, 4.5e9),
        "llama32_1b": (1.0e9, 1.5e9),
        "mamba2_130m": (0.1e9, 0.2e9),
        "whisper_tiny": (0.03e9, 0.08e9),
        "llama32_vision_90b": (80e9, 95e9),
        "zamba2_7b": (6e9, 8e9),
    }[arch]
    assert expected_range[0] <= n <= expected_range[1], n


def test_microbatch_accumulation_matches_single():
    cfg = get_smoke_config("llama32_1b")
    cfg = dataclasses.replace(cfg, dtype="float32", param_dtype="float32")
    tc1 = TrainConfig(microbatches=1, remat_policy="none")
    tc2 = TrainConfig(microbatches=2, remat_policy="none")
    params = init_params(KEY, model_specs(cfg))
    opt = init_opt_state(params)
    batch = synthetic_batch(cfg, 4, 16, kind="train")
    p1, _, m1 = jax.jit(make_train_step(cfg, tc1))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, tc2))(params, opt, batch)
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree_util.tree_leaves(d)) < 2e-5
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
