"""Property-based differential suite for the ``segment_ranks`` selection
fallback — the path every churned (non-contiguous) ownership layout routes
through. The contiguous rows path is already pinned by
tests/test_selection_equivalence.py; this suite pins the generic path on
exactly the layouts the churn engine produces: arbitrary owner
permutations, free-pool sentinel holes, duplicate scores (tie-break must
match top_k's lower-index-wins), and zero / partial / over-supply quotas —
batched vs ``impl="unrolled"`` must agree bit-exactly.

Runs under hypothesis when installed, seeded-parametrize otherwise
(tests/proputil.py).
"""
import jax.numpy as jnp
import numpy as np
from proputil import seeded_property

from repro.core import select as S

L = 96


def _case(seed):
    """A random non-contiguous selection case: shuffled owners (with some
    tenants empty and optional free-sentinel holes), duplicate-heavy or
    continuous scores, adversarial quota mix, random k_cap."""
    rng = np.random.default_rng(seed)
    T = int(rng.integers(1, 9))
    owner = rng.integers(0, T, L).astype(np.int32)
    if T >= 3:
        owner[owner == 1] = 0              # tenant 1 empty
    rng.shuffle(owner)
    if S.plan_layout(owner, T) is not None and T >= 2:
        owner[0], owner[-1] = T - 1, 0     # force non-contiguity
    if seed % 2 == 0:
        score = rng.integers(-4, 4, L).astype(np.float32)   # dense ties
    else:
        score = rng.standard_normal(L).astype(np.float32)
    active = rng.random(L) < rng.choice([0.2, 0.6, 1.0])
    quotas = rng.integers(0, 2 * L, T).astype(np.int32)     # over-supply mix
    quotas[rng.integers(0, T)] = 0
    k_cap = int(rng.choice([3, 17, L + 8]))
    return T, owner, score, active, quotas, k_cap


@seeded_property(n_fallback=40)
def test_fallback_bit_exact_noncontiguous(seed):
    T, owner, score, active, quotas, k_cap = _case(seed)
    got = S.select_top_quota(jnp.asarray(score), jnp.asarray(owner),
                             jnp.asarray(active), jnp.asarray(quotas), T,
                             k_cap)
    masks = jnp.asarray((owner[None] == np.arange(T)[:, None]) & active[None])
    ref = S.select_top_quota_unrolled(jnp.asarray(score), masks,
                                      jnp.asarray(quotas), k_cap)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@seeded_property(n_fallback=24)
def test_fallback_with_free_sentinel_holes(seed):
    """Owner vectors containing the churn engine's FREE sentinel (== T):
    sentinel pages are never selected, and the real tenants' selection is
    unchanged versus masking those pages out explicitly."""
    T, owner, score, active, quotas, k_cap = _case(seed)
    rng = np.random.default_rng(seed + 1)
    free = rng.random(L) < 0.3
    owner_h = np.where(free, T, owner).astype(np.int32)
    got = S.select_top_quota(jnp.asarray(score), jnp.asarray(owner_h),
                             jnp.asarray(active & ~free),
                             jnp.asarray(quotas), T, k_cap)
    masks = jnp.asarray((owner[None] == np.arange(T)[:, None])
                        & active[None] & ~free[None])
    ref = S.select_top_quota_unrolled(jnp.asarray(score), masks,
                                      jnp.asarray(quotas), k_cap)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert not np.asarray(got)[free].any()


@seeded_property(n_fallback=24)
def test_scatter_reductions_match_onehot(seed):
    rng = np.random.default_rng(seed)
    T = int(rng.integers(1, 9))
    owner = rng.integers(0, T, L).astype(np.int32)
    x = rng.integers(-5, 6, L).astype(np.int32)
    oh = (owner[None] == np.arange(T)[:, None]).astype(np.int64)
    ref = oh @ x
    got = S.by_tenant_scatter(jnp.asarray(x), jnp.asarray(owner), T)
    np.testing.assert_array_equal(np.asarray(got), ref)
    # pooled variant: sentinel lanes must not leak onto tenant T-1
    owner_h = owner.copy()
    owner_h[rng.random(L) < 0.4] = T
    oh2 = (owner_h[None] == np.arange(T)[:, None]).astype(np.int64)
    got2 = S.by_tenant_pooled(jnp.asarray(x), jnp.asarray(owner_h), T)
    np.testing.assert_array_equal(np.asarray(got2), oh2 @ x)


@seeded_property(n_fallback=24)
def test_allocation_ranks_noncontiguous(seed):
    rng = np.random.default_rng(seed)
    T = int(rng.integers(1, 9))
    owner = rng.integers(0, T, L).astype(np.int32)
    rng.shuffle(owner)
    new = rng.random(L) < rng.choice([0.0, 0.3, 1.0])
    ra = S.allocation_ranks(jnp.asarray(new), jnp.asarray(owner), T)
    rb = S.allocation_ranks_unrolled(jnp.asarray(new), jnp.asarray(owner), T)
    np.testing.assert_array_equal(np.asarray(ra)[new], np.asarray(rb)[new])


@seeded_property(n_fallback=24)
def test_pool_grant_properties(seed):
    """Grant partition: grants only free pages, per-tenant grant counts are
    min(ask, what the pool can still cover in slot-priority order), and the
    granted pages are exactly the lowest-index free pages."""
    rng = np.random.default_rng(seed)
    T = int(rng.integers(1, 7))
    free = rng.random(L) < rng.choice([0.1, 0.5, 0.9])
    need = rng.integers(0, L, T).astype(np.int32)
    got = np.asarray(S.pool_grant(jnp.asarray(free), jnp.asarray(need)))
    granted = got < T
    assert (free | ~granted).all()                   # only free pages granted
    n_free = int(free.sum())
    counts = np.bincount(got[granted], minlength=T)
    remaining = n_free
    for t in range(T):                               # slot-priority semantics
        expect = min(int(need[t]), remaining)
        assert counts[t] == expect, (t, counts, need, n_free)
        remaining -= expect
    # granted set = lowest-index free pages
    free_idx = np.flatnonzero(free)
    np.testing.assert_array_equal(np.flatnonzero(granted),
                                  free_idx[:int(counts.sum())])
