"""The analyzer's own regression surface.

Every pass must (a) flag the known-bad fixture planted for it and
(b) stay silent on the clean tick — otherwise the check.sh gate is either
blind or noisy. Plus: interval-arithmetic units, the ratchet baseline
mechanics, the CLI gate exit codes, and the fleet counter-ledger
regression tests (the fix the overflow pass's scale findings motivate).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import fixtures as FX
from repro.analysis import lint as LI
from repro.analysis.constancy import (JaxprSignature, assert_jaxpr_constant,
                                      check_constant, jaxpr_signature,
                                      signature_of)
from repro.analysis.findings import Finding, Report, write_baseline
from repro.analysis.interval import (F32_EXACT, Interval, dtype_interval,
                                     value_interval)
from repro.analysis.jaxpr_audit import (INT32_MAX, donation_pass, dtype_pass,
                                        overflow_pass, purity_pass)
from repro.analysis.__main__ import main as analysis_main

# the bad-donation fixture intentionally donates an unusable buffer
pytestmark = pytest.mark.filterwarnings(
    "ignore:Some donated buffers were not usable")


def _keys(report):
    return report.keys()


# ------------------------------------------------------------ intervals ----
def test_interval_algebra():
    a, b = Interval(0, 5, True), Interval(3, 10, True)
    assert a.union(b) == Interval(0, 10, True)
    assert a.contains(Interval(1, 4, True))
    assert not a.contains(b)
    assert Interval(0, 5, True).union(Interval(1, 2, False)).integral is False
    assert Interval(0, 5, True).bounded()
    assert not Interval(0, float("inf"), True).bounded()


def test_dtype_and_value_intervals():
    assert dtype_interval(jnp.int8) == Interval(-128, 127, True)
    assert dtype_interval(jnp.uint32).lo == 0
    assert dtype_interval(jnp.int32).hi == INT32_MAX
    iv = value_interval(jnp.full((4,), 7, jnp.int32))
    assert (iv.lo, iv.hi, iv.integral) == (7, 7, True)
    # a float array holding exact integers keeps the integral bit
    assert value_interval(jnp.zeros((3,), jnp.float32)).integral


# ------------------------------------------------- pass / fixture matrix ----
def test_purity_pass_flags_callbacks():
    report = Report()
    purity_pass(FX.bad_purity(), "fx", report)
    keys = " ".join(_keys(report))
    assert report.findings
    assert "callback" in keys or "debug" in keys


def test_dtype_pass_flags_float64():
    report = Report()
    dtype_pass(FX.bad_dtype(), "fx", report)
    assert report.findings
    assert any("float64" in f.message for f in report.findings)


def test_overflow_pass_flags_carry_at_horizon():
    closed, pairs, ivals, horizon = FX.bad_overflow_carry()
    report = Report()
    overflow_pass(closed, "fx", report, ivals, pairs, horizon)
    assert any(f.slug == "carry:counter" for f in report.findings)
    # ... and the same program is fine at a horizon it can survive
    ok = Report()
    overflow_pass(closed, "fx", ok, ivals, pairs, 100)
    assert not any(f.slug == "carry:counter" for f in ok.findings)


def test_overflow_pass_flags_in_scan_wrap():
    closed, pairs, ivals, horizon = FX.bad_overflow_scan()
    report = Report()
    overflow_pass(closed, "fx", report, ivals, pairs, horizon)
    assert any("scan-carry" in f.slug for f in report.findings)


def test_overflow_pass_flags_f32_precision_carry():
    closed, pairs, ivals, horizon = FX.bad_overflow_f32()
    report = Report()
    overflow_pass(closed, "fx", report, ivals, pairs, horizon)
    assert any("precision" in f.slug for f in report.findings)


def test_overflow_pass_ignores_transient_carry_jump():
    """A carry that jumps once and then holds (tier -1 -> 1) must not be
    extrapolated as a per-tick growth rate: the two-phase widening sees
    zero growth between iteration one and the union re-evaluation."""
    def tick(tier, hot):
        new = jnp.where(hot > 0, jnp.int8(1), tier)
        return new, new.sum()

    closed = jax.make_jaxpr(tick)(jnp.full((8,), -1, jnp.int8),
                                  jnp.zeros((8,), jnp.int32))
    report = Report()
    overflow_pass(closed, "fx", report, [Interval(-1, 1, True),
                                         Interval(0, 5, True)],
                  [(0, 0, "tier")], 100_000)
    assert not report.findings


def test_constancy_checker_and_diff():
    sig = assert_jaxpr_constant(FX.good_constancy_build, (2, 5))
    assert isinstance(sig, JaxprSignature) and sig.n_eqns > 0
    with pytest.raises(AssertionError) as ei:
        assert_jaxpr_constant(FX.bad_constancy_build, (2, 5), label="bad")
    assert "[bad]" in str(ei.value) and "eqn count" in str(ei.value)
    ok, _base, diff = check_constant(FX.bad_constancy_build, (2, 5))
    assert not ok and diff


def test_signature_helpers_agree():
    def f(x):
        return (x * 2).sum()
    x = jnp.zeros((4,), jnp.float32)
    assert jaxpr_signature(f, x) == signature_of(jax.make_jaxpr(f)(x))


def test_donation_pass_good_and_bad():
    fn, args, donate = FX.bad_donation()
    bad = Report()
    donation_pass(fn, args, donate, "fx", bad)
    assert any("unmatched" in f.slug for f in bad.findings)

    fn, args, donate = FX.good_donation()
    good = Report()
    donation_pass(fn, args, donate, "fx", good)
    assert not good.findings


def test_clean_tick_is_silent():
    closed, pairs, ivals, horizon = FX.clean_tick()
    report = Report()
    purity_pass(closed, "clean", report)
    dtype_pass(closed, "clean", report, carry_pairs=pairs)
    overflow_pass(closed, "clean", report, ivals, pairs, horizon)
    assert report.findings == []


# ----------------------------------------------------------------- lint ----
def test_lint_tenant_loop():
    fs = LI.lint_source(FX.BAD_LINT_TENANT_LOOP, "fx", in_core=True)
    assert any(f.slug.startswith("tenant-loop:") for f in fs)
    # outside core/ the unroll rule does not apply
    assert not LI.lint_source(FX.BAD_LINT_TENANT_LOOP, "fx", in_core=False)


def test_lint_np_in_graph():
    fs = LI.lint_source(FX.BAD_LINT_NP_IN_GRAPH, "fx", in_core=False)
    assert any(f.slug.startswith("np-in-graph:") for f in fs)


def test_lint_seam_defaults_builders_only():
    fs = LI.lint_source(FX.BAD_LINT_SEAM_DEFAULT, "fx", in_core=True)
    assert {f.slug for f in fs} == {"seam-default:make_tick.detector",
                                    "seam-default:make_tick.attrib"}
    # the seam contract binds builders, not runner flags
    assert not LI.lint_source(
        "def run_fleet(cfg, detect=True):\n    return cfg\n", "fx",
        in_core=True)


def test_lint_clean_source_silent():
    assert LI.lint_source(FX.CLEAN_LINT, "fx", in_core=True) == []


# ----------------------------------------------------- baseline ratchet ----
def test_baseline_ratchet(tmp_path):
    rep = Report()
    rep.add(Finding("lint", "t", "a", "m"))
    rep.add(Finding("lint", "t", "b", "m"))
    path = str(tmp_path / "baseline.json")
    write_baseline(rep, path, reasons={"lint:t:a": "known"})
    data = json.load(open(path))
    assert data["accepted"] == ["lint:t:a", "lint:t:b"]
    assert data["reasons"]["lint:t:a"] == "known"

    nxt = Report()
    nxt.add(Finding("lint", "t", "a", "m"))
    nxt.add(Finding("lint", "t", "c", "m"))
    assert [f.key for f in nxt.new_vs(data["accepted"])] == ["lint:t:c"]
    assert nxt.stale_vs(data["accepted"]) == ["lint:t:b"]


# -------------------------------------------------------- CLI gate codes ----
@pytest.mark.parametrize("fixture", ["purity", "dtype", "overflow",
                                     "constancy", "donation", "lint"])
def test_cli_gate_fails_each_bad_fixture(fixture, capsys):
    assert analysis_main(["--fixture", fixture, "--gate"]) == 1
    assert "GATE" in capsys.readouterr().err


def test_cli_gate_passes_clean_fixture(capsys):
    assert analysis_main(["--fixture", "clean", "--gate"]) == 0
    assert "0 findings" in capsys.readouterr().out


# ------------------------------------- fleet counter ledger (the fix) ----
def test_counter_ledger_exact_across_int32_wrap():
    """The overflow-forcing regression: an in-graph int32 counter pushed
    past INT32_MAX wraps negative on device; the chunk-boundary ledger
    still reports the exact int64 cumulative count."""
    from repro.obs.fleet import CounterLedger

    c = np.array([INT32_MAX - 5, 100], np.int32)
    ledger = CounterLedger({"counters": c})
    # two chunks advance the counter by 7 and 2**30 — the first wraps
    steps = [7, 2 ** 30]
    expect = np.zeros(2, np.int64)
    for s in steps:
        with np.errstate(over="ignore"):
            c = (c + np.int32(s)).astype(np.int32)   # device wraps silently
        expect += s
        ledger.absorb({"counters": c})
    assert c[0] < 0                                   # really wrapped
    assert (ledger.total["counters"] == expect).all()


def test_fleet_chunk_migration_carry_is_int32():
    """The chunk program accumulates integer migration counts in int32, not
    float32 (float32 silently drops units past 2^24 — the regression the
    overflow pass's carry-precision rule exists to catch)."""
    from repro.analysis.targets import fleet_chunk_target

    t = fleet_chunk_target(chunk=4, T=2, L=16, S=4, H=2, k_max=4)
    # the chunk's trailing outputs are the (lat, thr, mig) accumulators
    lat_av, thr_av, mig_av = t.closed.out_avals[-3:]
    assert lat_av.dtype == jnp.float32 and thr_av.dtype == jnp.float32
    assert mig_av.dtype == jnp.int32
    # and the float32 alternative demonstrably loses counts
    acc = np.float32(F32_EXACT)
    assert acc + np.float32(1.0) == acc


def test_rollout_ledger_matches_device_counters_short_horizon():
    """Below the wrap horizon the ledger and the raw device counters must
    agree exactly — widening changes nothing until a wrap happens."""
    from repro.core.workloads import (ChurnSlot, build_churn_schedule,
                                      web_like)
    from repro.obs.fleet import fleet_rollout, stack_schedules

    from repro.configs.base import TieringConfig

    cfg = TieringConfig(n_tenants=3, n_fast_pages=24, n_slow_pages=24,
                        lower_protection=(2, 2, 2), upper_bound=(12, 12, 12))
    sched = build_churn_schedule(
        [ChurnSlot(web_like(f), [(0, 48)]) for f in (10, 6, 8)], 48)
    want, rates = stack_schedules([sched, sched])
    roll = fleet_rollout(cfg, want, rates, 60, chunk=16, k_max=16)

    led = roll.counters()
    dev = roll.final_state.counters
    for name in led._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(led, name)),
            np.asarray(getattr(dev, name)).astype(np.int64), err_msg=name)
    assert np.asarray(led.promotions).dtype == np.int64
    assert roll.attribution_conserved()


def test_rollout_ledger_chunk_invariant():
    """Ledger totals are a pure function of the horizon, not the chunking
    (absorb at every boundary telescopes)."""
    from repro.core.workloads import (ChurnSlot, build_churn_schedule,
                                      web_like)
    from repro.obs.fleet import fleet_rollout, stack_schedules

    from repro.configs.base import TieringConfig

    cfg = TieringConfig(n_tenants=2, n_fast_pages=16, n_slow_pages=16,
                        lower_protection=(2, 2), upper_bound=(8, 8))
    sched = build_churn_schedule(
        [ChurnSlot(web_like(f), [(0, 64)]) for f in (8, 6)], 64)
    want, rates = stack_schedules([sched, sched])
    rolls = [fleet_rollout(cfg, want, rates, 64, chunk=c, k_max=16)
             for c in (8, 64)]
    a, b = (r.counters() for r in rolls)
    for name in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=name)
    np.testing.assert_array_equal(rolls[0].attribution_components(),
                                  rolls[1].attribution_components())
