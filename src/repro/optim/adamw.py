"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule,
and optional int8 block-quantized gradient compression (simulating a
compressed DP all-reduce payload — the distributed-optimization trick).

No optax dependency: optimizer state is a plain pytree {m, v, step}.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    m: object      # pytree like params (f32)
    v: object      # pytree like params (f32)
    step: jax.Array


def init_opt_state(params) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=zeros,
                    v=jax.tree_util.tree_map(jnp.copy, zeros)
                    if not isinstance(zeros, jax.ShapeDtypeStruct) else zeros,
                    step=jnp.zeros((), jnp.int32))


def abstract_opt_state(abstract_params) -> OptState:
    f32 = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params)
    return OptState(m=f32, v=f32, step=jax.ShapeDtypeStruct((), jnp.int32))


def lr_schedule(step: jax.Array, tc: TrainConfig) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(tc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step.astype(jnp.float32) - tc.warmup_steps)
                    / max(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tc.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), grads), norm


def compress_grads_int8(grads, block: int = 256):
    """Simulated compressed DP all-reduce: block-wise int8 quantize-dequantize.

    On a real deployment the int8 payload (+ per-block scales) is what crosses
    the DCN/ICI links between pods (4x fewer bytes on the gradient
    all-reduce); here we apply the quantization error so training sees the
    exact numerics of the compressed collective.
    """
    def q(g):
        g32 = g.astype(jnp.float32)
        flat = g32.reshape(-1)
        n = flat.shape[0]
        pad = (-n) % block
        flat = jnp.pad(flat, (0, pad)).reshape(-1, block)
        scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
        qv = jnp.clip(jnp.round(flat / jnp.maximum(scale, 1e-12)), -127, 127)
        deq = (qv * scale).reshape(-1)[:n].reshape(g.shape)
        return deq
    return jax.tree_util.tree_map(q, grads)


def adamw_update(params, grads, opt: OptState, tc: TrainConfig
                 ) -> Tuple[object, OptState, dict]:
    if tc.grad_compression:
        grads = compress_grads_int8(grads)
    grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    step = opt.step + 1
    lr = lr_schedule(step, tc)
    b1, b2 = tc.beta1, tc.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mhat / (jnp.sqrt(vhat) + tc.eps)
                          + tc.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt.m)
    flat_v = treedef.flatten_up_to(opt.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(m=new_m, v=new_v, step=step), metrics
