"""Observability & fleet telemetry — the paper's third pillar (§IV-C).

Everything under ``obs/`` splits into two halves:

  in-graph   — ``stats.TierStats`` (per-tenant tiering_stat-style metrics)
               and ``trace.MigrationRing`` (fixed-capacity migration event
               buffer). Both are pytrees of jnp arrays updated inside the
               compiled tick / serve step, so collection costs no host
               round-trips and works under jit, scan and vmap.
  host-side  — ``stats.stats_summary`` / ``trace.decode_ring`` decoders,
               ``pathology`` offline detectors for the paper's failure
               modes, and the ``fleet`` harness that vmaps the engine
               across simulated hosts and rolls telemetry up fleet-wide.
"""
from repro.obs.stats import (TierStats, below_protection, init_stats,
                             record_fast_entries, record_fast_exits,
                             residency_bucket, stats_export, stats_summary,
                             update_tick)
from repro.obs.trace import (DIR_DEMOTE, DIR_PROMOTE, MigrationRing,
                             decode_ring, init_ring, ring_record)

__all__ = [
    "TierStats", "below_protection", "init_stats", "record_fast_entries",
    "record_fast_exits", "residency_bucket", "stats_export", "stats_summary",
    "update_tick",
    "MigrationRing", "init_ring", "ring_record", "decode_ring",
    "DIR_PROMOTE", "DIR_DEMOTE",
]
