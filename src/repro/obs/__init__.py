"""Observability & fleet telemetry — the paper's third pillar (§IV-C).

Everything under ``obs/`` splits into two halves:

  in-graph   — ``stats.TierStats`` (per-tenant tiering_stat-style metrics),
               ``trace.MigrationRing`` (fixed-capacity migration event
               buffer) and ``streaming.DetectorState`` (the four pathology
               detectors as windowed scan state: per-tenant flag counters
               and first-flag ticks at any horizon, O(T) memory). All are
               pytrees of jnp arrays updated inside the compiled tick /
               serve step, so collection costs no host round-trips and
               works under jit, scan and vmap.
  host-side  — ``stats.stats_summary`` / ``trace.decode_ring`` decoders,
               ``pathology`` offline detectors (the differential reference
               for the streaming ones), the ``fleet`` harness that vmaps
               the engine across simulated hosts and rolls telemetry up
               fleet-wide, and the ``export``/``dashboard`` surfaces:
               Chrome-trace/Perfetto JSON of the migration rings,
               Prometheus text exposition of fleet counters, and a
               markdown fleet dashboard CLI.
"""
from repro.obs.export import (chrome_trace, fleet_exposition,
                              rollout_exposition, validate_chrome_trace,
                              validate_exposition, write_chrome_trace)
from repro.obs.stats import (TierStats, below_protection, hist_percentile,
                             hist_percentile_j, init_stats,
                             record_fast_entries, record_fast_exits,
                             residency_bucket, stats_export, stats_summary,
                             update_tick)
from repro.obs.streaming import (KINDS, DetectorSignals, DetectorSpec,
                                 DetectorState, flag_summary, init_detector,
                                 make_detector, run_detector,
                                 streaming_pathologies, update_detector)
from repro.obs.trace import (DIR_DEMOTE, DIR_PROMOTE, MigrationRing,
                             decode_ring, init_ring, ring_record)

__all__ = [
    "TierStats", "below_protection", "init_stats", "record_fast_entries",
    "record_fast_exits", "residency_bucket", "stats_export", "stats_summary",
    "update_tick", "hist_percentile", "hist_percentile_j",
    "MigrationRing", "init_ring", "ring_record", "decode_ring",
    "DIR_PROMOTE", "DIR_DEMOTE",
    "KINDS", "DetectorSpec", "DetectorState", "DetectorSignals",
    "make_detector", "init_detector", "update_detector", "run_detector",
    "streaming_pathologies", "flag_summary",
    "chrome_trace", "write_chrome_trace", "validate_chrome_trace",
    "fleet_exposition", "rollout_exposition", "validate_exposition",
]
