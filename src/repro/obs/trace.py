"""Fixed-capacity in-graph migration event ring buffer.

Every promotion/demotion executed by the engine tick or the KV tiering step
appends a (tick, tenant, page, direction, hotness-at-move) record. Records
are packed into ONE [capacity, 5] int32 buffer (hotness bit-cast), so an
append is a single scatter over the source lanes instead of five — scatter
is the dominant cost at L=256k pages, and the five parallel-array scatters
of the original layout were ~40% of the whole engine tick. Recording is
branch-free (``mode="drop"`` discards unselected lanes) and works under
jit, scan and vmap; the newest ``capacity`` events survive, older ones are
overwritten — exactly a kernel trace ring. ``decode_ring`` converts the
on-device ring to structured numpy records host-side.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

DIR_PROMOTE = 0
DIR_DEMOTE = 1

# packed column order in MigrationRing.data
COL_TICK, COL_TENANT, COL_PAGE, COL_DIR, COL_HOT = range(5)

EVENT_DTYPE = np.dtype([("tick", np.int32), ("tenant", np.int32),
                        ("page", np.int32), ("direction", np.int32),
                        ("hotness", np.float32)])


class MigrationRing(NamedTuple):
    data: jax.Array       # [C, 5] int32: tick, tenant, page, direction,
    #                       hotness (f32 bit-cast); tick = -1 = never written
    head: jax.Array       # scalar int32: total events ever recorded


def init_ring(capacity: int) -> MigrationRing:
    data = jnp.zeros((capacity, 5), jnp.int32).at[:, COL_TICK].set(-1)
    return MigrationRing(data=data, head=jnp.zeros((), jnp.int32))


def ring_record(ring: MigrationRing, mask: jax.Array, pages: jax.Array,
                tenants: jax.Array, hotness: jax.Array, direction: int,
                t: jax.Array) -> MigrationRing:
    """Append all events where ``mask`` is set. mask/pages/tenants/hotness
    share one shape (any rank); events land oldest-first at head..head+n."""
    C = ring.data.shape[0]
    m = mask.reshape(-1)
    offs = jnp.cumsum(m.astype(jnp.int32)) - 1          # slot among selected
    total = offs[-1] + 1 if m.shape[0] else jnp.zeros((), jnp.int32)
    # if one call selects more than C events, keep only the newest C — the
    # window of C consecutive offsets keeps scatter indices unique (a
    # duplicate-index set has an unspecified winner in XLA)
    keep = m & (offs >= total - C)
    idx = jnp.where(keep, (ring.head + offs) % C, C)    # C = OOB -> dropped
    rows = jnp.stack([
        jnp.broadcast_to(t, m.shape).astype(jnp.int32),
        tenants.reshape(-1).astype(jnp.int32),
        pages.reshape(-1).astype(jnp.int32),
        jnp.full(m.shape, direction, jnp.int32),
        jax.lax.bitcast_convert_type(
            hotness.reshape(-1).astype(jnp.float32), jnp.int32),
    ], axis=-1)                                         # [L, 5]
    return MigrationRing(
        data=ring.data.at[idx].set(rows, mode="drop"),
        head=ring.head + m.sum())


def decode_ring(ring: MigrationRing) -> tuple[np.ndarray, int]:
    """Host-side decode: (events, n_dropped). ``events`` is a structured
    numpy array (EVENT_DTYPE) ordered oldest -> newest; ``n_dropped`` is how
    many older events were overwritten by wraparound."""
    data = np.asarray(ring.data)
    C = data.shape[0]
    head = int(ring.head)
    n = min(head, C)
    out = np.empty(n, EVENT_DTYPE)
    if n == 0:
        return out, 0
    # oldest surviving event sits at head % C when the ring has wrapped
    start = head % C if head > C else 0
    order = (start + np.arange(n)) % C
    out["tick"] = data[order, COL_TICK]
    out["tenant"] = data[order, COL_TENANT]
    out["page"] = data[order, COL_PAGE]
    out["direction"] = data[order, COL_DIR]
    out["hotness"] = data[order, COL_HOT].view(np.float32)
    return out, max(head - C, 0)


def ring_summary(ring: MigrationRing) -> dict:
    """Wraparound accounting for a ring (scalar head) or a fleet-batched
    ring (head [...]): how many events were ever recorded, how many the
    fixed capacity retains, and how many wrap dropped. Exported as the
    ``ring_events_total`` / ``ring_dropped_total`` Prometheus counters so
    operators can tell a quiet host from a ring that silently wrapped."""
    C = ring.data.shape[-2]
    head = np.asarray(ring.head, np.int64)
    return {
        "capacity": C,
        "recorded": head if head.ndim else int(head),
        "retained": np.minimum(head, C) if head.ndim else int(min(int(head), C)),
        "dropped": np.maximum(head - C, 0) if head.ndim else int(max(int(head) - C, 0)),
    }
