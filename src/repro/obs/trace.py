"""Fixed-capacity in-graph migration event ring buffer.

Every promotion/demotion executed by the engine tick or the KV tiering step
appends a (tick, tenant, page, direction, hotness-at-move) record. The ring
is a pytree of parallel arrays updated with a cumsum/scatter (``mode="drop"``
discards unselected lanes), so recording is branch-free and works under jit,
scan and vmap; the newest ``capacity`` events survive, older ones are
overwritten — exactly a kernel trace ring. ``decode_ring`` converts the
on-device ring to structured numpy records host-side.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

DIR_PROMOTE = 0
DIR_DEMOTE = 1

EVENT_DTYPE = np.dtype([("tick", np.int32), ("tenant", np.int32),
                        ("page", np.int32), ("direction", np.int32),
                        ("hotness", np.float32)])


class MigrationRing(NamedTuple):
    tick: jax.Array       # [C] int32, -1 = never written
    tenant: jax.Array     # [C] int32
    page: jax.Array       # [C] int32
    direction: jax.Array  # [C] int32 (DIR_PROMOTE / DIR_DEMOTE)
    hotness: jax.Array    # [C] f32 page hotness at the move
    head: jax.Array       # scalar int32: total events ever recorded


def init_ring(capacity: int) -> MigrationRing:
    return MigrationRing(
        tick=jnp.full((capacity,), -1, jnp.int32),
        tenant=jnp.zeros((capacity,), jnp.int32),
        page=jnp.zeros((capacity,), jnp.int32),
        direction=jnp.zeros((capacity,), jnp.int32),
        hotness=jnp.zeros((capacity,), jnp.float32),
        head=jnp.zeros((), jnp.int32))


def ring_record(ring: MigrationRing, mask: jax.Array, pages: jax.Array,
                tenants: jax.Array, hotness: jax.Array, direction: int,
                t: jax.Array) -> MigrationRing:
    """Append all events where ``mask`` is set. mask/pages/tenants/hotness
    share one shape (any rank); events land oldest-first at head..head+n."""
    C = ring.tick.shape[0]
    m = mask.reshape(-1)
    offs = jnp.cumsum(m.astype(jnp.int32)) - 1          # slot among selected
    total = offs[-1] + 1 if m.shape[0] else jnp.zeros((), jnp.int32)
    # if one call selects more than C events, keep only the newest C — the
    # window of C consecutive offsets keeps scatter indices unique (a
    # duplicate-index set has an unspecified winner in XLA)
    keep = m & (offs >= total - C)
    idx = jnp.where(keep, (ring.head + offs) % C, C)    # C = OOB -> dropped
    tickv = jnp.broadcast_to(t, m.shape).astype(jnp.int32)
    dirv = jnp.full(m.shape, direction, jnp.int32)
    return MigrationRing(
        tick=ring.tick.at[idx].set(tickv, mode="drop"),
        tenant=ring.tenant.at[idx].set(
            tenants.reshape(-1).astype(jnp.int32), mode="drop"),
        page=ring.page.at[idx].set(
            pages.reshape(-1).astype(jnp.int32), mode="drop"),
        direction=ring.direction.at[idx].set(dirv, mode="drop"),
        hotness=ring.hotness.at[idx].set(
            hotness.reshape(-1).astype(jnp.float32), mode="drop"),
        head=ring.head + m.sum())


def decode_ring(ring: MigrationRing) -> tuple[np.ndarray, int]:
    """Host-side decode: (events, n_dropped). ``events`` is a structured
    numpy array (EVENT_DTYPE) ordered oldest -> newest; ``n_dropped`` is how
    many older events were overwritten by wraparound."""
    C = int(np.asarray(ring.tick).shape[0])
    head = int(ring.head)
    n = min(head, C)
    out = np.empty(n, EVENT_DTYPE)
    if n == 0:
        return out, 0
    # oldest surviving event sits at head % C when the ring has wrapped
    start = head % C if head > C else 0
    order = (start + np.arange(n)) % C
    out["tick"] = np.asarray(ring.tick)[order]
    out["tenant"] = np.asarray(ring.tenant)[order]
    out["page"] = np.asarray(ring.page)[order]
    out["direction"] = np.asarray(ring.direction)[order]
    out["hotness"] = np.asarray(ring.hotness)[order]
    return out, max(head - C, 0)
