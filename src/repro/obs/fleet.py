"""Fleet telemetry harness: the unified tick (core/tick.py) stacked across
N simulated hosts.

This is the ROADMAP's fleet-scale evaluation vehicle, rebuilt on the
unified tick core so a fleet is a batch of *heterogeneous* hosts — static
rosters and churned rosters side by side under ONE ``vmap`` (every host
runs the dynamic-ownership provider; a static host is simply the
degenerate schedule with constant ``want``). Three execution surfaces:

  ``run_fleet``        — the original static-layout fleet (hosts share one
                         owner vector; heterogeneity from workload data).
                         Kept for the obs acceptance property and as the
                         cheapest path when no host churns.
  ``run_mixed_fleet``  — heterogeneous static+churn hosts under one vmap,
                         full per-tick telemetry + pathology detection.
  ``fleet_rollout``    — the long-horizon engine: chunked ``lax.scan``
                         rollouts with donated carries (no host round-trips
                         inside a chunk, O(chunk) not O(horizon) output
                         memory), schedule archetypes gathered in-graph
                         (hosts sharing a schedule cost one copy), tiled
                         periodically so a 10k-tick horizon streams through
                         a fixed-size schedule, and sharded across devices
                         via ``pmap`` when more than one is available.

In-graph obs state (TierStats + migration ring) is collected per host with
zero extra tracing work — ``vmap`` batches the scatter/adds along the host
axis. Host-side, telemetry is decoded per host and rolled up fleet-wide:
latency percentiles, migration rates, pathology counts from
``obs.pathology``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TieringConfig
from repro.core.churn import ChurnSchedule, make_churn_tick
from repro.core.engine import make_tick
from repro.core.simulator import tenant_activity
from repro.core.state import init_state, stack_states
from repro.core.workloads import (ChurnSlot, TenantWorkload, as_churn_slots,
                                  build_churn_schedule, build_trace,
                                  cache_like, ci_like, microbenchmark,
                                  spark_like, thrasher, web_like)
from repro.obs.attribution import (COMPONENTS, AttributionSpec,
                                   attribution_conserved, fast_hit_fraction,
                                   make_attribution)
from repro.obs.pathology import Pathology, count_by_kind, detect_all
from repro.obs.sketch import sketch_merge, sketch_percentiles
from repro.obs.stats import stats_summary
from repro.obs.streaming import (KINDS, DetectorSpec, make_detector,
                                 streaming_pathologies)
from repro.obs.trace import decode_ring

# stable-pattern menu for clean hosts (hot sets that mostly fit fast tier)
MIX_MENU = ("web", "cache", "micro", "ci", "spark")


def heterogeneous_mixes(footprints: Sequence[int], n_hosts: int,
                        seed: int = 0, menu: Sequence[str] = MIX_MENU,
                        stagger: int = 8) -> List[List[TenantWorkload]]:
    """One tenant mix per host. Footprints are fixed per tenant *slot* (every
    host shares the static page-ownership layout ``run_fleet`` needs); the
    workload pattern and arrival of each slot vary per host."""
    rng = np.random.default_rng(seed)
    mk = {
        "web": lambda f, a: web_like(f, arrival=a),
        "cache": lambda f, a: cache_like(f, arrival=a),
        "micro": lambda f, a: microbenchmark(f, arrival=a),
        "ci": lambda f, a: ci_like(f, arrival=a),
        "spark": lambda f, a: spark_like(f, arrival=a),
    }
    mixes = []
    for _ in range(n_hosts):
        mix = []
        for f in footprints:
            kind = menu[int(rng.integers(len(menu)))]
            arrival = int(rng.integers(0, stagger + 1))
            mix.append(mk[kind](f, arrival))
        mixes.append(mix)
    return mixes


def inject_noisy_neighbor(mixes: List[List[TenantWorkload]], tenant: int,
                          fast_share: int,
                          hosts: Optional[Sequence[int]] = None,
                          arrival: Optional[int] = None
                          ) -> List[List[TenantWorkload]]:
    """Replace ``tenant``'s workload with a thrasher (promotion-hot pages
    never re-accessed before demotion — the §V-B5 noisy neighbor) on the
    given hosts (default: all). Footprint is preserved so the fleet keeps a
    common ownership layout. A late ``arrival`` gives detectors a clean
    baseline window before the noise starts."""
    hosts = set(range(len(mixes))) if hosts is None else set(hosts)
    out = []
    for h, mix in enumerate(mixes):
        mix = list(mix)
        if h in hosts:
            a = mix[tenant].arrival if arrival is None else arrival
            mix[tenant] = thrasher(mix[tenant].footprint, fast_share,
                                   arrival=a)
        out.append(mix)
    return out


@dataclass
class FleetResult:
    mode: str
    n_hosts: int
    # [H, ticks, T] each
    fast_usage: np.ndarray
    slow_usage: np.ndarray
    promotions: np.ndarray
    demotions: np.ndarray
    throughput: np.ndarray
    latency: np.ndarray
    thrash_events: np.ndarray
    attempted: np.ndarray
    lower_protection: tuple
    # per-host decoded telemetry
    stats: List[dict] = field(default_factory=list)   # stats_summary per host
    pathologies: List[List[Pathology]] = field(default_factory=list)
    # [H, ticks, T] bool per-host tenant roster (tenant has live pages);
    # detectors and roll-ups use it to tolerate mid-window departures
    active: Optional[np.ndarray] = None
    _final_state: object = None

    def steady_window(self, frac: float = 0.5) -> slice:
        n = self.latency.shape[1]
        return slice(int(n * (1 - frac)), n)

    def host_migrations(self, host: int):
        """Decode one host's migration ring -> (events, n_dropped)."""
        ring = jax.tree_util.tree_map(lambda x: x[host],
                                      self._final_state.ring)
        return decode_ring(ring)

    def pathology_counts(self) -> Dict[str, int]:
        """Fleet-wide counts by kind, keys sorted (stable across runs)."""
        out: Dict[str, int] = {}
        for ps in self.pathologies:
            for k, v in count_by_kind(ps).items():
                out[k] = out.get(k, 0) + v
        return dict(sorted(out.items()))

    def tenants_flagged(self, kind: Optional[str] = None
                        ) -> List[Tuple[int, int]]:
        """Sorted unique (host, tenant) pairs flagged, optionally for one
        pathology kind — deterministic order, safe for golden tests."""
        out = set()
        for h, ps in enumerate(self.pathologies):
            for p in ps:
                if kind is None or p.kind == kind:
                    out.add((h, p.tenant))
        return sorted(out)

    def rollup(self) -> dict:
        """Fleet-wide operator summary. Latency/throughput aggregates cover
        only resident tenant-ticks (``active``) so hosts with mid-window
        departures don't dilute percentiles with the idle-slot constant."""
        w = self.steady_window()
        lat = self.latency[:, w]
        mig = self.promotions[:, w] + self.demotions[:, w]
        hosts_bad = sum(1 for ps in self.pathologies if ps)
        if self.active is not None:
            act = np.asarray(self.active[:, w], bool)
            act = act if act.any() else np.ones_like(act)
            lat_vals = lat[act]
            thru_vals = self.throughput[:, w][act]
            worst_host = max(
                float(np.percentile(lat[h][act[h]], 99))
                for h in range(self.n_hosts) if act[h].any())
        else:
            lat_vals, thru_vals = lat, self.throughput[:, w]
            worst_host = float(np.percentile(lat, 99, axis=(1, 2)).max())
        return {
            "hosts": self.n_hosts,
            "ticks": self.latency.shape[1],
            "tenants": self.latency.shape[2],
            "latency_p50": float(np.percentile(lat_vals, 50)),
            "latency_p99": float(np.percentile(lat_vals, 99)),
            "latency_worst_host_p99": worst_host,
            "throughput_mean": float(thru_vals.mean()),
            "migrations_per_tick": float(mig.sum(axis=2).mean()),
            "thrash_total": int(self.thrash_events[:, -1].sum()),
            "pathology_counts": self.pathology_counts(),
            "hosts_with_pathology": hosts_bad,
        }


def _fleet_result(mode: str, cfg: TieringConfig, finals, outs,
                  active: np.ndarray, detect: bool) -> FleetResult:
    """One FleetResult builder shared by the static and mixed fleets."""
    H = active.shape[0]
    res = FleetResult(
        mode=mode, n_hosts=H,
        fast_usage=np.asarray(outs.fast_usage),
        slow_usage=np.asarray(outs.slow_usage),
        promotions=np.asarray(outs.promotions),
        demotions=np.asarray(outs.demotions),
        throughput=np.asarray(outs.throughput),
        latency=np.asarray(outs.latency),
        thrash_events=np.asarray(outs.thrash_events),
        attempted=np.asarray(outs.attempted_promotions),
        lower_protection=tuple(cfg.lower_protection[:cfg.n_tenants]),
        active=active,
        _final_state=finals)
    res.stats = [stats_summary(jax.tree_util.tree_map(lambda x: x[h],
                                                      finals.stats))
                 for h in range(H)]
    if detect:
        res.pathologies = [
            detect_all(res.fast_usage[h], res.slow_usage[h],
                       res.promotions[h], res.demotions[h], res.latency[h],
                       res.thrash_events[h], attempted=res.attempted[h],
                       lower_protection=res.lower_protection,
                       active=res.active[h])
            for h in range(H)]
    return res


def run_fleet(cfg: TieringConfig, host_mixes: List[List[TenantWorkload]],
              ticks: int, mode: str = "equilibria", k_max: int = 64,
              detect: bool = True) -> FleetResult:
    """Run every host's trace through one vmapped static-provider tick.

    All hosts must share the tenant footprint layout (same owner vector);
    ``heterogeneous_mixes`` guarantees that by construction. For fleets
    mixing static and churned hosts, use ``run_mixed_fleet``.
    """
    traces = [build_trace(mix, ticks) for mix in host_mixes]
    owner = traces[0][0]
    for o, _, _ in traces[1:]:
        if not np.array_equal(o, owner):
            raise ValueError("all hosts must share the footprint layout "
                             "(same per-tenant page counts)")
    cfg = cfg.with_(n_tenants=len(host_mixes[0]))
    H = len(host_mixes)
    accesses = jnp.asarray(np.stack([t[1] for t in traces]), jnp.float32)
    alive = jnp.asarray(np.stack([t[2] for t in traces]), bool)

    tick = make_tick(cfg, owner, mode, k_max)
    states = stack_states(init_state(cfg, owner.shape[0], owner=owner), H)

    @jax.jit
    @jax.vmap
    def run_host(state, acc, alv):
        return jax.lax.scan(tick, state, (acc, alv))

    finals, outs = run_host(states, accesses, alive)
    active = np.stack([tenant_activity(owner, np.asarray(tr[2]),
                                       cfg.n_tenants) for tr in traces])
    return _fleet_result(mode, cfg, finals, outs, active, detect)


# --------------------------------------------------------- mixed fleets ----
def stack_schedules(schedules: List[ChurnSchedule]
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Stack per-host churn schedules into fleet arrays, padding every
    host's rates to the fleet-wide max slot footprint.

    Returns (want [H, ticks, T] int32, rates [H, ticks, T, S] f32). Hosts
    must share slot count and horizon; footprints may differ freely (the
    pad rows are dead weight only for hosts with smaller slots).
    """
    ticks, T = schedules[0].want.shape
    for s in schedules[1:]:
        if s.want.shape != (ticks, T):
            raise ValueError("all hosts must share slot count and horizon; "
                             f"got {s.want.shape} vs {(ticks, T)}")
    S = max(s.rates.shape[2] for s in schedules)
    H = len(schedules)
    want = np.stack([s.want for s in schedules]).astype(np.int32)
    rates = np.zeros((H, ticks, T, S), np.float32)
    for h, s in enumerate(schedules):
        rates[h, :, :, :s.rates.shape[2]] = s.rates
    return want, rates


def mixed_fleet_hosts(static_mixes: List[List[TenantWorkload]],
                      churn_hosts: List[List[ChurnSlot]],
                      ticks: int) -> List[List[ChurnSlot]]:
    """Normalize a heterogeneous fleet to churn-slot rosters: static hosts
    become single-episode slots (the degenerate schedule)."""
    return [as_churn_slots(mix, ticks) for mix in static_mixes] + \
        [list(slots) for slots in churn_hosts]


def run_mixed_fleet(cfg: TieringConfig, hosts: List[List[ChurnSlot]],
                    ticks: int, mode: str = "equilibria", k_max: int = 64,
                    detect: bool = True,
                    n_pages: Optional[int] = None) -> FleetResult:
    """Heterogeneous fleet: static and churned hosts side by side under one
    vmap of the unified dynamic-ownership tick. ``hosts`` is one churn-slot
    roster per host (``mixed_fleet_hosts`` builds it from static mixes +
    churn rosters); every host needs the same slot count, nothing else.
    """
    T = len(hosts[0])
    for slots in hosts[1:]:
        if len(slots) != T:
            raise ValueError("all hosts must have the same slot count")
    cfg = cfg.with_(n_tenants=T)
    want, rates = stack_schedules(
        [build_churn_schedule(slots, ticks) for slots in hosts])
    H = want.shape[0]
    L = n_pages if n_pages is not None else \
        cfg.n_fast_pages + cfg.n_slow_pages
    tick = make_churn_tick(cfg, L, mode=mode, k_max=k_max)
    states = stack_states(init_state(cfg, L), H)

    @jax.jit
    @jax.vmap
    def run_host(state, r, w):
        return jax.lax.scan(tick, state, (r, w))

    finals, outs = run_host(states, jnp.asarray(rates, jnp.float32),
                            jnp.asarray(want, jnp.int32))
    return _fleet_result(mode, cfg, finals, outs, want > 0, detect)


# ----------------------------------------------- long-horizon rollouts ----
_WRAP32 = 1 << 32


class CounterLedger:
    """Wrap-safe host-side int64 widening of in-graph int32 counters.

    x64 is globally disabled, so the scan-carried cumulative counters
    (``Counters``, the attribution ledger) are int32 *in-graph* and wrap at
    fleet horizons (the overflow pass proves e.g. ``attempted_promotions``
    unsafe past ~2^31/L ticks). Rather than widening device state, the
    ledger promotes at the chunk boundary: counters are monotone mod 2^32,
    so ``(now - prev) mod 2^32`` is the *exact* per-chunk growth whenever a
    single chunk grows a counter by < 2^32 — true by construction (a chunk
    of C ticks grows any per-tenant counter by at most C * L). The int64
    running totals therefore stay exact at any horizon while the device
    carry stays int32.
    """

    def __init__(self, tree):
        self.prev = jax.tree_util.tree_map(
            lambda x: np.asarray(x).astype(np.int64), tree)
        self.total = jax.tree_util.tree_map(np.zeros_like, self.prev)

    def absorb(self, tree) -> None:
        now = jax.tree_util.tree_map(
            lambda x: np.asarray(x).astype(np.int64), tree)
        self.total = jax.tree_util.tree_map(
            lambda t, p, n: t + ((n - p) % _WRAP32),
            self.total, self.prev, now)
        self.prev = now


def make_fleet_chunk(vtick, want_j: jax.Array, rates_j: jax.Array,
                     period: int, n: int):
    """The chunk program: one ``lax.scan`` of ``n`` ticks over the vmapped
    tick, schedule columns gathered per host in-graph, per-tick outputs
    reduced to [H] running sums inside the scan.

    Module-level so the jaxpr auditor can trace it directly (purity /
    dtype / overflow / donation targets) and so its carries are visible in
    tests. The migration accumulator is deliberately **int32**: promotions
    and demotions are integer counts, and accumulating them in float32
    silently drops units past 2^24 (the overflow pass's carry-precision
    rule flags exactly that regression); the int32 carry is exact up to
    2^31 per chunk and is widened to int64 host-side (``CounterLedger`` /
    ``absorb``).
    """
    def chunk_fn(states, arch, t0):
        zero_f = jnp.zeros(arch.shape, jnp.float32)
        zero_i = jnp.zeros(arch.shape, jnp.int32)

        def body(carry, i):
            st, lat, thr, mig = carry
            tm = jnp.mod(t0 + i, period)
            w = jax.lax.dynamic_index_in_dim(want_j, tm, axis=1,
                                             keepdims=False)
            r = jax.lax.dynamic_index_in_dim(rates_j, tm, axis=1,
                                             keepdims=False)
            st, out = vtick(st, (r[arch], w[arch]))
            lat = lat + out.latency.mean(axis=-1)
            thr = thr + out.throughput.sum(axis=-1)
            mig = mig + (out.promotions + out.demotions).sum(axis=-1)
            return (st, lat, thr, mig), None

        (states, lat, thr, mig), _ = jax.lax.scan(
            body, (states, zero_f, zero_f, zero_i),
            jnp.arange(n, dtype=jnp.int32))
        return states, (lat, thr, mig)
    return chunk_fn


@dataclass
class RolloutSummary:
    """Chunked-rollout result: final fleet state plus streamed per-host
    reductions (full per-tick arrays are never materialized — output memory
    is O(1) in the horizon)."""
    n_hosts: int
    ticks: int
    chunk: int
    sharded: bool
    elapsed_s: float                 # wall time of the rollout loop
    latency_mean: np.ndarray         # [H] mean per-tick tenant-mean latency
    throughput_mean: np.ndarray      # [H] mean per-tick total throughput
    migrations_per_tick: np.ndarray  # [H]
    final_state: object = None       # batched TierState [H, ...]
    detector: Optional[DetectorSpec] = None
    attribution: Optional[AttributionSpec] = None
    # host-side int64 widening of the in-graph int32 cumulative counters
    # ({"counters": Counters, "att": {...}}), exact at any horizon
    ledger: Optional[CounterLedger] = None

    @property
    def host_ticks_per_s(self) -> float:
        return self.n_hosts * self.ticks / max(self.elapsed_s, 1e-9)

    def host_stats(self, host: int) -> dict:
        return stats_summary(jax.tree_util.tree_map(
            lambda x: x[host], self.final_state.stats))

    def counters(self):
        """Cumulative per-tenant counters [H, T]. With the chunk-boundary
        ledger (the default rollout path) these are int64 and exact even
        where the in-graph int32 carry wrapped."""
        if self.ledger is not None:
            return self.ledger.total["counters"]
        return jax.tree_util.tree_map(np.asarray, self.final_state.counters)

    def host_migrations(self, host: int):
        """Decode one host's migration ring -> (events, n_dropped)."""
        ring = jax.tree_util.tree_map(lambda x: x[host],
                                      self.final_state.ring)
        return decode_ring(ring)

    # ---- streaming pathology telemetry (obs/streaming.py) ----------------
    def host_pathologies(self, host: int) -> List[Pathology]:
        """One host's end-of-run pathologies from its streamed counters."""
        if self.detector is None:
            raise ValueError("rollout ran with detect=False")
        det = jax.tree_util.tree_map(lambda x: x[host], self.final_state.det)
        return streaming_pathologies(self.detector, det)

    def pathology_flag_ticks(self) -> np.ndarray:
        """[H, T, len(KINDS)] int32: ticks each running flag held."""
        return np.asarray(self.final_state.det.flag_ticks)

    def pathology_first_flag(self) -> np.ndarray:
        """[H, T, len(KINDS)] int32: first tick each flag held (-1 never)."""
        return np.asarray(self.final_state.det.first_flag)

    def pathology_counts(self) -> Dict[str, int]:
        """Fleet-wide end-of-run counts by kind, keys sorted."""
        out: Dict[str, int] = {}
        for h in range(self.n_hosts):
            for k, v in count_by_kind(self.host_pathologies(h)).items():
                out[k] = out.get(k, 0) + v
        return dict(sorted(out.items()))

    def tenants_flagged(self, kind: Optional[str] = None
                        ) -> List[Tuple[int, int]]:
        """Sorted unique (host, tenant) pairs flagged end-of-run."""
        out = set()
        for h in range(self.n_hosts):
            for p in self.host_pathologies(h):
                if kind is None or p.kind == kind:
                    out.add((h, p.tenant))
        return sorted(out)

    # ---- slowdown attribution ledger (obs/attribution.py) ----------------
    def _att(self):
        if self.attribution is None:
            raise ValueError("rollout ran with attrib=False")
        return self.final_state.attrib

    def _att_ledger(self) -> Optional[dict]:
        if self.ledger is not None and "att" in self.ledger.total:
            if self.attribution is None:
                raise ValueError("rollout ran with attrib=False")
            return self.ledger.total["att"]
        return None

    def attribution_components(self) -> np.ndarray:
        """[H, T, len(COMPONENTS)] int64 cumulative stall units by cause
        (ledger-widened: exact past int32 wrap on the default path)."""
        led = self._att_ledger()
        if led is not None:
            return led["comp"]
        return np.asarray(self._att().comp, np.int64)

    def attribution_totals(self) -> np.ndarray:
        """[H, T] int64 cumulative stall units (== components summed)."""
        led = self._att_ledger()
        if led is not None:
            return led["total"]
        return np.asarray(self._att().total, np.int64)

    def fast_hit_fraction(self) -> np.ndarray:
        """[H, T] fraction of access mass served from the fast tier."""
        return fast_hit_fraction(self._att())

    def stall_sketch(self) -> np.ndarray:
        """Fleet-merged per-tick stall-unit histogram ([SKETCH_BUCKETS])."""
        led = self._att_ledger()
        if led is not None:
            return sketch_merge(led["sketch"])
        return sketch_merge(self._att().sketch)

    def stall_percentiles(self, qs=(0.5, 0.95, 0.99)) -> np.ndarray:
        """Fleet-wide per-tick total-stall percentiles from the merged
        sketch — O(1) output memory at any horizon or fleet size."""
        return np.asarray(sketch_percentiles(self.stall_sketch(), qs))

    def attribution_conserved(self) -> bool:
        """Every host's ledger conserves: components sum to the total and
        the total matches the counter identity, bit-exact. On the default
        path the identity is checked on the int64-widened values, so it
        holds even past the in-graph int32 wrap point."""
        led = self._att_ledger()
        if led is not None:
            c = self.counters()
            comp, total = led["comp"], led["total"]
            expect = (np.asarray(c.attempted_promotions, np.int64)
                      - np.asarray(c.promotions, np.int64)
                      + np.asarray(c.reclaims, np.int64))
            return bool((comp.sum(axis=-1) == total).all()
                        and (comp >= 0).all()
                        and (total == expect).all())
        return attribution_conserved(self._att(), self.final_state.counters)

    def attribution_rollup(self) -> dict:
        """Operator roll-up: fleet component shares, worst tenants, sketch
        percentiles (O(H * T) host memory, like ``pathology_rollup``)."""
        comp = self.attribution_components()
        total = self.attribution_totals()
        fleet = comp.sum(axis=(0, 1))
        denom = max(int(fleet.sum()), 1)
        worst = np.unravel_index(np.argmax(total), total.shape)
        p50, p95, p99 = self.stall_percentiles((0.5, 0.95, 0.99))
        return {
            "hosts": self.n_hosts,
            "ticks": self.ticks,
            "stall_units_total": int(total.sum()),
            "component_totals": {k: int(v)
                                 for k, v in zip(COMPONENTS, fleet)},
            "component_shares": {k: float(v) / denom
                                 for k, v in zip(COMPONENTS, fleet)},
            "worst_tenant": (int(worst[0]), int(worst[1])),
            "worst_tenant_stall": int(total[worst]),
            "stall_p50": float(p50),
            "stall_p95": float(p95),
            "stall_p99": float(p99),
            "conserved": self.attribution_conserved(),
        }

    def pathology_rollup(self) -> dict:
        """Operator roll-up of the streamed pathology state (the fleet-scale
        analogue of ``FleetResult.rollup``, O(H * T) not O(H * ticks))."""
        flagged = self.tenants_flagged()
        first = self.pathology_first_flag()
        return {
            "hosts": self.n_hosts,
            "ticks": self.ticks,
            "pathology_counts": self.pathology_counts(),
            "tenants_flagged": flagged,
            "hosts_with_pathology": len({h for h, _ in flagged}),
            "earliest_flag_tick": (int(first[first >= 0].min())
                                   if (first >= 0).any() else -1),
        }


def fleet_rollout(cfg: TieringConfig, want: np.ndarray, rates: np.ndarray,
                  ticks: int, *, host_arch: Optional[np.ndarray] = None,
                  mode: str = "equilibria", k_max: int = 64,
                  chunk: int = 256, n_pages: Optional[int] = None,
                  shard: bool = True, warmup: bool = False,
                  detect: bool = True, attrib: bool = True) -> RolloutSummary:
    """Advance a fleet over a long horizon without host round-trips or
    memory blowup.

    want [A, P, T] / rates [A, P, T, S] are schedule *archetypes* over a
    period P; ``host_arch`` [H] maps each host to its archetype (default:
    one host per archetype). The schedule is tiled in time (tick t reads
    column ``t % P``) and gathered per host in-graph, so H hosts over a
    10k-tick horizon cost O(A * P) schedule memory, not O(H * ticks).

    Execution is chunked: one jitted ``lax.scan`` of ``chunk`` ticks with
    the fleet state donated between chunks (XLA reuses the carry buffers;
    per-tick outputs are reduced inside the scan to [H] running sums).
    With more than one local device and H divisible by the device count,
    chunks run under ``pmap`` with hosts sharded across devices.

    ``warmup=True`` runs one throwaway chunk on a scratch fleet state
    before the timed rollout so ``elapsed_s`` measures steady-state
    execution, not XLA compilation (the benchmark gate's tick-rate).

    ``detect=True`` (default) carries the streaming pathology detectors
    (obs/streaming.py) in the fleet state: per-host per-tenant flag counters
    and first-flag ticks at any horizon, O(H * T) extra memory — the
    observability the chunked rollout exists to keep while never
    materializing ``[ticks, ...]`` traces.

    ``attrib=True`` (default) additionally carries the per-tenant slowdown
    attribution ledger (obs/attribution.py): cumulative stall units by
    cause, fast-tier access mass, and a fixed-size mergeable stall sketch —
    again O(H * T) state, so fleet attribution percentiles come out of a
    10k-tick rollout in O(1) output memory (``attribution_rollup``).
    """
    want = np.asarray(want)
    rates = np.asarray(rates)
    A, period, T = want.shape
    host_arch = np.arange(A) if host_arch is None else np.asarray(host_arch)
    if host_arch.size and (host_arch.min() < 0 or host_arch.max() >= A):
        # XLA gathers clamp out-of-range indices silently — fail loudly here
        raise ValueError(f"host_arch must map into [0, {A}) archetypes")
    H = host_arch.shape[0]
    L = n_pages if n_pages is not None else \
        cfg.n_fast_pages + cfg.n_slow_pages
    cfg = cfg.with_(n_tenants=T)
    det_spec = (make_detector(ticks, T, cfg.lower_protection)
                if detect else None)
    att_spec = make_attribution(T, cfg.lat_fast) if attrib else None
    tick = make_churn_tick(cfg, L, mode=mode, k_max=k_max, detector=det_spec,
                           attrib=att_spec)
    vtick = jax.vmap(tick)
    want_j = jnp.asarray(want, jnp.int32)
    rates_j = jnp.asarray(rates, jnp.float32)

    def make_chunk_fn(n: int):
        return make_fleet_chunk(vtick, want_j, rates_j, period, n)

    chunk = max(min(chunk, ticks), 1)
    D = jax.local_device_count()
    use_pmap = bool(shard) and D > 1 and H % D == 0
    states = stack_states(init_state(cfg, L, detector=det_spec,
                                     attrib=att_spec), H)
    if use_pmap:
        def resh(x):
            return jnp.reshape(x, (D, H // D) + x.shape[1:])
        states = jax.tree_util.tree_map(resh, states)
        arch = jnp.asarray(host_arch.reshape(D, H // D))

        def compile_chunk(n):
            return jax.pmap(make_chunk_fn(n), in_axes=(0, 0, None),
                            donate_argnums=(0,))
    else:
        arch = jnp.asarray(host_arch)

        def compile_chunk(n):
            return jax.jit(make_chunk_fn(n), donate_argnums=(0,))

    run_chunk = compile_chunk(chunk)
    n_full, rem = divmod(ticks, chunk)
    run_rem = compile_chunk(rem) if rem else None

    if warmup:
        # compile (and once-run) every chunk program on a scratch state —
        # donation consumes the scratch buffers, the real fleet is untouched
        scratch = stack_states(init_state(cfg, L, detector=det_spec,
                                          attrib=att_spec), H)
        if use_pmap:
            scratch = jax.tree_util.tree_map(resh, scratch)
        scratch, _ = run_chunk(scratch, arch, 0)
        if run_rem is not None:
            jax.block_until_ready(
                jax.tree_util.tree_leaves(run_rem(scratch, arch, 0)[0])[0])
        else:
            jax.block_until_ready(jax.tree_util.tree_leaves(scratch)[0])

    lat_sum = np.zeros(H, np.float64)
    thr_sum = np.zeros(H, np.float64)
    mig_sum = np.zeros(H, np.int64)

    def host_view(tree):
        """Pull a device subtree to host with a flat [H, ...] host axis."""
        return jax.tree_util.tree_map(
            lambda x: np.asarray(x).reshape((H,) + np.shape(x)[2:])
            if use_pmap else np.asarray(x), tree)

    def ledger_view(st):
        tree = {"counters": st.counters}
        if att_spec is not None:
            tree["att"] = {"comp": st.attrib.comp, "total": st.attrib.total,
                           "sketch": st.attrib.sketch}
        return host_view(tree)

    ledger = CounterLedger(ledger_view(states))

    def absorb(acc):
        nonlocal lat_sum, thr_sum, mig_sum
        lat, thr, mig = (np.asarray(a).reshape(H) for a in acc)
        lat_sum = lat_sum + lat
        thr_sum = thr_sum + thr
        # the chunk's int32 migration count, widened wrap-safe like the
        # cumulative counters (exact while one chunk migrates < 2^32 pages)
        mig_sum = mig_sum + (mig.astype(np.int64) % _WRAP32)

    t0_wall = time.perf_counter()
    t = 0
    for _ in range(n_full):
        states, acc = run_chunk(states, arch, t)
        absorb(acc)
        ledger.absorb(ledger_view(states))
        t += chunk
    if run_rem is not None:
        states, acc = run_rem(states, arch, t)
        absorb(acc)
        ledger.absorb(ledger_view(states))
        t += rem
    jax.block_until_ready(jax.tree_util.tree_leaves(states)[0])
    elapsed = time.perf_counter() - t0_wall

    if use_pmap:
        states = jax.tree_util.tree_map(
            lambda x: jnp.reshape(x, (H,) + x.shape[2:]), states)
    return RolloutSummary(
        n_hosts=H, ticks=ticks, chunk=chunk, sharded=use_pmap,
        elapsed_s=elapsed,
        latency_mean=lat_sum / ticks,
        throughput_mean=thr_sum / ticks,
        migrations_per_tick=mig_sum / ticks,
        final_state=states, detector=det_spec, attribution=att_spec,
        ledger=ledger)
