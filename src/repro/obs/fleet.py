"""Fleet telemetry harness: the engine tick ``vmap``-ed across N simulated
hosts with heterogeneous tenant mixes.

This is the ROADMAP's fleet-scale evaluation vehicle: one compiled program
advances every host's tiering state in lockstep (hosts share the static
ownership layout; heterogeneity comes from per-host workload patterns,
arrivals and hotness), and the in-graph obs state (TierStats + migration
ring) is collected per host with zero extra tracing work — ``vmap`` batches
the scatter/adds along the host axis. Host-side, per-host telemetry is
decoded and rolled up fleet-wide: latency percentiles, migration rates, and
pathology counts from ``obs.pathology``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TieringConfig
from repro.core.engine import make_tick
from repro.core.simulator import tenant_activity
from repro.core.state import init_state
from repro.core.workloads import (TenantWorkload, build_trace, cache_like,
                                  ci_like, microbenchmark, spark_like,
                                  thrasher, web_like)
from repro.obs.pathology import Pathology, count_by_kind, detect_all
from repro.obs.stats import stats_summary
from repro.obs.trace import decode_ring

# stable-pattern menu for clean hosts (hot sets that mostly fit fast tier)
MIX_MENU = ("web", "cache", "micro", "ci", "spark")


def heterogeneous_mixes(footprints: Sequence[int], n_hosts: int,
                        seed: int = 0, menu: Sequence[str] = MIX_MENU,
                        stagger: int = 8) -> List[List[TenantWorkload]]:
    """One tenant mix per host. Footprints are fixed per tenant *slot* (every
    host shares the static page-ownership layout the engine needs); the
    workload pattern and arrival of each slot vary per host."""
    rng = np.random.default_rng(seed)
    mk = {
        "web": lambda f, a: web_like(f, arrival=a),
        "cache": lambda f, a: cache_like(f, arrival=a),
        "micro": lambda f, a: microbenchmark(f, arrival=a),
        "ci": lambda f, a: ci_like(f, arrival=a),
        "spark": lambda f, a: spark_like(f, arrival=a),
    }
    mixes = []
    for _ in range(n_hosts):
        mix = []
        for f in footprints:
            kind = menu[int(rng.integers(len(menu)))]
            arrival = int(rng.integers(0, stagger + 1))
            mix.append(mk[kind](f, arrival))
        mixes.append(mix)
    return mixes


def inject_noisy_neighbor(mixes: List[List[TenantWorkload]], tenant: int,
                          fast_share: int,
                          hosts: Optional[Sequence[int]] = None,
                          arrival: Optional[int] = None
                          ) -> List[List[TenantWorkload]]:
    """Replace ``tenant``'s workload with a thrasher (promotion-hot pages
    never re-accessed before demotion — the §V-B5 noisy neighbor) on the
    given hosts (default: all). Footprint is preserved so the fleet keeps a
    common ownership layout. A late ``arrival`` gives detectors a clean
    baseline window before the noise starts."""
    hosts = set(range(len(mixes))) if hosts is None else set(hosts)
    out = []
    for h, mix in enumerate(mixes):
        mix = list(mix)
        if h in hosts:
            a = mix[tenant].arrival if arrival is None else arrival
            mix[tenant] = thrasher(mix[tenant].footprint, fast_share,
                                   arrival=a)
        out.append(mix)
    return out


@dataclass
class FleetResult:
    mode: str
    n_hosts: int
    # [H, ticks, T] each
    fast_usage: np.ndarray
    slow_usage: np.ndarray
    promotions: np.ndarray
    demotions: np.ndarray
    throughput: np.ndarray
    latency: np.ndarray
    thrash_events: np.ndarray
    attempted: np.ndarray
    lower_protection: tuple
    # per-host decoded telemetry
    stats: List[dict] = field(default_factory=list)   # stats_summary per host
    pathologies: List[List[Pathology]] = field(default_factory=list)
    # [H, ticks, T] bool per-host tenant roster (tenant has live pages);
    # detectors and roll-ups use it to tolerate mid-window departures
    active: Optional[np.ndarray] = None
    _final_state: object = None

    def steady_window(self, frac: float = 0.5) -> slice:
        n = self.latency.shape[1]
        return slice(int(n * (1 - frac)), n)

    def host_migrations(self, host: int):
        """Decode one host's migration ring -> (events, n_dropped)."""
        ring = jax.tree_util.tree_map(lambda x: x[host],
                                      self._final_state.ring)
        return decode_ring(ring)

    def pathology_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for ps in self.pathologies:
            for k, v in count_by_kind(ps).items():
                out[k] = out.get(k, 0) + v
        return out

    def tenants_flagged(self, kind: Optional[str] = None) -> set:
        """(host, tenant) pairs flagged, optionally for one pathology kind."""
        out = set()
        for h, ps in enumerate(self.pathologies):
            for p in ps:
                if kind is None or p.kind == kind:
                    out.add((h, p.tenant))
        return out

    def rollup(self) -> dict:
        """Fleet-wide operator summary. Latency/throughput aggregates cover
        only resident tenant-ticks (``active``) so hosts with mid-window
        departures don't dilute percentiles with the idle-slot constant."""
        w = self.steady_window()
        lat = self.latency[:, w]
        mig = self.promotions[:, w] + self.demotions[:, w]
        hosts_bad = sum(1 for ps in self.pathologies if ps)
        if self.active is not None:
            act = np.asarray(self.active[:, w], bool)
            act = act if act.any() else np.ones_like(act)
            lat_vals = lat[act]
            thru_vals = self.throughput[:, w][act]
            worst_host = max(
                float(np.percentile(lat[h][act[h]], 99))
                for h in range(self.n_hosts) if act[h].any())
        else:
            lat_vals, thru_vals = lat, self.throughput[:, w]
            worst_host = float(np.percentile(lat, 99, axis=(1, 2)).max())
        return {
            "hosts": self.n_hosts,
            "ticks": self.latency.shape[1],
            "tenants": self.latency.shape[2],
            "latency_p50": float(np.percentile(lat_vals, 50)),
            "latency_p99": float(np.percentile(lat_vals, 99)),
            "latency_worst_host_p99": worst_host,
            "throughput_mean": float(thru_vals.mean()),
            "migrations_per_tick": float(mig.sum(axis=2).mean()),
            "thrash_total": int(self.thrash_events[:, -1].sum()),
            "pathology_counts": self.pathology_counts(),
            "hosts_with_pathology": hosts_bad,
        }


def run_fleet(cfg: TieringConfig, host_mixes: List[List[TenantWorkload]],
              ticks: int, mode: str = "equilibria", k_max: int = 64,
              detect: bool = True) -> FleetResult:
    """Run every host's trace through one vmapped engine; collect telemetry.

    All hosts must share the tenant footprint layout (same owner vector);
    ``heterogeneous_mixes`` guarantees that by construction.
    """
    traces = [build_trace(mix, ticks) for mix in host_mixes]
    owner = traces[0][0]
    for o, _, _ in traces[1:]:
        if not np.array_equal(o, owner):
            raise ValueError("all hosts must share the footprint layout "
                             "(same per-tenant page counts)")
    cfg = cfg.with_(n_tenants=len(host_mixes[0]))
    H = len(host_mixes)
    accesses = jnp.asarray(np.stack([t[1] for t in traces]), jnp.float32)
    alive = jnp.asarray(np.stack([t[2] for t in traces]), bool)

    tick = make_tick(cfg, owner, mode, k_max)
    state0 = init_state(cfg, owner.shape[0], owner=owner)
    states = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (H,) + x.shape), state0)

    @jax.jit
    @jax.vmap
    def run_host(state, acc, alv):
        return jax.lax.scan(tick, state, (acc, alv))

    finals, outs = run_host(states, accesses, alive)

    res = FleetResult(
        mode=mode, n_hosts=H,
        fast_usage=np.asarray(outs.fast_usage),
        slow_usage=np.asarray(outs.slow_usage),
        promotions=np.asarray(outs.promotions),
        demotions=np.asarray(outs.demotions),
        throughput=np.asarray(outs.throughput),
        latency=np.asarray(outs.latency),
        thrash_events=np.asarray(outs.thrash_events),
        attempted=np.asarray(outs.attempted_promotions),
        lower_protection=tuple(cfg.lower_protection[:cfg.n_tenants]),
        active=np.stack([tenant_activity(owner, np.asarray(tr[2]),
                                         cfg.n_tenants) for tr in traces]),
        _final_state=finals)
    res.stats = [stats_summary(jax.tree_util.tree_map(lambda x: x[h],
                                                      finals.stats))
                 for h in range(H)]
    if detect:
        res.pathologies = [
            detect_all(res.fast_usage[h], res.slow_usage[h],
                       res.promotions[h], res.demotions[h], res.latency[h],
                       res.thrash_events[h], attempted=res.attempted[h],
                       lower_protection=res.lower_protection,
                       active=res.active[h])
            for h in range(H)]
    return res
