"""Counterfactual interference baselines: each tenant re-run *alone* on the
same hardware and schedule, under ``vmap``.

The attribution ledger (obs/attribution.py) decomposes a tenant's stall by
*mechanism*; this harness quantifies stall by *neighborhood* — the paper's
noisy-neighbor question posed causally: "how much faster would tenant i be
with the box to itself?" For each tenant the schedule is masked so only
that tenant's slots are populated (``want``/``rates`` of every other slot
zeroed), and all T isolated runs advance under one ``vmap`` of the SAME
compiled tick the stacked run used — same policy, same pool, same horizon.

The interference index is the isolated-minus-stacked delta of the
fast-hit fraction (share of access mass served from the fast tier, read
from the ledger's ``acc_fast``/``acc_slow``):

    interference[i] = fast_hit_isolated[i] - fast_hit_stacked[i]

An isolated tenant contends with nobody, so the index is >= 0 on clean
fleets (up to f32 accumulation noise) and strictly positive for victims of
an injected noisy neighbor — the §V-B5 quantification, but measured
against a true counterfactual instead of a baseline time window.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TieringConfig
from repro.core.churn import ChurnSchedule, make_churn_tick
from repro.core.state import init_state, stack_states
from repro.obs.attribution import (AttributionSpec, fast_hit_fraction,
                                   make_attribution)


@dataclass
class CounterfactualResult:
    """Per-tenant stacked-vs-isolated comparison (all [T] numpy)."""
    fast_hit_stacked: np.ndarray    # fast-hit fraction, tenants stacked
    fast_hit_isolated: np.ndarray   # ... each tenant alone on the host
    interference: np.ndarray        # isolated - stacked (>= 0 expected)
    stall_stacked: np.ndarray       # mean modeled stall latency, stacked
    stall_isolated: np.ndarray      # ... isolated
    active: np.ndarray              # bool: slot ever scheduled
    stacked_state: object = None    # final TierState of the stacked run
    isolated_states: object = None  # batched [T, ...] final TierStates

    def summary(self) -> dict:
        act = self.active
        return {
            "tenants": int(self.active.shape[0]),
            "active_tenants": int(act.sum()),
            "interference": self.interference,
            "max_interference": float(self.interference[act].max())
            if act.any() else 0.0,
            "mean_interference": float(self.interference[act].mean())
            if act.any() else 0.0,
            "stall_amplification": np.where(
                self.stall_isolated > 1e-9,
                self.stall_stacked / np.maximum(self.stall_isolated, 1e-9),
                np.where(self.stall_stacked > 1e-9, np.inf, 1.0)),
        }


def isolate_schedules(schedule: ChurnSchedule
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Mask a [ticks, T] churn schedule into T single-tenant schedules:
    returns (want [T, ticks, T], rates [T, ticks, T, S]) where run i keeps
    only tenant i's slots populated."""
    want = np.asarray(schedule.want)
    rates = np.asarray(schedule.rates)
    T = want.shape[1]
    eye = np.eye(T)
    want_iso = (want[None] * eye[:, None, :]).astype(want.dtype)
    rates_iso = (rates[None] * eye[:, None, :, None]).astype(rates.dtype)
    return want_iso, rates_iso


def counterfactual_run(cfg: TieringConfig, schedule: ChurnSchedule,
                       mode: str = "equilibria", k_max: int = 64,
                       n_pages: Optional[int] = None,
                       spec: Optional[AttributionSpec] = None
                       ) -> CounterfactualResult:
    """Run the stacked schedule once and every tenant's isolated schedule
    under one vmap, both through the attribution-carrying unified tick."""
    T = cfg.n_tenants
    L = n_pages if n_pages is not None else \
        cfg.n_fast_pages + cfg.n_slow_pages
    spec = make_attribution(T, cfg.lat_fast) if spec is None else spec
    tick = make_churn_tick(cfg, L, mode=mode, k_max=k_max, attrib=spec)
    state0 = init_state(cfg, L, attrib=spec)
    rates = jnp.asarray(schedule.rates, jnp.float32)
    want = jnp.asarray(schedule.want, jnp.int32)

    @jax.jit
    def run(state, r, w):
        return jax.lax.scan(tick, state, (r, w))[0]

    stacked = run(state0, rates, want)

    want_iso, rates_iso = isolate_schedules(schedule)
    isolated = jax.jit(jax.vmap(run, in_axes=(0, 0, 0)))(
        stack_states(state0, T), jnp.asarray(rates_iso, jnp.float32),
        jnp.asarray(want_iso, jnp.int32))

    f_stacked = fast_hit_fraction(stacked.attrib)              # [T]
    f_iso = fast_hit_fraction(isolated.attrib)                 # [T, T]
    f_iso_diag = np.diagonal(f_iso).copy()
    active = np.asarray(schedule.want).max(axis=0) > 0
    ticks = max(int(stacked.attrib.ticks), 1)
    stall_stacked = np.asarray(stacked.attrib.stall_sum, np.float64) / ticks
    stall_iso = np.diagonal(
        np.asarray(isolated.attrib.stall_sum, np.float64)).copy() / ticks
    interference = np.where(active, f_iso_diag - f_stacked, 0.0)
    return CounterfactualResult(
        fast_hit_stacked=f_stacked, fast_hit_isolated=f_iso_diag,
        interference=interference,
        stall_stacked=np.where(active, stall_stacked, 0.0),
        stall_isolated=np.where(active, stall_iso, 0.0),
        active=active,
        stacked_state=stacked, isolated_states=isolated)
