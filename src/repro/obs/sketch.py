"""Fixed-size mergeable quantile sketch for streaming fleet percentiles.

``fleet_rollout`` must aggregate per-tenant attribution percentiles across
hosts in O(1) output memory — the seam the ROADMAP's 10k-host scale-out
needs (HybridTier-style sketch tracking, PAPERS.md). A full value stream is
O(H * T * ticks); this sketch is a histogram of SKETCH_BUCKETS int32
counters per host, updated with one scatter-add inside the compiled tick
and merged across hosts by plain addition (counts of disjoint streams sum).

Bucket geometry (host-side constants, baked into the traced add):

  * ``N_LINEAR`` exact unit buckets for values ``0 .. N_LINEAR-1`` — the
    integer stall units the attribution ledger emits are small most ticks,
    so the common range pays ZERO quantization error.
  * ``N_LOG`` log2-subdivided buckets beyond (``LOG_SUB`` per octave,
    relative width ``2^(1/LOG_SUB) - 1`` ~ 19%), covering up to
    ``N_LINEAR * 2^(N_LOG / LOG_SUB)``; larger values clamp into the last
    bucket.

``sketch_percentile`` follows the ``obs.stats.hist_percentile`` spec — the
LOWER EDGE of the first bucket where cumulative mass reaches ``q * total``
(empty sketch -> 0.0) — so its rank error is bounded by the mass of a
single bucket: exactly 0 for integer data in the linear range, and the
per-bucket mass fraction in the log tail (<= 2% on the attribution
acceptance distribution; pinned by tests/test_attribution.py).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

N_LINEAR = 128          # exact unit buckets: values 0..127
LOG_SUB = 4             # log2 sub-buckets per octave beyond the linear range
N_LOG = 36              # covers N_LINEAR * 2^(36/4) = 65536 before clamping
SKETCH_BUCKETS = N_LINEAR + N_LOG
_LOG0 = float(np.log2(N_LINEAR))


def init_sketch(batch_shape: Sequence[int] = ()) -> jax.Array:
    """Zero sketch counts, optionally with leading batch axes ([H] hosts)."""
    return jnp.zeros(tuple(batch_shape) + (SKETCH_BUCKETS,), jnp.int32)


def sketch_bucket(values: jax.Array) -> jax.Array:
    """Bucket index of each value (jnp; works under jit/scan/vmap).
    Negative values clamp to bucket 0, huge values to the last bucket."""
    v = jnp.maximum(values.astype(jnp.float32), 0.0)
    lin = jnp.minimum(v.astype(jnp.int32), N_LINEAR - 1)
    logb = jnp.floor(
        (jnp.log2(jnp.maximum(v, float(N_LINEAR))) - _LOG0) * LOG_SUB
    ).astype(jnp.int32)
    logb = N_LINEAR + jnp.clip(logb, 0, N_LOG - 1)
    return jnp.where(v < N_LINEAR, lin, logb)


def sketch_add(counts: jax.Array, values: jax.Array,
               weights: Optional[jax.Array] = None) -> jax.Array:
    """Fold ``values`` (any shape) into a [SKETCH_BUCKETS] sketch — one
    scatter-add, so a vmapped tick batches it along the host axis for free."""
    b = sketch_bucket(values).reshape(-1)
    w = (jnp.ones_like(b) if weights is None
         else weights.reshape(-1).astype(jnp.int32))
    return counts.at[b].add(w)


def sketch_edges() -> np.ndarray:
    """Host-side: inclusive lower edge of each bucket, [SKETCH_BUCKETS + 1]
    (the trailing entry is the exclusive top of the covered range)."""
    lin = np.arange(N_LINEAR, dtype=np.float64)
    log = N_LINEAR * 2.0 ** (np.arange(N_LOG + 1, dtype=np.float64) / LOG_SUB)
    return np.concatenate([lin, log])


def sketch_merge(counts) -> np.ndarray:
    """Merge sketches by summing every leading axis: [..., NB] -> [NB].
    Counts of disjoint value streams add — the mergeability that lets a
    fleet report one set of percentiles from per-host sketches."""
    c = np.asarray(counts, dtype=np.int64)
    return c.reshape(-1, c.shape[-1]).sum(axis=0)


def sketch_count(counts) -> int:
    return int(np.asarray(counts, dtype=np.int64).sum())


def sketch_percentile(counts, q: float) -> float:
    """The ``hist_percentile`` spec on sketch geometry: lower edge of the
    first bucket where cumulative mass >= q * total; empty -> 0.0."""
    c = sketch_merge(counts)
    cum = np.cumsum(c)
    total = cum[-1]
    if total == 0:
        return 0.0
    idx = int(np.argmax(cum >= q * total))
    return float(sketch_edges()[idx])


def sketch_percentiles(counts, qs: Sequence[float]) -> np.ndarray:
    c = sketch_merge(counts)   # merge once for many quantiles
    return np.array([sketch_percentile(c, q) for q in qs])
