"""In-graph per-tenant slowdown attribution: a causal ledger of where
tiering cost lands, folded into the unified tick (core/tick.py step 9c) and
carried through ``lax.scan`` like the streaming detectors.

The detectors (obs/streaming.py) answer "*is* tenant 7 pathological?"; this
ledger answers the operator's next question — "*why* is tenant 7 slow, by
how much, and who caused it?" Each tick the tick's promotion pipeline emits
an integer *deferral* count per tenant: hot slow-resident pages that wanted
the fast tier but were not promoted, plus pages the lifecycle step freed
under reclaim. That total modeled stall is decomposed into additive causes
by telescoping the pipeline's own quota cascade:

  quota_base = min(p_base, cand, k_max)      unthrottled scan promise
  quota_eq2  = after the Eq.2 fair-share throttle   (<= quota_base: the
               throttle factor clips to [promo_floor, 1])
  quota_mit  = after thrash-mitigation promo_scale  (<= quota_eq2: the
               controller only halves, promo_scale <= 1)
  promoted   = pages actually promoted              (<= quota_mit after
               headroom scaling + selection, per-tenant modes)

  hot_resident = cand - quota_base      demand beyond any scan budget
  throttled    = quota_base - quota_eq2 deferred by fair-share (Eq.2)
  mitigated    = quota_eq2 - quota_mit  deferred by thrash suppression
  contention   = quota_mit - promoted   residual: fast-tier headroom/floor
  reclaim      = freed                  churn reclaim stalls

Conservation (bit-exact in int32, pinned by tests/test_attribution.py):
components sum to ``cand - promoted + freed`` every tick, so the cumulative
ledger always equals ``Counters.attempted_promotions - Counters.promotions
+ Counters.reclaims`` — the tick cannot lose or invent stall units.

One mode needs care: tpp's promotion budget is a single *global* scan, so
one tenant's ``promoted`` can exceed its own per-tenant cap (it eats the
others' budget). The negative residual is folded back into
``hot_resident`` (the sum ``cand - promoted`` stays >= 0 per tenant because
tpp has no throttle/mitigation terms), keeping every component
non-negative in every mode.

The ledger also accumulates the perf-model access masses (``acc_fast`` /
``acc_slow`` — the fast-hit fraction the counterfactual harness compares),
a modeled stall-latency sum, and a per-host quantile sketch
(obs/sketch.py) of per-tenant-tick stall units so ``fleet_rollout``
reports fleet percentiles in O(1) output memory.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import sketch as SK

# fixed component order of the trailing axis of AttributionState.comp
COMPONENTS = ("hot_resident", "throttled", "mitigated", "reclaim",
              "contention")
N_COMP = len(COMPONENTS)


@dataclass(frozen=True)
class AttributionSpec:
    """Python constants baked into the traced tick — a spec never changes
    jaxpr size, only embedded scalars (the ``DetectorSpec`` pattern)."""
    n_tenants: int
    lat_fast: float = 1.0      # cfg.lat_fast: stall latency baseline


def make_attribution(n_tenants: int, lat_fast: float = 1.0) -> AttributionSpec:
    return AttributionSpec(n_tenants=n_tenants, lat_fast=float(lat_fast))


class AttribSignals(NamedTuple):
    """One tick's promotion-pipeline telemetry, all [T] (produced inside the
    unified tick after the perf model)."""
    cand: jax.Array         # int32 promotion candidates (hot slow-resident)
    promoted: jax.Array     # int32 pages actually promoted
    quota_base: jax.Array   # int32 min(p_base, cand, k_max)
    quota_eq2: jax.Array    # int32 ... after the Eq.2 throttle
    quota_mit: jax.Array    # int32 ... after thrash-mitigation promo_scale
    freed: jax.Array        # int32 pages freed by lifecycle reclaim
    a_fast: jax.Array       # f32 fast-tier access mass (perf model)
    a_slow: jax.Array       # f32 slow-tier access mass
    latency: jax.Array      # f32 modeled mean access latency


class AttributionState(NamedTuple):
    """Scan-carried ledger. O(T) per host plus one fixed-size sketch —
    independent of horizon and event count."""
    comp: jax.Array         # [T, N_COMP] int32 cumulative stall components
    total: jax.Array        # [T] int32 cumulative total stall units
    acc_fast: jax.Array     # [T] f32 cumulative fast access mass
    acc_slow: jax.Array     # [T] f32 cumulative slow access mass
    stall_sum: jax.Array    # [T] f32 cumulative modeled stall latency
    ticks: jax.Array        # scalar int32 ticks folded
    sketch: jax.Array       # [SKETCH_BUCKETS] int32 per-tenant-tick stalls


def init_attribution(spec: AttributionSpec) -> AttributionState:
    T = spec.n_tenants
    return AttributionState(
        comp=jnp.zeros((T, N_COMP), jnp.int32),
        total=jnp.zeros((T,), jnp.int32),
        acc_fast=jnp.zeros((T,), jnp.float32),
        acc_slow=jnp.zeros((T,), jnp.float32),
        stall_sum=jnp.zeros((T,), jnp.float32),
        ticks=jnp.zeros((), jnp.int32),
        sketch=SK.init_sketch())


def attribution_components(sig: AttribSignals) -> jax.Array:
    """[T, N_COMP] int32 stall components for one tick (order COMPONENTS).
    Telescoping guarantees the row sum is exactly
    ``cand - promoted + freed``; the tpp global-selection residual is folded
    into hot_resident so every entry stays >= 0."""
    i32 = jnp.int32
    x1 = (sig.cand - sig.quota_base).astype(i32)
    x2 = (sig.quota_base - sig.quota_eq2).astype(i32)
    x3 = (sig.quota_eq2 - sig.quota_mit).astype(i32)
    x4 = (sig.quota_mit - sig.promoted).astype(i32)
    contention = jnp.maximum(x4, 0)
    hot_resident = x1 + jnp.minimum(x4, 0)
    return jnp.stack(
        [hot_resident, x2, x3, sig.freed.astype(i32), contention], axis=-1)


def update_attribution(spec: AttributionSpec, att: AttributionState,
                       sig: AttribSignals) -> AttributionState:
    """Fold one tick's signals into the ledger (pure jnp: jit/scan/vmap)."""
    comp_new = attribution_components(sig)
    total_new = comp_new.sum(axis=-1)
    stall = jnp.maximum(sig.latency - spec.lat_fast, 0.0)
    return AttributionState(
        comp=att.comp + comp_new,
        total=att.total + total_new,
        acc_fast=att.acc_fast + sig.a_fast,
        acc_slow=att.acc_slow + sig.a_slow,
        stall_sum=att.stall_sum + stall,
        ticks=att.ticks + 1,
        sketch=SK.sketch_add(att.sketch, total_new))


# ------------------------------------------------------------ host side ----
def fast_hit_fraction(att: AttributionState) -> np.ndarray:
    """Per-tenant fraction of access mass served from the fast tier over the
    whole run. A tenant with no accesses is trivially all-fast (1.0) — keeps
    the counterfactual interference index at exactly 0 for empty slots.
    Works on a single host [T] or a batched fleet [H, T] state."""
    af = np.asarray(att.acc_fast, np.float64)
    as_ = np.asarray(att.acc_slow, np.float64)
    tot = af + as_
    return np.where(tot > 0, af / np.maximum(tot, 1e-30), 1.0)


def attribution_conserved(att: AttributionState, counters=None) -> bool:
    """The conservation property, bit-exact in integer accounting:
    components sum to the total ledger, and (when the run's ``Counters``
    are supplied) the total equals ``attempted - promotions + reclaims``."""
    comp = np.asarray(att.comp, np.int64)
    total = np.asarray(att.total, np.int64)
    ok = bool((comp.sum(axis=-1) == total).all() and (comp >= 0).all())
    if counters is not None:
        expect = (np.asarray(counters.attempted_promotions, np.int64)
                  - np.asarray(counters.promotions, np.int64)
                  + np.asarray(counters.reclaims, np.int64))
        ok = ok and bool((total == expect).all())
    return ok


def attribution_summary(spec: AttributionSpec,
                        att: AttributionState) -> dict:
    """Plain-numpy operator view of one host's ledger."""
    comp = np.asarray(att.comp, np.int64)
    if comp.ndim == 3:
        raise ValueError("got a batched AttributionState; index the host "
                         "axis first (tree_map(lambda x: x[h], att))")
    total = np.asarray(att.total, np.int64)
    ticks = max(int(att.ticks), 1)
    denom = np.maximum(total, 1).astype(np.float64)
    return {
        "components": comp,                       # [T, N_COMP]
        "component_names": COMPONENTS,
        "total": total,                           # [T]
        "component_share": comp / denom[:, None],
        "stall_units_per_tick": total / ticks,
        "stall_latency_mean": np.asarray(att.stall_sum, np.float64) / ticks,
        "fast_hit_fraction": fast_hit_fraction(att),
        "ticks": ticks,
    }
