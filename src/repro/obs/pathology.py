"""Offline pathology detectors over collected telemetry (paper §IV-C: the
observability that lets operators "diagnose performance pathologies at
scale").

Each detector consumes per-tick per-tenant numpy arrays (as produced by
``core.simulator.SimResult`` or the fleet harness) plus the static policy,
and returns ``Pathology`` records. Detectors are pure host-side numpy —
they run after collection, never in the compiled graph.

Detected pathologies (names follow the paper's failure-mode discussion):
  chronic_thrashing     — sustained promote->demote churn (§IV-F signature)
  protection_violation  — a tenant with demand above its lower protection is
                          held below it (§IV-B invariant broken)
  noisy_neighbor        — one tenant's migration traffic dominates while
                          neighbors' latency degrades (§III-F)
  promotion_stall       — promotion demand exists but success ratio stays
                          ~zero (misconfigured bound / starved promoter)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

# Default thresholds — ONE source of truth shared with the streaming
# in-graph detectors (obs/streaming.py builds its DetectorSpec from these,
# so the offline and online state machines can never drift apart).
STEADY_FRAC = 0.5            # _steady: judge the last half of the run
RESIDENT_MIN_FRAC = 0.5      # _tenant_in_window churn gate
THRASH_WINDOW = 20           # ticks per thrash-rate window
THRASH_RATE_THRESHOLD = 4.0  # events/window that makes a window "bad"
THRASH_FRAC_THRESHOLD = 0.5  # bad-window fraction that flags a tenant
PROT_TOLERANCE = 0.05        # slack below lower protection before violating
PROT_FRAC_THRESHOLD = 0.25   # violating-tick fraction that flags
NOISY_DOMINANCE = 0.5        # migration-traffic share that dominates
NOISY_DEGRADE = 1.10         # neighbor latency degrade vs early baseline
STALL_MIN_ATTEMPTS = 1.0     # attempts/tick that counts as sustained demand
STALL_SUCCESS = 0.02         # success ratio below which promotion "stalls"


@dataclass(frozen=True)
class Pathology:
    kind: str
    tenant: int
    severity: float              # >= 1.0 means "over threshold"
    evidence: Dict[str, float] = field(default_factory=dict)

    def __str__(self):
        ev = " ".join(f"{k}={v:.3g}" for k, v in self.evidence.items())
        return (f"[{self.kind}] tenant{self.tenant} "
                f"severity={self.severity:.2f} {ev}")


def _steady(n_ticks: int, frac: float = STEADY_FRAC) -> slice:
    return slice(int(n_ticks * (1 - frac)), n_ticks)


def _tenant_in_window(active: Optional[np.ndarray], w: slice, tenant: int,
                      min_frac: float = RESIDENT_MIN_FRAC) -> bool:
    """Churn gate: with a per-tick roster (``active`` [ticks, T] bool), a
    tenant is only judged over a window it meaningfully occupied — resident
    for >= ``min_frac`` of the window AND still resident at its end. A
    tenant that departed mid-window has no steady state to violate; judging
    its truncated tail produces exactly the false positives the churn tests
    pin (departure is not a protection violation or a promotion stall)."""
    if active is None:
        return True
    a = np.asarray(active[w, tenant], bool)
    if a.size == 0:
        return False
    return bool(a[-1]) and float(a.mean()) >= min_frac


def detect_chronic_thrashing(thrash_events: np.ndarray,
                             window: int = THRASH_WINDOW,
                             rate_threshold: float = THRASH_RATE_THRESHOLD,
                             frac_threshold: float = THRASH_FRAC_THRESHOLD,
                             active: Optional[np.ndarray] = None
                             ) -> List[Pathology]:
    """thrash_events: [ticks, T] *cumulative*. Flags tenants whose per-window
    thrash rate exceeds ``rate_threshold`` in >= ``frac_threshold`` of the
    steady-half windows — transient churn at arrival does not count.

    Thrashing is *history*, so (unlike protection violation / promotion
    stall) a tenant that departed mid-observation-window is still judged —
    but only over the windows it fully resided in. Without the ``active``
    roster, a departed thrasher's post-departure windows (rate 0) dilute
    its bad-window fraction and it can slip under the threshold entirely (a
    churn false *negative*, pinned by tests/test_churn.py)."""
    ticks, T = thrash_events.shape
    w = _steady(ticks)
    ev = thrash_events[w]
    if ev.shape[0] < 2 * window:
        window = max(ev.shape[0] // 4, 1)
    out: List[Pathology] = []
    idxs = np.arange(0, ev.shape[0], window)  # partial tail window dropped
    if idxs.shape[0] < 2:
        return out
    rates = np.diff(ev[idxs], axis=0).astype(np.float64)  # events per window
    for t in range(T):
        r_t = rates[:, t]
        if active is not None:
            a = np.asarray(active[w, t], bool)
            resident = np.array([a[idxs[j]:idxs[j + 1]].all()
                                 for j in range(len(idxs) - 1)])
            if not resident.any():
                continue
            r_t = r_t[resident]
        bad = float((r_t > rate_threshold).mean())
        if bad >= frac_threshold:
            out.append(Pathology(
                "chronic_thrashing", t, severity=bad / frac_threshold,
                evidence={"mean_rate": float(r_t.mean()),
                          "bad_window_frac": bad,
                          "rate_threshold": rate_threshold}))
    return out


def detect_protection_violation(fast_usage: np.ndarray,
                                slow_usage: np.ndarray,
                                lower_protection: Sequence[int],
                                attempted: Optional[np.ndarray] = None,
                                demotions: Optional[np.ndarray] = None,
                                tolerance: float = PROT_TOLERANCE,
                                frac_threshold: float = PROT_FRAC_THRESHOLD,
                                active: Optional[np.ndarray] = None
                                ) -> List[Pathology]:
    """fast/slow_usage: [ticks, T]. A tenant violates its lower protection
    when its total footprint covers the protection but its fast-tier share
    sits below protection*(1-tolerance) — for >= ``frac_threshold`` of the
    steady window. Tenants that simply don't demand that much are exempt;
    when ``attempted``/``demotions`` [ticks, T] are given, ticks where the
    tenant neither sought promotion nor was demoted don't count either (a
    cold tenant sitting in the slow tier by its own access pattern is not a
    policy violation). With a churn roster (``active`` [ticks, T]), tenants
    that departed mid-window are skipped and non-resident ticks never count
    as violations."""
    ticks, T = fast_usage.shape
    w = _steady(ticks)
    prot = np.asarray(lower_protection, np.float64)
    out: List[Pathology] = []
    for t in range(T):
        if t >= prot.shape[0] or prot[t] <= 0:
            continue
        if not _tenant_in_window(active, w, t):
            continue
        demand = fast_usage[w, t] + slow_usage[w, t] >= prot[t]
        held_below = fast_usage[w, t] < prot[t] * (1 - tolerance)
        viol = demand & held_below
        if active is not None:
            viol &= np.asarray(active[w, t], bool)
        if attempted is not None or demotions is not None:
            wants = np.zeros(viol.shape, bool)
            if attempted is not None:
                wants |= attempted[w, t] > 0
            if demotions is not None:
                wants |= demotions[w, t] > 0
            viol &= wants
        frac = float(viol.mean())
        if frac >= frac_threshold:
            out.append(Pathology(
                "protection_violation", t, severity=frac / frac_threshold,
                evidence={"violation_frac": frac,
                          "mean_fast": float(fast_usage[w, t].mean()),
                          "protection": float(prot[t])}))
    return out


def detect_noisy_neighbor(promotions: np.ndarray, demotions: np.ndarray,
                          latency: np.ndarray,
                          dominance_threshold: float = NOISY_DOMINANCE,
                          degrade_threshold: float = NOISY_DEGRADE
                          ) -> List[Pathology]:
    """[ticks, T] each. Flags a tenant whose share of total migration traffic
    exceeds ``dominance_threshold`` over the steady window while at least one
    *other* tenant's steady latency exceeds its own early-run baseline by
    ``degrade_threshold``x — migrations stall everyone (§III-F)."""
    ticks, T = promotions.shape
    if T < 2:
        return []
    w = _steady(ticks)
    base_w = slice(0, max(ticks // 4, 1))
    mig = (promotions[w] + demotions[w]).sum(axis=0).astype(np.float64)  # [T]
    total = mig.sum()
    out: List[Pathology] = []
    if total <= 0:
        return out
    lat_now = latency[w].mean(axis=0)
    lat_base = np.maximum(latency[base_w].mean(axis=0), 1e-9)
    degrade = lat_now / lat_base
    for t in range(T):
        share = mig[t] / total
        others = np.delete(degrade, t)
        worst = float(others.max()) if others.size else 0.0
        if share > dominance_threshold and worst > degrade_threshold:
            out.append(Pathology(
                "noisy_neighbor", t,
                severity=(share / dominance_threshold)
                * (worst / degrade_threshold),
                evidence={"migration_share": float(share),
                          "worst_neighbor_degrade": worst}))
    return out


def detect_promotion_stall(attempted: np.ndarray, promotions: np.ndarray,
                           min_attempts_per_tick: float = STALL_MIN_ATTEMPTS,
                           success_threshold: float = STALL_SUCCESS,
                           active: Optional[np.ndarray] = None
                           ) -> List[Pathology]:
    """[ticks, T] per-tick attempts vs successes. Flags tenants with sustained
    promotion demand in the steady window whose success ratio is ~zero. A
    tenant that departed mid-window (``active`` roster) is skipped — demand
    that vanished with the tenant is churn, not a stalled promoter."""
    ticks, T = attempted.shape
    w = _steady(ticks)
    out: List[Pathology] = []
    for t in range(T):
        if not _tenant_in_window(active, w, t):
            continue
        att = float(attempted[w, t].sum())
        n = attempted[w, t].shape[0]
        if att < min_attempts_per_tick * n:
            continue
        ratio = float(promotions[w, t].sum()) / max(att, 1.0)
        if ratio < success_threshold:
            out.append(Pathology(
                "promotion_stall", t,
                severity=success_threshold / max(ratio, 1e-9),
                evidence={"attempts_per_tick": att / n,
                          "success_ratio": ratio}))
    return out


def detect_all(fast_usage: np.ndarray, slow_usage: np.ndarray,
               promotions: np.ndarray, demotions: np.ndarray,
               latency: np.ndarray, thrash_events: np.ndarray,
               attempted: Optional[np.ndarray] = None,
               lower_protection: Sequence[int] = (),
               thrash_rate_threshold: float = THRASH_RATE_THRESHOLD,
               active: Optional[np.ndarray] = None) -> List[Pathology]:
    """Run every detector over one host's collected telemetry. ``active``
    ([ticks, T] bool, optional) is the churn roster. Current-state
    pathologies (protection violation, promotion stall) skip tenants that
    departed mid-observation-window instead of misreading the truncated
    tail; historical pathologies (chronic thrashing — judged over resident
    windows — and noisy neighbor) still report tenants that have since
    departed."""
    found = detect_chronic_thrashing(
        thrash_events, rate_threshold=thrash_rate_threshold, active=active)
    if len(lower_protection):
        found += detect_protection_violation(fast_usage, slow_usage,
                                             lower_protection,
                                             attempted=attempted,
                                             demotions=demotions,
                                             active=active)
    found += detect_noisy_neighbor(promotions, demotions, latency)
    if attempted is not None:
        found += detect_promotion_stall(attempted, promotions, active=active)
    return found


def count_by_kind(pathologies: Sequence[Pathology]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for p in pathologies:
        out[p.kind] = out.get(p.kind, 0) + 1
    return out
