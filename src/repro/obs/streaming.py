"""Streaming in-graph pathology detection: the offline detectors of
``obs/pathology.py`` reimplemented as windowed state machines carried
through ``lax.scan``.

The offline detectors need full ``[N_ticks, T]`` traces — exactly what the
chunked ``fleet_rollout`` (O(1) output memory) cannot produce. Here the
same four pathologies are detected *online*: a ``DetectorState`` pytree of
[T]-shaped counters rides in ``TierState`` and is folded one tick at a time
inside the unified tick (core/tick.py step 9b), so a 10k-host x 10k-tick
fleet reports per-host per-tenant pathology flags with O(H * T) memory and
a jaxpr that is constant in horizon and event count (the window geometry —
steady start, window width, baseline length — is baked in as Python
constants via ``DetectorSpec``).

Semantics contract (pinned by tests/test_streaming_obs.py):

  * chronic thrashing, protection violation and promotion stall accumulate
    the SAME integer counters the offline detectors derive from traces, so
    their end-of-run decisions (``streaming_pathologies``) agree *exactly*
    with ``detect_all`` on any horizon.
  * noisy neighbor replaces the offline f64 trace means with running f32
    sums; flags agree except within float error of the thresholds
    (documented <= 5% flag-count tolerance; in practice exact on every
    pinned scenario).
  * additionally each tick evaluates a *running* verdict from the counters
    so far, feeding ``flag_ticks`` (ticks the condition held) and
    ``first_flag`` (first tick it held, -1 = never) — online-only signals
    with no offline analogue (the offline pass only judges the full run).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import pathology as PA
from repro.obs.pathology import Pathology

# fixed kind order of the trailing axis of flag_ticks / first_flag
KINDS = ("chronic_thrashing", "protection_violation", "noisy_neighbor",
         "promotion_stall")
N_KINDS = len(KINDS)


@dataclass(frozen=True)
class DetectorSpec:
    """Host-side window geometry + thresholds, all Python constants (baked
    into the traced tick — a spec never changes jaxpr *size*, only the
    embedded scalars)."""
    horizon: int                 # ticks the run will last
    n_tenants: int
    protection: Tuple[float, ...]   # [T] lower protection (pages; 0 = none)
    steady_start: int            # first steady tick (offline _steady)
    window: int                  # thrash window width, post-adjustment
    base_ticks: int              # noisy-neighbor baseline = ticks < this
    thrash_rate_threshold: float = PA.THRASH_RATE_THRESHOLD
    thrash_frac_threshold: float = PA.THRASH_FRAC_THRESHOLD
    prot_tolerance: float = PA.PROT_TOLERANCE
    prot_frac_threshold: float = PA.PROT_FRAC_THRESHOLD
    noisy_dominance: float = PA.NOISY_DOMINANCE
    noisy_degrade: float = PA.NOISY_DEGRADE
    stall_min_attempts: float = PA.STALL_MIN_ATTEMPTS
    stall_success: float = PA.STALL_SUCCESS
    resident_min_frac: float = PA.RESIDENT_MIN_FRAC

    @property
    def n_steady(self) -> int:
        return self.horizon - self.steady_start


def make_detector(horizon: int, n_tenants: int,
                  lower_protection: Sequence[float] = (),
                  *, steady_frac: float = PA.STEADY_FRAC,
                  window: int = PA.THRASH_WINDOW,
                  **thresholds) -> DetectorSpec:
    """Derive the window geometry exactly as the offline detectors do:
    steady window = last ``steady_frac`` of the run, thrash window shrunk to
    ``max(steady_len // 4, 1)`` when the steady half can't fit two full
    windows, noisy baseline = first quarter of the run."""
    s0 = int(horizon * (1 - steady_frac))        # pathology._steady
    n_steady = horizon - s0
    if n_steady < 2 * window:                    # detect_chronic_thrashing
        window = max(n_steady // 4, 1)
    prot = [0.0] * n_tenants
    for i, v in enumerate(lower_protection[:n_tenants]):
        prot[i] = float(v)
    return DetectorSpec(
        horizon=horizon, n_tenants=n_tenants, protection=tuple(prot),
        steady_start=s0, window=window,
        base_ticks=max(horizon // 4, 1),         # detect_noisy_neighbor
        **thresholds)


class DetectorSignals(NamedTuple):
    """One tick's telemetry, all [T] (what the offline detectors read per
    trace row). Produced inside the unified tick after the perf model."""
    active: jax.Array        # bool  tenant resident this tick
    thrash_new: jax.Array    # int32 thrash events this tick
    fast_usage: jax.Array    # int32 fast-tier pages
    slow_usage: jax.Array    # int32 slow-tier pages
    attempted: jax.Array     # int32 promotion candidates
    promotions: jax.Array    # int32
    demotions: jax.Array     # int32
    latency: jax.Array       # f32


class DetectorState(NamedTuple):
    """Scan-carried detector memory. All [T] unless noted — O(T) per host,
    independent of horizon and event count."""
    # chronic thrashing: tumbling windows over the steady half
    win_events: jax.Array        # int32 thrash events in the open window
    win_resident: jax.Array      # bool  resident every tick of that window
    windows_resident: jax.Array  # int32 closed fully-resident windows
    windows_bad: jax.Array       # int32 ... of those, over rate threshold
    events_resident: jax.Array   # int32 thrash events inside resident windows
    # protection violation
    viol_ticks: jax.Array        # int32 violating steady ticks
    fast_sum: jax.Array          # f32   steady fast_usage sum (evidence)
    # promotion stall
    att_steady: jax.Array        # int32 steady promotion candidates
    promo_steady: jax.Array      # int32 steady promotions
    # noisy neighbor
    mig_steady: jax.Array        # int32 steady promotions + demotions
    lat_base_sum: jax.Array      # f32   latency over the baseline window
    lat_steady_sum: jax.Array    # f32   latency over the steady window
    # shared roster gate
    active_steady: jax.Array     # int32 resident steady ticks
    active_last: jax.Array       # bool  resident at last steady tick seen
    # online flags
    flag_ticks: jax.Array        # [T, N_KINDS] int32 ticks condition held
    first_flag: jax.Array        # [T, N_KINDS] int32 first such tick, -1


def init_detector(spec: DetectorSpec) -> DetectorState:
    T = spec.n_tenants
    z = jnp.zeros((T,), jnp.int32)
    f = jnp.zeros((T,), jnp.float32)
    b = jnp.zeros((T,), bool)
    return DetectorState(
        win_events=z, win_resident=jnp.ones((T,), bool),
        windows_resident=z, windows_bad=z, events_resident=z,
        viol_ticks=z, fast_sum=f, att_steady=z, promo_steady=z,
        mig_steady=z, lat_base_sum=f, lat_steady_sum=f,
        active_steady=z, active_last=b,
        flag_ticks=jnp.zeros((T, N_KINDS), jnp.int32),
        first_flag=jnp.full((T, N_KINDS), -1, jnp.int32))


def update_detector(spec: DetectorSpec, det: DetectorState,
                    sig: DetectorSignals, t: jax.Array) -> DetectorState:
    """Fold one tick. Mirrors the offline trace math exactly:

    * window j of chronic thrashing covers steady ticks
      ``[s0 + j*W, s0 + (j+1)*W)`` and its event count is the cumulative
      diff ``cum[s0+(j+1)W] - cum[s0+jW]`` — i.e. events *at* a boundary
      tick belong to the window that just closed, and events at ``s0``
      itself to none (the offline pass diffs cumulative samples).
    * residency of window j = active on every tick it covers.
    * protection / stall / noisy counters are plain steady-window sums.
    """
    i32 = jnp.int32
    s0, W = spec.steady_start, spec.window
    in_steady = t >= s0
    past_s0 = t > s0
    active = sig.active

    # ---- chronic thrashing: tumbling windows -----------------------------
    win_events = jnp.where(in_steady & past_s0,
                           det.win_events + sig.thrash_new.astype(i32),
                           jnp.zeros_like(det.win_events))
    boundary = in_steady & (jnp.mod(t - s0, W) == 0)
    eval_now = boundary & past_s0          # a window just closed
    bad = win_events.astype(jnp.float32) > spec.thrash_rate_threshold
    res_ok = det.win_resident              # covers the closed window's ticks
    windows_resident = det.windows_resident + (eval_now & res_ok).astype(i32)
    windows_bad = det.windows_bad + (eval_now & res_ok & bad).astype(i32)
    events_resident = det.events_resident + jnp.where(eval_now & res_ok,
                                                      win_events, 0)
    win_events = jnp.where(eval_now, 0, win_events)
    # boundary tick opens window j: its residency starts from this tick
    win_resident = jnp.where(
        boundary, active,
        jnp.where(in_steady, det.win_resident & active, det.win_resident))

    # ---- protection violation --------------------------------------------
    prot = jnp.asarray(spec.protection, jnp.float32)
    fu = sig.fast_usage.astype(jnp.float32)
    su = sig.slow_usage.astype(jnp.float32)
    viol = ((prot > 0)
            & (fu + su >= prot)
            & (fu < prot * (1.0 - spec.prot_tolerance))
            & active
            & ((sig.attempted > 0) | (sig.demotions > 0)))
    viol_ticks = det.viol_ticks + (in_steady & viol).astype(i32)
    fast_sum = det.fast_sum + jnp.where(in_steady, fu, 0.0)

    # ---- promotion stall + shared roster gate ----------------------------
    att_steady = det.att_steady + jnp.where(in_steady,
                                            sig.attempted.astype(i32), 0)
    promo_steady = det.promo_steady + jnp.where(in_steady,
                                                sig.promotions.astype(i32), 0)
    active_steady = det.active_steady + (in_steady & active).astype(i32)
    active_last = jnp.where(in_steady, active, det.active_last)

    # ---- noisy neighbor ---------------------------------------------------
    in_base = t < spec.base_ticks
    mig = (sig.promotions + sig.demotions).astype(i32)
    mig_steady = det.mig_steady + jnp.where(in_steady, mig, 0)
    lat = sig.latency.astype(jnp.float32)
    lat_base_sum = det.lat_base_sum + jnp.where(in_base, lat, 0.0)
    lat_steady_sum = det.lat_steady_sum + jnp.where(in_steady, lat, 0.0)

    # ---- running verdicts (online-only flag counters) --------------------
    steady_so_far = jnp.maximum(t - s0 + 1, 1).astype(jnp.float32)
    n_res = windows_resident.astype(jnp.float32)
    f_thrash = (windows_resident >= 1) & (
        windows_bad.astype(jnp.float32)
        >= spec.thrash_frac_threshold * n_res)
    gate = active & (active_steady.astype(jnp.float32)
                     >= spec.resident_min_frac * steady_so_far)
    f_prot = in_steady & gate & (prot > 0) & (
        viol_ticks.astype(jnp.float32)
        >= spec.prot_frac_threshold * steady_so_far)
    attf = att_steady.astype(jnp.float32)
    ratio = promo_steady.astype(jnp.float32) / jnp.maximum(attf, 1.0)
    f_stall = (in_steady & gate
               & (attf >= spec.stall_min_attempts * steady_so_far)
               & (ratio < spec.stall_success))
    if spec.n_tenants >= 2:
        total_mig = mig_steady.sum().astype(jnp.float32)
        share = mig_steady.astype(jnp.float32) / jnp.maximum(total_mig, 1.0)
        n_base_done = jnp.minimum(t + 1, spec.base_ticks).astype(jnp.float32)
        lat_base = jnp.maximum(
            lat_base_sum / jnp.maximum(n_base_done, 1.0), 1e-9)
        degrade = (lat_steady_sum / steady_so_far) / lat_base
        top2 = jax.lax.top_k(degrade, 2)[0]
        worst_other = jnp.where(degrade >= top2[0], top2[1], top2[0])
        f_noisy = (in_steady & (total_mig > 0)
                   & (share > spec.noisy_dominance)
                   & (worst_other > spec.noisy_degrade))
    else:
        f_noisy = jnp.zeros((spec.n_tenants,), bool)

    flags = jnp.stack([f_thrash, f_prot, f_noisy, f_stall], axis=-1)
    flag_ticks = det.flag_ticks + flags.astype(i32)
    first_flag = jnp.where(flags & (det.first_flag < 0),
                           t.astype(i32), det.first_flag)

    return DetectorState(
        win_events=win_events, win_resident=win_resident,
        windows_resident=windows_resident, windows_bad=windows_bad,
        events_resident=events_resident,
        viol_ticks=viol_ticks, fast_sum=fast_sum,
        att_steady=att_steady, promo_steady=promo_steady,
        mig_steady=mig_steady, lat_base_sum=lat_base_sum,
        lat_steady_sum=lat_steady_sum,
        active_steady=active_steady, active_last=active_last,
        flag_ticks=flag_ticks, first_flag=first_flag)


def run_detector(spec: DetectorSpec, *, active, thrash_new, fast_usage,
                 slow_usage, attempted, promotions, demotions,
                 latency) -> DetectorState:
    """Replay host-side [ticks, T] telemetry through the streaming update
    (one jitted scan). The differential bridge: feed it the SAME arrays the
    offline detectors consume and ``streaming_pathologies`` must agree with
    ``detect_all``."""
    xs = (jnp.asarray(active, bool),
          jnp.asarray(thrash_new, jnp.int32),
          jnp.asarray(fast_usage, jnp.int32),
          jnp.asarray(slow_usage, jnp.int32),
          jnp.asarray(attempted, jnp.int32),
          jnp.asarray(promotions, jnp.int32),
          jnp.asarray(demotions, jnp.int32),
          jnp.asarray(latency, jnp.float32))
    ticks = xs[0].shape[0]
    assert ticks == spec.horizon, (ticks, spec.horizon)

    def step(det, x):
        t, sig = x[0], DetectorSignals(*x[1:])
        return update_detector(spec, det, sig, t), None

    final, _ = jax.jit(lambda d, x: jax.lax.scan(step, d, x))(
        init_detector(spec), (jnp.arange(ticks, dtype=jnp.int32),) + xs)
    return final


def streaming_pathologies(spec: DetectorSpec,
                          det: DetectorState) -> List[Pathology]:
    """End-of-run decisions from the final counters — the same thresholds,
    gates and severity/evidence formulas as ``pathology.detect_all``, just
    computed from O(T) streamed state instead of [ticks, T] traces."""
    d = {f: np.asarray(getattr(det, f)) for f in det._fields}
    if d["flag_ticks"].ndim == 3:
        raise ValueError("got a batched DetectorState; index the host axis "
                         "first (tree_map(lambda x: x[h], det))")
    T = spec.n_tenants
    n_steady = spec.n_steady
    out: List[Pathology] = []
    if n_steady <= 0:
        return out

    for t in range(T):                       # chronic thrashing
        n_res = int(d["windows_resident"][t])
        if n_res < 1:
            continue
        bad_frac = float(d["windows_bad"][t]) / n_res
        if bad_frac >= spec.thrash_frac_threshold:
            out.append(Pathology(
                "chronic_thrashing", t,
                severity=bad_frac / spec.thrash_frac_threshold,
                evidence={"mean_rate": float(d["events_resident"][t]) / n_res,
                          "bad_window_frac": bad_frac,
                          "rate_threshold": spec.thrash_rate_threshold}))

    def in_window(t: int) -> bool:           # _tenant_in_window analogue
        return (bool(d["active_last"][t])
                and float(d["active_steady"][t]) / n_steady
                >= spec.resident_min_frac)

    if any(p > 0 for p in spec.protection):  # protection violation
        for t in range(T):
            if spec.protection[t] <= 0 or not in_window(t):
                continue
            frac = float(d["viol_ticks"][t]) / n_steady
            if frac >= spec.prot_frac_threshold:
                out.append(Pathology(
                    "protection_violation", t,
                    severity=frac / spec.prot_frac_threshold,
                    evidence={"violation_frac": frac,
                              "mean_fast": float(d["fast_sum"][t]) / n_steady,
                              "protection": spec.protection[t]}))

    if T >= 2:                               # noisy neighbor
        mig = d["mig_steady"].astype(np.float64)
        total = mig.sum()
        if total > 0:
            lat_now = d["lat_steady_sum"].astype(np.float64) / n_steady
            lat_base = np.maximum(
                d["lat_base_sum"].astype(np.float64) / spec.base_ticks, 1e-9)
            degrade = lat_now / lat_base
            for t in range(T):
                share = mig[t] / total
                others = np.delete(degrade, t)
                worst = float(others.max()) if others.size else 0.0
                if share > spec.noisy_dominance and worst > spec.noisy_degrade:
                    out.append(Pathology(
                        "noisy_neighbor", t,
                        severity=(share / spec.noisy_dominance)
                        * (worst / spec.noisy_degrade),
                        evidence={"migration_share": float(share),
                                  "worst_neighbor_degrade": worst}))

    for t in range(T):                       # promotion stall
        if not in_window(t):
            continue
        att = float(d["att_steady"][t])
        if att < spec.stall_min_attempts * n_steady:
            continue
        ratio = float(d["promo_steady"][t]) / max(att, 1.0)
        if ratio < spec.stall_success:
            out.append(Pathology(
                "promotion_stall", t,
                severity=spec.stall_success / max(ratio, 1e-9),
                evidence={"attempts_per_tick": att / n_steady,
                          "success_ratio": ratio}))
    return out


def flag_summary(det: DetectorState) -> dict:
    """Plain-numpy view of the online flag counters (works on a single host
    [T, K] or a batched fleet [H, T, K] state)."""
    return {"flag_ticks": np.asarray(det.flag_ticks),
            "first_flag": np.asarray(det.first_flag),
            "kinds": KINDS}
