"""Operator dashboard: render a fleet rollout's streamed telemetry as
markdown — pathology counts by host x tenant x kind, first-flag ticks, and
fast-residency percentiles decoded from the in-graph log2 histograms.

Runnable as a CLI over a small self-contained demo fleet (no benchmark
imports), which also feeds the exporter smoke in ``scripts/check.sh``:

    PYTHONPATH=src python -m repro.obs.dashboard --hosts 4 --noisy \
        --trace /tmp/fleet.trace.json --prom /tmp/fleet.prom

``--trace`` writes the migration rings as Chrome-trace JSON (open in
ui.perfetto.dev); ``--prom`` writes Prometheus text exposition.
"""
from __future__ import annotations

import argparse
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import TieringConfig
from repro.core.workloads import (ChurnSlot, build_churn_schedule, cache_like,
                                  spark_like, thrasher, web_like)
from repro.obs.attribution import COMPONENTS
from repro.obs.export import (rollout_exposition, validate_chrome_trace,
                              validate_exposition, write_chrome_trace)
from repro.obs.fleet import RolloutSummary, fleet_rollout, stack_schedules
from repro.obs.stats import hist_percentile
from repro.obs.streaming import KINDS

DEMO_FOOT = (32, 40, 40, 24)


def demo_fleet(hosts: int = 4, ticks: int = 160, noisy: bool = False,
               chunk: int = 64, k_max: int = 32
               ) -> Tuple[TieringConfig, RolloutSummary]:
    """A small mixed fleet (web/cache/spark slots, one mid-run slot churn
    per odd host) rolled out with streaming detectors. ``noisy=True``
    replaces slot 0 of the last host with the §V-B5 thrasher (late arrival,
    squeezed under slot 0's upper bound) so the demo flags a pathology."""
    total = sum(DEMO_FOOT)
    cfg = TieringConfig(n_tenants=4, n_fast_pages=int(total * 1.15),
                        n_slow_pages=total, lower_protection=(8, 12, 12, 8),
                        upper_bound=(24, 0, 0, 0), migration_cost=0.005)
    mk = (web_like, cache_like, spark_like, web_like)
    schedules = []
    for h in range(hosts):
        slots: List[ChurnSlot] = []
        for i, f in enumerate(DEMO_FOOT):
            if h % 2 and i == 2:   # odd hosts churn slot 2 mid-run
                eps = [(0, ticks // 2), (ticks * 5 // 8, ticks)]
            else:
                eps = [((h + i) % 4, ticks)]
            slots.append(ChurnSlot(mk[(h + i) % 4](f), eps))
        if noisy and h == hosts - 1:
            slots[0] = ChurnSlot(thrasher(DEMO_FOOT[0], fast_share=12),
                                 [(ticks // 5, ticks)])
        schedules.append(build_churn_schedule(slots, ticks))
    want, rates = stack_schedules(schedules)
    return cfg, fleet_rollout(cfg, want, rates, ticks, chunk=chunk,
                              k_max=k_max)


# ------------------------------------------------------------ rendering ----
def _md_table(headers: Sequence[str], rows: Sequence[Sequence]) -> str:
    head = "| " + " | ".join(headers) + " |"
    sep = "|" + "|".join("---" for _ in headers) + "|"
    body = ["| " + " | ".join(str(c) for c in r) + " |" for r in rows]
    return "\n".join([head, sep] + body)


def render_dashboard(roll: RolloutSummary,
                     quantiles: Sequence[float] = (0.5, 0.95, 0.99)) -> str:
    """The fleet roll-up as markdown: overview, pathology counters
    (host x tenant x kind from the streamed DetectorState), the slowdown
    attribution ledger (stall units by cause, fleet component shares and
    sketch percentiles), and fast-residency percentiles from the in-graph
    log2 histograms."""
    parts = ["# Fleet telemetry roll-up", ""]
    parts.append(_md_table(
        ["hosts", "ticks", "host-ticks/s", "mean latency", "migrations/tick"],
        [[roll.n_hosts, roll.ticks, f"{roll.host_ticks_per_s:,.0f}",
          f"{float(np.mean(roll.latency_mean)):.3f}",
          f"{float(np.mean(roll.migrations_per_tick)):.2f}"]]))
    parts.append("")

    parts.append("## Pathologies (streaming detectors)")
    if roll.detector is None:
        parts.append("_rollout ran with detect=False_")
    else:
        counts = roll.pathology_counts()
        parts.append(_md_table(
            ["kind", "tenants flagged (fleet-wide)"],
            [[k, v] for k, v in counts.items()] or [["(none)", 0]]))
        flagged = roll.tenants_flagged()
        if flagged:
            first = roll.pathology_first_flag()
            ticks_held = roll.pathology_flag_ticks()
            rows = []
            for h, t in flagged:
                for p in roll.host_pathologies(h):
                    if p.tenant != t:
                        continue
                    k = KINDS.index(p.kind)
                    rows.append([h, t, p.kind, f"{p.severity:.2f}",
                                 int(first[h, t, k]),
                                 int(ticks_held[h, t, k])])
            parts.append("")
            parts.append(_md_table(
                ["host", "tenant", "kind", "severity", "first flag tick",
                 "flag ticks"], rows))
    parts.append("")

    parts.append("## Slowdown attribution (stall units by cause)")
    if roll.attribution is None:
        parts.append("_rollout ran with attrib=False_")
    else:
        comp = roll.attribution_components()        # [H, T, C]
        total = roll.attribution_totals()           # [H, T]
        fhit = roll.fast_hit_fraction()             # [H, T]
        names = list(COMPONENTS)
        rows = []
        for h in range(roll.n_hosts):
            for t in range(comp.shape[1]):
                rows.append([h, t, int(total[h, t])]
                            + [int(c) for c in comp[h, t]]
                            + [f"{fhit[h, t]:.3f}"])
        parts.append(_md_table(
            ["host", "tenant", "stall units"] + names + ["fast-hit"], rows))
        parts.append("")
        rup = roll.attribution_rollup()
        shares = rup["component_shares"]
        parts.append(_md_table(
            ["fleet stall units"] + names
            + [f"p{int(q * 100)}/tick" for q in quantiles] + ["conserved"],
            [[rup["stall_units_total"]]
             + [f"{shares[k]:.1%}" for k in names]
             + [f"{v:.0f}" for v in roll.stall_percentiles(quantiles)]
             + [rup["conserved"]]]))
    parts.append("")

    parts.append("## Fast-tier residency (ticks, log2-bucket lower edges)")
    hist = np.asarray(roll.final_state.stats.resid_hist)   # [H, T, NB]
    rows = []
    for h in range(roll.n_hosts):
        ps = [hist_percentile(hist[h], q) for q in quantiles]
        for t in range(hist.shape[1]):
            rows.append([h, t] + [f"{p[t]:.0f}" for p in ps])
    parts.append(_md_table(
        ["host", "tenant"] + [f"p{int(q * 100)}" for q in quantiles], rows))
    parts.append("")
    return "\n".join(parts)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Render a demo fleet rollout as a markdown dashboard.")
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--ticks", type=int, default=160)
    ap.add_argument("--noisy", action="store_true",
                    help="inject a thrasher on the last host")
    ap.add_argument("--trace", metavar="PATH",
                    help="also write Chrome-trace JSON of the migration "
                         "rings (open in ui.perfetto.dev)")
    ap.add_argument("--prom", metavar="PATH",
                    help="also write Prometheus text exposition")
    args = ap.parse_args(argv)

    cfg, roll = demo_fleet(args.hosts, args.ticks, noisy=args.noisy)
    print(render_dashboard(roll))

    if args.trace:
        events = {h: roll.host_migrations(h)[0] for h in range(roll.n_hosts)}
        trace = write_chrome_trace(args.trace, events,
                                   t_resident=cfg.t_resident,
                                   horizon=args.ticks)
        n = validate_chrome_trace(trace)
        print(f"wrote {args.trace}: {n} trace events (validated)")
    if args.prom:
        text = rollout_exposition(roll)
        n = validate_exposition(text)
        with open(args.prom, "w") as f:
            f.write(text)
        print(f"wrote {args.prom}: {n} samples (validated)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
