"""In-graph per-tenant tiering statistics — the cgroup ``tiering_stat``
analogue of paper §IV-C, collected inside the compiled tick.

``TierStats`` rides in the engine/serving state pytree and is updated with
pure scatter/adds, so it works identically under ``jax.lax.scan`` (trace
engine), inside the jitted serve step, and under ``jax.vmap`` (fleet
harness). Cumulative totals live in ``core.state.Counters``; this module
adds the *distributional* and *windowed* metrics operators need to diagnose
pathologies: log-bucketed fast-tier residency histograms, attempt-vs-success
migration counters, contention / watermark / throttle state occupancy, and
EWMA-windowed thrash and migration rates.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

N_RESID_BUCKETS = 16         # log2 buckets: [0,2), [2,4), [4,8), ... ticks
WINDOW_DECAY = 0.9           # EWMA decay for windowed rates (per tick)


class TierStats(NamedTuple):
    """Per-tenant tiering_stat metrics. All [T]-leading unless noted."""
    # distribution: fast-tier residency time at demotion/free, log2 buckets
    resid_hist: jax.Array          # [T, N_RESID_BUCKETS] int32
    # attempt vs success (cumulative)
    promo_attempts: jax.Array      # [T] int32 candidates offered to promoter
    promo_success: jax.Array       # [T] int32 pages actually promoted
    demo_attempts: jax.Array       # [T] int32 demotion quota issued
    demo_success: jax.Array        # [T] int32 pages actually demoted
    # state occupancy (ticks spent in each condition, cumulative)
    contended_ticks: jax.Array     # [T] int32 local memory contended
    throttled_ticks: jax.Array     # [T] int32 promotion-throttled (Eq.2)
    below_protection_ticks: jax.Array  # [T] int32 held under lower protection
    # windowed rates (EWMA over ticks; rate ~ events per 1/(1-decay) ticks)
    thrash_rate: jax.Array         # [T] f32
    promo_rate: jax.Array          # [T] f32
    demo_rate: jax.Array           # [T] f32
    # aux: tick each fast-resident page/slot entered the fast tier (-1 = not
    # fast). Engine shape [L] (logical pages); serving shape [B, Mf] (slots).
    fast_since: jax.Array          # int32
    ticks: jax.Array               # scalar int32 ticks observed


def init_stats(n_tenants: int, fast_since_shape,
               n_buckets: int = N_RESID_BUCKETS) -> TierStats:
    z = jnp.zeros((n_tenants,), jnp.int32)
    f = jnp.zeros((n_tenants,), jnp.float32)
    return TierStats(
        resid_hist=jnp.zeros((n_tenants, n_buckets), jnp.int32),
        promo_attempts=z, promo_success=z, demo_attempts=z, demo_success=z,
        contended_ticks=z, throttled_ticks=z, below_protection_ticks=z,
        thrash_rate=f, promo_rate=f, demo_rate=f,
        fast_since=jnp.full(fast_since_shape, -1, jnp.int32),
        ticks=jnp.zeros((), jnp.int32))


def residency_bucket(age: jax.Array, n_buckets: int = N_RESID_BUCKETS
                     ) -> jax.Array:
    """log2 bucket of a residency age (ticks): 0/1 -> 0, 2-3 -> 1, 4-7 -> 2,
    ...; clipped to the last bucket."""
    a = jnp.maximum(age, 1).astype(jnp.float32)
    b = jnp.floor(jnp.log2(a)).astype(jnp.int32)
    return jnp.clip(b, 0, n_buckets - 1)


def bucket_edges(n_buckets: int = N_RESID_BUCKETS) -> np.ndarray:
    """Host-side: inclusive lower edge of each bucket, in ticks."""
    return np.concatenate([[0], 2 ** np.arange(1, n_buckets)])


def below_protection(fast_usage: jax.Array, slow_usage: jax.Array,
                     lower_protection: jax.Array) -> jax.Array:
    """[T] bool: tenant's footprint covers its lower protection but its
    fast-tier share sits below it — the §IV-B invariant under strain. Shared
    by both tick paths so the in-graph metric and the offline
    protection-violation detector keep one definition."""
    return ((lower_protection > 0)
            & (fast_usage < lower_protection)
            & (fast_usage + slow_usage >= lower_protection))


def record_fast_entries(stats: TierStats, entered: jax.Array,
                        t: jax.Array) -> TierStats:
    """Stamp the entry tick of pages/slots that just became fast-resident.
    entered: bool mask with the same shape as ``stats.fast_since``."""
    return stats._replace(
        fast_since=jnp.where(entered, t, stats.fast_since))


def record_fast_exits(stats: TierStats, exited: jax.Array,
                      owners: jax.Array, t: jax.Array) -> TierStats:
    """Bucket residency time for pages/slots leaving the fast tier (demotion
    or free) into the per-tenant histogram, and clear their entry stamps.
    exited/owners: same shape as ``stats.fast_since``."""
    exited = exited & (stats.fast_since >= 0)
    age = t - stats.fast_since
    bucket = residency_bucket(age, stats.resid_hist.shape[1])
    hist = stats.resid_hist.at[owners.reshape(-1), bucket.reshape(-1)].add(
        exited.reshape(-1).astype(jnp.int32))
    return stats._replace(
        resid_hist=hist,
        fast_since=jnp.where(exited, -1, stats.fast_since))


def record_fast_exits_at(stats: TierStats, pages: jax.Array,
                         exited: jax.Array, owners: jax.Array,
                         t: jax.Array) -> TierStats:
    """Compact variant of ``record_fast_exits`` for a 1-D ``fast_since``:
    ``pages`` indexes into it, ``exited``/``owners`` share ``pages``' shape.
    Lets callers that already hold a small candidate stream (e.g. the
    engine's [T, k] selection output) pay scatters over T*k lanes, not L."""
    L = stats.fast_since.shape[0]
    fs = stats.fast_since[pages]
    exited = exited & (fs >= 0)
    bucket = residency_bucket(t - fs, stats.resid_hist.shape[1])
    hist = stats.resid_hist.at[owners.reshape(-1), bucket.reshape(-1)].add(
        exited.reshape(-1).astype(jnp.int32))
    clear = jnp.where(exited, pages, L).reshape(-1)    # L = OOB -> dropped
    return stats._replace(
        resid_hist=hist,
        fast_since=stats.fast_since.at[clear].set(-1, mode="drop"))


def update_tick(stats: TierStats, *,
                promo_attempts: jax.Array, promo_success: jax.Array,
                demo_attempts: jax.Array, demo_success: jax.Array,
                thrash_new: jax.Array,
                contended: jax.Array, throttled: Optional[jax.Array] = None,
                below_protection: Optional[jax.Array] = None,
                decay: float = WINDOW_DECAY) -> TierStats:
    """Fold one tick's telemetry into the stats. All [T] except ``contended``
    (scalar bool, broadcast to every tenant)."""
    T = stats.promo_attempts.shape[0]
    c = jnp.broadcast_to(contended.astype(jnp.int32), (T,))
    thr = (jnp.zeros((T,), jnp.int32) if throttled is None
           else throttled.astype(jnp.int32))
    bp = (jnp.zeros((T,), jnp.int32) if below_protection is None
          else below_protection.astype(jnp.int32))
    return stats._replace(
        promo_attempts=stats.promo_attempts + promo_attempts,
        promo_success=stats.promo_success + promo_success,
        demo_attempts=stats.demo_attempts + demo_attempts,
        demo_success=stats.demo_success + demo_success,
        contended_ticks=stats.contended_ticks + c,
        throttled_ticks=stats.throttled_ticks + thr,
        below_protection_ticks=stats.below_protection_ticks + bp,
        thrash_rate=decay * stats.thrash_rate + thrash_new.astype(jnp.float32),
        promo_rate=decay * stats.promo_rate + promo_success.astype(jnp.float32),
        demo_rate=decay * stats.demo_rate + demo_success.astype(jnp.float32),
        ticks=stats.ticks + 1)


# One histogram-percentile spec, two implementations (jnp for in-graph
# exports, numpy for host-side decoding). For a [T, NB] histogram with
# inclusive cumulative mass ``cum`` and ``total = cum[:, -1]``, the
# q-percentile is the LOWER EDGE of the first bucket where
# ``cum >= q * total``. Pinned consequences (tests/test_streaming_obs.py):
#   * empty histogram (total == 0)  -> 0.0
#   * all mass in the last bucket   -> last edge for every q > 0
#   * q = 0 -> edges[0] = 0 (cum[0] >= 0 always holds)
#   * q = 1 -> the last non-empty bucket's edge
def hist_percentile_j(hist: jax.Array, q: float) -> jax.Array:
    """Pure-jnp per-tenant percentile (bucket lower edge) of residency."""
    edges = jnp.asarray(bucket_edges(hist.shape[1]), jnp.float32)
    cum = jnp.cumsum(hist, axis=1)
    total = cum[:, -1:]
    idx = jnp.argmax(cum >= q * total, axis=1)
    return jnp.where(total[:, 0] > 0, edges[idx], 0.0)


def stats_export(stats: TierStats) -> dict:
    """Derived tiering_stat metrics as pure jnp — safe under jit/vmap (the
    traced-state counterpart of ``stats_summary``)."""
    ticks = jnp.maximum(stats.ticks, 1).astype(jnp.float32)
    att_p = stats.promo_attempts.astype(jnp.float32)
    att_d = stats.demo_attempts.astype(jnp.float32)
    return {
        "resid_p50": hist_percentile_j(stats.resid_hist, 0.50),
        "resid_p99": hist_percentile_j(stats.resid_hist, 0.99),
        "promo_success_ratio": jnp.where(
            att_p > 0, stats.promo_success / jnp.maximum(att_p, 1.0), 1.0),
        "demo_success_ratio": jnp.where(
            att_d > 0, stats.demo_success / jnp.maximum(att_d, 1.0), 1.0),
        "contended_frac": stats.contended_ticks / ticks,
        "throttled_frac": stats.throttled_ticks / ticks,
        "below_protection_frac": stats.below_protection_ticks / ticks,
        "thrash_rate": stats.thrash_rate,
    }


# ------------------------------------------------------------ host side ----
def hist_percentile(hist: np.ndarray, q: float) -> np.ndarray:
    """Numpy twin of ``hist_percentile_j`` — same spec (see above), decoded
    host-side and vectorized over tenants."""
    hist = np.asarray(hist)
    edges = bucket_edges(hist.shape[1]).astype(np.float64)
    cum = np.cumsum(hist, axis=1)
    total = cum[:, -1]
    idx = np.argmax(cum >= q * total[:, None], axis=1)
    return np.where(total > 0, edges[idx], 0.0)


def stats_summary(stats: TierStats) -> dict:
    """Decode a TierStats pytree to plain numpy, with derived ratios the
    pathology detectors and reports consume."""
    h = np.asarray(stats.resid_hist)
    att_p = np.asarray(stats.promo_attempts).astype(np.float64)
    suc_p = np.asarray(stats.promo_success).astype(np.float64)
    att_d = np.asarray(stats.demo_attempts).astype(np.float64)
    suc_d = np.asarray(stats.demo_success).astype(np.float64)
    ticks = max(int(stats.ticks), 1)
    return {
        "resid_hist": h,
        "resid_bucket_edges": bucket_edges(h.shape[1]),
        "resid_p50": hist_percentile(h, 0.50),
        "resid_p99": hist_percentile(h, 0.99),
        "promo_attempts": att_p.astype(np.int64),
        "promo_success": suc_p.astype(np.int64),
        "promo_success_ratio": np.where(att_p > 0, suc_p / np.maximum(att_p, 1), 1.0),
        "demo_attempts": att_d.astype(np.int64),
        "demo_success": suc_d.astype(np.int64),
        "demo_success_ratio": np.where(att_d > 0, suc_d / np.maximum(att_d, 1), 1.0),
        "contended_frac": np.asarray(stats.contended_ticks) / ticks,
        "throttled_frac": np.asarray(stats.throttled_ticks) / ticks,
        "below_protection_frac": np.asarray(stats.below_protection_ticks) / ticks,
        "thrash_rate": np.asarray(stats.thrash_rate),
        "promo_rate": np.asarray(stats.promo_rate),
        "demo_rate": np.asarray(stats.demo_rate),
        "ticks": ticks,
    }


def format_tier_stat(stat: dict, summary: dict, tenant: int) -> str:
    """One tenant's cgroup-file-style report line block (§IV-C)."""
    lines = []
    for key in ("local_usage_bytes", "cxl_usage_bytes", "pgpromote",
                "pgdemote", "pgpromote_attempted", "pgreclaim", "pgalloc",
                "thrash_events", "sync_demotions"):
        if key in stat:
            lines.append(f"  {key} {int(np.asarray(stat[key])[tenant])}")
    lines.append(f"  promo_success_ratio "
                 f"{summary['promo_success_ratio'][tenant]:.3f}")
    lines.append(f"  resident_time_p50_ticks {summary['resid_p50'][tenant]:.0f}")
    lines.append(f"  resident_time_p99_ticks {summary['resid_p99'][tenant]:.0f}")
    lines.append(f"  thrash_rate_windowed {summary['thrash_rate'][tenant]:.2f}")
    lines.append(f"  contended_frac {summary['contended_frac'][tenant]:.3f}")
    lines.append(f"  throttled_frac {summary['throttled_frac'][tenant]:.3f}")
    lines.append(f"  below_protection_frac "
                 f"{summary['below_protection_frac'][tenant]:.3f}")
    return "\n".join(lines)
