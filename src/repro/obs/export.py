"""Host-side telemetry exporters: the migration event ring as a
Chrome-trace/Perfetto JSON timeline, and fleet counters + histogram
percentiles as Prometheus text exposition.

Both exporters consume already-decoded numpy telemetry (``decode_ring``
events, ``Counters``/``TierStats`` arrays, streaming ``DetectorState``
counters) — they never touch device state, so they cost nothing unless an
operator asks for them. Each has a validator used by the exporter smoke in
``scripts/check.sh``:

  * ``validate_chrome_trace`` — the object round-trips as JSON, every event
    carries the required fields, and timestamps are monotone per track
    (pid = host, tid = tenant).
  * ``validate_exposition`` — every line matches the Prometheus text-format
    grammar, sample names belong to a declared metric family, and histogram
    series are cumulative with a ``+Inf`` bucket equal to ``_count``.
"""
from __future__ import annotations

import json
import re
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.obs.attribution import COMPONENTS
from repro.obs.sketch import sketch_edges, sketch_percentile
from repro.obs.stats import bucket_edges, hist_percentile
from repro.obs.streaming import KINDS
from repro.obs.trace import DIR_PROMOTE, ring_summary

TICK_US = 1000          # one engine tick rendered as 1ms of trace time
QUANTILES = (0.5, 0.95, 0.99)


# ----------------------------------------------------- Chrome trace ---------
def chrome_trace(host_events: Mapping[int, np.ndarray], *,
                 t_resident: int = 8, horizon: Optional[int] = None,
                 tick_us: int = TICK_US) -> dict:
    """Render decoded migration rings as a Chrome-trace object (load the
    JSON in ui.perfetto.dev or chrome://tracing).

    ``host_events``: {host_id: structured EVENT_DTYPE array, oldest->newest
    (``decode_ring`` output)}. One trace *process* per host, one *thread*
    (track) per tenant. A promote->demote pair of the same page becomes one
    complete-event span — named ``thrash`` when the residency beat
    ``t_resident`` (cfg.t_resident: the §IV-F thrash signature), else
    ``fast_resident``. A demote with no opening promote in the ring window
    is an instant event; promotes still open at the end close at
    ``horizon`` (default: last event tick + 1) as ``fast_resident_open``.
    Events are sorted by (pid, tid, ts) so timestamps are monotone per
    track — the property ``validate_chrome_trace`` checks.
    """
    trace_events: List[dict] = []
    for host in sorted(host_events):
        ev = host_events[host]
        end = horizon if horizon is not None else \
            (int(ev["tick"].max()) + 1 if len(ev) else 0)
        trace_events.append({"ph": "M", "name": "process_name", "pid": host,
                             "tid": 0, "args": {"name": f"host{host}"}})
        for tn in sorted({int(x) for x in ev["tenant"]}):
            trace_events.append({"ph": "M", "name": "thread_name",
                                 "pid": host, "tid": tn,
                                 "args": {"name": f"tenant{tn}"}})
        open_promote: Dict[int, np.void] = {}
        spans: List[dict] = []
        for rec in ev:
            tick, tenant, page = (int(rec["tick"]), int(rec["tenant"]),
                                  int(rec["page"]))
            if int(rec["direction"]) == DIR_PROMOTE:
                open_promote[page] = rec
                continue
            opener = open_promote.pop(page, None)
            if opener is None:
                # its promote was overwritten by ring wraparound
                spans.append({"ph": "i", "s": "t", "name": "demote",
                              "cat": "migration", "pid": host, "tid": tenant,
                              "ts": tick * tick_us,
                              "args": {"page": page,
                                       "hotness": float(rec["hotness"])}})
                continue
            dur = tick - int(opener["tick"])
            spans.append({
                "ph": "X", "cat": "migration",
                "name": "thrash" if dur < t_resident else "fast_resident",
                "pid": host, "tid": tenant,
                "ts": int(opener["tick"]) * tick_us,
                "dur": max(dur * tick_us, 1),
                "args": {"page": page, "residency_ticks": dur,
                         "hotness_promote": float(opener["hotness"]),
                         "hotness_demote": float(rec["hotness"])}})
        for page, opener in open_promote.items():
            dur = max(end - int(opener["tick"]), 0)
            spans.append({
                "ph": "X", "cat": "migration", "name": "fast_resident_open",
                "pid": host, "tid": int(opener["tenant"]),
                "ts": int(opener["tick"]) * tick_us,
                "dur": max(dur * tick_us, 1),
                "args": {"page": page, "residency_ticks": dur,
                         "hotness_promote": float(opener["hotness"])}})
        spans.sort(key=lambda e: (e["tid"], e["ts"], e.get("dur", 0)))
        trace_events.extend(spans)
    return {"traceEvents": trace_events, "displayTimeUnit": "ms",
            "otherData": {"generator": "repro.obs.export",
                          "tick_us": tick_us}}


def write_chrome_trace(path: str, host_events: Mapping[int, np.ndarray],
                       **kwargs) -> dict:
    trace = chrome_trace(host_events, **kwargs)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace


def validate_chrome_trace(trace) -> int:
    """Raise ValueError unless ``trace`` is a well-formed Chrome-trace object
    with per-track monotone timestamps and balanced B/E duration spans.
    Accepts the object or its JSON text. Returns the number of non-metadata
    events validated."""
    if isinstance(trace, (str, bytes)):
        trace = json.loads(trace)
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be an object with 'traceEvents'")
    events = trace["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    last_ts: Dict[Tuple[int, int], float] = {}
    open_spans: Dict[Tuple[int, int], List[str]] = {}
    n = 0
    for i, e in enumerate(events):
        if not isinstance(e, dict) or "ph" not in e:
            raise ValueError(f"event {i}: not an object with 'ph'")
        ph = e["ph"]
        if ph == "M":
            continue
        for k in ("ts", "pid", "tid", "name"):
            if k not in e:
                raise ValueError(f"event {i}: missing '{k}'")
        if ph == "X" and e.get("dur", -1) < 0:
            raise ValueError(f"event {i}: complete event needs dur >= 0")
        key = (e["pid"], e["tid"])
        if e["ts"] < last_ts.get(key, float("-inf")):
            raise ValueError(f"event {i}: ts not monotone on track {key}")
        last_ts[key] = e["ts"]
        # B/E duration events nest as a per-track stack (trace-format spec)
        if ph == "B":
            open_spans.setdefault(key, []).append(e["name"])
        elif ph == "E":
            stack = open_spans.get(key)
            if not stack:
                raise ValueError(f"event {i}: 'E' with no open 'B' on "
                                 f"track {key}")
            stack.pop()
        n += 1
    for key, stack in open_spans.items():
        if stack:
            raise ValueError(f"track {key}: unclosed 'B' span(s) "
                             f"{stack!r} at end of trace")
    return n


# ------------------------------------------------- Prometheus text ----------
_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*")
# label values allow exactly three escapes: \\ \" \n (text-format spec);
# a stray backslash before anything else is a malformed sample
_LABEL_VAL = r"(?:\\[\\\"n]|[^\"\\\n])*"
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[a-zA-Z_][a-zA-Z0-9_]*=\"" + _LABEL_VAL + r"\""
    r"(?:,[a-zA-Z_][a-zA-Z0-9_]*=\"" + _LABEL_VAL + r"\")*,?)?\})?"
    r" (?P<value>[+-]?(?:[0-9]*\.?[0-9]+(?:[eE][+-]?[0-9]+)?|Inf|NaN))"
    r"(?: [0-9]+)?$")
_TYPES = ("counter", "gauge", "histogram", "summary", "untyped")


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n").replace('"', r"\"")


def prom_lines(name: str, help_: str, type_: str,
               samples: Iterable[Tuple[Mapping[str, object], float]],
               suffixed: bool = False) -> List[str]:
    """One metric family in text exposition format. ``samples`` is an
    iterable of ({label: value}, numeric). ``suffixed=True`` lets samples
    carry their own full name (histogram _bucket/_sum/_count) in a
    ``__name__`` pseudo-label."""
    assert _NAME_RE.fullmatch(name), name
    assert type_ in _TYPES, type_
    lines = [f"# HELP {name} {help_}", f"# TYPE {name} {type_}"]
    for labels, value in samples:
        labels = dict(labels)
        sample_name = labels.pop("__name__", name) if suffixed else name
        lab = ",".join(f'{k}="{_escape(str(v))}"'
                       for k, v in labels.items())
        lab = f"{{{lab}}}" if lab else ""
        if isinstance(value, float) and value != value:
            val = "NaN"
        elif value in (float("inf"), float("-inf")):
            val = "+Inf" if value > 0 else "-Inf"
        elif float(value) == int(value):
            val = str(int(value))
        else:
            val = repr(float(value))
        lines.append(f"{sample_name}{lab} {val}")
    return lines


def fleet_exposition(counters: Mapping[str, np.ndarray],
                     resid_hist: Optional[np.ndarray] = None,
                     flag_ticks: Optional[np.ndarray] = None,
                     first_flag: Optional[np.ndarray] = None,
                     kinds: Sequence[str] = KINDS,
                     stall_components: Optional[np.ndarray] = None,
                     stall_totals: Optional[np.ndarray] = None,
                     stall_sketch: Optional[np.ndarray] = None,
                     component_names: Sequence[str] = COMPONENTS,
                     ring_events: Optional[np.ndarray] = None,
                     ring_dropped: Optional[np.ndarray] = None,
                     prefix: str = "equilibria") -> str:
    """Fleet telemetry as Prometheus text exposition.

    counters:   {metric: [H, T] int array} cumulative counts (e.g. the
                ``Counters`` fields: promotions, demotions, ...).
    resid_hist: [H, T, NB] log2 fast-residency histograms -> native
                histogram series (le = *exclusive* upper edge of each log2
                bucket, i.e. the next bucket's lower edge) plus
                p50/p95/p99 quantile gauges via ``hist_percentile``.
    flag_ticks / first_flag: [H, T, K] streaming pathology counters.
    stall_components / stall_totals: attribution-ledger stall units
                ([H, T, C] by cause and [H, T] totals) -> labelled
                counters; the conservation invariant makes the component
                series sum to the total series exactly.
    stall_sketch: merged [SKETCH_BUCKETS] per-tick stall histogram ->
                one fleet-level native histogram + quantile gauges.
    ring_events / ring_dropped: [H] migration-ring wrap accounting
                (``ring_summary``): events ever recorded vs overwritten.
    """
    lines: List[str] = []
    for metric in sorted(counters):
        arr = np.asarray(counters[metric])
        H, T = arr.shape
        lines += prom_lines(
            f"{prefix}_{metric}_total",
            f"Cumulative {metric} per host/tenant.", "counter",
            [({"host": h, "tenant": t}, float(arr[h, t]))
             for h in range(H) for t in range(T)])

    if resid_hist is not None:
        resid_hist = np.asarray(resid_hist)
        H, T, NB = resid_hist.shape
        edges = bucket_edges(NB)
        # le of bucket i = exclusive upper edge = lower edge of bucket i+1
        les = [str(int(e)) for e in edges[1:]] + ["+Inf"]
        name = f"{prefix}_fast_residency_ticks"
        samples = []
        for h in range(H):
            for t in range(T):
                cum = np.cumsum(resid_hist[h, t])
                for i, le in enumerate(les):
                    samples.append(({"__name__": f"{name}_bucket",
                                     "host": h, "tenant": t, "le": le},
                                    float(cum[min(i, NB - 1)])))
                samples.append(({"__name__": f"{name}_count",
                                 "host": h, "tenant": t}, float(cum[-1])))
                # lower-edge approximation of the sum (log2 buckets)
                samples.append(({"__name__": f"{name}_sum", "host": h,
                                 "tenant": t},
                                float((resid_hist[h, t] * edges).sum())))
        lines += prom_lines(
            name, "Fast-tier residency at demotion/free (ticks; log2 "
            "buckets, sum approximated by bucket lower edges).",
            "histogram", samples, suffixed=True)
        qname = f"{prefix}_fast_residency_ticks_quantile"
        qsamples = []
        for q in QUANTILES:
            for h in range(H):
                p = hist_percentile(resid_hist[h], q)
                qsamples += [({"host": h, "tenant": t, "quantile": q},
                              float(p[t])) for t in range(T)]
        lines += prom_lines(
            qname, "Residency percentile (bucket lower edge).", "gauge",
            qsamples)

    if stall_components is not None:
        stall_components = np.asarray(stall_components)
        H, T, C = stall_components.shape
        lines += prom_lines(
            f"{prefix}_stall_component_total",
            "Cumulative attributed stall units by cause (conserves: "
            "components sum to stall_units_total).", "counter",
            [({"host": h, "tenant": t, "component": component_names[c]},
              float(stall_components[h, t, c]))
             for h in range(H) for t in range(T) for c in range(C)])
    if stall_totals is not None:
        stall_totals = np.asarray(stall_totals)
        H, T = stall_totals.shape
        lines += prom_lines(
            f"{prefix}_stall_units_total",
            "Cumulative attributed stall units per host/tenant.", "counter",
            [({"host": h, "tenant": t}, float(stall_totals[h, t]))
             for h in range(H) for t in range(T)])
    if stall_sketch is not None:
        stall_sketch = np.asarray(stall_sketch)
        edges = np.asarray(sketch_edges())
        cum = np.cumsum(stall_sketch.astype(np.int64))
        name = f"{prefix}_stall_units_per_tick"
        les = [("%g" % e) for e in edges[1:]] + ["+Inf"]
        samples = [({"__name__": f"{name}_bucket", "le": le},
                    float(cum[min(i, len(cum) - 1)]))
                   for i, le in enumerate(les)]
        samples.append(({"__name__": f"{name}_count"}, float(cum[-1])))
        samples.append(({"__name__": f"{name}_sum"},
                        float((stall_sketch * edges[:-1]).sum())))
        lines += prom_lines(
            name, "Fleet per-tenant-tick total stall units (mergeable "
            "sketch; sum approximated by bucket lower edges).",
            "histogram", samples, suffixed=True)
        lines += prom_lines(
            f"{prefix}_stall_units_quantile",
            "Stall-units percentile across tenant-ticks (sketch bucket "
            "lower edge).", "gauge",
            [({"quantile": q}, float(sketch_percentile(stall_sketch, q)))
             for q in QUANTILES])
    if ring_events is not None:
        ring_events = np.asarray(ring_events).reshape(-1)
        lines += prom_lines(
            f"{prefix}_ring_events_total",
            "Migration events ever recorded into the host's ring.",
            "counter",
            [({"host": h}, float(v)) for h, v in enumerate(ring_events)])
    if ring_dropped is not None:
        ring_dropped = np.asarray(ring_dropped).reshape(-1)
        lines += prom_lines(
            f"{prefix}_ring_dropped_total",
            "Migration events lost to ring wraparound (capacity "
            "overwrite).", "counter",
            [({"host": h}, float(v)) for h, v in enumerate(ring_dropped)])
    if flag_ticks is not None:
        flag_ticks = np.asarray(flag_ticks)
        H, T, K = flag_ticks.shape
        lines += prom_lines(
            f"{prefix}_pathology_flag_ticks_total",
            "Ticks the streaming pathology flag held.", "counter",
            [({"host": h, "tenant": t, "kind": kinds[k]},
              float(flag_ticks[h, t, k]))
             for h in range(H) for t in range(T) for k in range(K)])
    if first_flag is not None:
        first_flag = np.asarray(first_flag)
        H, T, K = first_flag.shape
        lines += prom_lines(
            f"{prefix}_pathology_first_flag_tick",
            "First tick the streaming pathology flag held (flagged "
            "tenants only).", "gauge",
            [({"host": h, "tenant": t, "kind": kinds[k]},
              float(first_flag[h, t, k]))
             for h in range(H) for t in range(T) for k in range(K)
             if first_flag[h, t, k] >= 0])
    return "\n".join(lines) + "\n"


def rollout_exposition(rollout, prefix: str = "equilibria") -> str:
    """Exposition of a ``fleet_rollout`` RolloutSummary: Counters totals,
    residency histograms, migration-ring wrap accounting, and — when the
    rollout streamed them — the pathology flag counters and the slowdown
    attribution ledger (component/total counters + the fleet stall
    sketch)."""
    counters = rollout.counters()
    det = rollout.final_state.det
    att = rollout.final_state.attrib if rollout.attribution is not None \
        else None
    ring = ring_summary(rollout.final_state.ring)
    return fleet_exposition(
        dict(counters._asdict()),
        resid_hist=np.asarray(rollout.final_state.stats.resid_hist),
        flag_ticks=None if det is None else det.flag_ticks,
        first_flag=None if det is None else det.first_flag,
        stall_components=None if att is None else np.asarray(att.comp),
        stall_totals=None if att is None else np.asarray(att.total),
        stall_sketch=None if att is None else rollout.stall_sketch(),
        ring_events=ring["recorded"], ring_dropped=ring["dropped"],
        prefix=prefix)


def kv_exposition(cache, prefix: str = "equilibria_kv") -> str:
    """Exposition of a serving-path ``TieredKVCache``: the KV tiering
    counters (promotions/demotions/sync demotions/thrash events per
    tenant, host label 0 — one cache per serving host) and its migration
    ring's wrap accounting."""
    counters = {k: np.asarray(v)[None, :]
                for k, v in cache.counters._asdict().items()}
    ring = ring_summary(cache.ring)
    return fleet_exposition(
        counters,
        ring_events=np.asarray([ring["recorded"]]),
        ring_dropped=np.asarray([ring["dropped"]]),
        prefix=prefix)


def validate_exposition(text: str) -> int:
    """Raise ValueError unless every line of ``text`` matches the Prometheus
    text-format grammar, every sample belongs to a declared metric family,
    and histogram series are cumulative with ``+Inf`` == ``_count``.
    Returns the number of samples validated."""
    declared: Dict[str, str] = {}
    hist_buckets: Dict[str, List[float]] = {}
    hist_counts: Dict[str, float] = {}
    n = 0
    for ln, line in enumerate(text.splitlines(), 1):
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(" ", 3)
            if len(parts) < 4 or parts[1] not in ("HELP", "TYPE"):
                raise ValueError(f"line {ln}: malformed comment {line!r}")
            if parts[1] == "TYPE":
                if parts[3] not in _TYPES:
                    raise ValueError(f"line {ln}: bad type {parts[3]!r}")
                if parts[2] in declared:
                    raise ValueError(f"line {ln}: duplicate TYPE for "
                                     f"{parts[2]!r}")
                declared[parts[2]] = parts[3]
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {ln}: not a valid sample: {line!r}")
        name = m.group("name")
        family = name
        for suffix in ("_bucket", "_sum", "_count"):
            base = name[:-len(suffix)] if name.endswith(suffix) else None
            if base and declared.get(base) == "histogram":
                family = base
        if family not in declared:
            raise ValueError(f"line {ln}: sample {name!r} has no TYPE")
        if declared[family] == "histogram":
            labels = m.group("labels") or ""
            key = family + "|" + re.sub(r'(^|,)le="[^"]*"', "", labels)
            value = float(m.group("value").replace("Inf", "inf"))
            if name.endswith("_bucket"):
                series = hist_buckets.setdefault(key, [])
                if series and value < series[-1]:
                    raise ValueError(f"line {ln}: histogram {key!r} buckets "
                                     "not cumulative")
                series.append(value)
                le = re.search(r'le="([^"]*)"', labels)
                if le is None:
                    raise ValueError(f"line {ln}: _bucket without le label")
                if le.group(1) == "+Inf":
                    hist_counts.setdefault(key, value)
            elif name.endswith("_count"):
                if key in hist_counts and hist_counts[key] != value:
                    raise ValueError(f"line {ln}: histogram {key!r} _count "
                                     "!= +Inf bucket")
        n += 1
    for key in hist_buckets:
        if key not in hist_counts:
            raise ValueError(f"histogram {key!r} missing +Inf bucket")
    return n
