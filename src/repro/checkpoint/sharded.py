"""Sharded checkpointing with atomic commit, async write, and elastic restore.

Layout: <dir>/step_<N>/
  manifest.json        tree structure, shapes, dtypes, step, save-time metadata
  <leaf-path>.npy      one file per pytree leaf (host-gathered)

Writes go to step_<N>.tmp/ and are renamed into place (atomic commit): a
crash mid-write never corrupts the latest checkpoint. ``save_async`` runs
the serialization on a background thread (double-buffered via host copies)
so the train loop is not blocked — the distributed-training pattern where
the device->host copy is the only synchronous part.

Elastic restore: leaves are plain host arrays; ``restore`` accepts an
optional shardings tree and device_puts each leaf with the *new* mesh's
sharding — restoring a 256-chip checkpoint onto any other topology.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

_SEP = "//"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                        for p in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None,
         keep: int = 3) -> Path:
    """Synchronous atomic checkpoint save."""
    base = Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat, _ = _flatten(tree)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fn = key.replace("/", "_").replace(_SEP, ".") + ".npy"
        np.save(tmp / fn, arr)
        manifest["leaves"][key] = {"file": fn, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic commit
    _gc(base, keep)
    return final


class AsyncCheckpointer:
    """Background-thread checkpointing: device->host copy happens inline
    (cheap), serialization + fsync on the worker thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_tree, extra, self.keep),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = Path(ckpt_dir)
    if not base.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in base.glob("step_*")
                   if p.is_dir() and not p.name.endswith(".tmp")
                   and (p / "manifest.json").exists())
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: Optional[int], like: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). `shardings` (optional, same structure) device_puts
    each leaf for the *current* mesh — elastic re-sharding."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat_like, treedef = _flatten(like)
    leaves = {}
    for key in flat_like:
        info = manifest["leaves"][key]
        leaves[key] = np.load(d / info["file"])
    ordered = [leaves[k] for k in flat_like]
    tree = jax.tree_util.tree_unflatten(treedef, ordered)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


def restore_extra(ckpt_dir: str, step: Optional[int] = None) -> dict:
    if step is None:
        step = latest_step(ckpt_dir)
    d = Path(ckpt_dir) / f"step_{step:08d}"
    return json.loads((d / "manifest.json").read_text()).get("extra", {})


def _gc(base: Path, keep: int):
    steps = sorted(p for p in base.glob("step_*")
                   if p.is_dir() and not p.name.endswith(".tmp"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)
