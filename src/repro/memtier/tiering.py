"""Equilibria tiering step over the paged KV cache.

Runs inside the compiled serve_step after attention: EWMA-updates page
hotness from attention mass, computes per-tenant quotas with the *same*
policy functions as the OS-level simulator (core/policy.py — Eq.1, Eq.2,
thrash controller), rounds them to per-sequence migrations (rate-limited,
one page per selected sequence per step ≈ migration bandwidth limit), and
executes the page copies between pools for all layers at once.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TieringConfig
from repro.core import policy as P
from repro.core.select import select_top_quota
from repro.core.state import Counters, TenantPolicy
from repro.memtier.kvcache import TieredKVCache
from repro.obs import stats as OS
from repro.obs import trace as OT


def _per_tenant_seq_select(score: jax.Array, eligible: jax.Array,
                           tenant: jax.Array, quota: jax.Array, n_tenants: int,
                           k_per_tenant: int = 4) -> jax.Array:
    """Pick up to quota[t] sequences per tenant with the highest score.
    score/eligible/tenant: [B]; quota: [T]. Returns selected [B] bool.
    Tenant-batched (core/select.py): one sort of B, constant in T."""
    B = score.shape[0]
    return select_top_quota(score, tenant, eligible, quota, n_tenants,
                            min(k_per_tenant, B))


def equilibria_kv_step(cache: TieredKVCache, fast_mass: jax.Array,
                       slow_mass: jax.Array, tcfg: TieringConfig,
                       policy: TenantPolicy, fast_budget: int,
                       mode: str = "equilibria") -> TieredKVCache:
    """One tiering step. fast_mass/slow_mass: [B, Mf]/[B, Ms] attention mass
    accumulated over layers this step (the hotness signal)."""
    B, Mf = cache.fast_page.shape
    Ms = cache.slow_page.shape[1]
    M = cache.page_tier.shape[1]
    T = policy.lower_protection.shape[0]
    barange = jnp.arange(B)
    t = cache.t

    # ---- hotness EWMA ----
    fast_used = cache.fast_page >= 0
    slow_used = cache.slow_page >= 0
    fast_hot = jnp.where(fast_used, tcfg.hot_decay * cache.fast_hot + fast_mass, 0.0)
    slow_hot = jnp.where(slow_used, tcfg.hot_decay * cache.slow_hot + slow_mass, 0.0)

    # ---- per-tenant usage & contention ----
    ten_oh = jax.nn.one_hot(cache.tenant, T, dtype=jnp.int32)      # [B, T]
    fast_cnt = fast_used.sum(axis=1)
    slow_cnt = slow_used.sum(axis=1)
    fast_usage = ten_oh.T @ fast_cnt                                # [T]
    global_fast = fast_cnt.sum()
    wmark = max(int(np.ceil(fast_budget * tcfg.watermark_free)), 1)
    slow_demand = (ten_oh.T @ (slow_hot.max(axis=1) >= tcfg.promo_hot_threshold
                               ).astype(jnp.int32)).sum()
    contended = (fast_budget - global_fast) < (wmark + slow_demand)

    # ---- quotas (paper Eq.1 / Eq.2, per tenant) ----
    throttled = jnp.zeros((T,), bool)
    if mode == "equilibria":
        d_scan = P.eq1_demotion_scan(fast_usage, fast_usage, policy, contended)
        sync = P.upper_bound_demotion(fast_usage, policy)
        d_quota = jnp.minimum(d_scan.astype(jnp.int32) + sync, 4)
        p_base = jnp.full((T,), 4.0, jnp.float32)
        p_scan, throttled = P.eq2_promotion_scan(p_base, fast_usage, policy,
                                                 contended, tcfg)
        p_quota = jnp.maximum((p_scan * cache.promo_scale), 0.0).astype(jnp.int32)
        bound_room = jnp.where(policy.upper_bound > 0,
                               jnp.maximum(policy.upper_bound - fast_usage, 0),
                               p_quota)
        p_quota = jnp.minimum(p_quota, bound_room)
    elif mode == "tpp":  # unregulated: demote when over budget, promote freely
        over = jnp.maximum(global_fast - (fast_budget - wmark), 0)
        d_quota = jnp.minimum(jnp.full((T,), over, jnp.int32), 4)
        p_quota = jnp.full((T,), 4, jnp.int32)
    else:  # static: no migration
        d_quota = jnp.zeros((T,), jnp.int32)
        p_quota = jnp.zeros((T,), jnp.int32)

    # ---- demotion: coldest fast page of selected sequences ----
    cold = jnp.where(fast_used, fast_hot, jnp.inf)
    src_f = jnp.argmin(cold, axis=1)                               # [B]
    has_fast = fast_used.any(axis=1)
    has_slow_free = (~slow_used).any(axis=1)
    demote_sel = _per_tenant_seq_select(
        -cold[barange, src_f], has_fast & has_slow_free, cache.tenant,
        d_quota, T)
    dst_s = jnp.argmax(~slow_used, axis=1)                         # first free slow

    apage_d = cache.fast_page[barange, src_f]                      # absolute page
    lpage_d = jnp.maximum(apage_d, 0) % M                          # page-table slot
    gpage_d = barange * (1 << 20) + jnp.maximum(apage_d, 0)        # stable identity
    thrash_new = P.thrash_check_demotions(
        cache.table, gpage_d, demote_sel, cache.tenant, t, tcfg, T)

    # obs: residency ends for the demoted fast slots; trace the event
    B_, Mf_ = cache.fast_page.shape
    exit_mask = jnp.zeros((B_, Mf_), bool).at[barange, src_f].set(demote_sel)
    slot_owner = jnp.broadcast_to(cache.tenant[:, None], (B_, Mf_))
    stats = OS.record_fast_exits(cache.stats, exit_mask, slot_owner, t)
    ring = OT.ring_record(cache.ring, demote_sel, gpage_d, cache.tenant,
                          fast_hot[barange, src_f], OT.DIR_DEMOTE, t)

    def move(dst_pool, src_pool, dst_idx, src_idx, sel):
        # dst/src pools: [L, B, Mp, pt, K, D]; move one page per selected seq
        src = src_pool[:, barange, src_idx]                        # [L, B, pt, K, D]
        cur = dst_pool[:, barange, dst_idx]
        out = jnp.where(sel[None, :, None, None, None], src, cur)
        return dst_pool.at[:, barange, dst_idx].set(out)

    slow_k = move(cache.slow_k, cache.fast_k, dst_s, src_f, demote_sel)
    slow_v = move(cache.slow_v, cache.fast_v, dst_s, src_f, demote_sel)
    slow_page = cache.slow_page.at[barange, dst_s].set(
        jnp.where(demote_sel, apage_d, cache.slow_page[barange, dst_s]))
    slow_hot = slow_hot.at[barange, dst_s].set(
        jnp.where(demote_sel, fast_hot[barange, src_f],
                  slow_hot[barange, dst_s]))
    fast_page = cache.fast_page.at[barange, src_f].set(
        jnp.where(demote_sel, -1, cache.fast_page[barange, src_f]))
    fast_hot = fast_hot.at[barange, src_f].set(
        jnp.where(demote_sel, 0.0, fast_hot[barange, src_f]))
    page_tier = cache.page_tier.at[barange, lpage_d].set(
        jnp.where(demote_sel, 1, cache.page_tier[barange, lpage_d]
                  .astype(jnp.int32)).astype(jnp.int8))
    page_idx = cache.page_idx.at[barange, lpage_d].set(
        jnp.where(demote_sel, dst_s, cache.page_idx[barange, lpage_d]))
    fast_used = fast_page >= 0
    slow_used = slow_page >= 0

    # ---- promotion: hottest slow page of selected sequences ----
    hot_s = jnp.where(slow_used, slow_hot, -jnp.inf)
    src_s = jnp.argmax(hot_s, axis=1)
    hot_enough = hot_s[barange, src_s] >= tcfg.promo_hot_threshold
    has_fast_free = (~fast_used).any(axis=1)
    headroom = jnp.maximum(fast_budget - fast_used.sum() - wmark, 0)
    promote_sel = _per_tenant_seq_select(
        hot_s[barange, src_s], hot_enough & has_fast_free, cache.tenant,
        jnp.minimum(p_quota, headroom), T)
    dst_f = jnp.argmax(~fast_used, axis=1)

    apage_p = slow_page[barange, src_s]
    lpage_p = jnp.maximum(apage_p, 0) % M
    fast_k = move(cache.fast_k, slow_k, dst_f, src_s, promote_sel)
    fast_v = move(cache.fast_v, slow_v, dst_f, src_s, promote_sel)
    fast_page = fast_page.at[barange, dst_f].set(
        jnp.where(promote_sel, apage_p, fast_page[barange, dst_f]))
    fast_hot = fast_hot.at[barange, dst_f].set(
        jnp.where(promote_sel, slow_hot[barange, src_s],
                  fast_hot[barange, dst_f]))
    slow_page = slow_page.at[barange, src_s].set(
        jnp.where(promote_sel, -1, slow_page[barange, src_s]))
    slow_hot = slow_hot.at[barange, src_s].set(
        jnp.where(promote_sel, 0.0, slow_hot[barange, src_s]))
    page_tier = page_tier.at[barange, lpage_p].set(
        jnp.where(promote_sel, 0, page_tier[barange, lpage_p]
                  .astype(jnp.int32)).astype(jnp.int8))
    page_idx = page_idx.at[barange, lpage_p].set(
        jnp.where(promote_sel, dst_f, page_idx[barange, lpage_p]))

    gpage_p = barange * (1 << 20) + jnp.maximum(apage_p, 0)
    table = P.thrash_record_promotions(cache.table, gpage_p, promote_sel, t)

    # obs: promoted pages start a fast-tier residency; trace the event
    enter_mask = jnp.zeros((B_, Mf_), bool).at[barange, dst_f].set(promote_sel)
    stats = OS.record_fast_entries(stats, enter_mask, t)
    ring = OT.ring_record(ring, promote_sel, gpage_p, cache.tenant,
                          fast_hot[barange, dst_f], OT.DIR_PROMOTE, t)

    # ---- counters & thrash controller ----
    promo_t = ten_oh.T @ promote_sel.astype(jnp.int32)
    demo_t = ten_oh.T @ demote_sel.astype(jnp.int32)
    att_t = ten_oh.T @ hot_enough.astype(jnp.int32)
    c = cache.counters
    counters = Counters(
        promotions=c.promotions + promo_t,
        demotions=c.demotions + demo_t,
        attempted_promotions=c.attempted_promotions + att_t,
        reclaims=c.reclaims, allocations=c.allocations,
        thrash_events=c.thrash_events + thrash_new,
        sync_demotions=c.sync_demotions)

    # obs: per-step tiering_stat roll-forward (§IV-C)
    fast_usage_now = ten_oh.T @ (fast_page >= 0).sum(axis=1)
    slow_usage_now = ten_oh.T @ (slow_page >= 0).sum(axis=1)
    below_prot = OS.below_protection(fast_usage_now, slow_usage_now,
                                     policy.lower_protection)
    stats = OS.update_tick(
        stats, promo_attempts=att_t, promo_success=promo_t,
        demo_attempts=d_quota, demo_success=demo_t, thrash_new=thrash_new,
        contended=contended, throttled=throttled,
        below_protection=below_prot, decay=tcfg.obs_window_decay)

    period = tcfg.controller_period

    def run_ctrl(args):
        scale, table_in, prev, mit_prev = args
        rate = (counters.thrash_events - prev).astype(jnp.float32)
        # decode is steady-state by construction after warmup
        steady = jnp.full((T,), t > 2 * period, bool)
        thrashing = rate > tcfg.r_thrashing
        mitigate = steady & thrashing
        # recovery needs a quiet window that isn't the mitigation's own
        # (same guard as core/policy.thrash_controller)
        scale = jnp.where(mitigate, jnp.maximum(scale * 0.5, 1 / 64), scale)
        scale = jnp.where(~thrashing & ~mit_prev,
                          jnp.minimum(scale * 2.0, 1.0), scale)
        slots = table_in.page.shape[0]
        cleared = table_in._replace(page=jnp.full((slots,), -1, jnp.int32))
        return scale, cleared, counters.thrash_events, steady, mitigate

    def no_ctrl(args):
        scale, table_in, prev, mit_prev = args
        return scale, table_in, prev, cache.steady, mit_prev

    promo_scale, table, thrash_prev, steady, mitigated_prev = jax.lax.cond(
        (t + 1) % period == 0, run_ctrl, no_ctrl,
        (cache.promo_scale, table, cache.thrash_prev, cache.mitigated_prev))

    return cache._replace(
        fast_k=fast_k, fast_v=fast_v, slow_k=slow_k, slow_v=slow_v,
        fast_page=fast_page, slow_page=slow_page,
        fast_hot=fast_hot, slow_hot=slow_hot,
        page_tier=page_tier, page_idx=page_idx,
        counters=counters, promo_scale=promo_scale,
        thrash_prev=thrash_prev, steady=steady,
        mitigated_prev=mitigated_prev, table=table,
        stats=stats, ring=ring, t=t + 1)
