"""Tiered paged KV cache — the Equilibria mechanism on the serving path.

Layout (per decoder layer, stacked on a leading L axis, scanned):
  fast_k/v: [L, B, Mf, pt, K, D]   fast tier (HBM-resident pages)
  slow_k/v: [L, B, Ms, pt, K, D]   slow tier (CXL/host-class pages)

Pages are per-sequence; the *global* fast tier is a shared budget enforced by
the Equilibria policy (per-tenant lower protection / upper bound / Eq.1 /
Eq.2 / thrash mitigation — the same functions as core/policy.py). Page
hotness is the per-page attention mass emitted by the attention computation —
the TPU-native analogue of NUMA hint faults: softmax weights *are* access
frequencies.

On a real TPU deployment the slow pools live in `pinned_host` memory and the
Pallas kernel (kernels/tiered_attention) streams them; in the CPU dry-run
both pools are device buffers and the latency difference is modeled.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TieringConfig
from repro.core import policy as P
from repro.core.state import Counters, TenantPolicy, ThrashTable, zero_counters
from repro.obs.stats import TierStats, init_stats, record_fast_entries
from repro.obs.trace import MigrationRing, init_ring

NEG_INF = -1e30


class TieredKVCache(NamedTuple):
    # pools (leading layer axis, scanned)
    fast_k: jax.Array      # [L, B, Mf, pt, K, D]
    fast_v: jax.Array
    slow_k: jax.Array      # [L, B, Ms, pt, K, D]
    slow_v: jax.Array
    # slot metadata [B, Mf] / [B, Ms]
    fast_page: jax.Array   # logical page id held by slot, -1 free (int32)
    slow_page: jax.Array
    fast_hot: jax.Array    # f32 EWMA attention mass
    slow_hot: jax.Array
    # logical page table [B, M]: tier (-1/0/1) and index within tier pool
    page_tier: jax.Array   # int8
    page_idx: jax.Array    # int32
    # sequence state
    seq_len: jax.Array     # [B] int32 tokens generated so far (global position)
    tenant: jax.Array      # [B] int32
    # fairness state
    counters: Counters     # [T]
    promo_scale: jax.Array  # [T] f32
    thrash_prev: jax.Array  # [T] int32
    steady: jax.Array       # [T] bool
    mitigated_prev: jax.Array  # [T] bool: mitigation fired at last controller run
    table: ThrashTable
    # observability (obs/, §IV-C): fast_since is per fast *slot* [B, Mf]
    stats: TierStats
    ring: MigrationRing
    t: jax.Array            # scalar int32 step


def cache_dims(cfg: ModelConfig, shape_seq: int, page_tokens: int,
               fast_frac: float = 0.75, slack: float = 0.3):
    """Logical pages M and per-tier pool sizes (Mf, Ms) for a target context.
    All rounded up to multiples of 16 so the page dim tiles the TP axis."""
    def r16(n):
        return max(16, ((n + 15) // 16) * 16)

    if cfg.sliding_window is not None:
        logical = r16(cfg.sliding_window // page_tokens + 2)  # ring over window
    else:
        logical = r16((shape_seq + page_tokens - 1) // page_tokens)
    mf = min(r16(int(np.ceil(logical * fast_frac)) + 1), logical)
    ms = min(r16(int(np.ceil(logical * slack)) + 1), logical)
    return logical, mf, ms


def kv_layer_count(cfg: ModelConfig) -> int:
    """Number of attention layers that need a paged KV cache."""
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.hybrid_attn_every + 1  # shared-block apps
    if cfg.family == "vlm":
        return cfg.num_layers - cfg.num_layers // cfg.cross_attn_every
    return cfg.num_layers  # dense/moe/encdec(decoder self-attn)


def init_cache(cfg: ModelConfig, tcfg: TieringConfig, batch: int, seq: int,
               abstract: bool = False):
    """Concrete zeros (tests) or ShapeDtypeStructs (dry-run input_specs)."""
    L = kv_layer_count(cfg)
    pt = tcfg.page_tokens
    M, Mf, Ms = cache_dims(cfg, seq, pt)
    K, D = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    T = tcfg.n_tenants

    def arr(shape, dtype, fill=0):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.full(shape, fill, dtype)

    tenant = (jax.ShapeDtypeStruct((batch,), jnp.int32) if abstract
              else jnp.arange(batch, dtype=jnp.int32) % T)
    z32 = functools.partial(arr, dtype=jnp.int32)

    stats = init_stats(T, (batch, Mf), tcfg.obs_resid_buckets)
    ring = init_ring(tcfg.obs_ring_capacity)
    if abstract:
        as_spec = lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)  # noqa: E731
        stats = jax.tree_util.tree_map(as_spec, stats)
        ring = jax.tree_util.tree_map(as_spec, ring)
    return TieredKVCache(
        fast_k=arr((L, batch, Mf, pt, K, D), dt),
        fast_v=arr((L, batch, Mf, pt, K, D), dt),
        slow_k=arr((L, batch, Ms, pt, K, D), dt),
        slow_v=arr((L, batch, Ms, pt, K, D), dt),
        fast_page=z32((batch, Mf), fill=-1),
        slow_page=z32((batch, Ms), fill=-1),
        fast_hot=arr((batch, Mf), jnp.float32),
        slow_hot=arr((batch, Ms), jnp.float32),
        page_tier=arr((batch, M), jnp.int8, fill=-1),
        page_idx=z32((batch, M)),
        seq_len=z32((batch,)),
        tenant=tenant,
        counters=(Counters(*[jax.ShapeDtypeStruct((T,), jnp.int32)] * 7)
                  if abstract else zero_counters(T)),
        promo_scale=arr((T,), jnp.float32, fill=1),
        thrash_prev=z32((T,)),
        steady=arr((T,), bool),
        mitigated_prev=arr((T,), bool),
        table=ThrashTable(page=z32((tcfg.thrash_table_slots,), fill=-1),
                          tick=z32((tcfg.thrash_table_slots,))),
        stats=stats, ring=ring,
        t=(jax.ShapeDtypeStruct((), jnp.int32) if abstract
           else jnp.zeros((), jnp.int32)),
    )


# ------------------------------------------------------- page allocation ----
def alloc_page_for_append(cache: TieredKVCache, tcfg: TieringConfig,
                          policy: TenantPolicy, fast_budget: int):
    """Allocate (or reuse, for SWA rings) the page that will hold this step's
    token, for every sequence. Fast placement requires the tenant to be under
    its upper bound AND the global fast budget to have headroom (§IV-D)."""
    B, M = cache.page_tier.shape
    pt_tokens = cache.fast_k.shape[3]
    pos = cache.seq_len                                   # [B] position to write
    apage = pos // pt_tokens                              # absolute page id
    lpage = apage % M                                     # ring slot for SWA
    need_new = (pos % pt_tokens) == 0
    barange = jnp.arange(B)
    cur_tier = cache.page_tier[barange, lpage].astype(jnp.int32)
    reuse = need_new & (cur_tier >= 0)                    # ring slot overwrite

    # per-tenant fast accounting
    T = policy.lower_protection.shape[0]
    fast_cnt = (cache.fast_page >= 0).sum(axis=1)         # [B]
    ten_oh = jax.nn.one_hot(cache.tenant, T, dtype=jnp.int32)  # [B, T]
    fast_usage = ten_oh.T @ fast_cnt                      # [T]
    global_fast = fast_cnt.sum()

    bound = policy.upper_bound[cache.tenant]
    under_bound = (bound == 0) | (fast_usage[cache.tenant] < bound)
    fast_free_slot = cache.fast_page < 0                  # [B, Mf]
    has_fast_slot = fast_free_slot.any(axis=1)
    budget_rank = jnp.cumsum((need_new & ~reuse).astype(jnp.int32)) - 1
    budget_ok = (global_fast + budget_rank) < fast_budget
    go_fast = need_new & ~reuse & under_bound & has_fast_slot & budget_ok

    fast_slot = jnp.argmax(fast_free_slot, axis=1)        # first free
    slow_free_slot = cache.slow_page < 0
    slow_slot = jnp.argmax(slow_free_slot, axis=1)

    # apply allocations
    new_tier = jnp.where(go_fast, 0, 1).astype(jnp.int8)
    new_idx = jnp.where(go_fast, fast_slot, slow_slot)
    page_tier = cache.page_tier.at[barange, lpage].set(
        jnp.where(need_new & ~reuse, new_tier, cache.page_tier[barange, lpage]))
    page_idx = cache.page_idx.at[barange, lpage].set(
        jnp.where(need_new & ~reuse, new_idx, cache.page_idx[barange, lpage]))
    take_fast = need_new & ~reuse & go_fast
    take_slow = need_new & ~reuse & ~go_fast
    fast_page = cache.fast_page.at[barange, fast_slot].set(
        jnp.where(take_fast, apage, cache.fast_page[barange, fast_slot]))
    slow_page = cache.slow_page.at[barange, slow_slot].set(
        jnp.where(take_slow, apage, cache.slow_page[barange, slow_slot]))
    # ring-slot reuse (SWA): refresh the pool slot's absolute page id
    reuse_idx = cache.page_idx[barange, lpage]
    reuse_fast = reuse & (cur_tier == 0)
    reuse_slow = reuse & (cur_tier == 1)
    fast_page = fast_page.at[barange, reuse_idx].set(
        jnp.where(reuse_fast, apage, fast_page[barange, reuse_idx]))
    slow_page = slow_page.at[barange, reuse_idx].set(
        jnp.where(reuse_slow, apage, slow_page[barange, reuse_idx]))
    alloc_t = ten_oh.T @ (need_new & ~reuse).astype(jnp.int32)

    # obs: new fast-tier placements start their residency clock (§IV-C)
    entered = jnp.zeros_like(cache.fast_page, bool).at[
        jnp.arange(B), fast_slot].set(take_fast)
    stats = record_fast_entries(cache.stats, entered, cache.t)

    cache = cache._replace(page_tier=page_tier, page_idx=page_idx,
                           fast_page=fast_page, slow_page=slow_page,
                           stats=stats,
                           counters=cache.counters._replace(
                               allocations=cache.counters.allocations + alloc_t))
    return cache, lpage


# ------------------------------------------------------------- KV append ----
def append_token_kv(pool_k, pool_v, other_k, other_v, cache: TieredKVCache,
                    lpage, k_new, v_new):
    """Write this step's K/V ([B,1,K,D]) into the page allocated by
    alloc_page_for_append. pool_* are this layer's [B, Mf|Ms, pt, K, D] slices;
    writes go to the fast pool slice or slow pool slice depending on tier."""
    B = k_new.shape[0]
    barange = jnp.arange(B)
    tier = cache.page_tier[barange, lpage]
    idx = cache.page_idx[barange, lpage]
    off = cache.seq_len % pool_k.shape[2]
    kw, vw = k_new[:, 0], v_new[:, 0]
    is_fast = tier == 0
    # masked writes into both pools (one is a no-op per sequence)
    fidx = jnp.where(is_fast, idx, 0)
    sidx = jnp.where(is_fast, 0, idx)
    pool_k = pool_k.at[barange, fidx, off].set(
        jnp.where(is_fast[:, None, None], kw, pool_k[barange, fidx, off]))
    pool_v = pool_v.at[barange, fidx, off].set(
        jnp.where(is_fast[:, None, None], vw, pool_v[barange, fidx, off]))
    other_k = other_k.at[barange, sidx, off].set(
        jnp.where(is_fast[:, None, None], other_k[barange, sidx, off], kw))
    other_v = other_v.at[barange, sidx, off].set(
        jnp.where(is_fast[:, None, None], other_v[barange, sidx, off], vw))
    return pool_k, pool_v, other_k, other_v


# -------------------------------------------------- tiered paged attention ----
def _pool_attention_partial(q, pool_k, pool_v, valid_tok):
    """Online-softmax partial over one pool.
    q: [B,K,G,D]; pool: [B,Mp,pt,K,D]; valid_tok: [B,Mp,pt] bool.
    Returns (acc [B,K,G,D], m [B,K,G], l [B,K,G], mass [B,K,G,Mp])."""
    B, Mp, pt, K, D = pool_k.shape
    kf = pool_k.reshape(B, Mp * pt, K, D).astype(jnp.float32)
    vf = pool_v.reshape(B, Mp * pt, K, D).astype(jnp.float32)
    sc = jnp.einsum("bkgd,btkd->bkgt", q, kf)
    vm = valid_tok.reshape(B, 1, 1, Mp * pt)
    sc = jnp.where(vm, sc, NEG_INF)
    m = sc.max(axis=-1)
    p = jnp.exp(sc - m[..., None])
    p = jnp.where(vm, p, 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bkgt,btkd->bkgd", p, vf)
    # per-(head, page) attention mass — summed over heads only after the
    # per-head merge corrections are applied (kernels/tiered_attention ref)
    mass = p.reshape(B, K, -1, Mp, pt).sum(axis=4)
    return acc, m, l, mass


def tiered_paged_attention(q, fast_k, fast_v, slow_k, slow_v,
                           fast_valid, slow_valid):
    """Decode attention over the two-tier paged cache (XLA reference; the
    Pallas kernel kernels/tiered_attention computes the same contraction).

    q: [B,1,H,D]. fast_*: [B,Mf,pt,K,D]; *_valid: [B,Mp,pt] token validity.
    Returns (out [B,1,H,D], fast_mass [B,Mf], slow_mass [B,Ms]).
    """
    B, _, H, D = q.shape
    K = fast_k.shape[3]
    G = H // K
    scale = 1.0 / np.sqrt(D)
    qg = (q[:, 0].reshape(B, K, G, D) * scale).astype(jnp.float32)
    acc_f, m_f, l_f, mass_f = _pool_attention_partial(qg, fast_k, fast_v, fast_valid)
    acc_s, m_s, l_s, mass_s = _pool_attention_partial(qg, slow_k, slow_v, slow_valid)
    # merge the two partials (flash-style)
    m = jnp.maximum(m_f, m_s)
    cf = jnp.exp(m_f - m)
    cs = jnp.exp(m_s - m)
    l = l_f * cf + l_s * cs
    acc = acc_f * cf[..., None] + acc_s * cs[..., None]
    out = (acc / jnp.maximum(l[..., None], 1e-30)).reshape(B, 1, H, D)
    # per-head merge corrections, then sum heads, then normalize by the
    # merged partition mass (identical math to kernels/tiered_attention)
    denom = jnp.maximum(l.sum(axis=(1, 2)), 1e-30)[:, None]
    mass_f = (mass_f * cf[..., None]).sum(axis=(1, 2)) / denom
    mass_s = (mass_s * cs[..., None]).sum(axis=(1, 2)) / denom
    return out.astype(q.dtype), mass_f, mass_s


def token_validity(cache: TieredKVCache, window: Optional[int]):
    """Valid token mask per pool slot: [B,Mf,pt], [B,Ms,pt]."""
    B, Mf = cache.fast_page.shape
    Ms = cache.slow_page.shape[1]
    pt = cache.fast_k.shape[3]
    cur = cache.seq_len  # tokens 0..cur (cur inclusive: this step's token written)

    def valid(slot_page):
        Mp = slot_page.shape[1]
        base = slot_page.astype(jnp.int32) * pt                     # [B,Mp]
        tok = base[:, :, None] + jnp.arange(pt)[None, None, :]      # [B,Mp,pt]
        ok = (slot_page >= 0)[:, :, None] & (tok <= cur[:, None, None])
        if window is not None:
            ok &= tok > (cur[:, None, None] - window)
        return ok

    return valid(cache.fast_page), valid(cache.slow_page)


def kv_tier_counters(cache: TieredKVCache) -> dict:
    """Host-side snapshot of the serving-path tiering counters: {metric:
    [T] numpy int array} — the cgroup ``tier_stat`` analogue for the KV
    cache, shaped for the Prometheus exporter (``export.kv_exposition``)."""
    return {k: np.asarray(v) for k, v in cache.counters._asdict().items()}
