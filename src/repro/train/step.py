"""Training step: loss, grads (with microbatch accumulation), AdamW update."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models.transformer import model_forward
from repro.optim.adamw import OptState, adamw_update


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy; stable in f32 over (possibly padded) vocab."""
    l32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(l32, axis=-1)
    ll = jnp.take_along_axis(l32, labels[..., None], axis=-1)[..., 0]
    return (lse - ll).mean()


def make_loss_fn(cfg: ModelConfig, tc: TrainConfig):
    compute_dt = jnp.dtype(cfg.dtype)

    def loss_fn(params, batch: Dict):
        # Cast f32 master weights to the compute dtype up front so the FSDP
        # all-gathers move bf16, not f32 (2x collective bytes otherwise —
        # EXPERIMENTS.md §Perf iteration C). Grads flow back to f32 masters.
        params = jax.tree_util.tree_map(
            lambda p: p.astype(compute_dt)
            if p.dtype == jnp.float32 else p, params)
        logits, aux = model_forward(params, batch, cfg, tc.remat_policy)
        loss = cross_entropy(logits, batch["labels"])
        total = loss + 0.01 * aux
        return total, {"ce": loss, "moe_aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Returns train_step(params, opt, batch) -> (params, opt, metrics)."""
    loss_fn = make_loss_fn(cfg, tc)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt: OptState, batch: Dict):
        if tc.microbatches > 1:
            n = tc.microbatches

            def micro(carry, mb):
                gacc, lacc = carry
                (loss, _), g = grad_fn(params, mb)
                gacc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + loss), None

            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / n, grads)
            loss = loss_sum / n
            extras = {}
        else:
            (loss, extras), grads = grad_fn(params, batch)
        params, opt, metrics = adamw_update(params, grads, opt, tc)
        metrics = {"loss": loss, **metrics, **extras}
        return params, opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, tc: TrainConfig):
    """Inference prefill: full forward, returns last-position logits (the KV
    writeback is a contiguous reshape into pages — see DESIGN.md)."""
    def prefill_step(params, batch: Dict):
        logits, _ = model_forward(params, batch, cfg, tc.remat_policy)
        return logits[:, -1]
    return prefill_step
