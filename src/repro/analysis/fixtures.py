"""Known-bad programs the analyzer must flag (and a clean one it must not).

These are the analyzer's own regression surface: each fixture plants
exactly the defect one pass exists to catch, so `tests/test_analysis.py`
(and `python -m repro.analysis --fixture <name> --gate`) can assert the
pass fires — and that the clean tick stays silent. Fixture findings are
never baselined.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.interval import Interval

FIXTURES = ("purity", "dtype", "overflow", "constancy", "donation", "lint",
            "clean")


# --------------------------------------------------------------- purity ----
def bad_purity():
    """A tick-shaped fn that re-enters Python: debug print + io_callback."""
    def f(c, x):
        jax.debug.print("tick {}", c)
        try:
            from jax.experimental import io_callback
            c = c + io_callback(lambda v: np.asarray(v, np.int32),
                                jax.ShapeDtypeStruct((), jnp.int32), x)
        except ImportError:  # pragma: no cover
            c = c + x
        return c, c
    return jax.make_jaxpr(f)(jnp.zeros((), jnp.int32),
                             jnp.ones((), jnp.int32))


# ---------------------------------------------------------------- dtype ----
def bad_dtype():
    """A float64 leak: traced under an enable_x64 escape hatch."""
    from jax.experimental import enable_x64

    def f(x):
        y = jnp.asarray(x, jnp.float64)      # the leak
        return (y * 2.0).sum()

    with enable_x64():
        return jax.make_jaxpr(f)(np.zeros((4,), np.float32))


# ------------------------------------------------------------- overflow ----
def bad_overflow_carry():
    """A per-tick counter growing ~L per tick: wraps int32 well inside the
    fleet horizon. Returns (closed, carry_pairs, input_ivals, horizon)."""
    L = 262_144

    def tick(counter, hits):
        return counter + hits.sum(), counter

    closed = jax.make_jaxpr(tick)(jnp.zeros((), jnp.int32),
                                  jnp.zeros((L,), jnp.int32))
    ivals = [Interval(0, 0, True), Interval(0, 1, True)]
    return closed, [(0, 0, "counter")], ivals, 10_000


def bad_overflow_scan():
    """The in-graph variant: a scan whose int32 carry wraps within the
    scanned length itself."""
    def f(c):
        def body(c, _):
            return c + 300_000, None
        c, _ = jax.lax.scan(body, c, None, length=10_000)
        return c
    closed = jax.make_jaxpr(f)(jnp.zeros((), jnp.int32))
    return closed, [], [Interval(0, 0, True)], 1


def bad_overflow_f32():
    """The old fleet accumulator shape: integer migration counts summed
    into a float32 scan carry — exact only to 2^24."""
    def f(acc, counts):
        def body(a, _):
            return a + counts.sum().astype(jnp.float32), None
        a, _ = jax.lax.scan(body, acc, None, length=5_000)
        return a
    closed = jax.make_jaxpr(f)(jnp.zeros((), jnp.float32),
                               jnp.zeros((64,), jnp.int32))
    return closed, [], [Interval(0, 0, True),
                        Interval(0, 32_768, True)], 1


# ------------------------------------------------------------ constancy ----
def bad_constancy_build(T: int):
    """A tenant-unrolled reduction: the jaxpr grows linearly in T."""
    def f(x):
        parts = []
        for t in range(T):                   # the defect: Python loop over T
            parts.append(x[t] * (t + 1))
        return sum(parts)
    return jax.make_jaxpr(f)(jnp.zeros((T, 8), jnp.float32))


def good_constancy_build(T: int):
    """The vectorized twin: constant structure at any T."""
    def f(x):
        w = jnp.arange(1, x.shape[0] + 1, dtype=jnp.float32)
        return (x * w[:, None]).sum(axis=0)
    return jax.make_jaxpr(f)(jnp.zeros((T, 8), jnp.float32))


# ------------------------------------------------------------- donation ----
def bad_donation():
    """Donates a buffer no output can alias (shape mismatch): XLA drops
    the donation silently. Returns (fn, args, donate_argnums)."""
    def f(a, b):
        return (a[:2] + b[:2]).sum()[None]
    a = jnp.zeros((8,), jnp.float32)
    b = jnp.zeros((8,), jnp.float32)
    return f, (a, b), (0,)


def good_donation():
    """A donation that aliases: same shape/dtype in and out."""
    def f(a, b):
        return a + b
    a = jnp.zeros((8,), jnp.float32)
    b = jnp.zeros((8,), jnp.float32)
    return f, (a, b), (0,)


# ----------------------------------------------------------------- lint ----
BAD_LINT_TENANT_LOOP = '''\
def make_tick(cfg):
    T = cfg.n_tenants
    def tick(state, inputs):
        acc = 0
        for ti in range(T):
            acc = acc + state[ti]
        return acc
    return tick
'''

BAD_LINT_NP_IN_GRAPH = '''\
import numpy as np
def make_tick(cfg):
    def tick(state, inputs):
        return np.maximum(state, 0) + inputs
    return tick
'''

BAD_LINT_SEAM_DEFAULT = '''\
def make_tick(cfg, detector=False, attrib=0):
    def tick(state, inputs):
        return state
    return tick
'''

CLEAN_LINT = '''\
import jax.numpy as jnp
def make_tick(cfg, detector=None, attrib=None):
    def tick(state, inputs):
        return jnp.maximum(state, 0) + inputs
    return tick
'''


# ---------------------------------------------------------------- clean ----
def clean_tick():
    """A real (small) unified tick: every jaxpr pass must stay silent at a
    modest horizon. Returns (closed, carry_pairs, input_ivals, horizon)."""
    from repro.analysis.targets import static_tick_target
    t = static_tick_target("equilibria", T=2, pages_per=8, k_max=4,
                           horizon=100)
    return t.closed, t.carry_pairs, t.input_ivals, t.horizon
