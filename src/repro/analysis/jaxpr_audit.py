"""Composable jaxpr-level audit passes.

Each pass inspects one ``ClosedJaxpr`` (recursing through scan/cond/
while/pjit sub-jaxprs via :mod:`repro.analysis.walk`) and appends
:class:`~repro.analysis.findings.Finding` objects to a shared
:class:`~repro.analysis.findings.Report`:

  purity_pass    — the compiled graph must not re-enter Python: no
                   ``io_callback``/``pure_callback``/``debug_print``,
                   no infeed/outfeed, no ordered effects.
  dtype_pass     — no 64-bit or complex leaks (x64 is globally off; a
                   64-bit aval means someone smuggled in an escape
                   hatch), no weak-type top-level outputs, carried state
                   keeps its declared width end to end.
  overflow_pass  — interval analysis (:mod:`repro.analysis.interval`)
                   over the integer dataflow: per-tick growth of each
                   carried counter, extrapolated to the declared fleet
                   horizon, plus in-graph scan-carry wrap and
                   int->float32 precision-loss events.
  donation_pass  — ``donate_argnums`` must survive to the lowered
                   artifact as input/output aliases (O(1) rollout
                   memory), checked both structurally on the jaxpr and
                   on the lowered StableHLO text.

Passes never raise on violations — they report. The CLI/gate decides
what is fatal by diffing against the committed baseline.
"""
from __future__ import annotations

import math
import re
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.findings import Finding, Report
from repro.analysis.interval import (EvalContext, Interval, IntervalEvaluator,
                                     dtype_interval)
from repro.analysis.walk import ClosedJaxpr, iter_eqns

INT32_MAX = 2 ** 31 - 1

# primitives that re-enter the Python host from inside the compiled graph
_CALLBACK_PRIMS = {
    "io_callback", "pure_callback", "debug_callback", "debug_print",
    "host_callback_call", "outside_call", "infeed", "outfeed",
}

_WIDE_DTYPES = {"float64", "int64", "uint64", "complex64", "complex128"}


# --------------------------------------------------------------- purity ----
def purity_pass(closed: ClosedJaxpr, target: str, report: Report) -> None:
    """No host callbacks, debug prints, or IO effects anywhere in the graph."""
    seen: Dict[str, int] = {}
    for eqn, path in iter_eqns(closed):
        name = eqn.primitive.name
        hit = None
        if name in _CALLBACK_PRIMS:
            hit = name
        else:
            for eff in getattr(eqn, "effects", ()) or ():
                eff_name = type(eff).__name__
                if any(k in eff_name for k in ("IO", "Callback", "Debug",
                                               "Ordered")):
                    hit = f"{name}+{eff_name}"
                    break
        if hit is None:
            continue
        base = f"{hit}@{path}" if path else hit
        k = seen.get(base, 0)
        seen[base] = k + 1
        slug = base if k == 0 else f"{base}#{k}"
        report.add(Finding(
            "purity", target, slug,
            f"host re-entry `{name}` at {path or '<top>'} — the compiled "
            f"tick must stay pure (no Python round-trips on the hot path)"))
    effects = getattr(closed, "effects", None)
    if effects:
        for eff in effects:
            eff_name = type(eff).__name__
            report.add(Finding(
                "purity", target, f"effect:{eff_name}",
                f"closed jaxpr carries effect {eff_name}; a pure graph has "
                f"an empty effect set"))


# ---------------------------------------------------------------- dtype ----
def dtype_pass(closed: ClosedJaxpr, target: str, report: Report,
               carry_pairs: Optional[Sequence[Tuple[int, int, str]]] = None,
               ) -> None:
    """No 64-bit/complex promotion; no weak-type outputs; stable carry widths.

    carry_pairs: (invar_idx, outvar_idx, name) triples pairing carried
    state leaves, used to check declared integer widths survive the tick.
    """
    wide_seen = set()

    def check_aval(aval, where):
        dtype = getattr(aval, "dtype", None)
        if dtype is None:
            return
        try:
            name = np.dtype(dtype).name
        except TypeError:
            # extended dtypes (typed PRNG keys) have no numpy equivalent
            # and no 64-bit hazard — their backing uint32 buffers do
            return
        if name in _WIDE_DTYPES and name != "complex64":
            key = (name, where)
            if key not in wide_seen:
                wide_seen.add(key)
                report.add(Finding(
                    "dtype", target, f"{name}@{where}",
                    f"{name} aval at {where or '<top>'} — x64 is globally "
                    f"disabled; a 64-bit value in-graph means an enable_x64 "
                    f"escape hatch leaked into the hot path"))

    for v in closed.jaxpr.invars:
        check_aval(v.aval, "invar")
    for eqn, path in iter_eqns(closed):
        for v in eqn.outvars:
            check_aval(v.aval, path)

    for i, v in enumerate(closed.jaxpr.outvars):
        aval = getattr(v, "aval", None)
        if aval is not None and getattr(aval, "weak_type", False):
            report.add(Finding(
                "dtype", target, f"weak-out{i}",
                f"top-level output {i} is weakly typed "
                f"({getattr(aval, 'dtype', '?')}) — weak types re-promote "
                f"at the next op; anchor with an explicit dtype"))

    for in_i, out_i, name in carry_pairs or ():
        a_in = closed.jaxpr.invars[in_i].aval
        a_out = getattr(closed.jaxpr.outvars[out_i], "aval", None)
        d_in = getattr(a_in, "dtype", None)
        d_out = getattr(a_out, "dtype", None)
        if d_in is not None and d_out is not None and d_in != d_out:
            report.add(Finding(
                "dtype", target, f"width-change:{name}",
                f"carried state leaf `{name}` enters as {np.dtype(d_in).name} "
                f"but leaves as {np.dtype(d_out).name} — declared widths in "
                f"core/state.py must survive the tick"))


# ------------------------------------------------------------- overflow ----
def overflow_pass(closed: ClosedJaxpr, target: str, report: Report,
                  input_ivals: Sequence[Interval],
                  carry_pairs: Sequence[Tuple[int, int, str]],
                  horizon: int) -> EvalContext:
    """Interval analysis: which carried integers wrap within ``horizon`` ticks.

    input_ivals seed every top-level invar (declared ranges: hotness caps,
    k caps, L, T...). For each carried integer leaf the per-tick growth
    ``g = out.hi - in.hi`` is extrapolated: unsafe when
    ``in.hi + g * horizon > INT32_MAX`` (or the leaf's actual dtype max).
    In-graph events (scan-carry wrap, int->f32 precision loss) surface as
    findings too; primitives the evaluator does not model are recorded as
    notes, never silently ignored.
    """
    ev = IntervalEvaluator(EvalContext())
    outs1 = ev.eval_closed(closed, list(input_ivals))

    # Second evaluation with each carry widened by its first-tick output:
    # a transient jump (tier -1 -> 1, a saturated gather) settles — its
    # second-iteration growth is zero — while a genuine cumulative counter
    # keeps the same per-tick rate. Only *persistent* growth extrapolates.
    in2 = list(input_ivals)
    for in_i, out_i, _name in carry_pairs:
        in2[in_i] = input_ivals[in_i].union(outs1[out_i])
    outs2 = IntervalEvaluator(EvalContext()).eval_closed(closed, in2)

    for in_i, out_i, name in carry_pairs:
        var = closed.jaxpr.invars[in_i]
        dtype = getattr(var.aval, "dtype", None)
        if dtype is None or not np.issubdtype(np.dtype(dtype), np.integer):
            continue
        o1, o2 = outs1[out_i], outs2[out_i]
        grow = max(o2.hi - o1.hi, 0.0)
        drop = min(o2.lo - o1.lo, 0.0)
        top = dtype_interval(dtype)
        if grow == 0.0 and drop == 0.0:
            continue
        hi_h = o1.hi + grow * (horizon - 1)
        lo_h = o1.lo + drop * (horizon - 1)
        if hi_h > top.hi or lo_h < top.lo:
            rate = grow if hi_h > top.hi else -drop
            safe = int((top.hi - o1.hi) // grow) if hi_h > top.hi else \
                int((o1.lo - top.lo) // max(-drop, 1.0))
            report.add(Finding(
                "overflow", target, f"carry:{name}",
                f"carried counter `{name}` ({np.dtype(dtype).name}) grows "
                f"up to {rate:g}/tick; wraps after ~{safe} ticks "
                f"(< declared horizon {horizon}) — widen the accumulator or "
                f"re-window it at the chunk boundary"))

    for event in ev.ctx.events:
        if event.kind == "cast-unbounded":
            # over-approximation (no finite bound survived to the cast):
            # informative, not gated
            report.note(f"overflow/{target}: {event.slug}: {event.detail}")
        else:
            # carry-overflow / carry-precision / cast-truncate / cast-precision
            report.add(Finding("overflow", target, event.slug, event.detail))
    for prim, n in sorted(ev.ctx.unknown_prims.items()):
        report.note(f"overflow/{target}: primitive `{prim}` (x{n}) not "
                    f"modeled; outputs widened to dtype range")
    return ev.ctx


def state_input_intervals(closed: ClosedJaxpr,
                          overrides: Dict[str, Interval],
                          names: Sequence[str]) -> List[Interval]:
    """Seed intervals for every invar: named overrides else dtype range.

    ``names`` aligns 1:1 with ``closed.jaxpr.invars`` (flattened pytree
    paths from the target builder); any name not overridden is assumed to
    span its dtype — sound, just less precise.
    """
    ivals: List[Interval] = []
    for var, name in zip(closed.jaxpr.invars, names):
        if name in overrides:
            ivals.append(overrides[name])
        else:
            dtype = getattr(var.aval, "dtype", None)
            ivals.append(dtype_interval(dtype) if dtype is not None
                         else Interval(-math.inf, math.inf, False))
    return ivals


# ------------------------------------------------------------- donation ----
_ALIAS_RE = re.compile(r"tf\.aliasing_output")


def donation_pass(fn: Callable, args: Sequence, donate_argnums: Sequence[int],
                  target: str, report: Report,
                  min_aliases: int = 1) -> None:
    """Donated inputs must alias outputs in the lowered artifact.

    Two layers: (1) structural feasibility — every donated leaf needs a
    shape/dtype-matching output leaf, else XLA silently drops the
    donation and the rollout pays double buffers; (2) the lowered
    StableHLO must carry ``tf.aliasing_output`` attributes (the CPU/TPU
    lowering of honored donations).
    """
    import jax

    jitted = jax.jit(fn, donate_argnums=tuple(donate_argnums))
    lowered = jitted.lower(*args)
    closed = jax.make_jaxpr(fn)(*args)

    flat_in, in_tree = jax.tree_util.tree_flatten(args)
    # leaf index ranges per top-level argument
    sizes = [len(jax.tree_util.tree_leaves(a)) for a in args]
    starts = np.cumsum([0] + sizes).tolist()
    out_specs = [(tuple(v.aval.shape), np.dtype(v.aval.dtype).name)
                 for v in closed.jaxpr.outvars if hasattr(v, "aval")]

    for argnum in donate_argnums:
        lo, hi = starts[argnum], starts[argnum + 1]
        for li in range(lo, hi):
            var = closed.jaxpr.invars[li]
            spec = (tuple(var.aval.shape), np.dtype(var.aval.dtype).name)
            if spec not in out_specs:
                report.add(Finding(
                    "donation", target, f"unmatched:arg{argnum}:leaf{li - lo}",
                    f"donated arg {argnum} leaf {li - lo} {spec} has no "
                    f"shape/dtype-matching output — XLA drops the donation "
                    f"and the chunked rollout double-buffers"))

    text = lowered.as_text()
    n_aliases = len(_ALIAS_RE.findall(text))
    n_donated_leaves = sum(sizes[a] for a in donate_argnums)
    if n_donated_leaves and n_aliases < min_aliases:
        report.add(Finding(
            "donation", target, "no-aliasing-in-lowered",
            f"{n_donated_leaves} leaves donated but lowered artifact has "
            f"{n_aliases} tf.aliasing_output attributes — donation did not "
            f"survive lowering"))


# -------------------------------------------------------------- compose ----
def audit_jaxpr(closed: ClosedJaxpr, target: str,
                report: Optional[Report] = None,
                carry_pairs: Optional[Sequence[Tuple[int, int, str]]] = None,
                input_ivals: Optional[Sequence[Interval]] = None,
                horizon: Optional[int] = None) -> Report:
    """Run purity + dtype (+ overflow when ranges given) on one program."""
    report = report if report is not None else Report()
    purity_pass(closed, target, report)
    dtype_pass(closed, target, report, carry_pairs=carry_pairs)
    if input_ivals is not None and carry_pairs is not None and horizon:
        overflow_pass(closed, target, report, input_ivals, carry_pairs,
                      horizon)
    return report
