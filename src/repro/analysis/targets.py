"""The real audit targets: what `python -m repro.analysis` proves things
about.

Each target builds a traced program plus the metadata the passes need:

  tick:{static,dynamic}:{mode}  — the unified tick, every policy mode x
      both ownership providers, at a small shape (tracing cost is
      shape-independent; the *structure* is what is audited).
  tick:scale                    — the dynamic tick at the ROADMAP's fleet
      scale point (L=256k pages, T=64, horizon 10k): where the overflow
      pass has to prove which int32 counters survive and which do not
      (the committed baseline acknowledges the unsafe ones; the fix is
      the chunk-boundary int64 ledger in obs/fleet.py).
  fleet:chunk                   — the chunked rollout program
      (obs.fleet.make_fleet_chunk) incl. its scan carries and the
      donation contract of the donated fleet state.
  kernel:*                      — the Pallas kernel wrappers (ref impls:
      the wrapper graphs, traced on CPU), incl. the selection-core
      kernels (seg_topk/seg_reduce/commit_moves).
  tick:pallas:equilibria        — the kernel-backed tick (impl=
      "pallas_interpret"): the pallas_call bodies audited as sub-jaxprs.

Constancy sweeps (tick structure invariant in T / schedule values) are
exposed as builders for the CLI and the test suite.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.interval import Interval, value_interval
from repro.analysis.walk import ClosedJaxpr

# declared input ranges for the overflow pass (trace data bounds)
RATE_MAX = 1.0e4          # per-page access rate per tick
DEFAULT_HORIZON = 10_000  # the ROADMAP fleet horizon
SCALE = dict(T=64, L=262_144, k_max=256, horizon=DEFAULT_HORIZON)


@dataclass
class AuditTarget:
    """One traced program plus the metadata the passes consume."""
    name: str
    closed: ClosedJaxpr
    # (invar_idx, outvar_idx, leaf_name) for scan-carried state leaves
    carry_pairs: List[Tuple[int, int, str]] = field(default_factory=list)
    input_ivals: Optional[List[Interval]] = None
    horizon: int = DEFAULT_HORIZON
    # optional donation contract: (fn, args, donate_argnums)
    donation: Optional[tuple] = None


def _leaf_names(tree) -> List[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(path) for path, _ in flat]


def _tick_target(name: str, tick, state, inputs,
                 input_overrides: Dict[int, Interval],
                 horizon: int) -> AuditTarget:
    """Package a tick fn as an audit target.

    Tick signature: (state, inputs) -> (state', out). The first
    ``len(state leaves)`` invars/outvars pair up as the scan carry; state
    leaves seed at their concrete init values, schedule inputs at their
    declared ranges (``input_overrides``: flat index within the inputs
    subtree -> Interval).
    """
    closed = jax.make_jaxpr(tick)(state, inputs)
    state_leaves = jax.tree_util.tree_leaves(state)
    names = [f"state{n}" for n in _leaf_names(state)]
    n_state = len(state_leaves)
    carry_pairs = [(i, i, names[i]) for i in range(n_state)]

    ivals = [value_interval(leaf) for leaf in state_leaves]
    in_leaves = jax.tree_util.tree_leaves(inputs)
    for j, leaf in enumerate(in_leaves):
        ivals.append(input_overrides.get(
            j, value_interval(leaf).union(Interval(0, RATE_MAX, False))))
    assert len(ivals) == len(closed.jaxpr.invars), \
        (len(ivals), len(closed.jaxpr.invars))
    return AuditTarget(name=name, closed=closed, carry_pairs=carry_pairs,
                       input_ivals=ivals, horizon=horizon)


# ------------------------------------------------------------- builders ----
def _small_cfg(T: int = 3, fast: int = 48, slow: int = 48, **kw):
    from repro.configs.base import TieringConfig
    return TieringConfig(n_tenants=T, n_fast_pages=fast, n_slow_pages=slow,
                         lower_protection=tuple([fast // (2 * T)] * T),
                         upper_bound=tuple([fast] * T), **kw)


def static_tick_target(mode: str, T: int = 3, pages_per: int = 16,
                       k_max: int = 8, horizon: int = DEFAULT_HORIZON,
                       hotness=None, impl: str = "batched",
                       name: Optional[str] = None) -> AuditTarget:
    from repro.core.engine import make_tick
    from repro.core.state import init_state
    cfg = _small_cfg(T=T, fast=T * pages_per // 2, slow=T * pages_per)
    owner = np.repeat(np.arange(T), pages_per)
    L = owner.shape[0]
    tick = make_tick(cfg, owner, mode=mode, k_max=k_max, hotness=hotness,
                     impl=impl)
    state = init_state(cfg, L, owner=owner, hotness=hotness)
    inputs = (jnp.zeros((L,), jnp.float32), jnp.ones((L,), bool))
    over = {0: Interval(0, RATE_MAX, False),       # accesses [L]
            1: Interval(0, 1, True)}               # alive [L] bool
    return _tick_target(name or f"tick:static:{mode}", tick, state, inputs,
                        over, horizon)


def hotness_tick_targets() -> List[AuditTarget]:
    """Provider tick programs under the purity/dtype/overflow passes.

    The sketch provider picks its probe branch at trace time (full
    enumeration when the per-tenant budget covers the rowspace, sampled
    draws otherwise) — both graphs are distinct audit targets."""
    from repro.core.hotness import SketchSpec
    variants = [
        ("sampled", "tick:hotness:sampled"),
        ("sketch", "tick:hotness:sketch"),          # full-coverage branch
        (SketchSpec(probe=6), "tick:hotness:sketch-sampled"),
        ("neomem", "tick:hotness:neomem"),
    ]
    return [static_tick_target("equilibria", hotness=spec, name=name)
            for spec, name in variants]


def dynamic_tick_target(mode: str, T: int = 3, L: int = 64, S: int = 16,
                        k_max: int = 8, horizon: int = DEFAULT_HORIZON,
                        name: Optional[str] = None) -> AuditTarget:
    from repro.core.churn import make_churn_tick
    from repro.core.state import init_state
    cfg = _small_cfg(T=T, fast=L // 2, slow=L // 2)
    tick = make_churn_tick(cfg, L, mode=mode, k_max=k_max)
    state = init_state(cfg, L)
    inputs = (jnp.zeros((T, S), jnp.float32), jnp.zeros((T,), jnp.int32))
    over = {0: Interval(0, RATE_MAX, False),       # rates [T, S]
            1: Interval(0, float(S), True)}        # want [T]
    return _tick_target(name or f"tick:dynamic:{mode}", tick, state, inputs,
                        over, horizon)


def scale_tick_target() -> AuditTarget:
    """The ROADMAP scale point: where int32 counters provably wrap.

    Tracing and interval analysis are shape-independent in cost, so the
    audit runs the *real* L=256k/T=64 program, not a toy stand-in."""
    return dynamic_tick_target(
        "equilibria", T=SCALE["T"], L=SCALE["L"], S=4096,
        k_max=SCALE["k_max"], horizon=SCALE["horizon"], name="tick:scale")


def fleet_chunk_target(chunk: int = 500, T: int = 4, L: int = 64,
                       S: int = 16, H: int = 4,
                       k_max: int = 8) -> AuditTarget:
    """The chunked rollout program: scan carries (fleet state + reduction
    accumulators) audited at the chunk length, donation contract on the
    donated fleet state."""
    from repro.core.churn import make_churn_tick
    from repro.core.state import init_state, stack_states
    from repro.obs.attribution import make_attribution
    from repro.obs.fleet import make_fleet_chunk
    from repro.obs.streaming import make_detector
    cfg = _small_cfg(T=T, fast=L // 2, slow=L // 2)
    det = make_detector(chunk, T, cfg.lower_protection)
    att = make_attribution(T, cfg.lat_fast)
    tick = make_churn_tick(cfg, L, mode="equilibria", k_max=k_max,
                           detector=det, attrib=att)
    period = 8
    want = jnp.full((H, period, T), S // 2, jnp.int32)
    rates = jnp.ones((H, period, T, S), jnp.float32)
    chunk_fn = make_fleet_chunk(jax.vmap(tick), want, rates, period, chunk)
    states = stack_states(init_state(cfg, L, detector=det, attrib=att), H)
    arch = jnp.arange(H, dtype=jnp.int32)
    t0 = jnp.zeros((), jnp.int32)
    closed = jax.make_jaxpr(chunk_fn)(states, arch, t0)
    ivals = [value_interval(leaf)
             for leaf in jax.tree_util.tree_leaves((states, arch, t0))]
    return AuditTarget(
        name="fleet:chunk", closed=closed, carry_pairs=[],
        input_ivals=ivals, horizon=chunk,
        donation=(chunk_fn, (states, arch, t0), (0,)))


def kernel_targets() -> List[AuditTarget]:
    """The kernel wrappers (ref impls — the graphs CPU CI runs)."""
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.migrate.ops import commit_moves, migrate_pages
    from repro.kernels.select.ops import seg_reduce, seg_topk
    from repro.kernels.ssd_scan.ops import ssd_scan
    from repro.kernels.tiered_attention.ops import tiered_attention

    out: List[AuditTarget] = []
    B, Hh, Ss, D = 1, 2, 32, 16

    q = jnp.ones((B, Hh, Ss, D), jnp.float32)
    out.append(AuditTarget(
        name="kernel:flash_attention",
        closed=jax.make_jaxpr(
            lambda q, k, v: flash_attention(q, k, v, impl="ref"))(q, q, q)))

    # pools: [L, B, Mp, pt, K, D]
    Lk, Bk, Mp, pt, Kk = 2, 2, 4, 4, 2
    src = jnp.ones((Lk, Bk, Mp, pt, Kk, D), jnp.float32)
    dst = jnp.zeros((Lk, Bk, Mp, pt, Kk, D), jnp.float32)
    idx = jnp.zeros((Bk,), jnp.int32)
    sel = jnp.ones((Bk,), bool)

    def mig(src_pool, dst_pool, src_idx, dst_idx, sel):
        return migrate_pages(src_pool, dst_pool, src_idx, dst_idx, sel,
                             impl="ref")
    out.append(AuditTarget(
        name="kernel:migrate",
        closed=jax.make_jaxpr(mig)(src, dst, idx, idx, sel),
        donation=(mig, (src, dst, idx, idx, sel), (1,))))

    x = jnp.ones((B, 64, 2, 8), jnp.float32)    # [B,S,H,P]
    a = jnp.ones((B, 64, 2), jnp.float32)
    bc = jnp.ones((B, 64, 2, 4), jnp.float32)   # [B,S,H,N]
    out.append(AuditTarget(
        name="kernel:ssd_scan",
        closed=jax.make_jaxpr(
            lambda x, a, b, c: ssd_scan(x, a, b, c, chunk=32,
                                        impl="ref"))(x, a, bc, bc)))

    Mf, Ms, pt, K = 4, 4, 8, 2
    q1 = jnp.ones((B, 1, Hh, D), jnp.float32)
    fk = jnp.ones((B, Mf, pt, K, D), jnp.float32)
    sk = jnp.ones((B, Ms, pt, K, D), jnp.float32)
    fp = jnp.zeros((B, Mf), jnp.int32)
    sp = jnp.full((B, Ms), -1, jnp.int32)
    sl = jnp.full((B,), pt, jnp.int32)
    out.append(AuditTarget(
        name="kernel:tiered_attention",
        closed=jax.make_jaxpr(
            lambda *a: tiered_attention(*a, impl="ref"))(
                q1, fk, fk, sk, sk, fp, sp, sl)))

    # selection-core kernels (kernels/select + the fused page-move commit)
    Ts, Sw = 3, 16
    score = jnp.ones((Ts, Sw), jnp.float32)
    valid = jnp.ones((Ts, Sw), bool)
    quotas = jnp.ones((Ts,), jnp.int32)
    out.append(AuditTarget(
        name="kernel:seg_topk",
        closed=jax.make_jaxpr(
            lambda s, v, q: seg_topk(s, v, q, 4, impl="ref"))(
                score, valid, quotas)))
    xi = jnp.ones((Ts, Sw), jnp.int32)
    out.append(AuditTarget(
        name="kernel:seg_reduce",
        closed=jax.make_jaxpr(
            lambda x, v: seg_reduce(x, v, impl="ref"))(xi, valid)))
    Lc, Cc, Nc = 24, 8, 6
    tier = jnp.zeros((Lc,), jnp.int32)
    ring = jnp.zeros((Cc, 5), jnp.int32)
    pages = jnp.zeros((Nc,), jnp.int32)
    take = jnp.zeros((Nc,), bool)
    tens = jnp.zeros((Nc,), jnp.int32)
    hot = jnp.zeros((Nc,), jnp.float32)
    z = jnp.zeros((), jnp.int32)
    out.append(AuditTarget(
        name="kernel:commit_moves",
        closed=jax.make_jaxpr(
            lambda *a: commit_moves(*a, direction=1, to_tier=0,
                                    impl="ref"))(
                tier, ring, z, pages, take, tens, hot, z)))
    return out


# ------------------------------------------------------ constancy sweeps ----
def tick_constancy_sweeps() -> Dict[str, Tuple[Callable, Sequence]]:
    """name -> (build, params): programs that must be jaxpr-constant.

    Each build(p) returns a ClosedJaxpr; the constancy checker asserts eqn
    count + primitive histogram are identical across the sweep."""
    def build_static_T(T):
        return static_tick_target("equilibria", T=T).closed

    def build_dynamic_T(T):
        return dynamic_tick_target("equilibria", T=T).closed

    def build_dynamic_L(L):
        return dynamic_tick_target("equilibria", L=L).closed

    def build_pallas_T(T):
        # kernel-backed tick: row padding to the block multiple keeps the
        # pallas_call grid/jaxpr structure constant in T
        return static_tick_target("equilibria", T=T,
                                  impl="pallas_interpret").closed

    sweeps = {
        "tick:static:T": (build_static_T, (2, 4)),
        "tick:dynamic:T": (build_dynamic_T, (2, 4)),
        "tick:dynamic:L": (build_dynamic_L, (64, 128)),
        "tick:pallas:T": (build_pallas_T, (2, 4)),
    }
    sweeps.update(hotness_constancy_sweeps())
    return sweeps


def hotness_constancy_sweeps() -> Dict[str, Tuple[Callable, Sequence]]:
    """Provider tick programs must not unroll in T, and the sketch/neomem
    candidate paths must not grow graph structure with L (their runtime
    cost is O(probe + T*N); graph constancy is the structural half of that
    claim). The sketch L-sweeps hold the trace-time probe branch fixed:
    ``probe=6`` keeps both L values in the sampled regime, the default
    spec keeps both in full coverage."""
    from repro.core.hotness import SketchSpec

    def build_T(prov):
        def build(T):
            return static_tick_target("equilibria", T=T,
                                      hotness=prov).closed
        return build

    def build_L(prov):
        def build(pages_per):
            return static_tick_target("equilibria", pages_per=pages_per,
                                      hotness=prov).closed
        return build

    sampled_regime = SketchSpec(probe=6)
    return {
        "tick:hotness:sampled:T": (build_T("sampled"), (2, 4)),
        "tick:hotness:sketch:T": (build_T(sampled_regime), (2, 4)),
        "tick:hotness:neomem:T": (build_T("neomem"), (2, 4)),
        "tick:hotness:sketch:L": (build_L(sampled_regime), (16, 32)),
        "tick:hotness:sketch-full:L": (build_L("sketch"), (16, 32)),
        "tick:hotness:neomem:L": (build_L("neomem"), (16, 32)),
    }


# ------------------------------------------------------------- registry ----
def all_targets(scale: bool = True,
                fleet: bool = True) -> List[AuditTarget]:
    from repro.core.tick import MODES
    out: List[AuditTarget] = []
    for mode in MODES:
        out.append(static_tick_target(mode))
    for mode in MODES:
        out.append(dynamic_tick_target(mode))
    out.extend(hotness_tick_targets())
    # the kernel-backed tick program (Pallas selection core, interpret
    # graph: the pallas_call bodies are walked as sub-jaxprs)
    out.append(static_tick_target("equilibria", impl="pallas_interpret",
                                  name="tick:pallas:equilibria"))
    if scale:
        out.append(scale_tick_target())
    if fleet:
        out.append(fleet_chunk_target())
    out.extend(kernel_targets())
    return out
