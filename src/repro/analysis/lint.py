"""AST-level lint for graph code.

The jaxpr passes see what *was* traced; the lint catches patterns that
shape what *will be* traced:

  tenant-loop   — Python ``for _ in range(<T-like>)`` in ``core/``
                  unrolls the graph linearly in tenant count, destroying
                  the constancy invariant. (The two intentionally
                  unrolled reference implementations in ``core/select.py``
                  live in the committed baseline.)
  np-in-graph   — ``np.`` calls inside a closure nested in a tick/
                  ownership/strategy builder execute at trace time on
                  host values; under jit they either constant-fold
                  silently or break retracing. Graph code uses ``jnp``.
  seam-default  — on builder functions, optional seam parameters
                  (``detector=``, ``attrib=``, ``detect=``) must default
                  to ``None`` so every engine composes without dragging
                  in the observability subtrees.

Slugs are ``rule:qualname`` (never line numbers) so the baseline
survives unrelated edits to the same file.
"""
from __future__ import annotations

import ast
import os
from typing import Iterable, List, Optional, Sequence

from repro.analysis.findings import Finding, Report

# loop bounds that smell like a tenant count
_TENANT_NAMES = {"T", "n_tenants", "num_tenants", "tenants"}
# builder functions whose nested closures get traced
_BUILDER_PREFIXES = ("make_",)
_BUILDER_SUFFIXES = ("_ownership", "_strategy", "_tick", "_provider")
# seam keywords that must default to None
_SEAM_PARAMS = {"detector", "attrib", "detect", "attribution"}


def _names_in(node: ast.AST) -> set:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)} | \
           {n.attr for n in ast.walk(node) if isinstance(n, ast.Attribute)}


def _is_builder(name: str) -> bool:
    return name.startswith(_BUILDER_PREFIXES) or \
        name.endswith(_BUILDER_SUFFIXES)


class _Linter(ast.NodeVisitor):
    def __init__(self, target: str, in_core: bool):
        self.target = target
        self.in_core = in_core
        self.findings: List[Finding] = []
        self.stack: List[str] = []          # enclosing function names
        self.seen_slugs = {}

    # ------------------------------------------------------------ helpers
    def _qual(self) -> str:
        return ".".join(self.stack) or "<module>"

    def _add(self, rule: str, message: str, qual: Optional[str] = None):
        base = f"{rule}:{qual or self._qual()}"
        k = self.seen_slugs.get(base, 0)
        self.seen_slugs[base] = k + 1
        slug = base if k == 0 else f"{base}#{k}"
        self.findings.append(Finding("lint", self.target, slug, message))

    # ------------------------------------------------------------- visits
    def visit_FunctionDef(self, node: ast.FunctionDef):
        self._check_seam_defaults(node)
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def _check_seam_defaults(self, node: ast.FunctionDef):
        # The seam contract binds *builders* (make_tick, make_churn_tick,
        # ...): their seams must default to None so engines compose
        # without observability subtrees. Plain runner flags (run_fleet's
        # detect=True toggle) are API surface, not graph seams.
        if not _is_builder(node.name):
            return
        args = node.args
        named = list(args.args) + list(args.kwonlyargs)
        defaults = ([None] * (len(args.args) - len(args.defaults))
                    + list(args.defaults) + list(args.kw_defaults))
        for arg, default in zip(named, defaults):
            if arg.arg not in _SEAM_PARAMS:
                continue
            ok = (isinstance(default, ast.Constant)
                  and default.value is None)
            if not ok:
                self._add(
                    "seam-default",
                    f"seam parameter `{arg.arg}` of "
                    f"{self._qual()}.{node.name} must default to None "
                    f"(engines compose without observability subtrees)",
                    qual=f"{self._qual()}.{node.name}.{arg.arg}"
                    if self.stack else f"{node.name}.{arg.arg}")

    def visit_For(self, node: ast.For):
        if self.in_core and self.stack:
            it = node.iter
            if (isinstance(it, ast.Call) and isinstance(it.func, ast.Name)
                    and it.func.id in ("range", "enumerate")):
                bound_names = set()
                for a in it.args:
                    bound_names |= _names_in(a)
                if bound_names & _TENANT_NAMES:
                    self._add(
                        "tenant-loop",
                        f"Python loop over a tenant-count bound "
                        f"({sorted(bound_names & _TENANT_NAMES)}) in "
                        f"{self._qual()} — unrolls the graph linearly in T; "
                        f"use vectorized lax ops")
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute):
        # np.* inside a closure nested in a builder: trace-time host math
        if (isinstance(node.value, ast.Name) and node.value.id == "np"
                and len(self.stack) >= 2
                and any(_is_builder(s) for s in self.stack[:-1])):
            self._add(
                "np-in-graph",
                f"`np.{node.attr}` inside traced closure {self._qual()} — "
                f"host numpy in graph code constant-folds at trace time or "
                f"breaks under jit; use jnp")
        self.generic_visit(node)


def lint_source(src: str, target: str, in_core: bool = False,
                ) -> List[Finding]:
    """Lint one source blob. ``target`` becomes the finding target (the
    repo-relative path for real files)."""
    tree = ast.parse(src)
    linter = _Linter(target, in_core=in_core)
    linter.visit(tree)
    return linter.findings


def lint_paths(paths: Sequence[str], report: Report,
               root: Optional[str] = None) -> None:
    """Lint .py files (directories recurse); findings append to report."""
    root = root or os.getcwd()

    def handle(path: str):
        rel = os.path.relpath(path, root)
        in_core = f"core{os.sep}" in rel or rel.startswith("core")
        with open(path) as fh:
            src = fh.read()
        try:
            report.extend(lint_source(src, rel.replace(os.sep, "/"),
                                      in_core=in_core))
        except SyntaxError as e:  # pragma: no cover
            report.add(Finding("lint", rel.replace(os.sep, "/"),
                               "syntax-error", str(e)))

    for p in paths:
        if os.path.isdir(p):
            for dirpath, _dirs, files in os.walk(p):
                for f in sorted(files):
                    if f.endswith(".py"):
                        handle(os.path.join(dirpath, f))
        elif p.endswith(".py"):
            handle(p)
