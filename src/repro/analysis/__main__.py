"""``python -m repro.analysis`` — audit the compiled tiering graphs.

Default run: trace every real target (unified tick: 4 policy modes x both
ownership providers, the L=256k/T=64 scale point, the fleet chunk program,
the four kernel wrappers), run the jaxpr passes + constancy sweeps, and
AST-lint ``src/repro``. Findings print keyed as ``pass:target:slug``.

  --gate            exit 1 on any finding not in the committed baseline
                    (analysis/baseline.json); stale baseline keys warn.
  --write-baseline  accept the current findings as the new baseline.
  --fixture NAME    audit a known-bad fixture instead of the real targets
                    (purity|dtype|overflow|constancy|donation|lint|clean);
                    fixtures are never baselined, so --gate exits non-zero
                    iff the fixture is flagged. Used by the analyzer's own
                    CI checks.
  --fast            skip the scale + fleet targets (quick local loop).
  --json            machine-readable report on stdout.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.analysis import constancy as C
from repro.analysis import fixtures as FX
from repro.analysis import lint as LI
from repro.analysis.findings import (BASELINE_PATH, Finding, Report,
                                     load_baseline, write_baseline)
from repro.analysis.jaxpr_audit import (donation_pass, dtype_pass,
                                        overflow_pass, purity_pass)

_REPO_SRC = os.path.normpath(
    os.path.join(os.path.dirname(__file__), os.pardir))      # src/repro


def _audit_target(t, report: Report) -> None:
    purity_pass(t.closed, t.name, report)
    dtype_pass(t.closed, t.name, report, carry_pairs=t.carry_pairs)
    if t.input_ivals is not None:
        overflow_pass(t.closed, t.name, report, t.input_ivals,
                      t.carry_pairs, t.horizon)
    if t.donation is not None:
        fn, args, donate = t.donation
        donation_pass(fn, args, donate, t.name, report)


def _run_real(report: Report, fast: bool, verbose: bool) -> None:
    from repro.analysis import targets as TG
    for t in TG.all_targets(scale=not fast, fleet=not fast):
        t0 = time.perf_counter()
        _audit_target(t, report)
        if verbose:
            print(f"  audited {t.name:28s} "
                  f"({time.perf_counter() - t0:.2f}s)", file=sys.stderr)
    for name, (build, params) in TG.tick_constancy_sweeps().items():
        ok, _sig, diff = C.check_constant(build, params)
        if not ok:
            report.add(Finding("constancy", name, "sweep",
                               "; ".join(diff)[:500]))
        if verbose:
            print(f"  constancy {name}: {'ok' if ok else 'VIOLATED'}",
                  file=sys.stderr)
    LI.lint_paths([_REPO_SRC], report,
                  root=os.path.normpath(os.path.join(_REPO_SRC, os.pardir)))


def _run_fixture(name: str, report: Report) -> None:
    if name == "purity":
        purity_pass(FX.bad_purity(), "fixture:purity", report)
    elif name == "dtype":
        dtype_pass(FX.bad_dtype(), "fixture:dtype", report)
    elif name == "overflow":
        for tag, fx in (("carry", FX.bad_overflow_carry),
                        ("scan", FX.bad_overflow_scan),
                        ("f32", FX.bad_overflow_f32)):
            closed, pairs, ivals, horizon = fx()
            overflow_pass(closed, f"fixture:overflow:{tag}", report, ivals,
                          pairs, horizon)
    elif name == "constancy":
        ok, _sig, diff = C.check_constant(FX.bad_constancy_build, (2, 5))
        if not ok:
            report.add(Finding("constancy", "fixture:constancy", "sweep",
                               "; ".join(diff)[:500]))
    elif name == "donation":
        fn, args, donate = FX.bad_donation()
        donation_pass(fn, args, donate, "fixture:donation", report)
    elif name == "lint":
        for tag, src in (("tenant", FX.BAD_LINT_TENANT_LOOP),
                         ("np", FX.BAD_LINT_NP_IN_GRAPH),
                         ("seam", FX.BAD_LINT_SEAM_DEFAULT)):
            report.extend(LI.lint_source(src, f"fixture:lint:{tag}",
                                         in_core=True))
    elif name == "clean":
        closed, pairs, ivals, horizon = FX.clean_tick()
        purity_pass(closed, "fixture:clean", report)
        dtype_pass(closed, "fixture:clean", report, carry_pairs=pairs)
        overflow_pass(closed, "fixture:clean", report, ivals, pairs, horizon)
        ok, _sig, diff = C.check_constant(FX.good_constancy_build, (2, 5))
        if not ok:
            report.add(Finding("constancy", "fixture:clean", "sweep",
                               "; ".join(diff)[:500]))
        fn, args, donate = FX.good_donation()
        donation_pass(fn, args, donate, "fixture:clean", report)
        report.extend(LI.lint_source(FX.CLEAN_LINT, "fixture:clean",
                                     in_core=True))
    else:
        raise SystemExit(f"unknown fixture {name!r}; "
                         f"choose from {FX.FIXTURES}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis of the compiled tiering graphs.")
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 on findings not in the committed baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept current findings as the baseline")
    ap.add_argument("--fixture", choices=FX.FIXTURES,
                    help="audit a known-bad fixture instead of real targets")
    ap.add_argument("--fast", action="store_true",
                    help="skip the scale + fleet targets")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable report on stdout")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    report = Report()
    if args.fixture:
        _run_fixture(args.fixture, report)
        baseline = []           # fixtures are never baselined
    else:
        _run_real(report, fast=args.fast, verbose=args.verbose)
        baseline = load_baseline()

    if args.write_baseline and not args.fixture:
        path = write_baseline(report)
        print(f"baseline written: {path} ({len(report.keys())} keys)")

    new = report.new_vs(baseline)
    stale = report.stale_vs(baseline)

    if args.as_json:
        out = report.to_json()
        out["new"] = [f.key for f in new]
        out["stale"] = stale
        print(json.dumps(out, indent=2))
    else:
        n_base = len(report.findings) - len(new)
        print(f"analysis: {len(report.findings)} findings "
              f"({len(new)} new, {n_base} baselined), "
              f"{len(report.notes)} notes")
        for f in new:
            print(f"NEW {f}")
        if args.verbose:
            for f in sorted(report.findings, key=lambda f: f.key):
                if f not in new:
                    print(f"    {f.key}  [baselined]")
            for n in report.notes:
                print(f"note: {n}")
        for k in stale:
            print(f"stale baseline entry (no longer fires): {k}")

    if args.gate and new:
        print(f"GATE: {len(new)} finding(s) not in baseline "
              f"({BASELINE_PATH})", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
