"""The shared "jaxpr invariant under parameter sweep" harness.

Scale-independence is a load-bearing claim: the compiled tick's program
must not change shape with tenant count, horizon, or event schedule —
otherwise compile cost and cache behavior stop being O(1) in fleet size.
Five test files used to pin this with hand-rolled
``len(jax.make_jaxpr(...).eqns)`` equalities; this module is the single
implementation they now share, and it pins the *primitive histogram* too
(sub-jaxprs included), so a rewrite that keeps the eqn count but swaps
ops (e.g. a gather becoming a tenant-unrolled select chain) still trips.

Usage::

    sig = jaxpr_signature(fn, *args)                  # one trace
    assert_jaxpr_constant(build, params)              # sweep a parameter
      # where build(p) returns (fn, args) — traced per parameter value

``assert_jaxpr_constant`` raises AssertionError with a primitive-level
diff on violation, so the failing op mix is visible in the test output.
"""
from __future__ import annotations

from typing import Callable, Iterable, List, NamedTuple, Sequence, Tuple

import jax

from repro.analysis.walk import n_eqns, prim_histogram


class JaxprSignature(NamedTuple):
    """Structural fingerprint of a traced program (sub-jaxprs included)."""
    n_eqns: int
    prims: Tuple[Tuple[str, int], ...]   # sorted (primitive, count)

    def histogram(self) -> dict:
        return dict(self.prims)

    def diff(self, other: "JaxprSignature") -> List[str]:
        """Human-readable per-primitive delta (empty iff equal)."""
        lines: List[str] = []
        if self.n_eqns != other.n_eqns:
            lines.append(f"eqn count: {self.n_eqns} != {other.n_eqns}")
        a, b = self.histogram(), other.histogram()
        for name in sorted(set(a) | set(b)):
            if a.get(name, 0) != b.get(name, 0):
                lines.append(f"  {name}: {a.get(name, 0)} -> {b.get(name, 0)}")
        return lines

    def __str__(self) -> str:
        return (f"JaxprSignature(eqns={self.n_eqns}, "
                f"prims={len(self.prims)} kinds)")


def signature_of(closed) -> JaxprSignature:
    """Signature of an already-traced ClosedJaxpr."""
    hist = prim_histogram(closed)
    return JaxprSignature(n_eqns(closed),
                          tuple(sorted(hist.items())))


def jaxpr_signature(fn: Callable, *args, **kwargs) -> JaxprSignature:
    """Trace ``fn(*args, **kwargs)`` and fingerprint the program."""
    return signature_of(jax.make_jaxpr(fn)(*args, **kwargs))


def sweep_signatures(build: Callable, params: Sequence,
                     ) -> List[Tuple[object, JaxprSignature]]:
    """Trace ``build(p)`` for each parameter value.

    ``build(p)`` returns ``(fn, args)`` (args a tuple) or a ClosedJaxpr
    directly. Returns [(param, signature), ...] in sweep order.
    """
    out = []
    for p in params:
        built = build(p)
        if hasattr(built, "jaxpr"):           # already a ClosedJaxpr
            sig = signature_of(built)
        else:
            fn, args = built
            sig = jaxpr_signature(fn, *args)
        out.append((p, sig))
    return out


def assert_jaxpr_constant(build: Callable, params: Sequence,
                          label: str = "") -> JaxprSignature:
    """Assert the traced program is identical across a parameter sweep.

    Raises AssertionError naming the first divergent parameter with a
    primitive-level diff. Returns the common signature on success.
    """
    sigs = sweep_signatures(build, params)
    (p0, base) = sigs[0]
    for p, sig in sigs[1:]:
        if sig != base:
            diff = "\n".join(base.diff(sig)) or "(histograms equal but "\
                "tuple order differs — report this)"
            raise AssertionError(
                f"jaxpr not constant{f' [{label}]' if label else ''}: "
                f"param {p0!r} vs {p!r}:\n{diff}")
    return base


def check_constant(build: Callable, params: Sequence,
                   ) -> Tuple[bool, JaxprSignature, List[str]]:
    """Non-raising variant for the CLI gate: (ok, base_signature, diff)."""
    try:
        base = assert_jaxpr_constant(build, params)
        return True, base, []
    except AssertionError as e:
        sigs = sweep_signatures(build, params[:1])
        return False, sigs[0][1], str(e).splitlines()
