"""Jaxpr traversal utilities shared by every pass.

All passes operate on a ``ClosedJaxpr`` and must see the WHOLE program,
including the sub-jaxprs that higher-order primitives carry in their
params (``scan``/``cond``/``while_loop``/``pjit``/``custom_jvp``/
``pallas_call``/...). Rather than special-casing each primitive, the
walker scans every eqn param for anything jaxpr-shaped — the same trick
``tests/test_selection_equivalence._prim_counts`` used, now shared.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Tuple

from jax import core as jax_core

try:  # jax >= 0.4.24 moved ClosedJaxpr around; resolve defensively
    ClosedJaxpr = jax_core.ClosedJaxpr
    Jaxpr = jax_core.Jaxpr
except AttributeError:  # pragma: no cover
    from jax.extend import core as jax_core  # type: ignore
    ClosedJaxpr = jax_core.ClosedJaxpr
    Jaxpr = jax_core.Jaxpr


def subjaxprs(eqn) -> List[Tuple[str, object]]:
    """(param_name, ClosedJaxpr-or-Jaxpr) for every sub-jaxpr of one eqn."""
    out = []
    for name, v in eqn.params.items():
        items = v if isinstance(v, (list, tuple)) else [v]
        for item in items:
            if isinstance(item, (ClosedJaxpr, Jaxpr)):
                out.append((name, item))
            elif hasattr(item, "jaxpr") and isinstance(
                    getattr(item, "jaxpr"), (ClosedJaxpr, Jaxpr)):
                out.append((name, item.jaxpr))
    return out


def _as_open(jx):
    """Jaxpr of either a ClosedJaxpr or a raw Jaxpr."""
    return jx.jaxpr if isinstance(jx, ClosedJaxpr) else jx


def iter_eqns(closed) -> Iterator[Tuple[object, str]]:
    """Yield (eqn, path) over the whole program, depth-first.

    ``path`` names the enclosing higher-order chain, e.g.
    ``"scan/body/cond[branch1]"`` — stable across retraces of the same
    program, used in finding messages (never in keys).
    """
    def walk(jx, path):
        for eqn in _as_open(jx).eqns:
            yield eqn, path
            subs = subjaxprs(eqn)
            for i, (pname, sub) in enumerate(subs):
                tag = eqn.primitive.name if len(subs) == 1 else \
                    f"{eqn.primitive.name}[{pname}{i}]"
                yield from walk(sub, f"{path}/{tag}" if path else tag)

    yield from walk(closed, "")


def prim_histogram(closed) -> Dict[str, int]:
    """Primitive name -> count over the whole program (sub-jaxprs included).

    This is the shared implementation behind the constancy checker: two
    traces with equal histograms have the same op mix regardless of var
    naming, so "jaxpr constant in T/horizon/events" can be asserted
    without brittle string comparison.
    """
    hist: Dict[str, int] = {}
    for eqn, _ in iter_eqns(closed):
        hist[eqn.primitive.name] = hist.get(eqn.primitive.name, 0) + 1
    return hist


def n_eqns(closed) -> int:
    """Total eqn count over the whole program (sub-jaxprs included)."""
    return sum(1 for _ in iter_eqns(closed))
