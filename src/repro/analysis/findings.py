"""Findings and the committed baseline: the audit's currency.

A ``Finding`` is one violation of a structural invariant, keyed by a
*stable* identifier (pass, target, detail slug — never line numbers or
numeric bounds, which drift) so a committed baseline can acknowledge known
violations while any NEW violation fails the gate. The model is a classic
ratchet lint: ``--write-baseline`` records the current findings,
``--gate`` fails on findings not in the baseline and reports baseline
entries that no longer fire (stale — safe to prune, never fatal).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

BASELINE_PATH = os.path.join(os.path.dirname(__file__), "baseline.json")


@dataclass(frozen=True)
class Finding:
    """One structural violation.

    key: stable identity for baseline matching — ``pass:target:slug``.
    message: human diagnosis (bounds, dtypes, line numbers live here; the
        message may change without invalidating the baseline entry).
    """
    pass_name: str        # purity | dtype | overflow | constancy | donation | lint
    target: str           # audit target name (e.g. tick:static:equilibria)
    slug: str             # stable detail (leaf path, rule:qualname, ...)
    message: str

    @property
    def key(self) -> str:
        return f"{self.pass_name}:{self.target}:{self.slug}"

    def __str__(self) -> str:
        return f"[{self.pass_name}] {self.target} :: {self.slug}\n    {self.message}"


@dataclass
class Report:
    """All findings of one audit run plus baseline bookkeeping."""
    findings: List[Finding] = field(default_factory=list)
    # approximation notes (e.g. primitives the interval analysis treated as
    # unbounded) — informational, never gated
    notes: List[str] = field(default_factory=list)

    def add(self, finding: Finding) -> None:
        self.findings.append(finding)

    def extend(self, findings: Sequence[Finding]) -> None:
        self.findings.extend(findings)

    def note(self, msg: str) -> None:
        if msg not in self.notes:
            self.notes.append(msg)

    def keys(self) -> List[str]:
        return sorted({f.key for f in self.findings})

    def new_vs(self, baseline: Sequence[str]) -> List[Finding]:
        """Findings whose key is not acknowledged by the baseline."""
        known = set(baseline)
        out, seen = [], set()
        for f in sorted(self.findings, key=lambda f: f.key):
            if f.key not in known and f.key not in seen:
                seen.add(f.key)
                out.append(f)
        return out

    def stale_vs(self, baseline: Sequence[str]) -> List[str]:
        """Baseline keys that no longer fire (candidates for pruning)."""
        have = {f.key for f in self.findings}
        return sorted(k for k in baseline if k not in have)

    def to_json(self) -> dict:
        return {
            "findings": [
                {"pass": f.pass_name, "target": f.target, "slug": f.slug,
                 "message": f.message}
                for f in sorted(self.findings, key=lambda f: f.key)],
            "notes": list(self.notes),
        }


def load_baseline(path: Optional[str] = None) -> List[str]:
    """Committed findings baseline -> list of acknowledged keys."""
    path = BASELINE_PATH if path is None else path
    if not os.path.exists(path):
        return []
    with open(path) as fh:
        data = json.load(fh)
    return list(data.get("accepted", []))


def write_baseline(report: Report, path: Optional[str] = None,
                   reasons: Optional[Dict[str, str]] = None) -> str:
    """Record the current findings as the accepted baseline."""
    path = BASELINE_PATH if path is None else path
    old_reasons: Dict[str, str] = {}
    if os.path.exists(path):
        with open(path) as fh:
            old_reasons = json.load(fh).get("reasons", {})
    keys = report.keys()
    data = {
        "accepted": keys,
        # free-form per-key justification, preserved across rewrites
        "reasons": {k: (reasons or {}).get(k, old_reasons.get(k, ""))
                    for k in keys},
    }
    with open(path, "w") as fh:
        json.dump(data, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path
