# Static analysis & invariant gating for the compiled tiering graph.
"""``repro.analysis`` — machine-checked structural invariants.

Equilibria's scale claims rest on properties of the *compiled artifact*,
not just runtime behavior: the tick must stay pure (no host round-trips),
its integer state must not overflow at fleet horizons, its jaxpr must be
constant in tenants/horizon/events, and the chunked rollout's donated
carries must really alias. This package proves those properties once, for
every engine, instead of re-asserting fragments per test:

  jaxpr_audit  — composable passes over a ``ClosedJaxpr`` (recursing into
                 scan/cond/while/pjit sub-jaxprs): purity, dtype
                 discipline, integer-overflow interval analysis, donation
                 aliasing.
  constancy    — the shared "jaxpr invariant under parameter sweep"
                 harness (eqn count + primitive histogram) used by the
                 test suite and the CLI gate.
  lint         — AST rules for graph code (no Python loops over tenants
                 in core/, no ``np.`` inside traced closures, seam
                 keywords default to None).
  targets      — the real audit targets: the unified tick (4 policy modes
                 x both ownership providers), the fleet rollout chunk
                 program, and the four Pallas kernel wrappers.
  fixtures     — known-bad programs each pass must flag (analyzer tests).

CLI: ``python -m repro.analysis`` (see ``--help``); ``--gate`` fails on
any finding not in the committed baseline (``analysis/baseline.json``) and
is wired into ``scripts/check.sh``.
"""
from repro.analysis.constancy import (JaxprSignature, assert_jaxpr_constant,
                                      jaxpr_signature, signature_of)
from repro.analysis.findings import Finding, Report
from repro.analysis.jaxpr_audit import (audit_jaxpr, donation_pass,
                                        dtype_pass, overflow_pass,
                                        purity_pass)
from repro.analysis.lint import lint_paths, lint_source

__all__ = [
    "Finding", "Report",
    "JaxprSignature", "jaxpr_signature", "signature_of",
    "assert_jaxpr_constant",
    "audit_jaxpr", "purity_pass", "dtype_pass", "overflow_pass",
    "donation_pass",
    "lint_paths", "lint_source",
]
