"""Interval analysis over jaxpr integer dataflow.

An ``Interval`` abstracts every element of an array as one ``[lo, hi]``
range plus an ``integral`` bit (the value is an exact integer — either an
int dtype or a float produced only by int conversions and exact ops).
The evaluator pushes intervals through a ``ClosedJaxpr`` eqn by eqn,
recursing into ``pjit``/``cond``/``scan``/``while`` sub-jaxprs, so the
overflow pass can answer two questions statically:

  * how fast can each scan-carried integer grow per tick (and therefore
    at what horizon does its dtype wrap)?
  * where does integer mass get converted into float32 beyond the 2^24
    exact-integer window (the silent-precision-loss pattern the fleet
    accumulators had)?

Sound-but-approximate by design: one interval per array (no per-element
tracking), unknown primitives produce their output dtype's full range
(recorded as a note, never silently), and scan carries are widened
linearly — ``carry_out <= carry_in + growth * length`` — which is exact
for the additive accumulators this codebase carries and conservative for
monotone ones. Trip-count-unknown ``while`` carries widen straight to the
dtype range.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, NamedTuple, Optional, Tuple

import numpy as np
from jax import core as jax_core

from repro.analysis.walk import ClosedJaxpr, subjaxprs

INF = math.inf
# exact-integer window of float32 (2^24): integers beyond this silently
# lose units when accumulated in f32
F32_EXACT = float(1 << 24)
F16_EXACT = float(1 << 11)


class Interval(NamedTuple):
    lo: float
    hi: float
    integral: bool = False

    def union(self, other: "Interval") -> "Interval":
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi),
                        self.integral and other.integral)

    def contains(self, other: "Interval") -> bool:
        return self.lo <= other.lo and other.hi <= self.hi

    def bounded(self) -> bool:
        return math.isfinite(self.lo) and math.isfinite(self.hi)


BOOL = Interval(0, 1, True)
TOP_F = Interval(-INF, INF, False)


def dtype_interval(dtype) -> Interval:
    """The full representable range of a dtype (the TOP element)."""
    try:
        dtype = np.dtype(dtype)
    except TypeError:
        # extended dtypes (typed PRNG keys): opaque to interval analysis;
        # treat as unbounded so downstream casts stay conservative
        return TOP_F
    if dtype == np.bool_:
        return BOOL
    if np.issubdtype(dtype, np.integer):
        info = np.iinfo(dtype)
        return Interval(float(info.min), float(info.max), True)
    return TOP_F


def value_interval(x) -> Interval:
    """Interval of a concrete constant/literal."""
    arr = np.asarray(x)
    if arr.size == 0:
        return Interval(0, 0, True)
    integral = bool(arr.dtype == np.bool_
                    or np.issubdtype(arr.dtype, np.integer))
    if np.issubdtype(arr.dtype, np.complexfloating):
        return TOP_F
    lo = float(arr.min())
    hi = float(arr.max())
    if not integral and np.issubdtype(arr.dtype, np.floating):
        # a float constant holding exact integers keeps the integral bit
        # (e.g. 0.0 seeds of integral accumulators)
        finite = np.isfinite(arr)
        integral = bool(finite.all() and (arr == np.round(arr)).all())
    return Interval(lo, hi, integral)


def _mul(a: float, b: float) -> float:
    if a == 0 or b == 0:
        return 0.0
    return a * b


def add_iv(a: Interval, b: Interval) -> Interval:
    return Interval(a.lo + b.lo, a.hi + b.hi, a.integral and b.integral)


def sub_iv(a: Interval, b: Interval) -> Interval:
    return Interval(a.lo - b.hi, a.hi - b.lo, a.integral and b.integral)


def mul_iv(a: Interval, b: Interval) -> Interval:
    cs = [_mul(a.lo, b.lo), _mul(a.lo, b.hi), _mul(a.hi, b.lo),
          _mul(a.hi, b.hi)]
    return Interval(min(cs), max(cs), a.integral and b.integral)


def scale_iv(a: Interval, n: float) -> Interval:
    """a summed over n independent draws: [min(n*lo, lo), max(n*hi, hi)]
    (covers reductions over masked/partial extents)."""
    lo = min(_mul(a.lo, n), a.lo, 0.0)
    hi = max(_mul(a.hi, n), a.hi, 0.0)
    return Interval(lo, hi, a.integral)


@dataclass
class Event:
    """One interval-analysis observation at a program point."""
    kind: str        # carry-overflow | cast-truncate | cast-precision
    path: str        # enclosing higher-order chain (walk.iter_eqns path)
    slug: str        # stable identity for baseline keys
    detail: str


@dataclass
class EvalContext:
    events: List[Event] = field(default_factory=list)
    unknown_prims: Dict[str, int] = field(default_factory=dict)
    _slug_seq: Dict[str, int] = field(default_factory=dict)

    def next_slug(self, base: str) -> str:
        k = self._slug_seq.get(base, 0)
        self._slug_seq[base] = k + 1
        return base if k == 0 else f"{base}#{k}"


def _reduce_extent(eqn) -> float:
    shape = eqn.invars[0].aval.shape
    axes = eqn.params.get("axes", tuple(range(len(shape))))
    n = 1
    for a in axes:
        n *= int(shape[a])
    return float(max(n, 1))


def _out_top(eqn) -> List[Interval]:
    return [dtype_interval(v.aval.dtype) if hasattr(v.aval, "dtype")
            else TOP_F for v in eqn.outvars]


class IntervalEvaluator:
    """Pushes intervals through one ClosedJaxpr (and its sub-jaxprs)."""

    def __init__(self, ctx: Optional[EvalContext] = None):
        self.ctx = ctx or EvalContext()

    # ------------------------------------------------------------------ env
    def eval_closed(self, closed: ClosedJaxpr, in_ivals: List[Interval],
                    path: str = "") -> List[Interval]:
        jaxpr = closed.jaxpr
        env: Dict[object, Interval] = {}
        for v, c in zip(jaxpr.constvars, closed.consts):
            env[v] = value_interval(c)
        if len(in_ivals) != len(jaxpr.invars):
            raise ValueError(f"expected {len(jaxpr.invars)} input intervals, "
                             f"got {len(in_ivals)}")
        for v, iv in zip(jaxpr.invars, in_ivals):
            env[v] = iv
        self._eval_eqns(jaxpr, env, path)
        return [self._read(env, v) for v in jaxpr.outvars]

    def _read(self, env, v) -> Interval:
        if isinstance(v, jax_core.Literal):
            return value_interval(v.val)
        if v not in env:
            # DropVar or untracked: fall back to dtype range
            return (dtype_interval(v.aval.dtype)
                    if hasattr(v.aval, "dtype") else TOP_F)
        return env[v]

    def _eval_eqns(self, jaxpr, env, path) -> None:
        for eqn in jaxpr.eqns:
            ivals = [self._read(env, v) for v in eqn.invars]
            outs = self._eval_eqn(eqn, ivals, path)
            for v, iv in zip(eqn.outvars, outs):
                # intersect with the output dtype's representable range:
                # whatever the op did, the array cannot hold more
                if hasattr(v.aval, "dtype"):
                    top = dtype_interval(v.aval.dtype)
                    try:
                        is_int = np.issubdtype(np.dtype(v.aval.dtype),
                                               np.integer)
                    except TypeError:   # extended dtype (typed PRNG key)
                        is_int = False
                    iv = Interval(max(iv.lo, top.lo), min(iv.hi, top.hi),
                                  iv.integral or top is BOOL or is_int
                                  if iv.integral is not None else iv.integral)
                env[v] = iv

    # ------------------------------------------------------------ dispatch
    def _eval_eqn(self, eqn, ivals: List[Interval],
                  path: str) -> List[Interval]:
        name = eqn.primitive.name
        fn = _RULES.get(name)
        if fn is not None:
            return fn(self, eqn, ivals, path)
        if name in _HIGHER_ORDER:
            return _HIGHER_ORDER[name](self, eqn, ivals, path)
        # single sub-jaxpr call-like primitives (custom_jvp, remat, ...):
        subs = subjaxprs(eqn)
        if len(subs) == 1 and isinstance(subs[0][1], ClosedJaxpr):
            sub = subs[0][1]
            if len(sub.jaxpr.invars) == len(ivals):
                outs = self.eval_closed(sub, ivals, f"{path}/{name}")
                if len(outs) == len(eqn.outvars):
                    return outs
        self.ctx.unknown_prims[name] = self.ctx.unknown_prims.get(name, 0) + 1
        return _out_top(eqn)

    # ----------------------------------------------------------- cast rule
    def _convert(self, eqn, ivals, path) -> List[Interval]:
        (a,) = ivals
        new_dtype = np.dtype(eqn.params["new_dtype"])
        top = dtype_interval(new_dtype)
        if np.issubdtype(new_dtype, np.integer):
            if a.bounded() and top.contains(Interval(a.lo, a.hi, True)):
                out = Interval(math.floor(a.lo), math.ceil(a.hi), True)
            else:
                # a *finite* bound provably exceeding the target range is a
                # real truncation; an unbounded one is usually analysis
                # over-approximation — downgraded to a note by the pass
                kind = "cast-truncate" if a.bounded() else "cast-unbounded"
                self.ctx.events.append(Event(
                    kind=kind, path=path,
                    slug=self.ctx.next_slug(f"cast-{new_dtype.name}@{path}"),
                    detail=f"cast to {new_dtype.name} from range "
                           f"[{a.lo:g}, {a.hi:g}] can wrap"))
                out = top
            return [out]
        if np.issubdtype(new_dtype, np.floating):
            exact = {2: F16_EXACT, 4: F32_EXACT}.get(new_dtype.itemsize)
            if (a.integral and exact is not None
                    and max(abs(a.lo), abs(a.hi)) > exact):
                self.ctx.events.append(Event(
                    kind="cast-precision", path=path,
                    slug=self.ctx.next_slug(
                        f"cast-{new_dtype.name}-precision@{path}"),
                    detail=f"integer mass up to {max(abs(a.lo), abs(a.hi)):g}"
                           f" cast to {new_dtype.name} (exact only to "
                           f"{exact:g}) — accumulation drops units"))
            return [Interval(a.lo, a.hi, a.integral)]
        return [TOP_F]

    # ------------------------------------------------------- higher order
    def _pjit(self, eqn, ivals, path) -> List[Interval]:
        sub = eqn.params["jaxpr"]
        return self.eval_closed(sub, ivals, f"{path}/pjit" if path else "pjit")

    def _cond(self, eqn, ivals, path) -> List[Interval]:
        branches = eqn.params["branches"]
        op_ivals = ivals[1:]
        outs: Optional[List[Interval]] = None
        for i, br in enumerate(branches):
            o = self.eval_closed(br, op_ivals, f"{path}/cond[{i}]")
            outs = o if outs is None else [a.union(b)
                                           for a, b in zip(outs, o)]
        return outs or []

    def _scan(self, eqn, ivals, path) -> List[Interval]:
        sub: ClosedJaxpr = eqn.params["jaxpr"]
        nc = eqn.params["num_consts"]
        ncar = eqn.params["num_carry"]
        length = float(eqn.params.get("length", 0) or 0)
        consts = ivals[:nc]
        carry0 = ivals[nc:nc + ncar]
        xs = ivals[nc + ncar:]          # per-slice interval == stacked interval
        spath = f"{path}/scan" if path else "scan"

        # Two-phase widening: growth measured between the first-iteration
        # output and a second evaluation at the union — a transient jump
        # (tier -1 -> 1, a saturated gather) settles at iteration two and
        # extrapolates to nothing; a genuine accumulator keeps its rate.
        out1 = self.eval_closed(sub, consts + carry0 + xs, spath)
        carryU = [c0.union(c1) for c0, c1 in zip(carry0, out1[:ncar])]
        out_u = self.eval_closed(sub, consts + carryU + xs, spath)
        widened: List[Interval] = []
        for j, (cu, c1, c2) in enumerate(zip(carryU, out1[:ncar],
                                             out_u[:ncar])):
            grow = max(c2.hi - c1.hi, 0.0)
            drop = min(c2.lo - c1.lo, 0.0)
            if grow == 0.0 and drop == 0.0:
                widened.append(cu.union(c2))
                continue
            lo = cu.lo if drop == 0 else cu.lo + _mul(drop, length)
            hi = cu.hi if grow == 0 else cu.hi + _mul(grow, length)
            w = Interval(lo, hi, cu.integral and c2.integral)
            widened.append(w)
            # overflow check against the carried var's dtype happens here,
            # where the growth rate and trip count are both known
            var = sub.jaxpr.invars[nc + j]
            dtype = getattr(var.aval, "dtype", None)
            if dtype is not None and np.issubdtype(np.dtype(dtype),
                                                   np.integer):
                top = dtype_interval(dtype)
                if not top.contains(w):
                    self.ctx.events.append(Event(
                        kind="carry-overflow", path=spath,
                        slug=self.ctx.next_slug(f"scan-carry{j}@{spath}"),
                        detail=(f"scan carry {j} ({np.dtype(dtype).name}) "
                                f"grows ~{grow:g}/iter over "
                                f"{int(length)} iters -> bound {w.hi:g} "
                                f"exceeds {np.dtype(dtype).name} range")))
            elif dtype is not None and np.issubdtype(np.dtype(dtype),
                                                     np.floating):
                # integer mass accumulated in a narrow float carry: exact
                # only below the mantissa window, then silently drops units
                exact = {2: F16_EXACT, 4: F32_EXACT}.get(
                    np.dtype(dtype).itemsize)
                if (exact is not None and w.integral
                        and max(abs(w.lo), abs(w.hi)) > exact):
                    self.ctx.events.append(Event(
                        kind="carry-precision", path=spath,
                        slug=self.ctx.next_slug(
                            f"scan-carry{j}-precision@{spath}"),
                        detail=(f"scan carry {j} accumulates integer counts "
                                f"in {np.dtype(dtype).name} up to "
                                f"{max(abs(w.lo), abs(w.hi)):g} (exact only "
                                f"to {exact:g}) over {int(length)} iters — "
                                f"accumulate in int32 and widen host-side")))
        out2 = self.eval_closed(sub, consts + widened + xs, spath)
        return out2[:ncar] + [iv.union(jv) for iv, jv in
                              zip(out1[ncar:], out2[ncar:])]

    def _while(self, eqn, ivals, path) -> List[Interval]:
        body: ClosedJaxpr = eqn.params["body_jaxpr"]
        cn = eqn.params["cond_nconsts"]
        bn = eqn.params["body_nconsts"]
        bconsts = ivals[cn:cn + bn]
        carry0 = ivals[cn + bn:]
        wpath = f"{path}/while" if path else "while"
        out1 = self.eval_closed(body, bconsts + carry0, wpath)
        # unknown trip count: any carry not already at fixpoint widens to
        # its dtype range
        outs = []
        for v, c0, c1 in zip(body.jaxpr.outvars, carry0, out1):
            if c0.contains(c1):
                outs.append(c0)
            elif hasattr(v.aval, "dtype"):
                outs.append(dtype_interval(v.aval.dtype))
            else:
                outs.append(TOP_F)
        return outs


# --------------------------------------------------------------- rules ----
def _r(fn: Callable) -> Callable:
    """Adapt a pure-interval rule (ivals -> [Interval])."""
    return lambda self, eqn, ivals, path: fn(eqn, ivals)


def _identity(eqn, ivals):
    return [ivals[0]]


def _union_all(eqn, ivals):
    out = ivals[0]
    for iv in ivals[1:]:
        out = out.union(iv)
    return [out]


def _bool_out(eqn, ivals):
    return [BOOL]


def _reduce_sum(eqn, ivals):
    return [scale_iv(ivals[0], _reduce_extent(eqn))]


def _cumsum(eqn, ivals):
    shape = eqn.invars[0].aval.shape
    axis = eqn.params.get("axis", 0)
    n = float(shape[axis]) if shape else 1.0
    return [scale_iv(ivals[0], n)]


def _iota(eqn, ivals):
    shape = eqn.outvars[0].aval.shape
    dim = eqn.params.get("dimension", 0)
    n = int(shape[dim]) if shape else 1
    return [Interval(0, float(max(n - 1, 0)), True)]


def _select_n(eqn, ivals):
    out = ivals[1]
    for iv in ivals[2:]:
        out = out.union(iv)
    return [out]


def _clamp(eqn, ivals):
    lo, x, hi = ivals
    return [Interval(max(x.lo, lo.lo), min(x.hi, hi.hi),
                     x.integral and lo.integral and hi.integral)]


def _div(eqn, ivals):
    a, b = ivals
    dtype = getattr(eqn.outvars[0].aval, "dtype", np.float32)
    integer = np.issubdtype(np.dtype(dtype), np.integer)
    if b.lo > 0:
        cs = [a.lo / b.lo, a.lo / b.hi, a.hi / b.lo, a.hi / b.hi]
        return [Interval(min(cs), max(cs), integer)]
    if b.hi < 0:
        cs = [a.lo / b.lo, a.lo / b.hi, a.hi / b.lo, a.hi / b.hi]
        return [Interval(min(cs), max(cs), integer)]
    return [dtype_interval(dtype) if integer else TOP_F]


def _rem(eqn, ivals):
    a, b = ivals
    m = max(abs(b.lo), abs(b.hi))
    if not math.isfinite(m):
        return _out_top(eqn)
    lo = 0.0 if a.lo >= 0 else -m
    hi = m if a.hi > 0 else 0.0
    return [Interval(lo, hi, a.integral and b.integral)]


def _neg(eqn, ivals):
    a = ivals[0]
    return [Interval(-a.hi, -a.lo, a.integral)]


def _abs(eqn, ivals):
    a = ivals[0]
    lo = 0.0 if a.lo <= 0 <= a.hi else min(abs(a.lo), abs(a.hi))
    return [Interval(lo, max(abs(a.lo), abs(a.hi)), a.integral)]


def _max_iv(eqn, ivals):
    a, b = ivals
    return [Interval(max(a.lo, b.lo), max(a.hi, b.hi),
                     a.integral and b.integral)]


def _min_iv(eqn, ivals):
    a, b = ivals
    return [Interval(min(a.lo, b.lo), min(a.hi, b.hi),
                     a.integral and b.integral)]


def _floor_like(eqn, ivals):
    a = ivals[0]
    return [Interval(math.floor(a.lo) if math.isfinite(a.lo) else a.lo,
                     math.ceil(a.hi) if math.isfinite(a.hi) else a.hi, True)]


def _sort(eqn, ivals):
    return list(ivals)


def _top_k(eqn, ivals):
    n = int(eqn.invars[0].aval.shape[-1])
    return [ivals[0], Interval(0, float(max(n - 1, 0)), True)]


def _arg_reduce(eqn, ivals):
    shape = eqn.invars[0].aval.shape
    axes = eqn.params.get("axes", (0,))
    n = int(shape[axes[0]]) if shape else 1
    return [Interval(0, float(max(n - 1, 0)), True)]


def _scatter_add(eqn, ivals):
    operand, _idx, updates = ivals[0], ivals[1], ivals[2]
    upd_aval = eqn.invars[2].aval
    n = float(max(int(np.prod(upd_aval.shape)) if upd_aval.shape else 1, 1))
    return [add_iv(operand, scale_iv(updates, n))]


def _scatter_replace(eqn, ivals):
    return [ivals[0].union(ivals[2])]


def _scatter_minmax(which):
    def rule(eqn, ivals):
        return [ivals[0].union(ivals[2])]
    return rule


def _pad(eqn, ivals):
    return [ivals[0].union(ivals[1])]


def _dus(eqn, ivals):
    # dynamic_update_slice(operand, update, *starts)
    return [ivals[0].union(ivals[1])]


def _dot_general(eqn, ivals):
    a, b = ivals[0], ivals[1]
    dims = eqn.params["dimension_numbers"]
    (lhs_c, _rhs_c), _ = dims
    shape = eqn.invars[0].aval.shape
    k = 1
    for ax in lhs_c:
        k *= int(shape[ax])
    prod = mul_iv(a, b)
    return [scale_iv(prod, float(max(k, 1)))]


def _exp(eqn, ivals):
    a = ivals[0]
    return [Interval(math.exp(min(a.lo, 700)) if math.isfinite(a.lo) else 0.0,
                     math.exp(min(a.hi, 700)) if math.isfinite(a.hi) else INF,
                     False)]


def _log(eqn, ivals):
    return [TOP_F]


def _bounded(lo, hi):
    def rule(eqn, ivals):
        return [Interval(lo, hi, False)]
    return rule


def _sign(eqn, ivals):
    return [Interval(-1, 1, True)]


def _square_like(eqn, ivals):
    a = ivals[0]
    p = mul_iv(a, a)
    return [Interval(max(p.lo, 0.0), p.hi, a.integral)]


def _integer_pow(eqn, ivals):
    a = ivals[0]
    y = int(eqn.params.get("y", 2))
    if y == 2:
        return _square_like(eqn, ivals)
    if y >= 0 and a.bounded():
        cs = [a.lo ** y, a.hi ** y]
        if a.lo <= 0 <= a.hi:
            cs.append(0.0)
        return [Interval(min(cs), max(cs), a.integral)]
    return _out_top(eqn)


def _and_or(eqn, ivals):
    dtype = getattr(eqn.outvars[0].aval, "dtype", np.bool_)
    if np.dtype(dtype) == np.bool_:
        return [BOOL]
    return [dtype_interval(dtype)]


_RULES: Dict[str, Callable] = {
    "add": _r(lambda e, iv: [add_iv(iv[0], iv[1])]),
    "add_any": _r(lambda e, iv: [add_iv(iv[0], iv[1])]),
    "sub": _r(lambda e, iv: [sub_iv(iv[0], iv[1])]),
    "mul": _r(lambda e, iv: [mul_iv(iv[0], iv[1])]),
    "div": _r(_div),
    "rem": _r(_rem),
    "neg": _r(_neg),
    "abs": _r(_abs),
    "sign": _r(_sign),
    "max": _r(_max_iv),
    "min": _r(_min_iv),
    "clamp": _r(_clamp),
    "floor": _r(_floor_like),
    "ceil": _r(_floor_like),
    "round": _r(_floor_like),
    "nextafter": _r(_identity),
    "is_finite": _r(_bool_out),
    "eq": _r(_bool_out), "ne": _r(_bool_out), "lt": _r(_bool_out),
    "le": _r(_bool_out), "gt": _r(_bool_out), "ge": _r(_bool_out),
    "eq_to": _r(_bool_out), "lt_to": _r(_bool_out), "le_to": _r(_bool_out),
    "and": _r(_and_or), "or": _r(_and_or), "xor": _r(_and_or),
    "not": _r(_and_or),
    "select_n": _r(_select_n),
    "broadcast_in_dim": _r(_identity),
    "reshape": _r(_identity),
    "squeeze": _r(_identity),
    "expand_dims": _r(_identity),
    "transpose": _r(_identity),
    "rev": _r(_identity),
    "slice": _r(_identity),
    "dynamic_slice": _r(lambda e, iv: [iv[0]]),
    "dynamic_update_slice": _r(_dus),
    "gather": _r(lambda e, iv: [iv[0]]),
    "take_along_axis": _r(lambda e, iv: [iv[0]]),
    "concatenate": _r(_union_all),
    "pad": _r(_pad),
    "copy": _r(_identity),
    "stop_gradient": _r(_identity),
    "convert_element_type": IntervalEvaluator._convert,
    "reduce_sum": _r(_reduce_sum),
    "reduce_prod": _r(lambda e, iv: _out_top(e)),
    "reduce_max": _r(_identity),
    "reduce_min": _r(_identity),
    "reduce_and": _r(_bool_out),
    "reduce_or": _r(_bool_out),
    "argmax": _r(_arg_reduce),
    "argmin": _r(_arg_reduce),
    "cumsum": _r(_cumsum),
    "cummax": _r(_identity),
    "cummin": _r(_identity),
    "iota": _r(_iota),
    "sort": _r(_sort),
    "top_k": _r(_top_k),
    "scatter-add": _r(_scatter_add),
    "scatter": _r(_scatter_replace),
    "scatter-max": _r(_scatter_minmax("max")),
    "scatter-min": _r(_scatter_minmax("min")),
    "scatter-mul": _r(lambda e, iv: _out_top(e)),
    "dot_general": _r(_dot_general),
    "exp": _r(_exp),
    "log": _r(_log),
    "log1p": _r(_log),
    "logistic": _r(_bounded(0, 1)),
    "tanh": _r(_bounded(-1, 1)),
    "erf": _r(_bounded(-1, 1)),
    "sin": _r(_bounded(-1, 1)),
    "cos": _r(_bounded(-1, 1)),
    "sqrt": _r(lambda e, iv: [Interval(0, INF, False)]),
    "rsqrt": _r(lambda e, iv: [Interval(0, INF, False)]),
    "integer_pow": _r(_integer_pow),
    "square": _r(_square_like),
}

_HIGHER_ORDER: Dict[str, Callable] = {
    "pjit": IntervalEvaluator._pjit,
    "closed_call": IntervalEvaluator._pjit,
    "core_call": IntervalEvaluator._pjit,
    "cond": IntervalEvaluator._cond,
    "scan": IntervalEvaluator._scan,
    "while": IntervalEvaluator._while,
}
