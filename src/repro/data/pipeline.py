"""Deterministic synthetic data pipeline + abstract input specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of a cell — weak-type-correct, shardable, no device allocation —
exactly what the dry-run lowers against. ``synthetic_batch`` materializes the
same shapes for smoke tests / examples, with a seeded LCG stream so the
pipeline is reproducible and shardable (each host slices its own rows).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _extras_spec(cfg: ModelConfig, batch: int, abstract: bool,
                 rng: Optional[np.random.Generator] = None) -> Dict:
    out: Dict = {}
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        shp = (batch, cfg.num_image_tokens, cfg.d_model)
        out["image_embeds"] = (jax.ShapeDtypeStruct(shp, dt) if abstract else
                               jnp.asarray(rng.normal(size=shp) * 0.02, dt))
    if cfg.family == "encdec":
        shp = (batch, cfg.encoder_seq, cfg.d_model)
        out["frames"] = (jax.ShapeDtypeStruct(shp, dt) if abstract else
                         jnp.asarray(rng.normal(size=shp) * 0.02, dt))
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Abstract inputs for one cell (train/prefill: full batch; decode: the
    per-step token batch — the KV/tier state is built by serve.init_serve_state)."""
    b = shape.global_batch
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
        }
        specs.update(_extras_spec(cfg, b, abstract=True))
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)}
        specs.update(_extras_spec(cfg, b, abstract=True))
        return specs
    # decode: one new token per sequence
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                    kind: str = "train") -> Dict:
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, size=(batch, seq), dtype=np.int32)
    out = {"tokens": jnp.asarray(toks)}
    if kind == "train":
        labels = np.roll(toks, -1, axis=1)
        out["labels"] = jnp.asarray(labels)
    out.update(_extras_spec(cfg, batch, abstract=False, rng=rng))
    return out


class SyntheticLoader:
    """Sharded, prefetching synthetic loader (host-side double buffering)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                 shard_id: int = 0, num_shards: int = 1):
        assert batch % num_shards == 0
        self.cfg, self.batch, self.seq = cfg, batch // num_shards, seq
        self.seed = seed * num_shards + shard_id
        self._step = 0
        self._next = None

    def _make(self, step: int) -> Dict:
        return synthetic_batch(self.cfg, self.batch, self.seq,
                               seed=self.seed + step * 7919)

    def __iter__(self) -> Iterator[Dict]:
        return self

    def __next__(self) -> Dict:
        if self._next is None:
            self._next = self._make(self._step)
        cur = self._next
        self._step += 1
        self._next = self._make(self._step)   # prefetch next
        return cur
