"""Fault-tolerant training driver.

Production behaviors, testable on CPU with injected failures:
  * checkpoint/restart: resume from the latest atomic checkpoint; a step
    that raises is retried after restoring state (transient-failure model);
    repeated failures at the same step abort (poison-step model).
  * straggler mitigation: per-step wall time tracked with an EWMA; steps
    slower than ``straggler_factor`` x EWMA are counted and surfaced via the
    ``on_straggler`` hook — on a real fleet this triggers hot-spare swap /
    re-sharding; here it is observable behavior under test.
  * heartbeat: a liveness file updated every step (what a cluster agent
    watches to detect a hung worker and restart the job).
  * elastic restart: restore accepts a different mesh (checkpoint leaves are
    host arrays; shardings are re-applied for the current topology).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import sharded as ckpt


@dataclass
class FTConfig:
    checkpoint_dir: str
    checkpoint_every: int = 50
    keep: int = 3
    max_retries_per_step: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    heartbeat_file: Optional[str] = None


@dataclass
class FTStats:
    restarts: int = 0
    retries: int = 0
    stragglers: int = 0
    step_time_ewma: float = 0.0
    completed_steps: int = 0


class TrainDriver:
    """Runs `step_fn(state, batch) -> (state, metrics)` fault-tolerantly."""

    def __init__(self, step_fn: Callable, cfg: FTConfig,
                 on_straggler: Optional[Callable[[int, float], None]] = None,
                 failure_injector: Optional[Callable[[int], None]] = None):
        self.step_fn = step_fn
        self.cfg = cfg
        self.stats = FTStats()
        self.on_straggler = on_straggler
        self.failure_injector = failure_injector
        self.ckpt = ckpt.AsyncCheckpointer(cfg.checkpoint_dir, keep=cfg.keep)

    def _heartbeat(self, step: int):
        if self.cfg.heartbeat_file:
            Path(self.cfg.heartbeat_file).write_text(
                json.dumps({"step": step, "t": time.time()}))

    def maybe_restore(self, state: Any, shardings: Any = None):
        """Resume from the latest checkpoint if one exists."""
        last = ckpt.latest_step(self.cfg.checkpoint_dir)
        if last is None:
            return state, 0
        restored = ckpt.restore(self.cfg.checkpoint_dir, last, state,
                                shardings)
        self.stats.restarts += 1
        return restored, last + 1

    def run(self, state: Any, batches, start_step: int = 0,
            num_steps: int = 100):
        metrics_log = []
        it = iter(batches)
        step = start_step
        while step < start_step + num_steps:
            batch = next(it)
            retries = 0
            while True:
                t0 = time.time()
                try:
                    if self.failure_injector is not None:
                        self.failure_injector(step)
                    new_state, metrics = self.step_fn(state, batch)
                    jax.block_until_ready(
                        jax.tree_util.tree_leaves(new_state)[0])
                    break
                except RuntimeError:
                    retries += 1
                    self.stats.retries += 1
                    if retries > self.cfg.max_retries_per_step:
                        raise
                    # transient failure: restore the last good state
                    last = ckpt.latest_step(self.cfg.checkpoint_dir)
                    if last is not None:
                        state = ckpt.restore(self.cfg.checkpoint_dir, last,
                                             state)
            dt = time.time() - t0
            ewma = self.stats.step_time_ewma
            if ewma > 0 and dt > self.cfg.straggler_factor * ewma:
                self.stats.stragglers += 1
                if self.on_straggler:
                    self.on_straggler(step, dt)
            a = self.cfg.ewma_alpha
            self.stats.step_time_ewma = dt if ewma == 0 else (1 - a) * ewma + a * dt

            state = new_state
            metrics_log.append(metrics)
            self.stats.completed_steps += 1
            self._heartbeat(step)
            if (step + 1) % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step, state, extra={"step": step})
            step += 1
        self.ckpt.wait()
        return state, metrics_log
