"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Chunked SSD algorithm for train/prefill; O(1)-state recurrent step for decode.
The chunked form here is also the oracle for kernels/ssd_scan.

TPU adaptation note (DESIGN.md §2): projections are split (wx/wz/wB/wC/wdt)
instead of one fused in_proj so the inner dimension shards cleanly over the
"model" mesh axis; head_dim is chosen per-arch so n_heads divides the TP axis.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.params import ParamSpec
from repro.models.layers import rms_norm


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s: SSMConfig = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    assert nh * s.head_dim == di, (di, s.head_dim)
    return di, nh, s.ngroups, s.state_dim


def mamba_specs(cfg: ModelConfig):
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    di, nh, g, n = ssm_dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "norm": ParamSpec((d,), ("embed",), dt, init="ones"),
        "wx": ParamSpec((d, di), ("embed", "ssm_inner"), dt),
        "wz": ParamSpec((d, di), ("embed", "ssm_inner"), dt),
        "wB": ParamSpec((d, g * n), ("embed", None), dt),
        "wC": ParamSpec((d, g * n), ("embed", None), dt),
        "wdt": ParamSpec((d, nh), ("embed", None), dt, init="small"),
        "conv_x": ParamSpec((s.conv_width, di), (None, "ssm_inner"), dt, init="small"),
        "conv_B": ParamSpec((s.conv_width, g * n), (None, None), dt, init="small"),
        "conv_C": ParamSpec((s.conv_width, g * n), (None, None), dt, init="small"),
        "dt_bias": ParamSpec((nh,), (None,), dt, init="zeros"),
        "A_log": ParamSpec((nh,), (None,), dt, init="zeros"),
        "D": ParamSpec((nh,), (None,), dt, init="ones"),
        "gnorm": ParamSpec((di,), ("ssm_inner",), dt, init="ones"),
        "wo": ParamSpec((di, d), ("ssm_inner", "embed"), dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: [B,S,C], w: [W,C]."""
    width, c = w.shape
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad.astype(jnp.float32),
        w.astype(jnp.float32)[:, None, :],          # [W, 1, C]
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c)
    return out.astype(x.dtype)


def segsum(a: jax.Array) -> jax.Array:
    """a: [..., q] log-decays -> [..., q, q] with L[i,j]=sum_{k=j+1..i} a_k (i>=j)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, a, b, c, chunk: int, h0: Optional[jax.Array] = None):
    """Chunked SSD scan (Mamba2 paper Listing 1, JAX port).

    x: [B,S,H,P] (already dt-scaled), a: [B,S,H] log decay (dt*A, negative),
    b, c: [B,S,H,N] (groups pre-broadcast to heads).
    Returns y: [B,S,H,P], h_final: [B,H,P,N].
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    xr = x.reshape(B, nc, chunk, H, P).astype(jnp.float32)
    ar = a.reshape(B, nc, chunk, H).transpose(0, 1, 3, 2).astype(jnp.float32)  # [B,c,H,q]
    br = b.reshape(B, nc, chunk, H, N).astype(jnp.float32)
    cr = c.reshape(B, nc, chunk, H, N).astype(jnp.float32)

    a_cum = jnp.cumsum(ar, axis=-1)                                   # [B,c,H,q]
    L = jnp.exp(segsum(ar))                                           # [B,c,H,q,q]
    # intra-chunk (diagonal blocks)
    scores = jnp.einsum("bclhn,bcshn->bchls", cr, br) * L
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores, xr)
    # per-chunk states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)                   # [B,c,H,q]
    states = jnp.einsum("bcshn,bchs,bcshp->bchpn", br, decay_states, xr)
    # inter-chunk recurrence
    if h0 is None:
        h0 = jnp.zeros((B, H, x.shape[-1], N), jnp.float32)
    states_cat = jnp.concatenate([h0[:, None].astype(jnp.float32), states], axis=1)
    chunk_sum = a_cum[..., -1].transpose(0, 2, 1)                     # [B,H,c]
    decay_chunk = jnp.exp(segsum(jnp.pad(chunk_sum, ((0, 0), (0, 0), (1, 0)))))
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states_cat)
    prev_states, h_final = new_states[:, :-1], new_states[:, -1]
    # inter-chunk contribution
    state_decay_out = jnp.exp(a_cum)                                  # [B,c,H,q]
    y_off = jnp.einsum("bclhn,bchpn,bchl->bclhp", cr, prev_states, state_decay_out)
    y = (y_diag + y_off).reshape(B, S, H, P)
    return y.astype(x.dtype), h_final


def ssd_recurrent_ref(x, a, b, c, h0=None):
    """O(S·N) sequential reference (oracle for ssd_chunked & the Pallas kernel)."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    h = (jnp.zeros((B, H, P, N), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))

    def step(h, t):
        xt, at, bt, ct = t
        h = h * jnp.exp(at)[..., None, None] + jnp.einsum(
            "bhn,bhp->bhpn", bt, xt)
        y = jnp.einsum("bhn,bhpn->bhp", ct, h)
        return h, y

    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          a.transpose(1, 0, 2).astype(jnp.float32),
          b.transpose(1, 0, 2, 3).astype(jnp.float32),
          c.transpose(1, 0, 2, 3).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h


class MambaCache(NamedTuple):
    """Per-layer decode state."""
    h: jax.Array          # [B, H, P, N] SSM state
    conv_x: jax.Array     # [B, W-1, di]
    conv_B: jax.Array     # [B, W-1, g*n]
    conv_C: jax.Array     # [B, W-1, g*n]


def mamba_cache_specs(cfg: ModelConfig, batch: int, n_layers: int):
    di, nh, g, n = ssm_dims(cfg)
    s = cfg.ssm
    w = s.conv_width - 1
    f32, dt = jnp.float32, jnp.dtype(cfg.dtype)
    return MambaCache(
        h=jax.ShapeDtypeStruct((n_layers, batch, nh, s.head_dim, n), f32),
        conv_x=jax.ShapeDtypeStruct((n_layers, batch, w, di), dt),
        conv_B=jax.ShapeDtypeStruct((n_layers, batch, w, g * n), dt),
        conv_C=jax.ShapeDtypeStruct((n_layers, batch, w, g * n), dt),
    )


def _project(p, u, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    x = jnp.einsum("bsd,di->bsi", u, p["wx"].astype(dt))
    z = jnp.einsum("bsd,di->bsi", u, p["wz"].astype(dt))
    bb = jnp.einsum("bsd,dn->bsn", u, p["wB"].astype(dt))
    cc = jnp.einsum("bsd,dn->bsn", u, p["wC"].astype(dt))
    dtv = jnp.einsum("bsd,dh->bsh", u, p["wdt"].astype(dt))
    return x, z, bb, cc, dtv


def mamba_block(p, u, cfg: ModelConfig,
                h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence Mamba2 block (train/prefill). u: [B,S,D] -> ([B,S,D], h_final)."""
    s: SSMConfig = cfg.ssm
    di, nh, g, n = ssm_dims(cfg)
    B, S, _ = u.shape
    un = rms_norm(u, p["norm"], cfg.rms_eps)
    x, z, bb, cc, dtv = _project(p, un, cfg)
    x = jax.nn.silu(_causal_conv(x, p["conv_x"]))
    bb = jax.nn.silu(_causal_conv(bb, p["conv_B"]))
    cc = jax.nn.silu(_causal_conv(cc, p["conv_C"]))
    dt_f = jax.nn.softplus(dtv.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))         # [B,S,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                       # [H]
    a = dt_f * A                                                       # [B,S,H] log-decay
    xh = x.reshape(B, S, nh, s.head_dim)
    xdt = xh.astype(jnp.float32) * dt_f[..., None]
    # broadcast groups -> heads
    bh = jnp.repeat(bb.reshape(B, S, g, n), nh // g, axis=2)
    ch = jnp.repeat(cc.reshape(B, S, g, n), nh // g, axis=2)
    y, h_fin = ssd_chunked(xdt, a, bh, ch, min(s.chunk_size, S), h0=h0)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di)
    y = rms_norm(y.astype(u.dtype) * jax.nn.silu(z), p["gnorm"], cfg.rms_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["wo"].astype(jnp.dtype(cfg.dtype)))
    return u + out, h_fin


def mamba_decode_step(p, u, cache: MambaCache, cfg: ModelConfig
                      ) -> Tuple[jax.Array, MambaCache]:
    """Single-token step. u: [B,1,D] -> ([B,1,D], new cache)."""
    s: SSMConfig = cfg.ssm
    di, nh, g, n = ssm_dims(cfg)
    B = u.shape[0]
    un = rms_norm(u, p["norm"], cfg.rms_eps)
    x, z, bb, cc, dtv = _project(p, un, cfg)

    def conv_step(buf, new, w):
        # buf: [B, W-1, C]; new: [B, 1, C]
        seq = jnp.concatenate([buf, new], axis=1)                      # [B, W, C]
        out = jnp.einsum("bwc,wc->bc", seq.astype(jnp.float32),
                         w.astype(jnp.float32))[:, None]
        return jax.nn.silu(out).astype(new.dtype), seq[:, 1:]

    x1, cx = conv_step(cache.conv_x, x, p["conv_x"])
    b1, cb = conv_step(cache.conv_B, bb, p["conv_B"])
    c1, ccv = conv_step(cache.conv_C, cc, p["conv_C"])
    dt_f = jax.nn.softplus(dtv[:, 0].astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))         # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt_f * A)                                             # [B,H]
    xh = x1[:, 0].reshape(B, nh, s.head_dim).astype(jnp.float32)
    bh = jnp.repeat(b1[:, 0].reshape(B, g, n), nh // g, axis=1).astype(jnp.float32)
    ch = jnp.repeat(c1[:, 0].reshape(B, g, n), nh // g, axis=1).astype(jnp.float32)
    h = cache.h * da[..., None, None] + jnp.einsum(
        "bhn,bhp,bh->bhpn", bh, xh, dt_f)
    y = jnp.einsum("bhn,bhpn->bhp", ch, h)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, 1, di)
    y = rms_norm(y.astype(u.dtype) * jax.nn.silu(z), p["gnorm"], cfg.rms_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["wo"].astype(jnp.dtype(cfg.dtype)))
    return u + out, MambaCache(h=h, conv_x=cx, conv_B=cb, conv_C=ccv)
