"""Parameter-spec system.

Models declare a nested dict of ParamSpec (shape + dtype + logical axes + init).
From specs we derive:
  * init_params(key, specs)     -> concrete pytree (smoke tests, examples)
  * abstract_params(specs)      -> ShapeDtypeStruct pytree (dry-run, no allocation)
  * logical_axes(specs)         -> pytree of logical-axis tuples (sharding rules)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names, len == ndim
    dtype: Any = jnp.float32
    init: str = "normal"              # normal | zeros | ones | embed | small
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


ParamTree = Dict[str, Any]  # nested dict of ParamSpec


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, specs: ParamTree):
    return jax.tree_util.tree_map(fn, specs, is_leaf=_is_spec)


def abstract_params(specs: ParamTree):
    return tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs)


def logical_axes(specs: ParamTree):
    return tree_map_specs(lambda s: s.axes, specs)


def param_count(specs: ParamTree) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=_is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def param_bytes(specs: ParamTree) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=_is_spec)
    return int(sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize for s in leaves))


def init_params(key: jax.Array, specs: ParamTree):
    """Materialize concrete parameters. Deterministic given key."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))

    def one(k, s: ParamSpec):
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        fan_in = s.shape[0] if len(s.shape) >= 1 else 1
        if s.init == "embed":
            std = 1.0
        elif s.init == "small":
            std = 0.02
        else:
            std = s.scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, s.shape, jnp.float32) * std).astype(s.dtype)

    out = [one(k, s) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)
