"""Model stacks for all assigned architecture families.

Every family exposes:
  * <family>_specs(cfg)                       — ParamSpec tree (stacked layers)
  * forward(params, tokens, cfg, ...)         — full-sequence logits (train/prefill)
  * decode blocks take an ``attend`` callback so the same block code runs
    against a contiguous cache (reference) or the tiered paged cache (serve/).

Layers are stacked along a leading "layers" axis and scanned with lax.scan so
HLO size is O(1) in depth (68 dry-run compiles on one CPU core).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec, tree_map_specs
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.unroll import scan_layers
from repro.sharding.context import constrain_batch


# ------------------------------------------------------------- utilities ----
def stack_specs(specs, n: int):
    """Prepend a stacked 'layers' dimension to every ParamSpec."""
    return tree_map_specs(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.dtype,
                            s.init, s.scale), specs)


def make_remat(body: Callable, policy: str) -> Callable:
    if policy == "none":
        return body
    if policy == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body)  # "block": full remat of each layer


def embed_specs(cfg: ModelConfig):
    # The table's model dim uses the dedicated "embed_tbl" logical axis
    # (unsharded): FSDP-sharding it over "data" conflicts with the
    # batch-sharded token indices and makes GSPMD replicate the lookup over
    # the batch (measured 1.3GB f32 all-reduces; EXPERIMENTS.md §Perf C).
    dt = jnp.dtype(cfg.param_dtype)
    specs = {
        "tok": ParamSpec((cfg.vocab_size, cfg.d_model),
                         ("vocab", "embed_tbl"), dt, init="embed"),
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), dt, init="ones"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                     ("embed_tbl", "vocab"), dt)
    return specs


def embed_tokens(params, tokens, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    return constrain_batch(params["embed"]["tok"].astype(dt)[tokens])


def lm_logits(params, x, cfg: ModelConfig):
    x = L.rms_norm(x, params["embed"]["final_norm"], cfg.rms_eps)
    dt = jnp.dtype(cfg.dtype)
    if cfg.tie_embeddings:
        out = jnp.einsum("bsd,vd->bsv", x, params["embed"]["tok"].astype(dt))
    else:
        out = jnp.einsum("bsd,dv->bsv", x, params["embed"]["lm_head"].astype(dt))
    return constrain_batch(out, model_dim=2)


# ------------------------------------------------- dense / MoE decoder LM ----
def decoder_block_specs(cfg: ModelConfig):
    dt = jnp.dtype(cfg.param_dtype)
    specs = {
        "ln1": ParamSpec((cfg.d_model,), ("embed",), dt, init="ones"),
        "attn": L.attention_specs(cfg),
        "ln2": ParamSpec((cfg.d_model,), ("embed",), dt, init="ones"),
    }
    if cfg.family == "moe":
        specs["moe"] = L.moe_specs(cfg)
    else:
        specs["mlp"] = L.mlp_specs(cfg)
    return specs


def decoder_block(p, x, cfg: ModelConfig, positions) -> Tuple[jax.Array, jax.Array]:
    """Pre-norm block. Returns (x, moe_aux_loss)."""
    h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
    x = x + L.self_attention(p["attn"], h, cfg, positions,
                             causal=True, window=cfg.sliding_window)
    h = L.rms_norm(x, p["ln2"], cfg.rms_eps)
    if cfg.family == "moe":
        y, aux = L.moe_block(p["moe"], h, cfg)
    else:
        y, aux = L.mlp(p["mlp"], h, cfg), jnp.zeros((), jnp.float32)
    return x + y, aux


def decoder_block_decode(p, x, cfg: ModelConfig, positions, attend) -> jax.Array:
    """Decode block; ``attend(q, k_new, v_new) -> attn [B,1,H,D]`` owns the cache."""
    h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
    q, k, v = L.attention_qkv(p["attn"], h, cfg, positions)
    x = x + L.attention_out(p["attn"], attend(q, k, v), cfg)
    h = L.rms_norm(x, p["ln2"], cfg.rms_eps)
    if cfg.family == "moe":
        y = L.moe_block_decode(p["moe"], h, cfg)
    else:
        y = L.mlp(p["mlp"], h, cfg)
    return x + y


def lm_specs(cfg: ModelConfig):
    return {"embed": embed_specs(cfg),
            "layers": stack_specs(decoder_block_specs(cfg), cfg.num_layers)}


def lm_forward(params, tokens, cfg: ModelConfig, remat: str = "block"):
    """tokens: [B,S] -> logits [B,S,V]; also returns aux (moe load-balance)."""
    x = embed_tokens(params, tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)

    def body(carry, lp):
        x, aux = carry
        x, a = decoder_block(lp, constrain_batch(x), cfg, positions)
        return (constrain_batch(x), aux + a), None

    body = make_remat(body, remat)
    (x, aux), _ = scan_layers(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    return lm_logits(params, x, cfg), aux


# ------------------------------------------------------------ Mamba2 LM ----
def ssm_lm_specs(cfg: ModelConfig):
    return {"embed": embed_specs(cfg),
            "layers": stack_specs(S.mamba_specs(cfg), cfg.num_layers)}


def ssm_lm_forward(params, tokens, cfg: ModelConfig, remat: str = "block"):
    x = embed_tokens(params, tokens, cfg)

    def body(x, lp):
        x, _ = S.mamba_block(lp, constrain_batch(x), cfg)
        return constrain_batch(x), None

    body = make_remat(body, remat)
    x, _ = scan_layers(body, x, params["layers"])
    return lm_logits(params, x, cfg), jnp.zeros((), jnp.float32)


# -------------------------------------------------- hybrid (zamba2-style) ----
def hybrid_specs(cfg: ModelConfig):
    dt = jnp.dtype(cfg.param_dtype)
    shared = {
        "in_proj": ParamSpec((2 * cfg.d_model, cfg.d_model), ("embed_x2", "embed"), dt),
        "ln1": ParamSpec((cfg.d_model,), ("embed",), dt, init="ones"),
        "attn": L.attention_specs(cfg),
        "ln2": ParamSpec((cfg.d_model,), ("embed",), dt, init="ones"),
        "mlp": L.mlp_specs(cfg),
        "out_proj": ParamSpec((cfg.d_model, cfg.d_model), ("embed", "embed_out"), dt,
                              init="small"),
    }
    return {"embed": embed_specs(cfg),
            "layers": stack_specs(S.mamba_specs(cfg), cfg.num_layers),
            "shared": shared}


def _shared_attn_block(sp, x, emb0, cfg: ModelConfig, positions):
    dt = jnp.dtype(cfg.dtype)
    h = jnp.concatenate([x, emb0], axis=-1)
    h = jnp.einsum("bse,ed->bsd", h, sp["in_proj"].astype(dt))
    a = L.rms_norm(h, sp["ln1"], cfg.rms_eps)
    h = h + L.self_attention(sp["attn"], a, cfg, positions, causal=True,
                             window=cfg.sliding_window)
    a = L.rms_norm(h, sp["ln2"], cfg.rms_eps)
    h = h + L.mlp(sp["mlp"], a, cfg)
    return x + jnp.einsum("bsd,de->bse", h, sp["out_proj"].astype(dt))


def hybrid_forward(params, tokens, cfg: ModelConfig, remat: str = "block"):
    """Zamba2-style: Mamba2 backbone, one *shared* attention block applied
    every ``hybrid_attn_every`` layers on concat(hidden, embeddings)."""
    x = embed_tokens(params, tokens, cfg)
    emb0 = x
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    sp = params["shared"]
    every = cfg.hybrid_attn_every

    def body(carry, xs):
        x, = carry
        lp, idx = xs
        x = constrain_batch(x)
        x = jax.lax.cond(idx % every == 0,
                         lambda x: _shared_attn_block(sp, x, emb0, cfg, positions),
                         lambda x: x, x)
        x, _ = S.mamba_block(lp, x, cfg)
        return (constrain_batch(x),), None

    body = make_remat(body, remat)
    (x,), _ = scan_layers(body, (x,),
                           (params["layers"], jnp.arange(cfg.num_layers)))
    return lm_logits(params, x, cfg), jnp.zeros((), jnp.float32)


# ----------------------------------------------------- VLM (llama-vision) ----
def vlm_specs(cfg: ModelConfig):
    """num_layers = self layers + cross layers; repeat unit of
    (cross_attn_every - 1) self blocks followed by 1 gated cross block."""
    every = cfg.cross_attn_every
    assert every > 1 and cfg.num_layers % every == 0
    n_units = cfg.num_layers // every
    dt = jnp.dtype(cfg.param_dtype)
    unit = {
        "self": stack_specs(decoder_block_specs(cfg), every - 1),
        "cross": {
            "ln": ParamSpec((cfg.d_model,), ("embed",), dt, init="ones"),
            "attn": L.attention_specs(cfg),
            "gate": ParamSpec((), (), dt, init="zeros"),
            "ln2": ParamSpec((cfg.d_model,), ("embed",), dt, init="ones"),
            "mlp": L.mlp_specs(cfg),
            "gate_mlp": ParamSpec((), (), dt, init="zeros"),
        },
    }
    return {"embed": embed_specs(cfg), "units": stack_specs(unit, n_units)}


def _cross_block(cp, x, enc, cfg: ModelConfig):
    h = L.rms_norm(x, cp["ln"], cfg.rms_eps)
    a = L.cross_attention(cp["attn"], h, enc, cfg)
    x = x + jnp.tanh(cp["gate"].astype(jnp.float32)).astype(x.dtype) * a
    h = L.rms_norm(x, cp["ln2"], cfg.rms_eps)
    y = L.mlp(cp["mlp"], h, cfg)
    return x + jnp.tanh(cp["gate_mlp"].astype(jnp.float32)).astype(x.dtype) * y


def vlm_forward(params, tokens, image_embeds, cfg: ModelConfig,
                remat: str = "block"):
    """tokens: [B,S]; image_embeds (stub frontend): [B, n_img, D]."""
    x = embed_tokens(params, tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    enc = image_embeds.astype(jnp.dtype(cfg.dtype))

    def self_body(carry, lp):
        x, aux = carry
        x, a = decoder_block(lp, constrain_batch(x), cfg, positions)
        return (constrain_batch(x), aux + a), None

    self_body = make_remat(self_body, remat)

    def unit_body(carry, up):
        x, aux = carry
        (x, aux), _ = scan_layers(self_body, (x, aux), up["self"])
        x = constrain_batch(_cross_block(up["cross"], x, enc, cfg))
        return (x, aux), None

    (x, aux), _ = scan_layers(unit_body, (x, jnp.zeros((), jnp.float32)),
                               params["units"])
    return lm_logits(params, x, cfg), aux


# ------------------------------------------------- enc-dec (whisper-tiny) ----
def encdec_specs(cfg: ModelConfig):
    dt = jnp.dtype(cfg.param_dtype)
    enc_block = {
        "ln1": ParamSpec((cfg.d_model,), ("embed",), dt, init="ones"),
        "attn": L.attention_specs(cfg),
        "ln2": ParamSpec((cfg.d_model,), ("embed",), dt, init="ones"),
        "mlp": L.mlp_specs(cfg),
    }
    dec_block = {
        "ln1": ParamSpec((cfg.d_model,), ("embed",), dt, init="ones"),
        "attn": L.attention_specs(cfg),
        "ln_x": ParamSpec((cfg.d_model,), ("embed",), dt, init="ones"),
        "xattn": L.attention_specs(cfg, cross=True),
        "ln2": ParamSpec((cfg.d_model,), ("embed",), dt, init="ones"),
        "mlp": L.mlp_specs(cfg),
    }
    return {
        "embed": embed_specs(cfg),
        "enc_ln": ParamSpec((cfg.d_model,), ("embed",), dt, init="ones"),
        "encoder": stack_specs(enc_block, cfg.encoder_layers),
        "decoder": stack_specs(dec_block, cfg.num_layers),
    }


def _sinusoid(seq: int, d: int) -> np.ndarray:
    pos = np.arange(seq)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


def encode_frames(params, frames, cfg: ModelConfig, remat: str = "block"):
    """frames: [B, T_enc, D] precomputed frame embeddings (stub conv frontend)."""
    dt = jnp.dtype(cfg.dtype)
    x = frames.astype(dt) + jnp.asarray(_sinusoid(frames.shape[1], cfg.d_model), dt)

    def body(x, lp):
        x = constrain_batch(x)
        h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
        x = x + L.self_attention(lp["attn"], h, cfg, None, causal=False)
        h = L.rms_norm(x, lp["ln2"], cfg.rms_eps)
        return constrain_batch(x + L.mlp(lp["mlp"], h, cfg)), None

    body = make_remat(body, remat)
    x, _ = scan_layers(body, x, params["encoder"])
    return L.rms_norm(x, params["enc_ln"], cfg.rms_eps)


def encdec_dec_block(p, x, enc, cfg: ModelConfig, positions):
    h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
    x = x + L.self_attention(p["attn"], h, cfg, positions, causal=True)
    h = L.rms_norm(x, p["ln_x"], cfg.rms_eps)
    x = x + L.cross_attention(p["xattn"], h, enc, cfg)
    h = L.rms_norm(x, p["ln2"], cfg.rms_eps)
    return x + L.mlp(p["mlp"], h, cfg)


def encdec_forward(params, tokens, frames, cfg: ModelConfig,
                   remat: str = "block"):
    enc = encode_frames(params, frames, cfg, remat)
    x = embed_tokens(params, tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)

    def body(x, lp):
        return constrain_batch(
            encdec_dec_block(lp, constrain_batch(x), enc, cfg, positions)), None

    body = make_remat(body, remat)
    x, _ = scan_layers(body, x, params["decoder"])
    return lm_logits(params, x, cfg), jnp.zeros((), jnp.float32)


# --------------------------------------------------------------- router ----
def model_specs(cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        return lm_specs(cfg)
    if cfg.family == "ssm":
        return ssm_lm_specs(cfg)
    if cfg.family == "hybrid":
        return hybrid_specs(cfg)
    if cfg.family == "vlm":
        return vlm_specs(cfg)
    if cfg.family == "encdec":
        return encdec_specs(cfg)
    raise ValueError(cfg.family)


def model_forward(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
                  remat: str = "block"):
    """Unified full-sequence forward. batch: tokens [+frames | +image_embeds]."""
    tokens = batch["tokens"]
    if cfg.family in ("dense", "moe"):
        return lm_forward(params, tokens, cfg, remat)
    if cfg.family == "ssm":
        return ssm_lm_forward(params, tokens, cfg, remat)
    if cfg.family == "hybrid":
        return hybrid_forward(params, tokens, cfg, remat)
    if cfg.family == "vlm":
        return vlm_forward(params, tokens, batch["image_embeds"], cfg, remat)
    if cfg.family == "encdec":
        return encdec_forward(params, tokens, batch["frames"], cfg, remat)
    raise ValueError(cfg.family)
