"""Scan-vs-unroll switch for layer stacks.

XLA's HloCostAnalysis counts a while-loop body ONCE (trip count ignored), so
the scan-over-layers HLO undercounts FLOPs/bytes/collective traffic. For the
roofline we re-lower each cell at two reduced depths with the stacks fully
unrolled (exact per-layer HLO, same sharding) and extrapolate linearly to the
production depth: cost(L) = base + L * per_layer. Production compiles keep
the scan (O(1) HLO size, fast compiles).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_UNROLL = False


def set_unroll(v: bool) -> None:
    global _UNROLL
    _UNROLL = bool(v)


def unrolled() -> bool:
    return _UNROLL


def scan_layers(body, carry, xs, length=None):
    """jax.lax.scan over the layer dim, or an exact python-level unroll."""
    if not _UNROLL:
        return jax.lax.scan(body, carry, xs)
    leaves = jax.tree_util.tree_leaves(xs)
    n = length if length is not None else leaves[0].shape[0]
    ys = []
    for i in range(n):
        xs_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, xs_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys_stacked = jax.tree_util.tree_map(
            lambda *a: jnp.stack(a, axis=0), *ys)
    else:
        ys_stacked = None
    return carry, ys_stacked


def chunk_unroll(n_chunks: int) -> int:
    """Unroll factor for inner chunk scans (flash attention reference)."""
    return n_chunks if _UNROLL else 1
