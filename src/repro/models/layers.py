"""Core neural layers, pure JAX on parameter pytrees.

Attention comes in four structural variants (picked by shape, not by flag):
  * attn_dense    — materialized scores; short sequences (<= ~8k)
  * attn_chunked  — online-softmax scan over KV chunks (flash-style); long prefill
  * attn_local    — banded two-block sliding-window attention; SWA at any length
  * attn_decode   — single-query attention against a contiguous KV cache
The tiered paged-KV decode attention (the paper-relevant one) lives in
serve/decode.py and kernels/tiered_attention/.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.params import ParamSpec

NEG_INF = -1e30


# ---------------------------------------------------------------- norms ----
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------- rotary ----
def rotary_embed(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, n_heads, head_dim]; positions: [..., S] (int)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs            # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                                  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------- attn cores ----
# GQA is computed in expanded-head form: KV (small, TP-replicated) is
# broadcast to H heads so every tensor keeps its "heads"->model sharding.
# Splitting H into (K, G) would break TP when K < mesh model size (e.g. 8 kv
# heads on a 16-wide axis): XLA then replicates the whole attention across
# the model axis (~5x FLOPs/device — measured; see EXPERIMENTS.md §Perf).
def _expand_kv(k: jax.Array, h: int) -> jax.Array:
    """[B,T,K,D] -> [B,T,H,D]; head i attends kv head i // (H/K) (q-grouping
    matches q.reshape(B,S,K,G,D) ordering)."""
    b, t, kh, d = k.shape
    if kh == h:
        return k
    return jnp.repeat(k, h // kh, axis=2)


def attn_dense(q, k, v, *, causal: bool, window: Optional[int] = None,
               q_offset: int = 0) -> jax.Array:
    """Materialized-scores attention. q:[B,S,H,D] k,v:[B,T,K,D] -> [B,S,H,D]."""
    b, s, h, d = q.shape
    t = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    ke = _expand_kv(k, h).astype(jnp.float32)
    ve = _expand_kv(v, h).astype(jnp.float32)
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32) * scale, ke)
    if causal or window is not None:
        qpos = jnp.arange(s) + q_offset
        kpos = jnp.arange(t)
        mask = jnp.ones((s, t), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, ve)
    return out.astype(q.dtype)


def attn_chunked(q, k, v, *, causal: bool = True, chunk: int = 1024) -> jax.Array:
    """Flash-style online-softmax over KV chunks; avoids the S×T score tensor.

    q:[B,S,H,D] k,v:[B,T,K,D]. Scans KV chunks; for causal, fully-masked
    chunks still execute (static schedule) but contribute nothing — the
    Pallas kernel (kernels/flash_attention) skips them on TPU.
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    assert t % chunk == 0, (t, chunk)
    n_chunks = t // chunk
    scale = 1.0 / np.sqrt(d)
    qe = (q * scale).astype(jnp.float32)                               # [B,S,H,D]
    kc = _expand_kv(k, h).reshape(b, n_chunks, chunk, h, d)
    vc = _expand_kv(v, h).reshape(b, n_chunks, chunk, h, d)
    qpos = jnp.arange(s)

    def body(carry, xs):
        acc, m, l = carry
        kb, vb, ci = xs
        sc = jnp.einsum("bshd,bchd->bhsc", qe, kb.astype(jnp.float32))
        if causal:
            kpos = ci * chunk + jnp.arange(chunk)
            mask = kpos[None, :] <= qpos[:, None]                      # [S, C]
            sc = jnp.where(mask[None, None], sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhsc,bchd->bhsd", p, vb.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, h, s, d), jnp.float32)
    m0 = jnp.full((b, h, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    from repro.models.unroll import chunk_unroll
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(n_chunks)),
        unroll=chunk_unroll(n_chunks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)                     # [B,S,H,D]


def attn_local(q, k, v, *, window: int) -> jax.Array:
    """Banded sliding-window attention: q block i attends kv blocks {i-1, i}.

    Sub-quadratic: FLOPs ~ 2·S·2W. Requires S % W == 0 (pad upstream).
    q:[B,S,H,D], k,v:[B,S,K,D].
    """
    b, s, h, d = q.shape
    w = window
    assert s % w == 0, (s, w)
    nb = s // w
    scale = 1.0 / np.sqrt(d)
    qb = (q * scale).reshape(b, nb, w, h, d).astype(jnp.float32)
    kb = _expand_kv(k, h).reshape(b, nb, w, h, d)
    vb = _expand_kv(v, h).reshape(b, nb, w, h, d)
    # previous block (block -1 = zeros, fully masked)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kb], axis=2).astype(jnp.float32)      # [B,nb,2w,H,D]
    v2 = jnp.concatenate([vprev, vb], axis=2).astype(jnp.float32)
    sc = jnp.einsum("bnshd,bnthd->bnhst", qb, k2)
    qpos = jnp.arange(w)
    kpos = jnp.arange(2 * w) - w                                       # relative to block start
    mask = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] > qpos[:, None] - w)
    block_first = (jnp.arange(nb) == 0)[:, None, None]                 # [nb,1,1]
    prev_ok = (kpos >= 0)[None, None, :]                               # [1,1,2w]
    mask_f = mask[None] & (~block_first | prev_ok)                     # [nb,w,2w]
    sc = jnp.where(mask_f[None, :, None], sc, NEG_INF)                 # [1,nb,1,w,2w]
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bnhst,bnthd->bnshd", p, v2)
    return out.reshape(b, s, h, d).astype(q.dtype)


def attn_decode(q, k_cache, v_cache, kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Single-token decode attention against a contiguous cache.

    q:[B,1,H,D], caches:[B,T,K,D]; kv_len (opt): [B] valid lengths.
    """
    b, _, h, d = q.shape
    t = k_cache.shape[1]
    scale = 1.0 / np.sqrt(d)
    ke = _expand_kv(k_cache, h).astype(jnp.float32)
    ve = _expand_kv(v_cache, h).astype(jnp.float32)
    sc = jnp.einsum("bhd,bthd->bht", q[:, 0].astype(jnp.float32) * scale, ke)
    if kv_len is not None:
        valid = jnp.arange(t)[None] < kv_len[:, None]                  # [B,T]
        sc = jnp.where(valid[:, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bht,bthd->bhd", p, ve)
    return out[:, None].astype(q.dtype)


# -------------------------------------------------------- attention block ----
def attention_specs(cfg: ModelConfig, *, cross: bool = False, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    hd = cfg.resolved_head_dim
    h, kh = cfg.num_heads, cfg.num_kv_heads
    dt = jnp.dtype(cfg.param_dtype)
    specs = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim"), dt),
        "wk": ParamSpec((d, kh, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wv": ParamSpec((d, kh, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed"), dt),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), ("head_dim",), dt, init="ones")
        specs["k_norm"] = ParamSpec((hd,), ("head_dim",), dt, init="ones")
    return specs


def attention_qkv(p, x, cfg: ModelConfig, positions, *, kv_x=None, rope: bool = True):
    """Project to q,k,v (+qk-norm, +rope). Returns q:[B,S,H,D], k,v:[B,T,K,D]."""
    dt = jnp.dtype(cfg.dtype)
    kv_src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", kv_src, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", kv_src, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    if rope and kv_x is None and positions is not None:
        q = rotary_embed(q, positions, cfg.rope_theta)
        k = rotary_embed(k, positions, cfg.rope_theta)
    return q, k, v


def attention_out(p, attn, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    return jnp.einsum("bshk,hkd->bsd", attn, p["wo"].astype(dt))


def self_attention(p, x, cfg: ModelConfig, positions, *, causal=True,
                   window=None) -> jax.Array:
    """Full self-attention block body (no residual/norm)."""
    q, k, v = attention_qkv(p, x, cfg, positions)
    s = x.shape[1]
    if window is not None and s > window:
        attn = attn_local(q, k, v, window=window)
    elif s > 2048 and causal:
        attn = attn_chunked(q, k, v, causal=causal, chunk=min(1024, s))
    else:
        attn = attn_dense(q, k, v, causal=causal, window=window)
    return attention_out(p, attn, cfg)


def cross_attention(p, x, enc, cfg: ModelConfig) -> jax.Array:
    q, k, v = attention_qkv(p, x, cfg, None, kv_x=enc, rope=False)
    attn = attn_dense(q, k, v, causal=False)
    return attention_out(p, attn, cfg)


# ------------------------------------------------------------------ MLP ----
def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    if cfg.act == "silu":
        return {
            "wg": ParamSpec((d, f), ("embed", "mlp"), dt),
            "wu": ParamSpec((d, f), ("embed", "mlp"), dt),
            "wd": ParamSpec((f, d), ("mlp", "embed"), dt),
        }
    return {
        "w1": ParamSpec((d, f), ("embed", "mlp"), dt),
        "b1": ParamSpec((f,), ("mlp",), dt, init="zeros"),
        "w2": ParamSpec((f, d), ("mlp", "embed"), dt),
        "b2": ParamSpec((d,), ("embed",), dt, init="zeros"),
    }


def mlp(p, x, cfg: ModelConfig) -> jax.Array:
    dt = jnp.dtype(cfg.dtype)
    if "wg" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(dt))
        return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["wd"].astype(dt))
    h = jnp.einsum("bsd,df->bsf", x, p["w1"].astype(dt)) + p["b1"].astype(dt)
    h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["w2"].astype(dt)) + p["b2"].astype(dt)


# ------------------------------------------------------------------ MoE ----
def moe_specs(cfg: ModelConfig):
    assert cfg.moe is not None
    d, m = cfg.d_model, cfg.moe
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "router": ParamSpec((d, m.num_experts), ("embed", "experts_dim"), dt, init="small"),
        "wg": ParamSpec((m.num_experts, d, m.d_ff_expert), ("experts", "embed", "expert_mlp"), dt),
        "wu": ParamSpec((m.num_experts, d, m.d_ff_expert), ("experts", "embed", "expert_mlp"), dt),
        "wd": ParamSpec((m.num_experts, m.d_ff_expert, d), ("experts", "expert_mlp", "embed"), dt),
    }


def moe_block(p, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Top-k MoE with *grouped gather dispatch* (per-sample groups).

    Each sample is a routing group: every expert takes its top-C tokens
    within the sample (C = S*top_k/E * capacity_factor), gathered directly —
    no [T, E, C] dispatch one-hot einsum. The gather keeps all data local to
    the sample's data shard (no cross-shard traffic), its backward is a
    scatter-add, and expert FLOPs = capacity_factor x the ideal active
    FLOPs. (The original GShard dispatch-einsum costs T*E*C*D flops — 2-4x
    the expert matmuls themselves; see EXPERIMENTS.md §Perf mixtral
    iteration.) Returns (out, aux_loss). x: [B, S, D].
    """
    m: MoEConfig = cfg.moe
    dt = jnp.dtype(cfg.dtype)
    b, s, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                            # [B,S,E]
    gate_vals, _ = jax.lax.top_k(probs, m.top_k)                       # [B,S,k]
    thresh = gate_vals[..., -1:]                                       # [B,S,1]
    # token-choice top-k membership, expert-side capacity selection
    score = jnp.where(probs >= thresh, probs, 0.0)                     # [B,S,E]
    capacity = max(int(np.ceil(s * m.top_k / m.num_experts
                               * m.capacity_factor)), 4)
    capacity = min(capacity, s)
    vals, idx = jax.lax.top_k(score.transpose(0, 2, 1), capacity)      # [B,E,C]
    keep = vals > 0.0
    barange = jnp.arange(b)[:, None, None]
    xe = x[barange, idx]                                               # [B,E,C,D]
    g = jnp.einsum("becd,edf->becf", xe, p["wg"].astype(dt))
    u = jnp.einsum("becd,edf->becf", xe, p["wu"].astype(dt))
    ye = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, p["wd"].astype(dt))
    w = (vals * keep).astype(jnp.float32)                              # gates
    weighted = ye.astype(jnp.float32) * w[..., None]
    out = jnp.zeros((b, s, d), jnp.float32).at[barange, idx].add(weighted)
    denom = jnp.zeros((b, s), jnp.float32).at[barange, idx].add(w)
    out = out / jnp.maximum(denom, 1e-9)[..., None]

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    me = probs.mean((0, 1))
    assigned = (probs >= thresh).astype(jnp.float32)
    ce = assigned.mean((0, 1)) / m.top_k * m.num_experts
    aux = jnp.sum(me * ce)
    return out.astype(dt), aux


def moe_block_decode(p, x, cfg: ModelConfig) -> jax.Array:
    """MoE for decode (few tokens): gather per-token expert weights.

    x: [B, 1, D]. No grad needed; gathers top_k expert mats per token.
    """
    m: MoEConfig = cfg.moe
    dt = jnp.dtype(cfg.dtype)
    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    logits = jnp.einsum("td,de->te", tokens.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)              # [T,k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    wg = p["wg"].astype(dt)[expert_idx]                                 # [T,k,D,F]
    wu = p["wu"].astype(dt)[expert_idx]
    wd = p["wd"].astype(dt)[expert_idx]
    g = jnp.einsum("td,tkdf->tkf", tokens, wg)
    u = jnp.einsum("td,tkdf->tkf", tokens, wu)
    y = jnp.einsum("tkf,tkfd->tkd", jax.nn.silu(g) * u, wd)
    out = jnp.einsum("tkd,tk->td", y.astype(jnp.float32),
                     gate_vals.astype(jnp.float32))
    return out.reshape(b, s, d).astype(dt)
