"""Trace-driven two-tier simulator: workloads -> engine -> summary metrics.

The same policy code (core/policy.py, core/engine.py) drives both this
simulator (for the paper's evaluation) and the tiered KV-cache serving path
(serve/): the simulator is how we reproduce the paper's numbers without a
2-socket CXL box; the perf model constants come from the paper (§V-A,
Fig. 2: 252ns CXL vs ~100ns local, ~0.1 bandwidth ratio).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import TieringConfig
from repro.core.engine import TickOutput, run_engine
from repro.core.workloads import (TenantWorkload, build_trace,
                                  stacked_heterogeneous, suggest_policy)
from repro.obs.pathology import Pathology, detect_all
from repro.obs.stats import stats_summary
from repro.obs.trace import decode_ring


@dataclass
class SimResult:
    mode: str
    fast_usage: np.ndarray      # [ticks, T]
    slow_usage: np.ndarray      # [ticks, T]
    promotions: np.ndarray      # [ticks, T]
    demotions: np.ndarray       # [ticks, T]
    throughput: np.ndarray      # [ticks, T]
    latency: np.ndarray         # [ticks, T]
    promo_scale: np.ndarray     # [ticks, T]
    thrash_events: np.ndarray   # [ticks, T] cumulative
    attempted: np.ndarray = None        # [ticks, T] promotion candidates
    # observability (obs/): decoded from the final engine state
    tier_stats: Optional[dict] = None   # obs.stats.stats_summary output
    migrations: Optional[np.ndarray] = None  # obs.trace.EVENT_DTYPE records
    migrations_dropped: int = 0
    lower_protection: tuple = ()

    def steady_window(self, frac: float = 0.5) -> slice:
        n = self.fast_usage.shape[0]
        return slice(int(n * (1 - frac)), n)

    def mean_throughput(self, window: Optional[slice] = None) -> np.ndarray:
        w = window or self.steady_window()
        return self.throughput[w].mean(axis=0)

    def mean_latency(self, window: Optional[slice] = None) -> np.ndarray:
        w = window or self.steady_window()
        return self.latency[w].mean(axis=0)

    def p99_latency(self, window: Optional[slice] = None) -> np.ndarray:
        w = window or self.steady_window()
        return np.percentile(self.latency[w], 99, axis=0)

    def mean_fast(self, window: Optional[slice] = None) -> np.ndarray:
        w = window or self.steady_window()
        return self.fast_usage[w].mean(axis=0)

    def migration_rate(self, window: Optional[slice] = None) -> np.ndarray:
        w = window or self.steady_window()
        return (self.promotions[w] + self.demotions[w]).mean(axis=0)

    def pathologies(self, **kw) -> List[Pathology]:
        """Run the offline obs.pathology detectors over this run."""
        return detect_all(
            self.fast_usage, self.slow_usage, self.promotions,
            self.demotions, self.latency, self.thrash_events,
            attempted=self.attempted,
            lower_protection=self.lower_protection, **kw)


def simulate(cfg: TieringConfig, tenants: List[TenantWorkload], ticks: int,
             mode: str = "equilibria", k_max: int = 256,
             impl: str = "batched") -> SimResult:
    owner, accesses, alive = build_trace(tenants, ticks)
    cfg = cfg.with_(n_tenants=len(tenants))
    final, outs = run_engine(cfg, owner, accesses, alive, mode=mode,
                             k_max=k_max, impl=impl)
    events, dropped = decode_ring(final.ring)
    return SimResult(
        mode=mode,
        fast_usage=np.asarray(outs.fast_usage),
        slow_usage=np.asarray(outs.slow_usage),
        promotions=np.asarray(outs.promotions),
        demotions=np.asarray(outs.demotions),
        throughput=np.asarray(outs.throughput),
        latency=np.asarray(outs.latency),
        promo_scale=np.asarray(outs.promo_scale),
        thrash_events=np.asarray(outs.thrash_events),
        attempted=np.asarray(outs.attempted_promotions),
        tier_stats=stats_summary(final.stats),
        migrations=events,
        migrations_dropped=dropped,
        lower_protection=tuple(cfg.lower_protection[:cfg.n_tenants]),
    )


def compare_modes(cfg: TieringConfig, tenants: List[TenantWorkload], ticks: int,
                  modes=("equilibria", "tpp")) -> Dict[str, SimResult]:
    return {m: simulate(cfg, tenants, ticks, mode=m) for m in modes}


# ---------------------------------------------------------------- presets ----
def _stacked(n_tenants: int) -> Tuple[TieringConfig, List[TenantWorkload]]:
    """Stacked-heterogeneous host: n heterogeneous cgroups (cache/web/CI/
    stream/bursty), fast tier sized to ~55% of the summed footprint, per-
    tenant policy derived from workload shape (``suggest_policy``)."""
    tenants = stacked_heterogeneous(n_tenants)
    prot, bound = suggest_policy(tenants)
    total = sum(w.footprint for w in tenants)
    fast = (int(total * 0.55) // 64) * 64
    cfg = TieringConfig(n_tenants=n_tenants, n_fast_pages=fast,
                        n_slow_pages=total, lower_protection=prot,
                        upper_bound=bound)
    return cfg, tenants


PRESETS: Dict[str, Callable[[], Tuple[TieringConfig, List[TenantWorkload]]]] = {
    "stacked16": lambda: _stacked(16),
    "stacked64": lambda: _stacked(64),
}


def simulate_preset(name: str, ticks: int = 300, mode: str = "equilibria",
                    k_max: int = 128, **cfg_overrides) -> SimResult:
    """Run a named scenario preset (see ``PRESETS``)."""
    cfg, tenants = PRESETS[name]()
    if cfg_overrides:
        cfg = cfg.with_(**cfg_overrides)
    return simulate(cfg, tenants, ticks, mode=mode, k_max=k_max)
