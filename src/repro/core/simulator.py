"""Trace-driven two-tier simulator: workloads -> engine -> summary metrics.

The same policy code (core/policy.py, core/engine.py) drives both this
simulator (for the paper's evaluation) and the tiered KV-cache serving path
(serve/): the simulator is how we reproduce the paper's numbers without a
2-socket CXL box; the perf model constants come from the paper (§V-A,
Fig. 2: 252ns CXL vs ~100ns local, ~0.1 bandwidth ratio).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import TieringConfig
from repro.core.churn import churn_events, run_churn_engine
from repro.core.engine import TickOutput, run_engine
from repro.core.workloads import (ChurnSlot, TenantWorkload,
                                  build_churn_schedule, build_trace,
                                  churn_stacked, stacked_heterogeneous,
                                  suggest_churn_policy, suggest_policy)
from repro.obs.pathology import Pathology, detect_all
from repro.obs.stats import stats_summary
from repro.obs.trace import decode_ring


@dataclass
class SimResult:
    """One simulated run's collected telemetry (host-side numpy).

    Fields
    ------
    mode : str
        Engine mode the run used (``equilibria``/``tpp``/``memtis``/``static``).
    fast_usage, slow_usage : np.ndarray
        [ticks, T] per-tenant page counts in each tier.
    promotions, demotions : np.ndarray
        [ticks, T] migrations performed that tick.
    throughput, latency : np.ndarray
        [ticks, T] perf-model outputs (latency in units of ``lat_fast``).
    promo_scale : np.ndarray
        [ticks, T] thrash-mitigation promotion multiplier trajectory.
    thrash_events : np.ndarray
        [ticks, T] *cumulative* §IV-F thrash detections.
    attempted : np.ndarray, optional
        [ticks, T] promotion candidates scanned that tick (obs).
    tier_stats : dict, optional
        ``obs.stats.stats_summary`` export decoded from the final state.
    migrations : np.ndarray, optional
        Decoded migration event ring (``obs.trace.EVENT_DTYPE`` records).
    migrations_dropped : int
        Ring-capacity overflow count (events overwritten before decode).
    lower_protection : tuple
        The run's configured per-tenant protections (for the detectors).
    active : np.ndarray, optional
        [ticks, T] bool tenant roster. Churn runs take it from the
        schedule; static runs derive it from trace liveness. The
        churn-aware pathology detectors use it to tolerate mid-window
        departures.
    pool_free : np.ndarray, optional
        [ticks] unallocated pages (the churn engine's free pool).
    """
    mode: str
    fast_usage: np.ndarray
    slow_usage: np.ndarray
    promotions: np.ndarray
    demotions: np.ndarray
    throughput: np.ndarray
    latency: np.ndarray
    promo_scale: np.ndarray
    thrash_events: np.ndarray
    attempted: Optional[np.ndarray] = None
    tier_stats: Optional[dict] = None
    migrations: Optional[np.ndarray] = None
    migrations_dropped: int = 0
    lower_protection: tuple = ()
    active: Optional[np.ndarray] = None
    pool_free: Optional[np.ndarray] = None

    def steady_window(self, frac: float = 0.5) -> slice:
        n = self.fast_usage.shape[0]
        return slice(int(n * (1 - frac)), n)

    def mean_throughput(self, window: Optional[slice] = None) -> np.ndarray:
        w = window or self.steady_window()
        return self.throughput[w].mean(axis=0)

    def mean_latency(self, window: Optional[slice] = None) -> np.ndarray:
        w = window or self.steady_window()
        return self.latency[w].mean(axis=0)

    def p99_latency(self, window: Optional[slice] = None) -> np.ndarray:
        w = window or self.steady_window()
        return np.percentile(self.latency[w], 99, axis=0)

    def mean_fast(self, window: Optional[slice] = None) -> np.ndarray:
        w = window or self.steady_window()
        return self.fast_usage[w].mean(axis=0)

    def migration_rate(self, window: Optional[slice] = None) -> np.ndarray:
        w = window or self.steady_window()
        return (self.promotions[w] + self.demotions[w]).mean(axis=0)

    def pathologies(self, **kw) -> List[Pathology]:
        """Run the offline obs.pathology detectors over this run."""
        kw.setdefault("active", self.active)
        return detect_all(
            self.fast_usage, self.slow_usage, self.promotions,
            self.demotions, self.latency, self.thrash_events,
            attempted=self.attempted,
            lower_protection=self.lower_protection, **kw)


def tenant_activity(owner: np.ndarray, alive: np.ndarray,
                    n_tenants: int) -> np.ndarray:
    """[ticks, T] bool: tenant has any live page this tick (static traces)."""
    return np.stack([alive[:, owner == i].any(axis=1)
                     for i in range(n_tenants)], axis=1)


def build_result(mode: str, cfg: TieringConfig, final, outs,
                 active: Optional[np.ndarray]) -> SimResult:
    """The one SimResult builder: decode the final engine state (stats
    summary + migration ring) and pull the per-tick outputs to host. Both
    ownership providers produce the same state/outputs structure, so one
    builder serves static, churn and (per-host slices of) fleet runs."""
    events, dropped = decode_ring(final.ring)
    return SimResult(
        mode=mode,
        fast_usage=np.asarray(outs.fast_usage),
        slow_usage=np.asarray(outs.slow_usage),
        promotions=np.asarray(outs.promotions),
        demotions=np.asarray(outs.demotions),
        throughput=np.asarray(outs.throughput),
        latency=np.asarray(outs.latency),
        promo_scale=np.asarray(outs.promo_scale),
        thrash_events=np.asarray(outs.thrash_events),
        attempted=np.asarray(outs.attempted_promotions),
        tier_stats=stats_summary(final.stats),
        migrations=events,
        migrations_dropped=dropped,
        lower_protection=tuple(cfg.lower_protection[:cfg.n_tenants]),
        active=active,
        pool_free=np.asarray(outs.pool_free),
    )


def simulate(cfg: TieringConfig, tenants: List[TenantWorkload], ticks: int,
             mode: str = "equilibria", k_max: int = 256,
             impl: str = "batched", hotness=None) -> SimResult:
    owner, accesses, alive = build_trace(tenants, ticks)
    cfg = cfg.with_(n_tenants=len(tenants))
    final, outs = run_engine(cfg, owner, accesses, alive, mode=mode,
                             k_max=k_max, impl=impl, hotness=hotness)
    return build_result(mode, cfg, final, outs,
                        tenant_activity(owner, alive, cfg.n_tenants))


def simulate_churn(cfg: TieringConfig, slots: List[ChurnSlot], ticks: int,
                   mode: str = "equilibria", k_max: int = 256,
                   n_pages: Optional[int] = None, hotness=None,
                   impl: str = "batched") -> SimResult:
    """Run a dynamic-roster scenario through the churn engine
    (core/churn.py): slots' lifecycle episodes become in-graph
    arrival/departure/resize events; ownership and the free pool are engine
    state. ``SimResult.active`` carries the per-tick roster for the
    churn-aware pathology detectors; ``pool_free`` the free-pool depth."""
    schedule = build_churn_schedule(slots, ticks)
    cfg = cfg.with_(n_tenants=len(slots))
    final, outs = run_churn_engine(cfg, schedule, mode=mode, k_max=k_max,
                                   n_pages=n_pages, hotness=hotness,
                                   impl=impl)
    return build_result(mode, cfg, final, outs, schedule.want > 0)


def compare_modes(cfg: TieringConfig, tenants: List[TenantWorkload], ticks: int,
                  modes=("equilibria", "tpp")) -> Dict[str, SimResult]:
    return {m: simulate(cfg, tenants, ticks, mode=m) for m in modes}


# ---------------------------------------------------------------- presets ----
def _stacked(n_tenants: int) -> Tuple[TieringConfig, List[TenantWorkload]]:
    """Stacked-heterogeneous host: n heterogeneous cgroups (cache/web/CI/
    stream/bursty), fast tier sized to ~55% of the summed footprint, per-
    tenant policy derived from workload shape (``suggest_policy``)."""
    tenants = stacked_heterogeneous(n_tenants)
    prot, bound = suggest_policy(tenants)
    total = sum(w.footprint for w in tenants)
    fast = (int(total * 0.55) // 64) * 64
    cfg = TieringConfig(n_tenants=n_tenants, n_fast_pages=fast,
                        n_slow_pages=total, lower_protection=prot,
                        upper_bound=bound)
    return cfg, tenants


def churn_roster_config(slots: List[ChurnSlot],
                        fast_frac: float = 0.45) -> TieringConfig:
    """Derive a host config from a churn roster: fast tier sized to
    ``fast_frac`` of the summed slot capacity (rounded to 64 pages),
    per-slot policy from workload shape — the engine re-partitions it on
    every membership change. Shared by the churn presets and
    ``benchmarks/churn_sweep.py`` so they stay one scenario."""
    prot, bound = suggest_churn_policy(slots)
    total = sum(s.capacity() for s in slots)
    fast = max((int(total * fast_frac) // 64) * 64, 64)
    return TieringConfig(n_tenants=len(slots), n_fast_pages=fast,
                         n_slow_pages=total, lower_protection=prot,
                         upper_bound=bound)


def _churn_stacked(n_stable: int, n_poisson: int, n_serverless: int,
                   ticks: int = 240
                   ) -> Tuple[TieringConfig, List[ChurnSlot]]:
    """Churned stacked host: a stable base plus Poisson and serverless slot
    churn (≥50 lifecycle events at the churn16 scale)."""
    slots = churn_stacked(n_stable, n_poisson, n_serverless, ticks=ticks)
    return churn_roster_config(slots), slots


PRESETS: Dict[str, Callable[[], Tuple[TieringConfig, List[TenantWorkload]]]] = {
    "stacked16": lambda: _stacked(16),
    "stacked64": lambda: _stacked(64),
}

# presets generate lifecycle episodes out to a 960-tick horizon; running
# shorter simply truncates the schedule (build_churn_schedule clips)
CHURN_PRESETS: Dict[str, Callable[[], Tuple[TieringConfig, List[ChurnSlot]]]] = {
    "churn16": lambda: _churn_stacked(6, 6, 4, ticks=960),
}


def preset_churn_events(name: str, ticks: int = 240) -> Tuple[int, int]:
    """(arrivals, departures) a churn preset schedules over ``ticks``."""
    _, slots = CHURN_PRESETS[name]()
    return churn_events(build_churn_schedule(slots, ticks).want)


def simulate_preset(name: str, ticks: int = 300, mode: str = "equilibria",
                    k_max: int = 128, hotness=None,
                    **cfg_overrides) -> SimResult:
    """Run a named scenario preset (``PRESETS`` or ``CHURN_PRESETS``)."""
    if name in CHURN_PRESETS:
        cfg, slots = CHURN_PRESETS[name]()
        if cfg_overrides:
            cfg = cfg.with_(**cfg_overrides)
        return simulate_churn(cfg, slots, ticks, mode=mode, k_max=k_max,
                              hotness=hotness)
    cfg, tenants = PRESETS[name]()
    if cfg_overrides:
        cfg = cfg.with_(**cfg_overrides)
    return simulate(cfg, tenants, ticks, mode=mode, k_max=k_max,
                    hotness=hotness)
