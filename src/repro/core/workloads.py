"""Synthetic tenant workloads (numpy trace generators).

Each generator produces deterministic access traces — the paper's §V-B
microbenchmarks are deterministic sequential-pass workloads, and Meta's
production workloads are modeled by their published characteristics:
  Cache  — random access over the whole footprint, ~60% hot (§V-D1)
  Web    — stable hot working set (~28GB protection), JIT-specialized (§V-D3)
  CI     — spiky footprint: linking phases are memory-intensive (§V-D2)
  TaoBench  — steady usage & access pattern (§V-C)
  SparkBench— bursty usage, varying hotness across analytics phases (§V-C)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class TenantWorkload:
    footprint: int                 # steady-state pages
    arrival: int = 0
    departure: Optional[int] = None
    pattern: str = "hotcold"       # hotcold | uniform | stream | bursty
    hot_frac: float = 0.2
    hot_rate: float = 4.0
    cold_rate: float = 0.05
    ramp: int = 10                 # ticks to ramp up footprint
    stream_window: int = 0         # stream: hot-window size (pages)
    stream_step: int = 0           # stream: window advance per tick
    phase_len: int = 0             # bursty: footprint pulse period
    burst_low: float = 0.3         # bursty: low-phase footprint fraction
    rotate_hot_every: int = 0      # hotcold: rotate hot set (phase changes)


def _footprint_at(w: TenantWorkload, age: int) -> int:
    """Live footprint (pages) of a workload at episode-age ``age``."""
    n = w.footprint
    f = n if age >= w.ramp else max(int(n * (age + 1) / w.ramp), 1)
    if w.pattern == "bursty" and w.phase_len > 0:
        phase = (age // w.phase_len) % 2
        low = max(int(n * w.burst_low), 1)
        if phase == 1:
            f = low
        else:
            # allocations grow through the active phase (the burst
            # frontier is fresh data — see spark_like)
            pa = age % w.phase_len
            grow = min(1.0, (pa + 1) / max(w.phase_len // 2, 1))
            f = low + int((n - low) * grow)
    return f


def _rates_at(w: TenantWorkload, age: int, f: int) -> np.ndarray:
    """Per-page access rates over the tenant-local address space [0, f)."""
    rates = np.full(f, w.cold_rate, np.float32)
    if w.pattern == "uniform":
        rates[:] = w.hot_rate
    elif w.pattern in ("hotcold", "bursty"):
        h = max(int(f * w.hot_frac), 1)
        if w.pattern == "bursty" and w.rotate_hot_every == 0:
            # bursty working data is the freshest allocation (tail)
            start = max(f - h, 0)
        elif w.rotate_hot_every > 0:
            start = ((age // w.rotate_hot_every) * h) % max(f - h, 1)
        else:
            start = 0
        rates[start:start + h] = w.hot_rate
    elif w.pattern == "stream":
        win = min(max(w.stream_window, 1), f)
        start = (age * max(w.stream_step, 1)) % f
        end = start + win
        rates[start:min(end, f)] = w.hot_rate
        if end > f:  # wrap
            rates[:end - f] = w.hot_rate
    return rates


def build_trace(tenants: List[TenantWorkload], ticks: int
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (owner [L], accesses [ticks, L] f32, alive [ticks, L] bool)."""
    sizes = [w.footprint for w in tenants]
    base = np.cumsum([0] + sizes)
    L = int(base[-1])
    owner = np.zeros(L, np.int32)
    for i, w in enumerate(tenants):
        owner[base[i]:base[i + 1]] = i

    accesses = np.zeros((ticks, L), np.float32)
    alive = np.zeros((ticks, L), bool)

    for i, w in enumerate(tenants):
        lo = base[i]
        for t in range(ticks):
            if t < w.arrival or (w.departure is not None and t >= w.departure):
                continue
            age = t - w.arrival
            f = _footprint_at(w, age)
            alive[t, lo:lo + f] = True
            accesses[t, lo:lo + f] = _rates_at(w, age, f)
    return owner, accesses, alive


# ------------------------------------------------ paper workload analogues ----
def microbenchmark(footprint: int, arrival: int = 0, hotness: float = 1.0,
                   ramp: int = 10) -> TenantWorkload:
    """§V-B sequential-pass microbenchmark: uniform accesses at a hotness level."""
    return TenantWorkload(footprint=footprint, arrival=arrival,
                          pattern="uniform", hot_rate=4.0 * hotness, ramp=ramp)


def thrasher(footprint: int, fast_share: int, arrival: int = 0) -> TenantWorkload:
    """§V-B5: hot enough to trigger promotion, but pages are not re-accessed
    before demotion — a rotating window larger than the tenant's fast share."""
    return TenantWorkload(
        footprint=footprint, arrival=arrival, pattern="stream",
        stream_window=max(2 * fast_share, 8), stream_step=max(fast_share // 2, 4),
        hot_rate=4.0, cold_rate=0.0)


def cache_like(footprint: int, arrival: int = 0) -> TenantWorkload:
    """§V-D1 Cache: random accesses over the whole space, up to 60% hot."""
    return TenantWorkload(footprint=footprint, arrival=arrival,
                          pattern="hotcold", hot_frac=0.6, hot_rate=3.0,
                          cold_rate=0.3)


def web_like(footprint: int, arrival: int = 0, hot_pages: int = 0) -> TenantWorkload:
    """§V-D3 Web: stable modest hot set (profiling-derived protection)."""
    hf = hot_pages / footprint if hot_pages else 0.35
    return TenantWorkload(footprint=footprint, arrival=arrival,
                          pattern="hotcold", hot_frac=hf, hot_rate=4.0,
                          cold_rate=0.02)


def ci_like(footprint: int, arrival: int = 0, phase_len: int = 40) -> TenantWorkload:
    """§V-D2 CI: spiky usage — linking phases are memory-intensive."""
    return TenantWorkload(footprint=footprint, arrival=arrival, pattern="bursty",
                          phase_len=phase_len, burst_low=0.35, hot_frac=0.5,
                          hot_rate=3.0, cold_rate=0.2, ramp=15)


def tao_like(footprint: int, arrival: int = 0) -> TenantWorkload:
    """§V-C TaoBench: steady usage, hot caching access pattern (ramps up and
    consumes memory — the paper's Fig. 7 squeeze)."""
    return TenantWorkload(footprint=footprint, arrival=arrival,
                          pattern="hotcold", hot_frac=0.6, hot_rate=5.0,
                          cold_rate=2.0, ramp=40)


def spark_like(footprint: int, arrival: int = 0) -> TenantWorkload:
    """§V-C SparkBench: bursty usage; analytics phases shift the hot set, so
    its pages "manifest as less hot" than the cache workloads' — under
    system-level tiering it is forced into the slow tier (paper Fig. 7)."""
    return TenantWorkload(footprint=footprint, arrival=arrival, pattern="bursty",
                          phase_len=30, burst_low=0.25, hot_frac=0.3,
                          hot_rate=1.5, cold_rate=0.05, ramp=8)


def stream_like(footprint: int, arrival: int = 0) -> TenantWorkload:
    """Sequential scanner (ETL/media style): a hot window sweeping the
    footprint — pages get hot once, then cool. Unlike ``thrasher`` the window
    is modest, so a bounded fast share serves it without churn."""
    return TenantWorkload(
        footprint=footprint, arrival=arrival, pattern="stream",
        stream_window=max(footprint // 8, 4),
        stream_step=max(footprint // 32, 1), hot_rate=3.0, cold_rate=0.05)


# ----------------------------------------------- stacked-host scenarios ----
def stacked_heterogeneous(n_tenants: int = 16,
                          base_footprint: int = 96) -> List[TenantWorkload]:
    """Equilibria's target deployment (§V): many heterogeneous cgroups
    stacked on one host. Cycles cache/web/CI/stream/bursty generators with
    staggered arrivals and varied footprints; deterministic in n_tenants."""
    kinds = (cache_like, web_like, ci_like, stream_like, spark_like)
    out = []
    for i in range(n_tenants):
        make = kinds[i % len(kinds)]
        footprint = base_footprint + 8 * ((i * 5) % 7)
        arrival = 6 * (i % 5)
        out.append(make(footprint, arrival=arrival))
    return out


# --------------------------------------------- churn (dynamic ownership) ----
@dataclass
class ChurnSlot:
    """One tenant slot of a dynamic roster: a workload shape plus the
    lifecycle episodes during which a tenant occupies the slot. Episodes are
    half-open ``[arrival, departure)`` tick ranges, sorted and disjoint;
    each episode is a fresh tenant (the churn engine resets per-slot
    controller state on arrival)."""
    workload: TenantWorkload
    episodes: List[Tuple[int, int]] = field(default_factory=list)

    def capacity(self) -> int:
        return self.workload.footprint


def as_churn_slots(tenants: List[TenantWorkload],
                   ticks: int) -> List[ChurnSlot]:
    """Express a static tenant mix as single-episode churn slots — the
    degenerate schedule the unified tick core treats identically to a
    prebuilt static trace (owner fixed after the first grant, free pool
    empty). This is how the mixed fleet harness (obs/fleet.py) runs static
    and churned hosts side by side under one vmap."""
    return [ChurnSlot(w, [(w.arrival,
                           ticks if w.departure is None else w.departure)])
            for w in tenants]


def build_churn_schedule(slots: List["ChurnSlot"], ticks: int):
    """Compile a slot roster into the churn engine's per-tick schedule:
    (want [ticks, T] int32 target footprints, rates [ticks, T, S] f32
    tenant-local access rates) — see ``core.churn.ChurnSchedule``. The same
    pattern generators as ``build_trace`` drive the rates, but over the
    tenant-local address space (rank among the tenant's pages) instead of a
    fixed physical range, because physical placement is dynamic."""
    from repro.core.churn import ChurnSchedule
    T = len(slots)
    S = max((s.workload.footprint for s in slots), default=1)
    want = np.zeros((ticks, T), np.int32)
    rates = np.zeros((ticks, T, S), np.float32)
    for i, slot in enumerate(slots):
        w = slot.workload
        for a, d in slot.episodes:
            for t in range(max(a, 0), min(d, ticks)):
                age = t - a
                f = min(_footprint_at(w, age), S)
                want[t, i] = f
                rates[t, i, :f] = _rates_at(w, age, f)[:f]
    return ChurnSchedule(want=want, rates=rates)


def _episodes(rng, ticks: int, mean_life: float, mean_gap: float,
              min_life: int, first: int) -> List[Tuple[int, int]]:
    eps = []
    t = first
    while t < ticks:
        life = max(int(rng.exponential(mean_life)), min_life)
        eps.append((t, t + life))
        t = t + life + 1 + int(rng.exponential(mean_gap))
    return eps


def poisson_churn(n_slots: int = 8, ticks: int = 240,
                  arrival_rate: float = 0.05, mean_life: float = 45.0,
                  base_footprint: int = 48, seed: int = 0
                  ) -> List[ChurnSlot]:
    """Poisson arrivals with exponential lifetimes: the datacenter's rolling
    container roster. Patterns cycle through the heterogeneous menu."""
    rng = np.random.default_rng(seed)
    kinds = (cache_like, web_like, ci_like, stream_like, spark_like)
    slots = []
    for i in range(n_slots):
        w = kinds[i % len(kinds)](base_footprint + 8 * ((i * 3) % 5))
        w.ramp = min(w.ramp, 6)            # churned tenants ramp fast
        eps = _episodes(rng, ticks, mean_life, 1.0 / arrival_rate,
                        min_life=8, first=int(rng.exponential(1.0 / arrival_rate)))
        slots.append(ChurnSlot(w, eps))
    return slots


def serverless_bursts(n_slots: int = 4, ticks: int = 240,
                      mean_life: float = 6.0, mean_gap: float = 8.0,
                      footprint: int = 64, seed: int = 1) -> List[ChurnSlot]:
    """Short-lived memory-hungry functions (the serverless-CXL churn regime,
    arXiv:2309.01736): uniform-hot footprints that live a handful of ticks,
    arrive again almost immediately, and never reach steady state."""
    rng = np.random.default_rng(seed)
    slots = []
    for i in range(n_slots):
        w = TenantWorkload(footprint=footprint, pattern="uniform",
                           hot_rate=4.0, cold_rate=0.0, ramp=1)
        eps = _episodes(rng, ticks, mean_life, mean_gap, min_life=2,
                        first=int(rng.integers(0, 6)))
        slots.append(ChurnSlot(w, eps))
    return slots


def diurnal_roster(n_slots: int = 8, ticks: int = 240, period: int = 80,
                   min_active: int = 2, base_footprint: int = 48,
                   seed: int = 2) -> List[ChurnSlot]:
    """Diurnal roster swing: the number of resident tenants follows a
    sinusoid between ``min_active`` and ``n_slots`` (stacking density peaks
    once per ``period``); slot i is occupied while the roster exceeds i."""
    rng = np.random.default_rng(seed)
    tt = np.arange(ticks)
    roster = min_active + np.round(
        (n_slots - min_active) * 0.5 * (1 - np.cos(2 * np.pi * tt / period))
    ).astype(int)
    kinds = (cache_like, web_like, spark_like)
    slots = []
    for i in range(n_slots):
        occ = roster > i
        edges = np.flatnonzero(np.diff(np.concatenate([[0], occ.view(np.int8),
                                                       [0]])))
        eps = [(int(edges[j]), int(edges[j + 1]))
               for j in range(0, len(edges), 2)]
        w = kinds[int(rng.integers(len(kinds)))](base_footprint
                                                 + 8 * (i % 3))
        w.ramp = min(w.ramp, 6)
        slots.append(ChurnSlot(w, eps))
    return slots


def churn_stacked(n_stable: int = 6, n_poisson: int = 6,
                  n_serverless: int = 4, ticks: int = 240,
                  seed: int = 0) -> List[ChurnSlot]:
    """The ``churn16`` roster: a stable base of long-lived tenants, a
    Poisson-churned middle, and a serverless burst tail — the stacked-host
    mix the paper targets, with the lifecycle dynamics it cannot express
    statically. Deterministic in its arguments."""
    stable_kinds = (web_like, cache_like)
    slots = [ChurnSlot(stable_kinds[i % 2](64 + 8 * (i % 3)),
                       [(3 * i, ticks)])
             for i in range(n_stable)]
    slots += poisson_churn(n_poisson, ticks, base_footprint=48, seed=seed)
    slots += serverless_bursts(n_serverless, ticks, footprint=56,
                               seed=seed + 1)
    return slots


def suggest_churn_policy(slots: List[ChurnSlot]
                         ) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Per-slot (lower_protection, upper_bound) from the slot's workload
    shape (same derivation as ``suggest_policy``); the churn engine's
    in-graph re-partitioning takes care of membership changes."""
    return suggest_policy([s.workload for s in slots])


def suggest_policy(tenants: List[TenantWorkload]
                   ) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Derive per-tenant (lower_protection, upper_bound) from workload shape,
    the way operators would from profiling (paper §IV-B / §V-D): protect the
    stable hot set of hot/cold workloads, cap sweeping streamers, leave
    bursty analytics unconfigured (they donate when idle)."""
    prot, bound = [], []
    for w in tenants:
        if w.pattern == "hotcold":
            prot.append(int(w.footprint * w.hot_frac * 0.8))
            bound.append(0)
        elif w.pattern == "stream":
            prot.append(0)
            bound.append(max(2 * w.stream_window, 16))
        else:                      # bursty / uniform: no knobs configured
            prot.append(0)
            bound.append(0)
    return tuple(prot), tuple(bound)
