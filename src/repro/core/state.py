"""Equilibria state: page metadata, per-tenant counters, thrash table.

Everything is a pytree of jnp arrays so the whole control plane is jittable
and runs inside compiled steps (the TPU analogue of "in the kernel").

Pages are *logical*: in the static engine each tenant owns a fixed
contiguous range of logical page ids; in the dynamic-ownership engine
(core/churn.py) the ``owner`` vector is itself state — pages move between
tenants and the free pool as tenants arrive, resize and depart. ``tier`` is
the dynamic placement: 0 = fast (local DRAM / HBM analogue), 1 = slow (CXL
analogue), -1 = not allocated. A page with ``owner == n_tenants`` (the FREE
sentinel) belongs to the free pool.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TieringConfig
from repro.obs.attribution import (AttributionSpec, AttributionState,
                                   init_attribution)
from repro.obs.stats import TierStats, init_stats, stats_export
from repro.obs.streaming import DetectorSpec, DetectorState, init_detector
from repro.obs.trace import MigrationRing, init_ring

TIER_NONE = -1
TIER_FAST = 0
TIER_SLOW = 1


class TenantPolicy(NamedTuple):
    """Static per-tenant fairness policy (paper §IV-B), in pages."""
    lower_protection: jax.Array   # [T] int32; 0 = no protection
    upper_bound: jax.Array        # [T] int32; 0 = unbounded


class Counters(NamedTuple):
    """Per-tenant observability (paper §IV-C — the cgroup tier_stat analogue)."""
    promotions: jax.Array          # [T] int32: pages promoted (pgpromote)
    demotions: jax.Array           # [T] int32: pages demoted (pgdemote)
    attempted_promotions: jax.Array  # [T] int32: candidates scanned
    reclaims: jax.Array            # [T] int32: pages freed
    allocations: jax.Array         # [T] int32: pages allocated
    thrash_events: jax.Array       # [T] int32: promote->demote under t_resident
    sync_demotions: jax.Array      # [T] int32: allocation-path (upper-bound) demotes


class ThrashTable(NamedTuple):
    """Fixed-size direct-mapped table of recently-promoted pages (§IV-F).

    slot = page_id % slots; collisions are the paper's 'sampling'."""
    page: jax.Array               # [slots] int32, -1 empty
    tick: jax.Array               # [slots] int32 promotion time


class TierState(NamedTuple):
    # page metadata [L]
    tier: jax.Array               # int8: -1/0/1
    hot: jax.Array                # f32 EWMA access rate
    last_access: jax.Array        # int32 tick
    owner: jax.Array              # int32 tenant id; n_tenants = free pool.
    #                               Static engines carry it unchanged; the
    #                               churn engine mutates it every tick.
    # tenant state [T]
    counters: Counters
    promo_scale: jax.Array        # f32: thrash-mitigation promotion multiplier
    thrash_prev: jax.Array        # int32: thrash_events at last controller run
    usage_prev: jax.Array         # int32: total usage at last controller run
    freed_since: jax.Array        # int32: pages freed since last controller run
    steady: jax.Array             # bool: steady-state flag (set by controller)
    mitigated_prev: jax.Array     # bool: mitigation fired at last controller run
    table: ThrashTable
    # observability (obs/, §IV-C): in-graph stats + migration event ring
    stats: TierStats
    ring: MigrationRing
    t: jax.Array                  # scalar int32 tick
    # streaming pathology detectors (obs/streaming.py). None (the default)
    # is an *empty pytree subtree*: states built without a detector keep
    # their pre-existing tree structure, jaxprs and golden traces bit-exact.
    det: Optional[DetectorState] = None
    # per-tenant slowdown attribution ledger (obs/attribution.py) — the
    # same optional-subtree pattern as ``det``
    attrib: Optional[AttributionState] = None
    # hotness-provider state (core/hotness.py): None for the stateless
    # providers (exact/sampled), a SketchState/NeomemState pytree otherwise
    # — the same optional-subtree pattern as ``det``/``attrib``
    hotness: Optional[Any] = None


def zero_counters(n_tenants: int) -> Counters:
    z = jnp.zeros((n_tenants,), jnp.int32)
    return Counters(z, z, z, z, z, z, z)


def init_state(cfg: TieringConfig, n_pages: int, owner=None,
               detector: Optional[DetectorSpec] = None,
               attrib: Optional[AttributionSpec] = None,
               hotness=None) -> TierState:
    """``owner``: [n_pages] int tenant ids, or None for an all-free pool
    (the dynamic-ownership engine's starting point). ``detector``: a
    ``DetectorSpec`` to carry streaming pathology detectors in the state;
    ``attrib``: an ``AttributionSpec`` to carry the slowdown-attribution
    ledger; ``hotness``: a hotness-provider spec (core/hotness.py) to carry
    that provider's state (each must match the spec passed to the tick
    builder)."""
    from repro.core.hotness import init_hotness  # state <-> hotness cycle
    T = cfg.n_tenants
    owner_j = (jnp.full((n_pages,), T, jnp.int32) if owner is None
               else jnp.asarray(owner, jnp.int32))
    return TierState(
        tier=jnp.full((n_pages,), TIER_NONE, jnp.int8),
        hot=jnp.zeros((n_pages,), jnp.float32),
        last_access=jnp.zeros((n_pages,), jnp.int32),
        owner=owner_j,
        counters=zero_counters(T),
        promo_scale=jnp.ones((T,), jnp.float32),
        thrash_prev=jnp.zeros((T,), jnp.int32),
        usage_prev=jnp.zeros((T,), jnp.int32),
        freed_since=jnp.zeros((T,), jnp.int32),
        steady=jnp.zeros((T,), bool),
        mitigated_prev=jnp.zeros((T,), bool),
        table=ThrashTable(page=jnp.full((cfg.thrash_table_slots,), -1, jnp.int32),
                          tick=jnp.zeros((cfg.thrash_table_slots,), jnp.int32)),
        stats=init_stats(T, (n_pages,), cfg.obs_resid_buckets),
        ring=init_ring(cfg.obs_ring_capacity),
        t=jnp.zeros((), jnp.int32),
        det=None if detector is None else init_detector(detector),
        attrib=None if attrib is None else init_attribution(attrib),
        hotness=init_hotness(hotness, cfg, n_pages),
    )


def stack_states(state: TierState, n: int) -> TierState:
    """Broadcast one host's TierState to a leading fleet axis: every leaf
    ``x`` becomes ``[n, *x.shape]``. The fleet harness (obs/fleet.py) vmaps
    the unified tick over this axis; ``shard_map``/``pmap`` shard it across
    devices when more than one is available."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), state)


def make_policy(cfg: TieringConfig) -> TenantPolicy:
    T = cfg.n_tenants
    prot = np.zeros(T, np.int32)
    bound = np.zeros(T, np.int32)
    for i, v in enumerate(cfg.lower_protection[:T]):
        prot[i] = v
    for i, v in enumerate(cfg.upper_bound[:T]):
        bound[i] = v
    return TenantPolicy(jnp.asarray(prot), jnp.asarray(bound))


def tenant_usage(state: TierState, owner_onehot: jax.Array):
    """owner_onehot: [T, L] static ownership. Returns (fast[T], slow[T]) page counts."""
    fast = owner_onehot @ (state.tier == TIER_FAST).astype(jnp.int32)
    slow = owner_onehot @ (state.tier == TIER_SLOW).astype(jnp.int32)
    return fast, slow


def tier_stat(state: TierState, owner_onehot: jax.Array, page_bytes: int = 1 << 24):
    """Observability export — the cgroup `memory.tier_stat` analogue (§IV-C).

    Cumulative counters come from ``Counters``; the distributional and
    windowed fields (residency histogram/percentiles, attempt-vs-success
    ratios, occupancy fractions) come from the in-graph ``obs.TierStats``.
    """
    fast, slow = tenant_usage(state, owner_onehot)
    c = state.counters
    stat = {
        "local_usage_bytes": fast * page_bytes,
        "cxl_usage_bytes": slow * page_bytes,
        "pgpromote": c.promotions,
        "pgdemote": c.demotions,
        "pgpromote_attempted": c.attempted_promotions,
        "pgreclaim": c.reclaims,
        "pgalloc": c.allocations,
        "thrash_events": c.thrash_events,
        "sync_demotions": c.sync_demotions,
        "promo_rate_scale": state.promo_scale,
        "steady_state": state.steady,
    }
    stat.update(stats_export(state.stats))  # pure jnp: jit/vmap-safe
    return stat
