"""Tenant-batched selection & reduction primitives — trace-time constant in T.

The engine's hot path repeatedly needs "take the `quota[t]` best pages of
every tenant t" (demotion picks coldest-first, promotion hottest-first),
"rank each tenant's new pages in index order" (allocation gating), and
per-tenant sums. The seed implementation unrolled a Python loop over tenants
at trace time — one `top_k` per tenant per call site, plus [T, L] one-hot
matmul reductions — so compile time, jaxpr size and kernel count all grew
linearly with T. Everything here is one fixed-size op chain regardless of T.

Two batched strategies, chosen at trace time from the static owner vector:

* **contiguous layout** (what `core/workloads.build_trace` always produces:
  tenant t owns pages [bounds[t], bounds[t+1])): selection is a static
  gather into padded [T, S] rows + ONE batched masked `top_k`; per-tenant
  sums and segmented index-ranks are a single `cumsum` + static boundary
  gathers. On CPU this is ~45x cheaper than a length-L composite sort at
  L=256k (XLA's TopK is O(L), its variadic sort is not).
* **generic fallback** (arbitrary owner permutation): one stable
  lexicographic sort by (segment, key) — `segment_ranks` — and scatter-add
  reductions. Still constant in T. Because the owner vector enters as a
  runtime array (never a trace constant), this is also the path the
  dynamic-ownership engine (core/churn.py) routes every churned layout
  through: the same compiled sort serves any ownership the lifecycle events
  produce.

Tie-breaking matches `jax.lax.top_k` exactly in both strategies ("lower
index wins" on equal scores), so results are bit-equal to the unrolled
reference (`select_top_quota_unrolled`, kept for the equivalence suite and
the scale benchmark's baseline).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class Selection(NamedTuple):
    """Result of a per-tenant quota selection.

    ``mask`` is always present. The compact fields are set by the
    contiguous-rows strategy only: they expose the [T, k] candidate stream
    the batched top_k already produced, so downstream accounting (migration
    ring, residency histograms, thrash table) can run over T*k lanes instead
    of L — at L=256k that is the difference between ~1ms and ~30ms scatters.
    """
    mask: jax.Array                  # [L] bool: selected pages
    pages: Optional[jax.Array]       # [T, k] int32 page ids (or None)
    take: Optional[jax.Array]        # [T, k] bool: lane actually selected
    counts: Optional[jax.Array]      # [T] int32: selected per tenant


# ------------------------------------------------------ contiguous layout ----
class ContiguousLayout(NamedTuple):
    """Static (trace-time) description of a contiguous ownership layout."""
    n_tenants: int
    n_pages: int
    row_page: jax.Array    # [T, S] int32 page id per tenant row (pads clamped)
    row_valid: jax.Array   # [T, S] bool
    bounds: jax.Array      # [T+1] int32: tenant t owns [bounds[t], bounds[t+1])
    page_start: jax.Array  # [L] int32: segment start of each page's tenant


def plan_layout(owner: np.ndarray, n_tenants: int
                ) -> Optional[ContiguousLayout]:
    """Build the static layout if ``owner`` is sorted-contiguous, else None."""
    owner = np.asarray(owner)
    counts = np.bincount(owner, minlength=n_tenants)
    if counts.shape[0] > n_tenants:
        return None
    if not np.array_equal(owner, np.repeat(np.arange(n_tenants), counts)):
        return None
    L = owner.shape[0]
    S = max(int(counts.max()) if counts.size else 0, 1)
    bounds = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    col = np.arange(S)[None, :]
    row_page = bounds[:-1, None] + col
    row_valid = col < counts[:, None]
    row_page = np.where(row_valid, row_page, 0).astype(np.int32)
    return ContiguousLayout(
        n_tenants=n_tenants, n_pages=L,
        row_page=jnp.asarray(row_page), row_valid=jnp.asarray(row_valid),
        bounds=jnp.asarray(bounds),
        page_start=jnp.asarray(bounds[owner], jnp.int32))


def select_top_quota_rows(score: jax.Array, active: jax.Array,
                          quotas: jax.Array, layout: ContiguousLayout,
                          k_cap: int) -> Selection:
    """Contiguous-layout quota select: static gather to [T, S] rows, one
    batched masked top_k, scatter the winners back. Bit-equal to the
    unrolled per-tenant top_k loop."""
    L = layout.n_pages
    T, S = layout.row_page.shape
    s2 = jnp.where(layout.row_valid & active[layout.row_page],
                   score[layout.row_page], -jnp.inf)
    k = min(k_cap, S)
    vals, cols = jax.lax.top_k(s2, k)
    take = (jnp.arange(k)[None, :] < quotas[:, None]) & jnp.isfinite(vals)
    pages = jnp.take_along_axis(layout.row_page, cols, axis=1)
    flat = jnp.where(take, pages, L).reshape(-1)       # L = OOB -> dropped
    mask = jnp.zeros((L,), bool).at[flat].set(True, mode="drop")
    return Selection(mask=mask, pages=pages, take=take,
                     counts=take.sum(axis=1).astype(jnp.int32))


def by_tenant_contiguous(x: jax.Array, layout: ContiguousLayout) -> jax.Array:
    """Per-tenant sum, O(L), no scatter.

    Integers sum associatively, so the int path uses a vectorized row
    gather + axis reduce (~7x cheaper than a sequential length-L cumsum on
    CPU). Floats keep the original cumsum + boundary-gather association:
    the golden traces pin the f32 perf-model reductions bitwise, and a
    reassociated sum would shift them."""
    if x.dtype == jnp.bool_:
        x = x.astype(jnp.int32)
    if jnp.issubdtype(x.dtype, jnp.integer):
        rows = jnp.where(layout.row_valid, x[layout.row_page],
                         jnp.zeros((), x.dtype))
        return rows.sum(axis=1, dtype=x.dtype)
    cs = jnp.concatenate([jnp.zeros((1,), x.dtype), jnp.cumsum(x)])
    return cs[layout.bounds[1:]] - cs[layout.bounds[:-1]]


def allocation_ranks_contiguous(new: jax.Array,
                                layout: ContiguousLayout) -> jax.Array:
    """Index-order rank of each new page among its tenant's new pages:
    exclusive cumsum minus the value at the (static) segment start."""
    L = new.shape[0]
    cs0 = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                           jnp.cumsum(new.astype(jnp.int32))])
    return cs0[:L] - cs0[layout.page_start]


# ------------------------------------------------------- generic (sorted) ----
def segment_ranks(seg: jax.Array, key: jax.Array, n_seg: int) -> jax.Array:
    """Within-segment rank of every element, ordered by (key asc, index asc).

    seg: [L] int32 segment id in [0, n_seg]; use ``n_seg`` as the sentinel
    for inactive elements (they still get ranks, callers just never select
    them). One stable lexicographic sort of length L regardless of the
    number of segments.
    """
    L = seg.shape[0]
    idx = jnp.arange(L, dtype=jnp.int32)
    seg_s, _, idx_s = jax.lax.sort((seg.astype(jnp.int32), key, idx),
                                   num_keys=2)
    counts = jnp.zeros((n_seg + 1,), jnp.int32).at[seg].add(1)
    starts = jnp.cumsum(counts) - counts          # exclusive prefix sum
    rank_s = jnp.arange(L, dtype=jnp.int32) - starts[seg_s]
    return jnp.zeros((L,), jnp.int32).at[idx_s].set(rank_s)


def select_top_quota(score: jax.Array, owner: jax.Array, active: jax.Array,
                     quotas: jax.Array, n_tenants: int,
                     k_cap: int) -> jax.Array:
    """Select up to quotas[t] highest-score active elements of each tenant
    for an ARBITRARY owner permutation (one composite sort). The per-tenant
    take is capped at ``min(k_cap, L)``, mirroring the unrolled top_k's
    window; non-finite scores are never selected."""
    L = score.shape[0]
    active = active & jnp.isfinite(score)
    seg = jnp.where(active, owner, n_tenants).astype(jnp.int32)
    ranks = segment_ranks(seg, -score, n_tenants)
    q = jnp.minimum(quotas.astype(jnp.int32), min(k_cap, L))
    q_ext = jnp.concatenate([q, jnp.zeros((1,), jnp.int32)])
    return active & (ranks < q_ext[seg])


def by_tenant_scatter(x: jax.Array, owner: jax.Array,
                      n_tenants: int) -> jax.Array:
    """Per-tenant sum for arbitrary owner vectors (scatter-add)."""
    return jnp.zeros((n_tenants,), x.dtype).at[owner].add(x)


def by_tenant_pooled(x: jax.Array, owner: jax.Array,
                     n_tenants: int) -> jax.Array:
    """Per-tenant sum tolerant of the free-pool sentinel ``owner ==
    n_tenants``: sentinel lanes land in a scratch bucket instead of being
    clipped onto the last real tenant (XLA's default scatter mode clips
    out-of-bounds indices)."""
    return jnp.zeros((n_tenants + 1,), x.dtype).at[owner].add(x)[:n_tenants]


def select_global(score: jax.Array, mask: jax.Array, quota: jax.Array,
                  k_max: int) -> jax.Array:
    """Tenant-blind top-quota select (the TPP baseline's global scan)."""
    L = score.shape[0]
    k = min(k_max, L)
    s = jnp.where(mask, score, -jnp.inf)
    vals, idx = jax.lax.top_k(s, k)
    take = (jnp.arange(k) < quota) & jnp.isfinite(vals)
    return jnp.zeros((L,), bool).at[idx].set(take)


def pool_grant(free_mask: jax.Array, need: jax.Array) -> jax.Array:
    """Partition the free pool among tenants requesting pages (churn grant).

    free_mask: [L] bool — pages currently in the free pool; need: [T] int32
    pages each tenant wants granted this tick. Free pages are ranked in index
    order and tenant t receives the rank interval
    ``[cumsum(need)[t-1], cumsum(need)[t])`` — deterministic, one pass,
    constant in T. When the pool is over-subscribed the intervals simply run
    off the end of the pool: lower slot ids win (admission priority),
    trailing tenants get partial or empty grants.

    Returns [L] int32: the granting tenant id per page, or ``n_tenants``
    (the FREE sentinel) where no grant happens.
    """
    T = need.shape[0]
    rank = masked_rank(free_mask)
    cum = jnp.cumsum(need.astype(jnp.int32))
    tenant = jnp.searchsorted(cum, rank, side="right").astype(jnp.int32)
    granted = free_mask & (rank < cum[-1]) & (tenant < T)
    return jnp.where(granted, tenant, T)


def allocation_ranks(new: jax.Array, owner: jax.Array,
                     n_tenants: int) -> jax.Array:
    """Index-order rank of each new page among its tenant's new pages,
    arbitrary owner permutation. Values outside ``new`` are unspecified."""
    L = new.shape[0]
    seg = jnp.where(new, owner, n_tenants).astype(jnp.int32)
    return segment_ranks(seg, jnp.zeros((L,), jnp.int32), n_tenants)


# ------------------------------------------------------------------------
# Unrolled references (seed behavior). Kept verbatim so the equivalence
# suite can pin the batched implementations to them bit-exactly and the
# scale benchmark can measure the speedup against the real baseline.
# ------------------------------------------------------------------------
def masked_rank(mask: jax.Array) -> jax.Array:
    """Rank of each True element among True elements (by index order)."""
    return jnp.cumsum(mask.astype(jnp.int32)) - mask.astype(jnp.int32)


def select_top_quota_unrolled(score: jax.Array, masks: jax.Array,
                              quotas: jax.Array, k_max: int) -> jax.Array:
    """Per-tenant top_k unroll (one kernel per tenant). masks: [T, L]."""
    T, L = masks.shape
    sel = jnp.zeros((L,), jnp.int32)
    k = min(k_max, L)
    for ti in range(T):
        s = jnp.where(masks[ti], score, -jnp.inf)
        vals, idx = jax.lax.top_k(s, k)
        take = (jnp.arange(k) < quotas[ti]) & jnp.isfinite(vals)
        sel = sel.at[idx].max(take.astype(jnp.int32))
    return sel.astype(bool)


def allocation_ranks_unrolled(new: jax.Array, owner: jax.Array,
                              n_tenants: int) -> jax.Array:
    """Per-tenant masked-cumsum unroll (seed engine step 2)."""
    ranks = jnp.zeros(new.shape, jnp.int32)
    for ti in range(n_tenants):
        m = new & (owner == ti)
        ranks = jnp.where(m, masked_rank(m), ranks)
    return ranks


# ------------------------------------------------------------------------
# Selection strategies: the seam between the unified tick core (core/tick.py)
# and the per-tenant primitives above. A Strategy bundles the three
# owner-dependent operations the tick needs; every callable takes the
# *runtime* owner vector so one tick body serves both a trace-constant
# ownership (static engine — the owner argument is ignored in favor of the
# layout baked in at trace time) and ownership-as-state (churn engine).
# ------------------------------------------------------------------------
class Strategy(NamedTuple):
    """Owner-parameterized selection/reduction strategy for one tick flavor.

    by_tenant(x [L], owner [L]) -> [T] per-tenant sum
    select(score [L], owner [L], active [L], quotas [T]) -> Selection
    alloc_ranks(new [L], owner [L]) -> [L] index-order rank among the
        tenant's ``new`` pages (values outside ``new`` unspecified)

    The two optional members are fused-kernel upgrades (None on the jnp
    strategies; the tick core falls back to its composed jnp ops):

    alloc_stats(new [L], owner [L]) -> (ranks [L], counts [T]) — one fused
        pass producing both the allocation ranks and the per-tenant new-page
        counts (otherwise two separate reductions).
    move(tier [L], ring_data [C,5], head, sel: Selection, hotv [L],
         direction, to_tier, t) -> (tier', ring_data', head') — commits a
        compact selection as *the* page-move primitive: tier scatter +
        migration-ring append in one kernel pass, bit-identical to the
        separate ``jnp.where`` + ``obs/trace.ring_record``. Only set when
        ``select`` produces the compact [T, k] stream.
    """
    by_tenant: Callable[[jax.Array, jax.Array], jax.Array]
    select: Callable[..., Selection]
    alloc_ranks: Callable[[jax.Array, jax.Array], jax.Array]
    alloc_stats: Optional[Callable[..., tuple]] = None
    move: Optional[Callable[..., tuple]] = None


def static_strategy(owner: np.ndarray, n_tenants: int, k_max: int,
                    impl: str = "batched") -> Strategy:
    """Strategy for a trace-constant owner vector. Picks the fastest
    applicable primitive set (padded-row batched top_k for contiguous
    layouts, composite-sort fallback for arbitrary permutations, or the
    seed's unrolled per-tenant loops for the equivalence suite).
    ``impl="jnp"`` is an alias for the default "batched" path;
    "pallas"/"pallas_interpret"/"pallas_ref" route the selection core
    through the Pallas kernels (``kernels/select``, ``kernels/migrate``;
    "pallas_ref" runs the kernels' jnp oracles compiled by XLA — the
    kernel *algorithm* on backends without a Mosaic lowering)."""
    T = n_tenants
    if impl == "jnp":
        impl = "batched"
    if impl in ("pallas", "pallas_interpret", "pallas_ref"):
        return pallas_static_strategy(owner, n_tenants, k_max, impl)
    owner_j = jnp.asarray(owner, jnp.int32)
    if impl == "unrolled":
        owner_oh = jnp.asarray(
            (owner[None, :] == np.arange(T)[:, None]).astype(np.float32))
        owner_oh_i = owner_oh.astype(jnp.int32)

        def by_tenant(x: jax.Array, _owner: jax.Array) -> jax.Array:
            m = owner_oh if jnp.issubdtype(x.dtype, jnp.floating) else owner_oh_i
            return m @ x

        def select(score, _owner, active, quotas):
            mask = select_top_quota_unrolled(
                score, owner_oh.astype(bool) & active[None], quotas, k_max)
            return Selection(mask, None, None, None)

        def alloc_ranks(new, _owner):
            return allocation_ranks_unrolled(new, owner_j, T)
    elif (layout := plan_layout(owner, T)) is not None:
        # contiguous ownership (build_trace's layout): padded-row top_k and
        # cumsum/boundary-gather reductions — the fastest path by far
        def by_tenant(x: jax.Array, _owner: jax.Array) -> jax.Array:
            return by_tenant_contiguous(x, layout)

        def select(score, _owner, active, quotas):
            return select_top_quota_rows(score, active, quotas, layout, k_max)

        def alloc_ranks(new, _owner):
            return allocation_ranks_contiguous(new, layout)
    else:
        # arbitrary owner permutation: composite-sort ranks + scatter-adds
        def by_tenant(x: jax.Array, _owner: jax.Array) -> jax.Array:
            return by_tenant_scatter(x, owner_j, T)

        def select(score, _owner, active, quotas):
            return Selection(
                select_top_quota(score, owner_j, active, quotas, T, k_max),
                None, None, None)

        def alloc_ranks(new, _owner):
            return allocation_ranks(new, owner_j, T)
    return Strategy(by_tenant, select, alloc_ranks)


def dynamic_strategy(n_tenants: int, k_max: int,
                     impl: str = "batched") -> Strategy:
    """Strategy for ownership-as-state: the owner vector is a runtime array
    (never a trace constant), so every call routes through the segment-sort
    fallback and the pool-sentinel-tolerant scatter reductions.
    "pallas"/"pallas_interpret"/"pallas_ref" swap the selection step for
    the tiled segmented top-k kernel (see ``pallas_dynamic_strategy``)."""
    if impl == "jnp":
        impl = "batched"
    if impl in ("pallas", "pallas_interpret", "pallas_ref"):
        return pallas_dynamic_strategy(n_tenants, k_max, impl)
    T = n_tenants

    def by_tenant(x: jax.Array, owner: jax.Array) -> jax.Array:
        return by_tenant_pooled(x, owner, T)

    def select(score, owner, active, quotas):
        return Selection(
            select_top_quota(score, owner, active, quotas, T, k_max),
            None, None, None)

    def alloc_ranks(new, owner):
        return allocation_ranks(new, owner, T)

    return Strategy(by_tenant, select, alloc_ranks)


# ------------------------------------------------------------------------
# Pallas strategies: same seam, kernel-backed selection core. Bit-exactness
# contract (pinned by tests/test_select_kernels.py): the interpret-mode
# strategies produce ticks bitwise identical to the "batched" jnp default.
# Three facts make that possible without giving up kernel reordering
# freedom: (1) selection is compare-only — the segmented top-k's
# (score desc, index asc) extraction order is exactly ``jax.lax.top_k``'s
# "lower index wins" and the stable composite sort's tie-break; (2) the
# integer reductions (counts, usage, allocation ranks) are associative, so
# the kernels' tiled order is bit-equal to any jnp association; (3) the f32
# perf-model reductions are NOT reassociated — they stay on the
# golden-pinned jnp cumsum/scatter paths.
# ------------------------------------------------------------------------
def _static_rows(owner: np.ndarray, n_tenants: int) -> np.ndarray:
    """[T, S] page-id rows (index order within tenant, -1 pads) for an
    arbitrary trace-constant owner permutation."""
    owner = np.asarray(owner)
    L = owner.shape[0]
    counts = np.bincount(owner, minlength=n_tenants)[:n_tenants]
    S = max(int(counts.max()) if counts.size else 0, 1)
    rows = np.full((n_tenants, S), -1, np.int32)
    order = np.argsort(owner, kind="stable")
    seg = owner[order]
    starts = np.concatenate([[0], np.cumsum(counts)])
    rows[seg, np.arange(L) - starts[seg]] = order
    return rows


def _rows_select(KSEL, score, active, quotas, page_rows, valid_rows,
                 page_rows_pad, k: int, L: int, kimpl: str,
                 compact: bool) -> Selection:
    """Shared body: gather scores into [T, S] rows, run the segmented
    top-k kernel, scatter winners back to an [L] mask."""
    elig = valid_rows & active[page_rows]
    cols, take, counts = KSEL.seg_topk(score[page_rows], elig, quotas, k,
                                       impl=kimpl)
    pages = jnp.take_along_axis(page_rows_pad, cols, axis=1)
    flat = jnp.where(take, pages, L).reshape(-1)       # L = OOB -> dropped
    mask = jnp.zeros((L,), bool).at[flat].set(True, mode="drop")
    if not compact:
        # mask-only, matching the jnp generic path's Selection shape so the
        # [L]-lane downstream accounting (and the migration-ring event
        # order) stays bitwise identical
        return Selection(mask, None, None, None)
    return Selection(mask=mask, pages=pages, take=take, counts=counts)


def pallas_static_strategy(owner: np.ndarray, n_tenants: int, k_max: int,
                           impl: str = "pallas_interpret") -> Strategy:
    """Kernel-backed strategy for a trace-constant owner vector.

    Contiguous layouts get the full treatment: segmented top-k selection,
    fused rank+count reduction, and the ``commit_moves`` page-move kernel
    over the compact [T, k] stream. Arbitrary permutations still run the
    kernels over a precomputed [T, S] rowspace but return mask-only
    selections (the jnp generic path's shape), keeping event order
    bit-identical."""
    from repro.kernels.migrate import ops as KMIG
    from repro.kernels.select import ops as KSEL
    kimpl = {"pallas": "pallas",
             "pallas_ref": "ref"}.get(impl, "pallas_interpret")
    T = n_tenants
    owner_np = np.asarray(owner)
    owner_j = jnp.asarray(owner_np, jnp.int32)
    L = owner_np.shape[0]
    layout = plan_layout(owner_np, T)
    contiguous = layout is not None
    if contiguous:
        page_rows, valid_rows = layout.row_page, layout.row_valid
        col_j = jnp.asarray(
            np.arange(L, dtype=np.int32) - np.asarray(layout.page_start))
    else:
        rows_np = _static_rows(owner_np, T)
        page_rows = jnp.asarray(np.maximum(rows_np, 0))
        valid_rows = jnp.asarray(rows_np >= 0)
    S = page_rows.shape[1]
    k = min(k_max, S)
    page_rows_pad = jnp.concatenate(
        [jnp.where(valid_rows, page_rows, L),
         jnp.full((T, 1), L, jnp.int32)], axis=1)

    def select(score, _owner, active, quotas):
        return _rows_select(KSEL, score, active, quotas, page_rows,
                            valid_rows, page_rows_pad, k, L, kimpl,
                            compact=contiguous)

    def by_tenant(x: jax.Array, _owner: jax.Array) -> jax.Array:
        if jnp.issubdtype(x.dtype, jnp.floating):
            # golden-pinned f32 association: keep the jnp reduction order
            return (by_tenant_contiguous(x, layout) if contiguous
                    else by_tenant_scatter(x, owner_j, T))
        xi = x.astype(jnp.int32) if x.dtype == jnp.bool_ else x
        return KSEL.seg_sums(xi[page_rows], valid_rows,
                             impl=kimpl).astype(xi.dtype)

    def alloc_stats(new, _owner):
        sums, pre = KSEL.seg_reduce(new.astype(jnp.int32)[page_rows],
                                    valid_rows, impl=kimpl)
        if contiguous:
            ranks = pre[owner_j, col_j]
        else:
            flat = jnp.where(valid_rows, page_rows, L).reshape(-1)
            ranks = jnp.zeros((L,), jnp.int32).at[flat].set(
                pre.reshape(-1), mode="drop")
        return ranks, sums

    def alloc_ranks(new, _owner):
        return alloc_stats(new, _owner)[0]

    move = None
    if contiguous:
        def move(tier, ring_data, head, sel: Selection, hotv, direction,
                 to_tier, t):
            # lane tenant from the Selection's own row shape: hotness
            # providers hand the tick compact streams of their *buffer*
            # width, not the strategy rowspace's k
            tenants = jnp.broadcast_to(
                jnp.arange(T, dtype=jnp.int32)[:, None],
                sel.take.shape).reshape(-1)
            return KMIG.commit_moves(
                tier, ring_data, head, sel.pages.reshape(-1),
                sel.take.reshape(-1), tenants,
                hotv[sel.pages].reshape(-1), t, direction=direction,
                to_tier=to_tier, impl=kimpl)

    return Strategy(by_tenant, select, alloc_ranks, alloc_stats, move)


def pallas_dynamic_strategy(n_tenants: int, k_max: int,
                            impl: str = "pallas_interpret",
                            s_max: Optional[int] = None) -> Strategy:
    """Kernel-backed strategy for ownership-as-state. The rowspace is
    rebuilt every call from the runtime owner vector (one zero-key segment
    sort — the same primitive the jnp path spends on ranking — then a
    scatter into [T, S] rows), so the segmented top-k kernel replaces the
    composite-key sort proper. Equivalence-focused: the [T, S] rowspace
    defaults to S = L (``s_max`` caps it when the max per-tenant footprint
    is known), so the perf target remains the static contiguous strategy;
    reductions stay on the pool-sentinel-tolerant jnp scatters."""
    from repro.kernels.select import ops as KSEL
    kimpl = {"pallas": "pallas",
             "pallas_ref": "ref"}.get(impl, "pallas_interpret")
    T = n_tenants

    def by_tenant(x: jax.Array, owner: jax.Array) -> jax.Array:
        return by_tenant_pooled(x, owner, T)

    def select(score, owner, active, quotas):
        L = score.shape[0]
        S = min(s_max, L) if s_max else L
        owned = owner < T
        seg = jnp.where(owned, owner, T).astype(jnp.int32)
        col = segment_ranks(seg, jnp.zeros((L,), jnp.int32), T)
        row = jnp.where(owned, seg, T)
        page_rows = jnp.full((T, S), L, jnp.int32).at[row, col].set(
            jnp.arange(L, dtype=jnp.int32), mode="drop")
        valid_rows = page_rows < L
        page_rows_pad = jnp.concatenate(
            [page_rows, jnp.full((T, 1), L, jnp.int32)], axis=1)
        return _rows_select(KSEL, score, active,
                            quotas, jnp.minimum(page_rows, L - 1),
                            valid_rows, page_rows_pad, min(k_max, S), L,
                            kimpl, compact=False)

    def alloc_ranks(new, owner):
        return allocation_ranks(new, owner, T)

    return Strategy(by_tenant, select, alloc_ranks)
