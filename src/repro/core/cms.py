"""Decayed count-min sketch over hashed page ids (HybridTier direction).

The sketch provider (core/hotness.py) cannot afford the exact engine's
dense [L] EWMA bookkeeping at fleet scale, so page hotness lives in a
``[depth, width]`` count-min sketch instead: every sampled access adds its
(unbiased, scaled) weight to one bucket per row, the whole sketch decays by
``hot_decay`` each tick, and an estimate is the min over rows — the classic
one-sided guarantee (estimate >= true decayed count, never under), pinned
by the property suite in tests/test_hotness_sketch.py.

Hash design ((page + b_d) * a_d mod width, width a power of two, a_d odd):

* a_d odd makes multiplication invertible mod width, so ANY window of
  fewer than ``width`` consecutive page ids is collision-free within
  itself. Tenant footprints are (near-)contiguous id ranges in every
  engine layout, so a tenant's own pages never alias each other; only
  cross-tenant aliasing remains, and the min over ``depth`` independent
  rows suppresses it.
* small multipliers (< 2**10) keep ``(page + b) * a`` inside int32 for
  any pool up to ~2**20 pages — x64 stays disabled and the analysis
  overflow pass can prove the bound (``sketch_hotness`` asserts it).

Everything here is pure jnp on plain arrays (no engine state), so the
property tests exercise the same code the compiled tick runs.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MULT_MAX = 1 << 10        # exclusive bound on the hash multipliers


class CMSParams(NamedTuple):
    """Trace-time sketch geometry + hash constants (derived from ``seed``)."""
    depth: int
    width: int            # power of two
    decay: float          # per-tick multiplicative decay (1.0 = pure count)
    mults: jax.Array      # [depth] int32 odd, < MULT_MAX
    offs: jax.Array       # [depth] int32, < width


def cms_params(depth: int = 2, width: int = 1 << 15, decay: float = 1.0,
               seed: int = 0) -> CMSParams:
    assert width & (width - 1) == 0, f"width must be a power of two: {width}"
    rng = np.random.default_rng(seed)
    mults = (rng.integers(0, MULT_MAX // 2, depth) * 2 + 1).astype(np.int32)
    offs = rng.integers(0, width, depth).astype(np.int32)
    return CMSParams(depth=depth, width=width, decay=decay,
                     mults=jnp.asarray(mults), offs=jnp.asarray(offs))


def make_cms(p: CMSParams) -> jax.Array:
    return jnp.zeros((p.depth, p.width), jnp.float32)


def cms_hash(p: CMSParams, pages: jax.Array) -> jax.Array:
    """[depth, *pages.shape] bucket index per row. ``pages`` must be >= 0
    and small enough that ``(page + width) * mult`` stays inside int32."""
    shape = (p.depth,) + (1,) * pages.ndim
    a = p.mults.reshape(shape)
    b = p.offs.reshape(shape)
    return ((pages[None] + b) * a) & (p.width - 1)


def cms_add(p: CMSParams, cms: jax.Array, pages: jax.Array,
            amounts: jax.Array, valid: jax.Array) -> jax.Array:
    """Scatter-add ``amounts`` into every row's bucket for each valid lane.
    One scatter over depth * lanes — the per-tick cost is O(probed lanes),
    never O(L)."""
    h = jnp.where(valid[None], cms_hash(p, pages), p.width)   # OOB -> dropped
    d = jnp.broadcast_to(
        jnp.arange(p.depth, dtype=jnp.int32).reshape(
            (p.depth,) + (1,) * pages.ndim), h.shape)
    return cms.at[d, h].add(jnp.broadcast_to(amounts[None], h.shape),
                            mode="drop")


def cms_assign(p: CMSParams, cms: jax.Array, pages: jax.Array,
               values: jax.Array, valid: jax.Array) -> jax.Array:
    """Scatter-SET each valid lane's value into every row's bucket.

    Only sound when lanes cover disjoint buckets (e.g. distinct pages from
    an injective window, ``max page - min page < width``): with collisions,
    last-writer-wins would silently drop counts. The sketch provider uses
    this in its full-coverage regime so the bucket recurrence can be
    written in the exact engine's ``decay * prev + accesses`` multiply-add
    form — XLA then rounds both identically and the estimates converge
    bit-for-bit with the dense EWMA."""
    h = jnp.where(valid[None], cms_hash(p, pages), p.width)   # OOB -> dropped
    d = jnp.broadcast_to(
        jnp.arange(p.depth, dtype=jnp.int32).reshape(
            (p.depth,) + (1,) * pages.ndim), h.shape)
    return cms.at[d, h].set(jnp.broadcast_to(values[None], h.shape),
                            mode="drop")


def cms_clear(p: CMSParams, cms: jax.Array, pages: jax.Array,
              valid: jax.Array) -> jax.Array:
    """Zero every row's bucket for each valid lane — the page-free hook
    (the hardware analogue: freeing a page resets its tracker counter).
    Colliding live pages transiently under-count until their next access;
    that trades the one-sided guarantee at freed-page hash sites for not
    carrying dead pages' residue into their successors' estimates."""
    h = jnp.where(valid[None], cms_hash(p, pages), p.width)   # OOB -> dropped
    d = jnp.broadcast_to(
        jnp.arange(p.depth, dtype=jnp.int32).reshape(
            (p.depth,) + (1,) * pages.ndim), h.shape)
    return cms.at[d, h].set(0.0, mode="drop")


def cms_decay(p: CMSParams, cms: jax.Array) -> jax.Array:
    """One tick of exponential aging — the sketch analogue of the exact
    engine's ``hot_decay * hot``."""
    return cms * jnp.float32(p.decay)


def cms_merge(a: jax.Array, b: jax.Array) -> jax.Array:
    """Combine two sketches built with the SAME params (elementwise add):
    estimates of the merge upper-bound the merged true counts, and the
    operation is associative (property-pinned on integer-valued counts)."""
    return a + b


def cms_estimate(p: CMSParams, cms: jax.Array, pages: jax.Array) -> jax.Array:
    """Point estimate per lane: min over depth rows (>= the true decayed
    count; collisions only ever inflate)."""
    h = cms_hash(p, pages)
    d = jnp.arange(p.depth, dtype=jnp.int32).reshape(
        (p.depth,) + (1,) * pages.ndim)
    return cms[d, h].min(axis=0)


def topn_rows(score: jax.Array, page: jax.Array, valid: jax.Array,
              n: int) -> Tuple[jax.Array, jax.Array]:
    """Top-n lanes of each row by score, best first.

    score/page/valid: [T, M]. Returns ``(pages [T, n], score [T, n])`` with
    -1 page ids (and -inf scores) on empty lanes; pads with empties when
    M < n so callers get a shape-stable buffer. Ties keep the LOWER lane
    index (``lax.top_k``), so callers that present lanes in ascending page
    order inherit the exact engine's lower-page-wins tie-break.
    """
    T, M = score.shape
    s = jnp.where(valid, score, -jnp.inf)
    k = min(n, M)
    vals, cols = jax.lax.top_k(s, k)
    keep = vals > -jnp.inf
    pages = jnp.where(keep, jnp.take_along_axis(page, cols, axis=1), -1)
    if k < n:
        pages = jnp.concatenate(
            [pages, jnp.full((T, n - k), -1, pages.dtype)], axis=1)
        vals = jnp.concatenate(
            [vals, jnp.full((T, n - k), -jnp.inf, vals.dtype)], axis=1)
    return pages, vals
