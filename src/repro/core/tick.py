"""The unified tick core: ONE regulated promotion/demotion pipeline serving
every deployment shape the paper targets.

Equilibria's contribution is a single control plane (hotness -> Eq.1
demotion scan -> Eq.2 promotion scan -> upper-bound sync demotion -> thrash
mitigation -> §IV-C telemetry). Before this module the repo carried two
near-identical copies of that pipeline — ``core/engine.py`` (static
ownership) and ``core/churn.py`` (ownership-as-state) — which had already
drifted once. Here the pipeline exists exactly once, parameterized by an
**ownership provider**:

  static ownership  — the owner vector is a trace-time constant; per-tick
                      inputs are ``(accesses [L], alive [L])``; the
                      lifecycle step frees pages whose tenant trace died;
                      selection uses the fastest layout-aware primitives
                      (``select.static_strategy``).
  dynamic ownership — the owner vector is state (FREE sentinel = T); per-
                      tick inputs are ``(rates [T, S], want [T])``; the
                      lifecycle step reclaims/grants pages, resets reused
                      slots and re-partitions policy; selection routes
                      through the runtime-owner fallback
                      (``select.dynamic_strategy``).

The static trace is the degenerate case of the churn schedule (owner fixed
after the first grant, free pool empty): ``tests/test_tick_unification.py``
pins that a constant-roster scenario produces identical integer
trajectories through both providers, so the two paths can never disagree on
shared semantics again.

A provider contributes only:

  * ``prepare(state, inputs) -> Prepared`` — the ownership/lifecycle step
    (tick step 1): which pages are live, what they are accessed at, the
    effective policy, the controller carry-ins, and any lifecycle mutations
    of tier/hot/table/stats.
  * ``strategy`` — the three owner-parameterized selection/reduction ops
    (``select.Strategy``).
  * ``pool_free(owner, tier)`` — the provider's definition of "unused
    pages" for telemetry.

Everything downstream of step 1 — allocation gating, hotness, contention,
Eq.1/Eq.2-regulated migration, sync upper-bound demotion, counters, obs,
the periodic thrash controller and the perf model — is written once below
and is bit-exact with the pre-unification engines (the golden-trace
fixtures pass unregenerated).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TieringConfig
from repro.core import hotness as HOT
from repro.core import policy as P
from repro.core import select as SEL
from repro.core.state import (TIER_FAST, TIER_NONE, TIER_SLOW, Counters,
                              TenantPolicy, ThrashTable, TierState,
                              make_policy)
from repro.obs import attribution as AT
from repro.obs import stats as OS
from repro.obs import streaming as DS
from repro.obs import trace as OT

MODES = ("equilibria", "tpp", "memtis", "static")


class TickOutput(NamedTuple):
    fast_usage: jax.Array      # [T] pages
    slow_usage: jax.Array      # [T]
    promotions: jax.Array      # [T] this tick
    demotions: jax.Array       # [T]
    throughput: jax.Array      # [T] accesses per latency-unit (1.0 = all-fast)
    latency: jax.Array         # [T] mean access latency (units of lat_fast)
    promo_scale: jax.Array     # [T]
    thrash_events: jax.Array   # [T] cumulative
    fast_free: jax.Array       # scalar
    attempted_promotions: jax.Array  # [T] candidates this tick (obs)
    pool_free: jax.Array       # scalar: unallocated pages (churn: free pool)


class Prepared(NamedTuple):
    """Everything tick step 1 (the ownership/lifecycle step) hands to the
    shared pipeline. Controller fields are the *carry-ins* for this tick —
    the static provider passes state through (plus ``freed_since``
    accumulation); the dynamic provider resets them for reused slots."""
    owner: jax.Array          # [L] effective owner this tick
    owner_c: jax.Array        # [L] gather-safe owner (sentinel clamped)
    alive: jax.Array          # [L] bool
    active: jax.Array         # [T] bool tenant roster this tick — the SAME
    #                           definition the offline detectors judge with
    #                           (static: any live page; dynamic: want > 0)
    accesses: jax.Array       # [L] f32
    tier: jax.Array           # [L] int32, post-lifecycle
    hot: jax.Array            # [L] f32, post-lifecycle
    table: ThrashTable        # post-invalidation
    stats: object             # TierStats, lifecycle exits recorded
    ring: object              # MigrationRing
    pol: TenantPolicy         # effective policy this tick
    freed_t: jax.Array        # [T] pages freed by the lifecycle step
    rows: Callable[[], HOT.RowSpace]  # lazy tenant-local page rowspace for
    #                           hotness providers that iterate per-tenant
    #                           footprints (sketch probes, neomem reports).
    #                           A thunk: the exact provider never calls it,
    #                           so the default tick carries zero extra ops.
    promo_scale: jax.Array    # [T] controller carry-ins --------------------
    steady: jax.Array
    mitigated_prev: jax.Array
    thrash_prev: jax.Array
    usage_prev: jax.Array
    freed_since: jax.Array


class OwnershipProvider(NamedTuple):
    """The seam between a deployment shape and the shared tick pipeline."""
    n_pages: int
    strategy: SEL.Strategy
    prepare: Callable[[TierState, tuple], Prepared]
    pool_free: Callable[[jax.Array, jax.Array], jax.Array]


def static_ownership(cfg: TieringConfig, owner: np.ndarray, k_max: int,
                     impl: str = "batched") -> OwnershipProvider:
    """Fixed tenant roster: ``owner`` [L] is a trace-time constant, per-tick
    inputs are ``(accesses [L] f32, alive [L] bool)`` from a prebuilt trace.
    The lifecycle step only frees pages whose trace liveness ended."""
    T = cfg.n_tenants
    owner_j = jnp.asarray(owner, jnp.int32)
    strategy = SEL.static_strategy(owner, T, k_max, impl=impl)
    pol = make_policy(cfg)
    rs_cache: list = []   # rowspace is a trace-time constant; build once

    def rows() -> HOT.RowSpace:
        if not rs_cache:
            rs_cache.append(HOT.static_rowspace(np.asarray(owner), T))
        return rs_cache[0]

    def prepare(state: TierState, inputs) -> Prepared:
        accesses, alive = inputs
        t = state.t
        tier = state.tier.astype(jnp.int32)
        died = (tier != TIER_NONE) & ~alive
        freed_t = strategy.by_tenant(died.astype(jnp.int32), owner_j)
        # fast-resident pages that die end their residency here (obs).
        # Deaths are rare (most ticks: none), and the [L]-lane residency
        # scatter is the single most expensive op in the tick at scale —
        # cond-skip it on death-free ticks (an empty mask is a value no-op,
        # so trajectories are unchanged).
        stats = jax.lax.cond(
            died.any(),
            lambda s: OS.record_fast_exits(
                s, died & (tier == TIER_FAST), owner_j, t),
            lambda s: s, state.stats)
        tier = jnp.where(died, TIER_NONE, tier)
        # roster for the streaming detectors: any live page this tick —
        # identical to the offline harness's ``tenant_activity``
        active = strategy.by_tenant(alive.astype(jnp.int32), owner_j) > 0
        # carry the state's owner through (it never changes); gathers use
        # the trace-time constant ``owner_j`` exactly as the seed engine did
        return Prepared(
            owner=state.owner, owner_c=owner_j, alive=alive, active=active,
            accesses=accesses,
            tier=tier, hot=state.hot, table=state.table, stats=stats,
            ring=state.ring, pol=pol, freed_t=freed_t, rows=rows,
            promo_scale=state.promo_scale, steady=state.steady,
            mitigated_prev=state.mitigated_prev,
            thrash_prev=state.thrash_prev, usage_prev=state.usage_prev,
            freed_since=state.freed_since + freed_t)

    return OwnershipProvider(
        n_pages=owner_j.shape[0], strategy=strategy, prepare=prepare,
        pool_free=lambda owner_, tier_: (tier_ == TIER_NONE).sum())


def dynamic_ownership(cfg: TieringConfig, n_pages: int, k_max: int,
                      impl: str = "batched") -> OwnershipProvider:
    """Tenant lifecycle as in-graph events: ``TierState.owner`` is mutated
    every tick by a ``(rates [T, S], want [T])`` schedule — reclaim
    (departure/shrink, coldest-first demote-and-free), rank-interval pool
    grants, slot-reuse controller resets and per-tick policy re-partition.
    The static trace is this provider's degenerate case (constant ``want``,
    empty pool after the first grant)."""
    T = cfg.n_tenants
    L = n_pages
    FREE = T
    n_fast = cfg.n_fast_pages
    wmark = max(int(np.ceil(n_fast * cfg.watermark_free)), 1)
    strategy = SEL.dynamic_strategy(T, k_max, impl=impl)
    base_pol = make_policy(cfg)
    weights = None
    if cfg.tenant_weights:
        w = np.ones(T, np.float32)
        for i, v in enumerate(cfg.tenant_weights[:T]):
            w[i] = v
        weights = jnp.asarray(w)

    def prepare(state: TierState, inputs) -> Prepared:
        rates, want = inputs
        S = rates.shape[1]
        t = state.t
        owner = state.owner
        tier = state.tier.astype(jnp.int32)
        hot = state.hot
        want = want.astype(jnp.int32)
        active = want > 0

        # ---- reclaim (departure & shrink), coldest-first ----------------
        owned = owner < FREE
        cnt = strategy.by_tenant(owned.astype(jnp.int32), owner)
        delta = want - cnt
        arrived = (cnt == 0) & (delta > 0)
        release_q = jnp.minimum(jnp.maximum(-delta, 0), cnt)
        cold0 = HOT.cold_score(t, state.last_access, hot)
        # k_cap = L: a departing tenant frees its whole footprint this tick
        reclaimed = SEL.select_top_quota(cold0, owner, owned, release_q, T, L)
        owner_c = jnp.minimum(owner, T - 1)
        rec_fast = reclaimed & (tier == TIER_FAST)
        # reclaims are event-driven (departure/shrink ticks only): cond-skip
        # the [L]-lane residency scatter on quiet ticks (empty-mask no-op)
        stats = jax.lax.cond(
            rec_fast.any(),
            lambda s: OS.record_fast_exits(s, rec_fast, owner_c, t),
            lambda s: s, state.stats)
        freed_t = strategy.by_tenant(reclaimed.astype(jnp.int32), owner)
        owner = jnp.where(reclaimed, FREE, owner)
        tier = jnp.where(reclaimed, TIER_NONE, tier)
        hot = jnp.where(reclaimed, 0.0, hot)
        # a reclaimed page's thrash-table entry is stale: without this, a
        # page promoted by the old tenant and re-granted soon after would
        # count a false thrash hit against its new owner
        tp = state.table.page
        stale = (tp >= 0) & reclaimed[jnp.maximum(tp, 0)]
        table = ThrashTable(page=jnp.where(stale, -1, tp),
                            tick=jnp.where(stale, 0, state.table.tick))

        # ---- grant from the free pool -----------------------------------
        need = jnp.maximum(delta, 0)
        grant_owner = SEL.pool_grant(owner == FREE, need)
        granted = grant_owner < FREE
        owner = jnp.where(granted, grant_owner, owner)
        owner_c = jnp.minimum(owner, T - 1)
        owned = owner < FREE

        # ---- slot reuse: fresh arrivals get clean controller state ------
        promo_scale0 = jnp.where(arrived, 1.0, state.promo_scale)
        steady0 = jnp.where(arrived, False, state.steady)
        mitigated0 = jnp.where(arrived, False, state.mitigated_prev)
        thrash_prev0 = jnp.where(arrived, state.counters.thrash_events,
                                 state.thrash_prev)
        usage_prev0 = jnp.where(arrived, 0, state.usage_prev)
        freed_since0 = jnp.where(arrived, 0, state.freed_since + freed_t)

        # ---- per-page accesses from the tenant-local schedule -----------
        prank = SEL.segment_ranks(jnp.where(owned, owner, T),
                                  jnp.zeros((L,), jnp.int32), T)
        accesses = jnp.where(
            owned, rates[owner_c, jnp.minimum(prank, S - 1)], 0.0)

        # ---- policy re-partition on membership --------------------------
        pol = P.repartition_policy(base_pol, active, n_fast - wmark, weights)

        # tenant rowspace from the live owner vector, built only when a
        # hotness provider asks (one [T, S] scatter; the exact provider's
        # trace never contains it)
        owner_f, owned_f, prank_f = owner, owned, prank

        def rows() -> HOT.RowSpace:
            row = jnp.where(owned_f, owner_f, T)
            col = jnp.where(owned_f & (prank_f < S), prank_f, S)
            page = jnp.full((T, S), -1, jnp.int32).at[row, col].set(
                jnp.arange(L, dtype=jnp.int32), mode="drop")
            return HOT.RowSpace(page=page, valid=page >= 0)

        return Prepared(
            owner=owner, owner_c=owner_c, alive=owned, active=active,
            accesses=accesses,
            tier=tier, hot=hot, table=table, stats=stats, ring=state.ring,
            pol=pol, freed_t=freed_t, rows=rows,
            promo_scale=promo_scale0, steady=steady0,
            mitigated_prev=mitigated0, thrash_prev=thrash_prev0,
            usage_prev=usage_prev0, freed_since=freed_since0)

    return OwnershipProvider(
        n_pages=L, strategy=strategy, prepare=prepare,
        pool_free=lambda owner_, tier_: (owner_ == FREE).sum())


def make_tick_core(cfg: TieringConfig, provider: OwnershipProvider,
                   mode: str = "equilibria", k_max: int = 256,
                   detector: Optional[DS.DetectorSpec] = None,
                   attrib: Optional[AT.AttributionSpec] = None,
                   hotness=None):
    """Build the jittable unified tick over an ownership provider.

    One compiled tick per provider serves any schedule data: trace size,
    jaxpr size and kernel count are constant in T (tenant-batched
    selection) and in the number of lifecycle events (ownership is scan
    data, not structure).

    ``detector``: optional streaming-pathology spec (obs/streaming.py). When
    set, the state must carry a matching ``DetectorState`` (build it via
    ``init_state(..., detector=spec)``) and step 9b folds this tick's
    telemetry into it; the spec's window geometry is baked in as constants,
    so jaxpr size stays independent of the horizon it was built for.

    ``attrib``: optional slowdown-attribution spec (obs/attribution.py).
    When set, the state must carry a matching ``AttributionState``
    (``init_state(..., attrib=spec)``) and step 9c folds the promotion
    pipeline's quota cascade into the per-tenant stall ledger.

    ``hotness``: optional hotness-provider spec (core/hotness.py) — a
    provider name (``"exact"``/``"sampled"``/``"sketch"``/``"neomem"``), a
    spec NamedTuple, or a prebuilt ``HotnessProvider``. None (the default)
    is the exact dense EWMA, bit-exact with the pre-seam tick. Stateful
    providers must be paired with ``init_state(..., hotness=spec)``.
    """
    assert mode in MODES, mode
    T = cfg.n_tenants
    if detector is not None:
        assert detector.n_tenants == T, (detector.n_tenants, T)
    if attrib is not None:
        assert attrib.n_tenants == T, (attrib.n_tenants, T)
    L = provider.n_pages
    n_fast = cfg.n_fast_pages
    wmark = max(int(np.ceil(n_fast * cfg.watermark_free)), 1)
    strategy = provider.strategy
    by_tenant = strategy.by_tenant
    alloc_ranks = strategy.alloc_ranks
    hot_provider = HOT.resolve_hotness(hotness, cfg, L, k_max)

    def tick(state: TierState, inputs) -> Tuple[TierState, TickOutput]:
        t = state.t
        page_ids = jnp.arange(L, dtype=jnp.int32)

        # ---- 1. ownership / lifecycle (the provider seam) -----------------
        prep = provider.prepare(state, inputs)
        owner, owner_c = prep.owner, prep.owner_c
        alive, accesses = prep.alive, prep.accesses
        tier, stats, ring = prep.tier, prep.stats, prep.ring
        pol = prep.pol

        # Migration accounting (thrash table, residency histogram, event
        # ring) runs over the selection's compact [T, k] candidate stream
        # when available (contiguous batched path) — scatters over T*k lanes
        # instead of L — and falls back to the full [L] masks otherwise.
        def sel_counts(sel: SEL.Selection) -> jax.Array:
            if sel.counts is not None:
                return sel.counts
            return by_tenant(sel.mask.astype(jnp.int32), owner)

        def sel_tenants(sel: SEL.Selection) -> jax.Array:
            return jnp.broadcast_to(
                jnp.arange(T, dtype=jnp.int32)[:, None], sel.take.shape)

        def sel_thrash(tbl, sel: SEL.Selection) -> jax.Array:
            if sel.pages is None:
                return by_tenant(P.thrash_hits(
                    tbl, page_ids, sel.mask, t, cfg).astype(jnp.int32), owner)
            hits = P.thrash_hits(tbl, sel.pages, sel.take, t, cfg)
            return hits.sum(axis=1).astype(jnp.int32)

        def sel_record_promos(tbl, sel: SEL.Selection):
            if sel.pages is None:
                return P.thrash_record_promotions(tbl, page_ids, sel.mask, t)
            return P.thrash_record_promotions(tbl, sel.pages, sel.take, t)

        def sel_exits(st, sel: SEL.Selection):
            if sel.pages is None:
                return OS.record_fast_exits(st, sel.mask, owner_c, t)
            return OS.record_fast_exits_at(st, sel.pages, sel.take,
                                           sel_tenants(sel), t)

        def sel_ring(rg, sel: SEL.Selection, hotv, direction):
            if sel.pages is None:
                return OT.ring_record(rg, sel.mask, page_ids, owner_c, hotv,
                                      direction, t)
            return OT.ring_record(rg, sel.take, sel.pages, sel_tenants(sel),
                                  hotv[sel.pages], direction, t)

        def move_pages(tier_, ring_, sel: SEL.Selection, hotv, direction,
                       to_tier):
            """Commit a selection's page moves: tier scatter + migration-ring
            append. When the strategy provides the fused page-move kernel
            (kernels/migrate commit_moves) and the selection carries the
            compact [T, k] stream, both come out of one kernel pass —
            bit-identical to the composed jnp ops of the fallback."""
            if strategy.move is not None and sel.pages is not None:
                tier2, data2, head2 = strategy.move(
                    tier_, ring_.data, ring_.head, sel, hotv, direction,
                    to_tier, t)
                return tier2, OT.MigrationRing(data=data2, head=head2)
            ring2 = sel_ring(ring_, sel, hotv, direction)
            return jnp.where(sel.mask, to_tier, tier_), ring2

        # ---- 2. allocate new pages ----------------------------------------
        # Allocation is event-driven (first grant / arrivals); most ticks
        # have no new pages, so the whole block — the [L] rank cumsums and
        # the entry stamps — runs under a cond. With ``new`` empty every
        # branch output equals the pass-through (wheres over a False mask,
        # a zero by_tenant, an empty entry stamp), so values are unchanged.
        new = alive & (tier == TIER_NONE)
        fast_usage = by_tenant((tier == TIER_FAST).astype(jnp.int32), owner)
        fast_free = n_fast - fast_usage.sum()

        def do_alloc(args):
            tier_, stats_ = args
            alloc_ = None
            # per-tenant upper bound gating of *fast* placement
            if mode in ("equilibria", "memtis") and cfg.enable_upper_bound:
                if strategy.alloc_stats is not None:
                    # fused kernel pass: allocation ranks + per-tenant new-
                    # page counts from one segmented reduction
                    ranks, alloc_ = strategy.alloc_stats(new, owner)
                else:
                    ranks = alloc_ranks(new, owner)
                bound = pol.upper_bound[owner_c]
                under_bound = ((bound == 0)
                               | (fast_usage[owner_c] + ranks < bound))
            else:
                under_bound = jnp.ones((L,), bool)
            elig = new & under_bound
            grank = SEL.masked_rank(elig)
            go_fast = elig & (grank < jnp.maximum(fast_free - wmark, 0))
            tier_ = jnp.where(go_fast, TIER_FAST,
                              jnp.where(new, TIER_SLOW, tier_))
            if alloc_ is None:
                alloc_ = by_tenant(new.astype(jnp.int32), owner)
            return tier_, alloc_, OS.record_fast_entries(stats_, go_fast, t)

        tier, alloc_t, stats = jax.lax.cond(
            new.any(), do_alloc,
            lambda args: (args[0], jnp.zeros((T,), jnp.int32), args[1]),
            (tier, stats))

        # ---- 3. hotness / recency (the hotness-provider seam) -------------
        last_access = jnp.where(new | (accesses > 0), t, state.last_access)
        hview = hot_provider.step(HOT.HotCtx(
            hstate=state.hotness, prev_hot=prep.hot, accesses=accesses,
            alive=alive, new=new, tier=tier, last_access=last_access,
            owner=owner, owner_c=owner_c, t=t, rows=prep.rows,
            strategy=provider.strategy))
        hot = hview.hot

        # ---- 4. contention ------------------------------------------------
        # Local memory is contended when free space cannot absorb both the
        # watermark and the pending promotion demand (kswapd-style: promotion
        # pressure drives background demotion, §IV-D).
        fast_usage = by_tenant((tier == TIER_FAST).astype(jnp.int32), owner)
        fast_free = n_fast - fast_usage.sum()
        demand_t = jnp.minimum(hview.demand_t, k_max)
        promo_demand = jnp.minimum(demand_t.sum(), k_max)
        contended = fast_free < wmark + promo_demand

        # ---- 5. demotion ---------------------------------------------------
        sync_quota = jnp.zeros((T,), jnp.int32)
        if mode == "equilibria":
            d_scan = P.eq1_demotion_scan(fast_usage, fast_usage, pol, contended)
            if not cfg.enable_protection:
                # ablation: proportional pressure without protection
                d_scan = jnp.where(contended, fast_usage.astype(jnp.float32),
                                   0.0)
            # Eq.1 sets each tenant's *share* of reclaim work; the total is
            # kswapd-style demand-driven: free enough for the watermark plus
            # pending promotions, no more (work-conserving donation, §V-B3).
            # A tenant's OWN promotion demand never drives its own demotion
            # (that would be pure churn); only neighbors' demand evicts it.
            demand_other = jnp.minimum(promo_demand - demand_t, k_max)
            needed_t = jnp.maximum(wmark + demand_other - fast_free, 0)
            total_scan = jnp.maximum(d_scan.sum(), 1.0)
            share = jnp.ceil(d_scan * jnp.minimum(
                needed_t.astype(jnp.float32) / total_scan, 1.0)).astype(jnp.int32)
            if cfg.enable_upper_bound:
                sync_quota = P.upper_bound_demotion(fast_usage, pol)
            quota = jnp.minimum(share + sync_quota, k_max)
        elif mode == "tpp":
            needed = jnp.maximum(2 * wmark - fast_free, 0)
            quota = jnp.minimum(needed, k_max * T)  # global
        elif mode == "memtis":
            sync_quota = P.upper_bound_demotion(fast_usage, pol)
            quota = jnp.minimum(sync_quota, k_max)
        else:  # static
            quota = jnp.zeros((T,), jnp.int32)

        fast_mask = tier == TIER_FAST
        if mode == "tpp":
            dsel = hview.demote_global(fast_mask, quota)
        elif mode == "static":
            dsel = SEL.Selection(jnp.zeros((L,), bool), None, None, None)
        else:
            dsel = hview.demote(fast_mask, quota)
        demoted = dsel.mask
        demo_t = sel_counts(dsel)

        # thrash detection on demotions (§IV-F)
        thrash_new = sel_thrash(prep.table, dsel)
        stats = sel_exits(stats, dsel)
        tier, ring = move_pages(tier, ring, dsel, hot, OT.DIR_DEMOTE,
                                TIER_SLOW)
        fast_usage = fast_usage - demo_t
        fast_free = n_fast - fast_usage.sum()

        # ---- 6. promotion ---------------------------------------------------
        # just-demoted pages are not promotion candidates this tick
        pcand = hview.promo_cand(tier, demoted)
        cand_t = pcand.cand_t
        throttled = jnp.zeros((T,), bool)
        q_base = q_eq2 = q_mit = None   # attribution quota cascade (9c)
        if mode == "equilibria":
            p_base = jnp.full((T,), float(cfg.p_base), jnp.float32)
            if cfg.enable_promo_throttle:
                p_scan, throttled = P.eq2_promotion_scan(p_base, fast_usage,
                                                         pol, contended, cfg)
            else:
                p_scan = p_base
            p_eq2 = p_scan                            # pre-mitigation scan
            p_scan = p_scan * prep.promo_scale        # thrash mitigation
            p_quota = jnp.minimum(p_scan.astype(jnp.int32), k_max)
            if attrib is not None:
                # telescoping quota cascade: each stage capped the same way
                # the pipeline caps p_quota below (min with cand and k_max),
                # so successive differences are the deferral components
                c0 = jnp.minimum(cand_t, k_max)
                q_base = jnp.minimum(jnp.full((T,), int(cfg.p_base),
                                              jnp.int32), c0)
                q_eq2 = jnp.minimum(
                    jnp.minimum(p_eq2.astype(jnp.int32), k_max), c0)
                q_mit = jnp.minimum(p_quota, c0)
        elif mode in ("tpp", "memtis"):
            p_quota = jnp.full((T,), cfg.p_base, jnp.int32)  # unregulated
            if attrib is not None:
                # no throttle / mitigation stages: the whole cascade is the
                # unregulated scan budget
                q_base = q_eq2 = q_mit = jnp.minimum(
                    p_quota, jnp.minimum(cand_t, k_max))
        else:
            p_quota = jnp.zeros((T,), jnp.int32)
            if attrib is not None:   # no promotion path at all
                q_base = q_eq2 = q_mit = p_quota

        # never overfill: cap total promotions by free fast capacity.
        # NOTE: promotions may transiently exceed a tenant's upper bound —
        # the allocating thread then demotes synchronously in the same tick
        # (paper §IV-D); that promote->sync-demote cycle is exactly the
        # thrashing signature §IV-F detects.
        p_quota = jnp.minimum(p_quota, jnp.minimum(cand_t, k_max))
        headroom = jnp.maximum(fast_free - wmark, 0)
        total = p_quota.sum()
        scale = jnp.where(total > headroom,
                          headroom.astype(jnp.float32) / jnp.maximum(total, 1),
                          1.0)
        p_quota = jnp.floor(p_quota.astype(jnp.float32) * scale).astype(jnp.int32)

        if mode == "tpp":
            psel = pcand.select_global(p_quota.sum())
        elif mode == "static":
            psel = SEL.Selection(jnp.zeros((L,), bool), None, None, None)
        else:
            psel = pcand.select(p_quota)
        promoted = psel.mask
        promo_t = sel_counts(psel)
        tier, ring = move_pages(tier, ring, psel, hot, OT.DIR_PROMOTE,
                                TIER_FAST)
        table = sel_record_promos(prep.table, psel)
        stats = OS.record_fast_entries(stats, promoted, t)

        # ---- 6b. synchronous upper-bound demotion (allocation path, §IV-D):
        # promotions that pushed a tenant past its bound are shed in the same
        # tick by the "allocating thread" — these demotions hit the thrash
        # table immediately when they evict recently-promoted pages.
        sync2_t = jnp.zeros((T,), jnp.int32)
        if mode in ("equilibria", "memtis") and cfg.enable_upper_bound:
            fast_usage2 = by_tenant((tier == TIER_FAST).astype(jnp.int32),
                                    owner)
            over2 = jnp.where(pol.upper_bound > 0,
                              jnp.maximum(fast_usage2 - pol.upper_bound, 0), 0)
            over2 = jnp.minimum(over2, k_max)
            ssel = hview.demote(tier == TIER_FAST, over2)
            thr2 = sel_thrash(table, ssel)
            thrash_new = thrash_new + thr2
            stats = sel_exits(stats, ssel)
            tier, ring = move_pages(tier, ring, ssel, hot, OT.DIR_DEMOTE,
                                    TIER_SLOW)
            sync2_t = sel_counts(ssel)
            demo_t = demo_t + sync2_t

        # ---- 7. counters ----------------------------------------------------
        c = state.counters
        counters = Counters(
            promotions=c.promotions + promo_t,
            demotions=c.demotions + demo_t,
            attempted_promotions=c.attempted_promotions + cand_t,
            reclaims=c.reclaims + prep.freed_t,
            allocations=c.allocations + alloc_t,
            thrash_events=c.thrash_events + thrash_new,
            sync_demotions=c.sync_demotions
            + jnp.minimum(sync_quota, demo_t) + sync2_t,
        )
        fast_usage = by_tenant((tier == TIER_FAST).astype(jnp.int32), owner)
        slow_usage = by_tenant((tier == TIER_SLOW).astype(jnp.int32), owner)

        # ---- 7b. observability (obs/, §IV-C) --------------------------------
        # tpp's quota is one global scan budget; split it evenly so
        # demo_success_ratio stays comparable across modes
        demo_att = (jnp.broadcast_to((quota + T - 1) // T, (T,))
                    if quota.ndim == 0 else quota)
        below_prot = OS.below_protection(fast_usage, slow_usage,
                                         pol.lower_protection)
        # sync upper-bound demotions (6b) bypass the step-5 quota; count them
        # on both sides so demo_success_ratio stays <= 1
        stats = OS.update_tick(
            stats, promo_attempts=cand_t, promo_success=promo_t,
            demo_attempts=jnp.minimum(demo_att, k_max) + sync2_t,
            demo_success=demo_t,
            thrash_new=thrash_new, contended=contended, throttled=throttled,
            below_protection=below_prot, decay=cfg.obs_window_decay)

        new_state = TierState(
            tier=tier.astype(jnp.int8), hot=hot, last_access=last_access,
            owner=owner,
            counters=counters, promo_scale=prep.promo_scale,
            thrash_prev=prep.thrash_prev, usage_prev=prep.usage_prev,
            freed_since=prep.freed_since, steady=prep.steady,
            mitigated_prev=prep.mitigated_prev,
            table=table, stats=stats, ring=ring, t=t + 1, det=state.det,
            attrib=state.attrib, hotness=hview.hstate)

        # ---- 8. periodic controller (§IV-F) ---------------------------------
        def run_ctrl(s: TierState) -> TierState:
            out = P.thrash_controller(s, fast_usage + slow_usage, cfg)
            return s._replace(promo_scale=out.promo_scale, steady=out.steady,
                              table=out.table, thrash_prev=out.thrash_prev,
                              usage_prev=out.usage_prev,
                              freed_since=out.freed_since,
                              mitigated_prev=out.mitigated_prev)

        new_state = jax.lax.cond(
            (t + 1) % cfg.controller_period == 0, run_ctrl, lambda s: s,
            new_state)

        # ---- 9. perf model ---------------------------------------------------
        a_fast = by_tenant(accesses * (tier == TIER_FAST), owner)
        a_slow = by_tenant(accesses * (tier == TIER_SLOW), owner)
        a_tot = a_fast + a_slow
        migrations = (promo_t + demo_t).sum().astype(jnp.float32)
        lat = jnp.where(
            a_tot > 0,
            (a_fast * cfg.lat_fast + a_slow * cfg.lat_slow)
            / jnp.maximum(a_tot, 1e-9),
            cfg.lat_fast) + migrations * cfg.migration_cost
        thru = jnp.where(a_tot > 0, a_tot / lat, 0.0)

        # ---- 9b. streaming pathology detectors (obs/streaming.py) ----------
        # fed the exact per-tick values the offline detectors read from
        # TickOutput traces, so the streamed verdicts can agree bit-for-bit
        if detector is not None:
            new_state = new_state._replace(det=DS.update_detector(
                detector, state.det,
                DS.DetectorSignals(
                    active=prep.active, thrash_new=thrash_new,
                    fast_usage=fast_usage, slow_usage=slow_usage,
                    attempted=cand_t, promotions=promo_t, demotions=demo_t,
                    latency=lat), t))

        # ---- 9c. slowdown attribution ledger (obs/attribution.py) ----------
        # the promotion pipeline's quota cascade, telescoped into additive
        # per-tenant stall components; conservation against Counters is
        # bit-exact because cand_t / promo_t / freed_t are the SAME values
        # step 7 accumulates into attempted/promotions/reclaims
        if attrib is not None:
            new_state = new_state._replace(attrib=AT.update_attribution(
                attrib, state.attrib,
                AT.AttribSignals(
                    cand=cand_t, promoted=promo_t, quota_base=q_base,
                    quota_eq2=q_eq2, quota_mit=q_mit, freed=prep.freed_t,
                    a_fast=a_fast, a_slow=a_slow, latency=lat)))

        out = TickOutput(
            fast_usage=fast_usage, slow_usage=slow_usage,
            promotions=promo_t, demotions=demo_t,
            throughput=thru, latency=lat, promo_scale=new_state.promo_scale,
            thrash_events=counters.thrash_events,
            fast_free=n_fast - fast_usage.sum(),
            attempted_promotions=cand_t,
            pool_free=provider.pool_free(owner, tier))
        return new_state, out

    return tick
