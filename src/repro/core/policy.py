"""Equilibria fairness policy — the paper's equations, as pure functions.

Eq. 1 (demotion modulation), Eq. 2 (promotion regulation, fourth-power
throttle with a 1/16 floor — see DESIGN.md on the paper's min/max typo),
thrashing detection/controller, and the steady-state detector (§IV-F).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TieringConfig
from repro.core.state import TenantPolicy, ThrashTable, TierState


def eq1_demotion_scan(fast_usage: jax.Array, n_lru: jax.Array,
                      policy: TenantPolicy, contended: jax.Array) -> jax.Array:
    """Paper Eq. 1: d_scan = n_lru * (n_cgroup - n_protection) / n_cgroup.

    Zero for tenants at/below their lower protection (they are *exempt* from
    demotion under contention). Only applies when local memory is contended.
    fast_usage, n_lru: [T] pages. Returns [T] f32 scan quota.
    """
    n_cgroup = fast_usage.astype(jnp.float32)
    n_prot = policy.lower_protection.astype(jnp.float32)
    over = jnp.maximum(n_cgroup - n_prot, 0.0)
    d = jnp.where(n_cgroup > 0, n_lru.astype(jnp.float32) * over / jnp.maximum(n_cgroup, 1.0), 0.0)
    return jnp.where(contended, d, 0.0)


def upper_bound_demotion(fast_usage: jax.Array, policy: TenantPolicy) -> jax.Array:
    """Upper-bound enforcement (§IV-D): "as the usage approaches the upper
    bound, a background thread demotes pages ... gently"; at/over the bound
    the allocating thread demotes synchronously. We model both: once usage
    reaches 95% of the bound, demote down toward 90% (the gentle background
    path); any overage past the bound is additionally forced (sync path).
    Returns [T] pages that must be demoted regardless of global pressure."""
    bound = policy.upper_bound
    # thresholds in real arithmetic, not truncation: "near" means
    # usage >= 0.95*bound, i.e. usage >= ceil(0.95*bound) for integer pages
    # (truncating made small bounds trigger early: bound=10 demoted at 9);
    # the gentle target is the nearest integer to 0.9*bound. The small
    # epsilon absorbs f32 product dust around exact integers.
    bf = bound.astype(jnp.float32)
    near_thr = jnp.ceil(0.95 * bf - 1e-4).astype(jnp.int32)
    target = jnp.round(0.9 * bf).astype(jnp.int32)
    near = fast_usage >= near_thr
    gentle = jnp.maximum(fast_usage - target, 0)
    over = jnp.maximum(fast_usage - bound, 0)
    quota = jnp.where(near, jnp.maximum(gentle, over), over)
    return jnp.where(bound > 0, quota, 0).astype(jnp.int32)


def eq2_promotion_scan(p_base: jax.Array, fast_usage: jax.Array,
                       policy: TenantPolicy, contended: jax.Array,
                       cfg: TieringConfig) -> Tuple[jax.Array, jax.Array]:
    """Paper Eq. 2: p_scan = p_base * clip((n_prot/n_cgroup)^4, 1/16, 1).

    A tenant is "promotion throttled" (§IV-E) when either
      (a) a lower protection is configured, usage exceeds it, AND local
          memory is fully utilized, or
      (b) usage is approaching (>=95%) or exceeds its configured upper bound.
    Tenants with neither knob set (prot=0, bound=0) are never throttled —
    there is no configured fair share to be over, and the clip factor would
    be 1.0 anyway (flagging them only polluted obs throttle occupancy).
    Returns (p_scan [T] f32, throttled [T] bool).
    """
    usage = fast_usage.astype(jnp.float32)
    prot = policy.lower_protection.astype(jnp.float32)
    bound = policy.upper_bound.astype(jnp.float32)
    over_prot = (prot > 0) & (usage > prot) & contended
    near_bound = (bound > 0) & (usage >= 0.95 * bound)
    throttled = over_prot | near_bound
    # reference share for the ratio: the protection; when only the bound
    # triggers (no protection set), the bound itself is the fair share.
    ref = jnp.where(prot > 0, prot, jnp.where(bound > 0, bound, usage))
    ratio = jnp.where(usage > 0, ref / jnp.maximum(usage, 1.0), 1.0)
    factor = jnp.clip(ratio ** 4, cfg.promo_floor, 1.0)
    p = jnp.where(throttled, p_base * factor, p_base)
    return p, throttled


def repartition_policy(base: TenantPolicy, active: jax.Array,
                       capacity, weights: jax.Array = None) -> TenantPolicy:
    """Recompute the effective per-slot policy on a membership change
    (churn engine, every tick — pure jnp so it runs in-graph).

    Departed slots lose both knobs (a protection configured for a tenant
    that left must not keep reserving fast pages). When the *active* slots'
    protections oversubscribe ``capacity`` (fast tier minus watermark), they
    are scaled down to fit — proportionally by default, or biased by
    ``weights`` ([T] f32 fair-share weights: heavier slots keep more of
    their configured ask). Upper bounds pass through for active slots.
    """
    prot = jnp.where(active, base.lower_protection, 0).astype(jnp.float32)
    w = jnp.ones_like(prot) if weights is None else weights.astype(jnp.float32)
    w = jnp.where(active, w, 0.0)
    ask = w * prot
    total_ask = jnp.maximum(ask.sum(), 1.0)
    cap = jnp.asarray(capacity, jnp.float32)
    over = prot.sum() > cap
    scaled = jnp.floor(cap * ask / total_ask)
    prot_eff = jnp.where(over, jnp.minimum(scaled, prot), prot)
    bound_eff = jnp.where(active, base.upper_bound, 0)
    return TenantPolicy(prot_eff.astype(jnp.int32),
                        bound_eff.astype(jnp.int32))


# ------------------------------------------------------- thrash tracking ----
def thrash_record_promotions(table: ThrashTable, promoted_pages: jax.Array,
                             promoted_mask: jax.Array, t: jax.Array) -> ThrashTable:
    """Insert promoted pages into the direct-mapped table (slot = page % S).

    Two pages promoted in the SAME call can collide on a slot; the surviving
    entry is whichever XLA's scatter keeps (unspecified, and dependent on
    lane order — the batched engine feeds [T, k] lanes, the unrolled one
    [L]). That is acceptable: collisions are the paper's 'sampling', and it
    is the one place the batched/unrolled engines may diverge (the
    equivalence suite uses page counts below the slot count, where no
    same-tick collision is possible)."""
    slots = table.page.shape[0]
    idx = promoted_pages % slots
    idx = jnp.where(promoted_mask, idx, slots)  # dropped writes -> OOB
    page = table.page.at[idx].set(promoted_pages, mode="drop")
    tick = table.tick.at[idx].set(jnp.broadcast_to(t, promoted_pages.shape),
                                  mode="drop")
    return ThrashTable(page=page, tick=tick)


def thrash_hits(table: ThrashTable, demoted_pages: jax.Array,
                demoted_mask: jax.Array, t: jax.Array,
                cfg: TieringConfig) -> jax.Array:
    """Per-lane thrash flag: demoted page was promoted < t_resident ago."""
    slots = table.page.shape[0]
    idx = demoted_pages % slots
    hit = (table.page[idx] == demoted_pages) & demoted_mask
    recent = (t - table.tick[idx]) < cfg.t_resident
    return hit & recent


def thrash_check_demotions(table: ThrashTable, demoted_pages: jax.Array,
                           demoted_mask: jax.Array, owners: jax.Array,
                           t: jax.Array, cfg: TieringConfig,
                           n_tenants: int) -> jax.Array:
    """Count demotions of pages promoted < t_resident ago. Returns [T] int32.
    Scatter-add, not a [L, T] one-hot: shape-polymorphic in both L and T
    (the one-hot was an O(L*T) hot-path cost at scale)."""
    is_thrash = thrash_hits(table, demoted_pages, demoted_mask, t, cfg)
    return jnp.zeros((n_tenants,), jnp.int32).at[owners].add(
        is_thrash.astype(jnp.int32))


class ControllerOut(NamedTuple):
    promo_scale: jax.Array
    steady: jax.Array
    table: ThrashTable
    thrash_prev: jax.Array
    usage_prev: jax.Array
    freed_since: jax.Array
    mitigated_prev: jax.Array


def thrash_controller(state: TierState, usage_total: jax.Array,
                      cfg: TieringConfig) -> ControllerOut:
    """Periodic controller (§IV-F, every `controller_period` ticks):
    steady-state detection, then halve/double promotion rates of thrashing
    steady-state tenants; clear the table to start the next window.

    Recovery (doubling back toward 1.0) requires a quiet window that was
    *not* the window the mitigation itself fired in: a freshly-halved tenant
    always looks quiet for one window — doubling on that evidence bounced a
    mitigated tenant straight back into thrashing every other period. The
    ``mitigated_prev`` flag makes recovery wait for a clean window first, so
    the scale trajectory after mitigation is monotone."""
    thrash_rate = (state.counters.thrash_events - state.thrash_prev).astype(jnp.float32)
    # steady state: small rate-of-change of active pages AND small free rate
    u = usage_total.astype(jnp.float32)
    prev = state.usage_prev.astype(jnp.float32)
    denom = jnp.maximum(jnp.maximum(u, prev), 1.0)
    active_delta = jnp.abs(u - prev) / denom
    free_rate = state.freed_since.astype(jnp.float32) / denom
    steady = (active_delta < cfg.steady_active_delta) & (free_rate < cfg.steady_free_rate)

    thrashing = thrash_rate > cfg.r_thrashing
    mitigate = steady & thrashing if cfg.enable_thrash_mitigation else jnp.zeros_like(steady)
    recover = ~thrashing & ~state.mitigated_prev
    scale = state.promo_scale
    scale = jnp.where(mitigate, jnp.maximum(scale * 0.5, 1.0 / 64.0), scale)
    scale = jnp.where(recover, jnp.minimum(scale * 2.0, 1.0), scale)

    slots = state.table.page.shape[0]
    cleared = ThrashTable(page=jnp.full((slots,), -1, jnp.int32),
                          tick=jnp.zeros((slots,), jnp.int32))
    return ControllerOut(
        promo_scale=scale, steady=steady, table=cleared,
        thrash_prev=state.counters.thrash_events,
        usage_prev=usage_total,
        freed_since=jnp.zeros_like(state.freed_since),
        mitigated_prev=mitigate)
