"""Baseline tiering policies the paper compares against.

All baselines run through the same engine (core/engine.py) selected by
``mode`` so comparisons are apples-to-apples:

  * ``tpp`` — TPP / upstream Linux (Maruf et al., ASPLOS'23): watermark-driven
    demotion picks the *system-wide* coldest pages (global LRU, no per-tenant
    quotas); promotion is NUMA-hint-fault-style — any slow page that looks hot
    is promoted, budgeted globally, first-come-first-served. No protections,
    no bounds, no thrash mitigation. This is the paper's primary baseline.
  * ``memtis`` — MEMTIS-like (SOSP'23) multi-tenancy: only an *upper limit*
    of fast-tier usage per cgroup, enforced at allocation/overage; no
    work-conserving lower protection, no promotion regulation.
  * ``static`` — tier fixed at allocation time (first-touch), no migration:
    the no-tiering lower bound.
  * ``equilibria`` — the paper's system (the default in core/engine.py).

Ablation flags on TieringConfig (enable_protection / enable_upper_bound /
enable_promo_throttle / enable_thrash_mitigation) turn individual Equilibria
mechanisms off for component studies (§V-B).
"""
from repro.core.engine import MODES, make_tick, run_engine  # noqa: F401

BASELINE_MODES = ("tpp", "memtis", "static")
