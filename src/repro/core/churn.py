"""Dynamic-ownership tiering engine: tenant lifecycle as in-graph events.

The static engine (core/engine.py) freezes the owner vector at trace time,
so every scenario it can express is a fixed tenant roster. Equilibria's
target deployment is the opposite: containers are stacked, arrive, resize
and depart continuously (the serverless-CXL churn regime is exactly where
tiering policies break). This module makes ownership *state*: the
``TierState.owner`` vector ([L] int32, ``n_tenants`` = FREE sentinel) is
mutated inside the compiled tick by a per-tick schedule

    want  [T]    int32 — target footprint of each tenant slot (0 = departed)
    rates [T, S] f32   — access rate of the tenant's k-th page (tenant-local
                         address space; S = max slot footprint)

so one jaxpr handles an arbitrary churn schedule — the trace is constant in
the number of lifecycle events (they are data, not structure). Each tick:

  1. *reclaim* (departure / shrink): tenants over target release their
     coldest pages — demote-and-free: fast-resident reclaims end their
     residency in the obs histograms, then pages return to the free pool
     (owner=FREE, tier=NONE, hotness cleared).
  2. *grant* (arrival / grow): tenants under target receive free pages via
     a rank-interval partition of the pool (``select.pool_grant``; lower
     slot ids win admission when the pool is over-subscribed). Granted
     pages start unallocated and flow through the normal allocation gate
     (upper bound + watermark) in the same tick.
  3. *slot reuse reset*: a fresh arrival in a previously used slot starts
     with clean controller state (promo_scale=1, thrash window zeroed).
  4. *policy re-partition*: effective protections/bounds are recomputed
     from the active mask every tick (``policy.repartition_policy``) —
     departed slots stop reserving fast pages; oversubscribed protections
     scale (weight-aware) to fit the fast tier.
  5. the regular engine pipeline (allocation, hotness, Eq.1/Eq.2-regulated
     migration, thrash mitigation, §IV-C obs) — all selection routed
     through the ``segment_ranks`` fallback, which takes the owner vector
     as a runtime array.

Per-page access rates come from the tenant-local schedule: page l's rate is
``rates[owner[l], rank(l)]`` where rank is the page's index-order position
among its tenant's pages — the tenant's address space stays stable while
membership is constant and compacts on shrink.

Conservation invariants (pinned by tests/test_churn.py property suite):
every page is owned by at most one tenant (structural: owner is a single
int per page), departed tenants own zero pages, and
``fast + slow + free == L`` every tick.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TieringConfig
from repro.core import policy as P
from repro.core import select as SEL
from repro.core.engine import MODES, TickOutput
from repro.core.state import (TIER_FAST, TIER_NONE, TIER_SLOW, Counters,
                              ThrashTable, TierState, init_state, make_policy)
from repro.obs import stats as OS
from repro.obs import trace as OT


class ChurnSchedule(NamedTuple):
    """Host-side (numpy) lifecycle schedule for a churn run."""
    want: np.ndarray      # [ticks, T] int32 target footprints (0 = departed)
    rates: np.ndarray     # [ticks, T, S] f32 tenant-local access rates


def churn_events(want: np.ndarray) -> Tuple[int, int]:
    """(arrivals, departures) across a [ticks, T] schedule: transitions of
    the active mask, counting initially-active slots as arrivals."""
    active = np.asarray(want) > 0
    prev = np.concatenate([np.zeros((1, active.shape[1]), bool), active[:-1]])
    arrivals = int((active & ~prev).sum())
    departures = int((~active & prev).sum())
    return arrivals, departures


def make_churn_tick(cfg: TieringConfig, n_pages: int, mode: str = "equilibria",
                    k_max: int = 256):
    """Build the jittable dynamic-ownership tick.

    n_pages: size of the physical page pool (fast + slow capacity). Inputs
    per tick: ``(rates [T, S] f32, want [T] int32)``.
    """
    assert mode in MODES, mode
    T = cfg.n_tenants
    L = n_pages
    FREE = T
    n_fast = cfg.n_fast_pages
    wmark = max(int(np.ceil(n_fast * cfg.watermark_free)), 1)
    base_pol = make_policy(cfg)
    weights = None
    if cfg.tenant_weights:
        w = np.ones(T, np.float32)
        for i, v in enumerate(cfg.tenant_weights[:T]):
            w[i] = v
        weights = jnp.asarray(w)

    def by_tenant(x: jax.Array, owner: jax.Array) -> jax.Array:
        return SEL.by_tenant_pooled(x, owner, T)

    def select_pt(score, owner, mask, quotas, k_cap=k_max):
        return SEL.select_top_quota(score, owner, mask, quotas, T, k_cap)

    def tick(state: TierState, inputs) -> Tuple[TierState, TickOutput]:
        rates, want = inputs
        S = rates.shape[1]
        t = state.t
        owner = state.owner
        tier = state.tier.astype(jnp.int32)
        hot = state.hot
        stats = state.stats
        ring = state.ring
        page_ids = jnp.arange(L, dtype=jnp.int32)
        want = want.astype(jnp.int32)
        active = want > 0
        owner_c = jnp.minimum(owner, T - 1)        # gather-safe owner index

        # ---- 1. lifecycle: reclaim (departure & shrink), coldest-first ----
        owned = owner < FREE
        cnt = by_tenant(owned.astype(jnp.int32), owner)
        delta = want - cnt
        arrived = (cnt == 0) & (delta > 0)
        release_q = jnp.minimum(jnp.maximum(-delta, 0), cnt)
        cold0 = (t - state.last_access).astype(jnp.float32) * 1e3 - hot
        # k_cap = L: a departing tenant frees its whole footprint this tick
        reclaimed = select_pt(cold0, owner, owned, release_q, k_cap=L)
        rec_fast = reclaimed & (tier == TIER_FAST)
        stats = OS.record_fast_exits(stats, rec_fast, owner_c, t)
        freed_t = by_tenant(reclaimed.astype(jnp.int32), owner)
        owner = jnp.where(reclaimed, FREE, owner)
        tier = jnp.where(reclaimed, TIER_NONE, tier)
        hot = jnp.where(reclaimed, 0.0, hot)
        # a reclaimed page's thrash-table entry is stale: without this, a
        # page promoted by the old tenant and re-granted soon after would
        # count a false thrash hit against its new owner
        tp = state.table.page
        stale = (tp >= 0) & reclaimed[jnp.maximum(tp, 0)]
        table0 = ThrashTable(page=jnp.where(stale, -1, tp),
                             tick=jnp.where(stale, 0, state.table.tick))

        # ---- 1b. lifecycle: grant from the free pool --------------------
        need = jnp.maximum(delta, 0)
        grant_owner = SEL.pool_grant(owner == FREE, need)
        granted = grant_owner < FREE
        owner = jnp.where(granted, grant_owner, owner)
        owner_c = jnp.minimum(owner, T - 1)
        owned = owner < FREE
        alive = owned                         # every owned page is live

        # ---- 1c. slot reuse: fresh arrivals get clean controller state --
        promo_scale0 = jnp.where(arrived, 1.0, state.promo_scale)
        steady0 = jnp.where(arrived, False, state.steady)
        mitigated0 = jnp.where(arrived, False, state.mitigated_prev)
        thrash_prev0 = jnp.where(arrived, state.counters.thrash_events,
                                 state.thrash_prev)
        usage_prev0 = jnp.where(arrived, 0, state.usage_prev)
        freed_since0 = jnp.where(arrived, 0, state.freed_since + freed_t)

        # ---- 1d. per-page accesses from the tenant-local schedule -------
        prank = SEL.segment_ranks(jnp.where(owned, owner, T),
                                  jnp.zeros((L,), jnp.int32), T)
        accesses = jnp.where(
            owned, rates[owner_c, jnp.minimum(prank, S - 1)], 0.0)

        # ---- 1e. policy re-partition on membership ----------------------
        pol = P.repartition_policy(base_pol, active, n_fast - wmark, weights)

        # ---- 2. allocate granted pages (engine step 2, dynamic owner) ---
        new = alive & (tier == TIER_NONE)
        fast_usage = by_tenant((tier == TIER_FAST).astype(jnp.int32), owner)
        fast_free = n_fast - fast_usage.sum()
        if mode in ("equilibria", "memtis") and cfg.enable_upper_bound:
            ranks = SEL.allocation_ranks(new, owner, T)
            bound = pol.upper_bound[owner_c]
            under_bound = (bound == 0) | (fast_usage[owner_c] + ranks < bound)
        else:
            under_bound = jnp.ones((L,), bool)
        elig = new & under_bound
        grank = SEL.masked_rank(elig)
        go_fast = elig & (grank < jnp.maximum(fast_free - wmark, 0))
        tier = jnp.where(go_fast, TIER_FAST, jnp.where(new, TIER_SLOW, tier))
        alloc_t = by_tenant(new.astype(jnp.int32), owner)
        stats = OS.record_fast_entries(stats, go_fast, t)

        # ---- 3. hotness / recency ---------------------------------------
        hot = jnp.where(alive, cfg.hot_decay * hot + accesses, 0.0)
        last_access = jnp.where(new | (accesses > 0), t, state.last_access)

        # ---- 4. contention ----------------------------------------------
        fast_usage = by_tenant((tier == TIER_FAST).astype(jnp.int32), owner)
        fast_free = n_fast - fast_usage.sum()
        cand_pre = (tier == TIER_SLOW) & (hot >= cfg.promo_hot_threshold) & alive
        demand_t = jnp.minimum(by_tenant(cand_pre.astype(jnp.int32), owner),
                               k_max)
        promo_demand = jnp.minimum(demand_t.sum(), k_max)
        contended = fast_free < wmark + promo_demand

        # ---- 5. demotion -------------------------------------------------
        sync_quota = jnp.zeros((T,), jnp.int32)
        if mode == "equilibria":
            d_scan = P.eq1_demotion_scan(fast_usage, fast_usage, pol, contended)
            if not cfg.enable_protection:
                d_scan = jnp.where(contended, fast_usage.astype(jnp.float32),
                                   0.0)
            demand_other = jnp.minimum(promo_demand - demand_t, k_max)
            needed_t = jnp.maximum(wmark + demand_other - fast_free, 0)
            total_scan = jnp.maximum(d_scan.sum(), 1.0)
            share = jnp.ceil(d_scan * jnp.minimum(
                needed_t.astype(jnp.float32) / total_scan, 1.0)).astype(jnp.int32)
            if cfg.enable_upper_bound:
                sync_quota = P.upper_bound_demotion(fast_usage, pol)
            quota = jnp.minimum(share + sync_quota, k_max)
        elif mode == "tpp":
            needed = jnp.maximum(2 * wmark - fast_free, 0)
            quota = jnp.minimum(needed, k_max * T)
        elif mode == "memtis":
            sync_quota = P.upper_bound_demotion(fast_usage, pol)
            quota = jnp.minimum(sync_quota, k_max)
        else:  # static
            quota = jnp.zeros((T,), jnp.int32)

        age = (t - last_access).astype(jnp.float32)
        cold_score = age * 1e3 - hot
        fast_mask = tier == TIER_FAST
        if mode == "tpp":
            demoted = SEL.select_global(cold_score, fast_mask, quota,
                                        k_max * T)
        elif mode == "static":
            demoted = jnp.zeros((L,), bool)
        else:
            demoted = select_pt(cold_score, owner, fast_mask, quota)
        demo_t = by_tenant(demoted.astype(jnp.int32), owner)

        thrash_new = by_tenant(P.thrash_hits(
            table0, page_ids, demoted, t, cfg).astype(jnp.int32), owner)
        stats = OS.record_fast_exits(stats, demoted, owner_c, t)
        ring = OT.ring_record(ring, demoted, page_ids, owner_c, hot,
                              OT.DIR_DEMOTE, t)
        tier = jnp.where(demoted, TIER_SLOW, tier)
        fast_usage = fast_usage - demo_t
        fast_free = n_fast - fast_usage.sum()

        # ---- 6. promotion ------------------------------------------------
        cand = ((tier == TIER_SLOW) & (hot >= cfg.promo_hot_threshold)
                & alive & ~demoted)
        cand_t = by_tenant(cand.astype(jnp.int32), owner)
        throttled = jnp.zeros((T,), bool)
        if mode == "equilibria":
            p_base = jnp.full((T,), float(cfg.p_base), jnp.float32)
            if cfg.enable_promo_throttle:
                p_scan, throttled = P.eq2_promotion_scan(p_base, fast_usage,
                                                         pol, contended, cfg)
            else:
                p_scan = p_base
            p_scan = p_scan * promo_scale0
            p_quota = jnp.minimum(p_scan.astype(jnp.int32), k_max)
        elif mode in ("tpp", "memtis"):
            p_quota = jnp.full((T,), cfg.p_base, jnp.int32)
        else:
            p_quota = jnp.zeros((T,), jnp.int32)

        p_quota = jnp.minimum(p_quota, jnp.minimum(cand_t, k_max))
        headroom = jnp.maximum(fast_free - wmark, 0)
        total = p_quota.sum()
        scale = jnp.where(total > headroom,
                          headroom.astype(jnp.float32) / jnp.maximum(total, 1),
                          1.0)
        p_quota = jnp.floor(p_quota.astype(jnp.float32) * scale).astype(jnp.int32)

        if mode == "tpp":
            promoted = SEL.select_global(hot, cand, p_quota.sum(), k_max * T)
        elif mode == "static":
            promoted = jnp.zeros((L,), bool)
        else:
            promoted = select_pt(hot, owner, cand, p_quota)
        promo_t = by_tenant(promoted.astype(jnp.int32), owner)
        tier = jnp.where(promoted, TIER_FAST, tier)
        table = P.thrash_record_promotions(table0, page_ids, promoted, t)
        stats = OS.record_fast_entries(stats, promoted, t)
        ring = OT.ring_record(ring, promoted, page_ids, owner_c, hot,
                              OT.DIR_PROMOTE, t)

        # ---- 6b. synchronous upper-bound demotion -----------------------
        sync2_t = jnp.zeros((T,), jnp.int32)
        if mode in ("equilibria", "memtis") and cfg.enable_upper_bound:
            fast_usage2 = by_tenant((tier == TIER_FAST).astype(jnp.int32),
                                    owner)
            over2 = jnp.where(pol.upper_bound > 0,
                              jnp.maximum(fast_usage2 - pol.upper_bound, 0), 0)
            over2 = jnp.minimum(over2, k_max)
            cold2 = (t - last_access).astype(jnp.float32) * 1e3 - hot
            sync_dem = select_pt(cold2, owner, tier == TIER_FAST, over2)
            thrash_new = thrash_new + by_tenant(P.thrash_hits(
                table, page_ids, sync_dem, t, cfg).astype(jnp.int32), owner)
            stats = OS.record_fast_exits(stats, sync_dem, owner_c, t)
            ring = OT.ring_record(ring, sync_dem, page_ids, owner_c, hot,
                                  OT.DIR_DEMOTE, t)
            tier = jnp.where(sync_dem, TIER_SLOW, tier)
            sync2_t = by_tenant(sync_dem.astype(jnp.int32), owner)
            demo_t = demo_t + sync2_t

        # ---- 7. counters -------------------------------------------------
        c = state.counters
        counters = Counters(
            promotions=c.promotions + promo_t,
            demotions=c.demotions + demo_t,
            attempted_promotions=c.attempted_promotions + cand_t,
            reclaims=c.reclaims + freed_t,
            allocations=c.allocations + alloc_t,
            thrash_events=c.thrash_events + thrash_new,
            sync_demotions=c.sync_demotions
            + jnp.minimum(sync_quota, demo_t) + sync2_t,
        )
        fast_usage = by_tenant((tier == TIER_FAST).astype(jnp.int32), owner)
        slow_usage = by_tenant((tier == TIER_SLOW).astype(jnp.int32), owner)

        # ---- 7b. observability ------------------------------------------
        demo_att = (jnp.broadcast_to((quota + T - 1) // T, (T,))
                    if quota.ndim == 0 else quota)
        below_prot = OS.below_protection(fast_usage, slow_usage,
                                         pol.lower_protection)
        stats = OS.update_tick(
            stats, promo_attempts=cand_t, promo_success=promo_t,
            demo_attempts=jnp.minimum(demo_att, k_max) + sync2_t,
            demo_success=demo_t,
            thrash_new=thrash_new, contended=contended, throttled=throttled,
            below_protection=below_prot, decay=cfg.obs_window_decay)

        new_state = TierState(
            tier=tier.astype(jnp.int8), hot=hot, last_access=last_access,
            owner=owner,
            counters=counters, promo_scale=promo_scale0,
            thrash_prev=thrash_prev0, usage_prev=usage_prev0,
            freed_since=freed_since0, steady=steady0,
            mitigated_prev=mitigated0,
            table=table, stats=stats, ring=ring, t=t + 1)

        # ---- 8. periodic controller -------------------------------------
        def run_ctrl(s: TierState) -> TierState:
            out = P.thrash_controller(s, fast_usage + slow_usage, cfg)
            return s._replace(promo_scale=out.promo_scale, steady=out.steady,
                              table=out.table, thrash_prev=out.thrash_prev,
                              usage_prev=out.usage_prev,
                              freed_since=out.freed_since,
                              mitigated_prev=out.mitigated_prev)

        new_state = jax.lax.cond(
            (t + 1) % cfg.controller_period == 0, run_ctrl, lambda s: s,
            new_state)

        # ---- 9. perf model ----------------------------------------------
        a_fast = by_tenant(accesses * (tier == TIER_FAST), owner)
        a_slow = by_tenant(accesses * (tier == TIER_SLOW), owner)
        a_tot = a_fast + a_slow
        migrations = (promo_t + demo_t).sum().astype(jnp.float32)
        lat = jnp.where(
            a_tot > 0,
            (a_fast * cfg.lat_fast + a_slow * cfg.lat_slow)
            / jnp.maximum(a_tot, 1e-9),
            cfg.lat_fast) + migrations * cfg.migration_cost
        thru = jnp.where(a_tot > 0, a_tot / lat, 0.0)

        out = TickOutput(
            fast_usage=fast_usage, slow_usage=slow_usage,
            promotions=promo_t, demotions=demo_t,
            throughput=thru, latency=lat, promo_scale=new_state.promo_scale,
            thrash_events=counters.thrash_events,
            fast_free=n_fast - fast_usage.sum(),
            attempted_promotions=cand_t,
            pool_free=(owner == FREE).sum())
        return new_state, out

    return tick


def run_churn_engine(cfg: TieringConfig, schedule: ChurnSchedule,
                     mode: str = "equilibria", k_max: int = 256,
                     n_pages: int = None) -> Tuple[TierState, TickOutput]:
    """Run a full churn schedule (scan over ticks) from an all-free pool.

    The physical pool defaults to the configured capacity
    ``n_fast_pages + n_slow_pages``; grants beyond it are truncated in slot
    priority order (admission control under memory pressure).
    """
    L = n_pages if n_pages is not None else cfg.n_fast_pages + cfg.n_slow_pages
    tick = make_churn_tick(cfg, L, mode=mode, k_max=k_max)
    state = init_state(cfg, L)          # owner=None: everything in the pool

    @jax.jit
    def run(state, rates, want):
        return jax.lax.scan(tick, state, (rates, want))

    return run(state, jnp.asarray(schedule.rates, jnp.float32),
               jnp.asarray(schedule.want, jnp.int32))
