"""Dynamic-ownership tiering engine: tenant lifecycle as in-graph events —
a thin adapter over the unified tick core (core/tick.py).

The static engine (core/engine.py) freezes the owner vector at trace time,
so every scenario it can express is a fixed tenant roster. Equilibria's
target deployment is the opposite: containers are stacked, arrive, resize
and depart continuously (the serverless-CXL churn regime is exactly where
tiering policies break). The dynamic ownership provider
(``core.tick.dynamic_ownership``) makes ownership *state*: the
``TierState.owner`` vector ([L] int32, ``n_tenants`` = FREE sentinel) is
mutated inside the compiled tick by a per-tick schedule

    want  [T]    int32 — target footprint of each tenant slot (0 = departed)
    rates [T, S] f32   — access rate of the tenant's k-th page (tenant-local
                         address space; S = max slot footprint)

so one jaxpr handles an arbitrary churn schedule — the trace is constant in
the number of lifecycle events (they are data, not structure). Each tick the
provider's lifecycle step runs before the shared pipeline:

  1. *reclaim* (departure / shrink): tenants over target release their
     coldest pages — demote-and-free; stale thrash-table entries for
     reclaimed pages are invalidated.
  2. *grant* (arrival / grow): tenants under target receive free pages via
     a rank-interval partition of the pool (``select.pool_grant``; lower
     slot ids win admission when the pool is over-subscribed).
  3. *slot reuse reset*: a fresh arrival in a previously used slot starts
     with clean controller state (promo_scale=1, thrash window zeroed).
  4. *policy re-partition*: effective protections/bounds recomputed from
     the active mask every tick (``policy.repartition_policy``).
  5. per-page access rates from the tenant-local schedule: page l's rate is
     ``rates[owner[l], rank(l)]`` — the tenant's address space stays stable
     while membership is constant and compacts on shrink.

then steps 2–9 (allocation, hotness, Eq.1/Eq.2-regulated migration, thrash
mitigation, §IV-C obs, perf model) are the SAME code the static engine
runs — ``tests/test_tick_unification.py`` pins that a constant roster
produces identical trajectories through both adapters.

Conservation invariants (pinned by tests/test_churn.py property suite):
every page is owned by at most one tenant (structural: owner is a single
int per page), departed tenants own zero pages, and
``fast + slow + free == L`` every tick.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TieringConfig
from repro.core.engine import MODES, TickOutput  # noqa: F401  (re-export)
from repro.core.state import TierState, init_state
from repro.core.tick import dynamic_ownership, make_tick_core

__all__ = ["ChurnSchedule", "churn_events", "make_churn_tick",
           "run_churn_engine", "MODES", "TickOutput"]


class ChurnSchedule(NamedTuple):
    """Host-side (numpy) lifecycle schedule for a churn run."""
    want: np.ndarray      # [ticks, T] int32 target footprints (0 = departed)
    rates: np.ndarray     # [ticks, T, S] f32 tenant-local access rates


def churn_events(want: np.ndarray) -> Tuple[int, int]:
    """(arrivals, departures) across a [ticks, T] schedule: transitions of
    the active mask, counting initially-active slots as arrivals."""
    active = np.asarray(want) > 0
    prev = np.concatenate([np.zeros((1, active.shape[1]), bool), active[:-1]])
    arrivals = int((active & ~prev).sum())
    departures = int((~active & prev).sum())
    return arrivals, departures


def make_churn_tick(cfg: TieringConfig, n_pages: int, mode: str = "equilibria",
                    k_max: int = 256, detector=None, attrib=None,
                    hotness=None, impl: str = "batched"):
    """Build the jittable dynamic-ownership tick.

    n_pages: size of the physical page pool (fast + slow capacity). Inputs
    per tick: ``(rates [T, S] f32, want [T] int32)``. ``detector``: optional
    ``obs.streaming.DetectorSpec`` (state must then carry a DetectorState).
    ``attrib``: optional ``obs.attribution.AttributionSpec`` (state must
    then carry an AttributionState). ``hotness``: optional hotness-provider
    spec (core/hotness.py); stateful providers pair with
    ``init_state(..., hotness=...)``. ``impl``: "batched" (default; "jnp"
    alias) or "pallas"/"pallas_interpret" — route the selection step
    through the segmented top-k kernel (``select.pallas_dynamic_strategy``).
    """
    provider = dynamic_ownership(cfg, n_pages, k_max=k_max, impl=impl)
    return make_tick_core(cfg, provider, mode=mode, k_max=k_max,
                          detector=detector, attrib=attrib, hotness=hotness)


def run_churn_engine(cfg: TieringConfig, schedule: ChurnSchedule,
                     mode: str = "equilibria", k_max: int = 256,
                     n_pages: Optional[int] = None, detector=None,
                     attrib=None, hotness=None,
                     impl: str = "batched") -> Tuple[TierState, TickOutput]:
    """Run a full churn schedule (scan over ticks) from an all-free pool.

    The physical pool defaults to the configured capacity
    ``n_fast_pages + n_slow_pages``; grants beyond it are truncated in slot
    priority order (admission control under memory pressure).
    """
    L = n_pages if n_pages is not None else cfg.n_fast_pages + cfg.n_slow_pages
    tick = make_churn_tick(cfg, L, mode=mode, k_max=k_max, detector=detector,
                           attrib=attrib, hotness=hotness, impl=impl)
    state = init_state(cfg, L, detector=detector,  # owner=None: all pooled
                       attrib=attrib, hotness=hotness)

    @jax.jit
    def run(state, rates, want):
        return jax.lax.scan(tick, state, (rates, want))

    return run(state, jnp.asarray(schedule.rates, jnp.float32),
               jnp.asarray(schedule.want, jnp.int32))
