"""The tiering engine: one jittable `tick` implementing allocation, hotness
tracking, regulated demotion/promotion, thrashing mitigation and the perf
model. Modes select the policy:

  equilibria — the paper (Eq.1 + Eq.2 + upper bound + thrash mitigation)
  tpp        — baseline Linux/TPP: watermark-driven *global-LRU* demotion,
               hint-fault-style *global* promotion, no fairness
  memtis     — MEMTIS-like: upper limit only (allocation-time enforcement)
  static     — tier fixed at allocation, no migration

Page ownership is static (tenant i owns a fixed logical range); liveness and
tier are dynamic.

The tick is tenant-batched (core/select.py): per-tenant selection is one
batched padded-row top_k (contiguous layouts) or one composite-key sort
(arbitrary layouts), per-tenant reductions are cumsum/boundary-gathers or
scatter-adds, and migration accounting runs over the compact [T, k]
candidate stream — so trace time, jaxpr size and kernel count are all
constant in T and one compiled tick serves any tenant count (T=64+,
L=256k+ supported). ``impl="unrolled"`` rebuilds the seed engine
(per-tenant top_k loops + [T, L] one-hot matmuls) for equivalence tests
and as the scale benchmark's baseline.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TieringConfig
from repro.core import policy as P
from repro.core import select as SEL
from repro.core.state import (TIER_FAST, TIER_NONE, TIER_SLOW, Counters,
                              TenantPolicy, TierState, init_state, make_policy)
from repro.obs import stats as OS
from repro.obs import trace as OT

MODES = ("equilibria", "tpp", "memtis", "static")
IMPLS = ("batched", "unrolled")


class TickOutput(NamedTuple):
    fast_usage: jax.Array      # [T] pages
    slow_usage: jax.Array      # [T]
    promotions: jax.Array      # [T] this tick
    demotions: jax.Array       # [T]
    throughput: jax.Array      # [T] accesses per latency-unit (1.0 = all-fast)
    latency: jax.Array         # [T] mean access latency (units of lat_fast)
    promo_scale: jax.Array     # [T]
    thrash_events: jax.Array   # [T] cumulative
    fast_free: jax.Array       # scalar
    attempted_promotions: jax.Array  # [T] candidates this tick (obs)
    pool_free: jax.Array       # scalar: unallocated pages (churn: free pool)


def make_tick(cfg: TieringConfig, owner: np.ndarray, mode: str = "equilibria",
              k_max: int = 256, impl: str = "batched"):
    """Build the jittable tick. owner: [L] int (static tenant of each page).

    impl: "batched" (segmented selection + scatter-add reductions, trace-time
    constant in T) or "unrolled" (the seed engine: per-tenant top_k loops and
    [T, L] one-hot matmuls — kept for equivalence tests and benchmarks).
    """
    assert mode in MODES, mode
    assert impl in IMPLS, impl
    T = cfg.n_tenants
    L = owner.shape[0]
    owner_j = jnp.asarray(owner, jnp.int32)
    n_fast = cfg.n_fast_pages
    wmark = max(int(np.ceil(n_fast * cfg.watermark_free)), 1)
    pol: TenantPolicy = make_policy(cfg)

    if impl == "unrolled":
        owner_oh = jnp.asarray(
            (owner[None, :] == np.arange(T)[:, None]).astype(np.float32))
        owner_oh_i = owner_oh.astype(jnp.int32)

        def by_tenant(x: jax.Array) -> jax.Array:
            m = owner_oh if jnp.issubdtype(x.dtype, jnp.floating) else owner_oh_i
            return m @ x

        def select_pt(score, active, quotas):
            mask = SEL.select_top_quota_unrolled(
                score, owner_oh.astype(bool) & active[None], quotas, k_max)
            return SEL.Selection(mask, None, None, None)

        def alloc_ranks(new):
            return SEL.allocation_ranks_unrolled(new, owner_j, T)
    elif (layout := SEL.plan_layout(owner, T)) is not None:
        # contiguous ownership (build_trace's layout): padded-row top_k and
        # cumsum/boundary-gather reductions — the fastest path by far
        def by_tenant(x: jax.Array) -> jax.Array:
            return SEL.by_tenant_contiguous(x, layout)

        def select_pt(score, active, quotas):
            return SEL.select_top_quota_rows(score, active, quotas, layout,
                                             k_max)

        def alloc_ranks(new):
            return SEL.allocation_ranks_contiguous(new, layout)
    else:
        # arbitrary owner permutation: composite-sort ranks + scatter-adds
        def by_tenant(x: jax.Array) -> jax.Array:
            return SEL.by_tenant_scatter(x, owner_j, T)

        def select_pt(score, active, quotas):
            mask = SEL.select_top_quota(score, owner_j, active, quotas, T,
                                        k_max)
            return SEL.Selection(mask, None, None, None)

        def alloc_ranks(new):
            return SEL.allocation_ranks(new, owner_j, T)

    def tick(state: TierState, inputs) -> Tuple[TierState, TickOutput]:
        accesses, alive = inputs
        t = state.t
        tier = state.tier.astype(jnp.int32)
        page_ids = jnp.arange(L, dtype=jnp.int32)

        # Migration accounting (thrash table, residency histogram, event
        # ring) runs over the selection's compact [T, k] candidate stream
        # when available (contiguous batched path) — scatters over T*k lanes
        # instead of L — and falls back to the full [L] masks otherwise.
        def sel_counts(sel: SEL.Selection) -> jax.Array:
            if sel.counts is not None:
                return sel.counts
            return by_tenant(sel.mask.astype(jnp.int32))

        def sel_tenants(sel: SEL.Selection) -> jax.Array:
            return jnp.broadcast_to(
                jnp.arange(T, dtype=jnp.int32)[:, None], sel.take.shape)

        def sel_thrash(tbl, sel: SEL.Selection) -> jax.Array:
            if sel.pages is None:
                return by_tenant(P.thrash_hits(
                    tbl, page_ids, sel.mask, t, cfg).astype(jnp.int32))
            hits = P.thrash_hits(tbl, sel.pages, sel.take, t, cfg)
            return hits.sum(axis=1).astype(jnp.int32)

        def sel_record_promos(tbl, sel: SEL.Selection):
            if sel.pages is None:
                return P.thrash_record_promotions(tbl, page_ids, sel.mask, t)
            return P.thrash_record_promotions(tbl, sel.pages, sel.take, t)

        def sel_exits(st, sel: SEL.Selection):
            if sel.pages is None:
                return OS.record_fast_exits(st, sel.mask, owner_j, t)
            return OS.record_fast_exits_at(st, sel.pages, sel.take,
                                           sel_tenants(sel), t)

        def sel_ring(rg, sel: SEL.Selection, hotv, direction):
            if sel.pages is None:
                return OT.ring_record(rg, sel.mask, page_ids, owner_j, hotv,
                                      direction, t)
            return OT.ring_record(rg, sel.take, sel.pages, sel_tenants(sel),
                                  hotv[sel.pages], direction, t)

        # ---- 1. free dead pages -------------------------------------------
        died = (tier != TIER_NONE) & ~alive
        freed_t = by_tenant(died.astype(jnp.int32))
        # fast-resident pages that die end their residency here (obs)
        stats = OS.record_fast_exits(state.stats, died & (tier == TIER_FAST),
                                     owner_j, t)
        tier = jnp.where(died, TIER_NONE, tier)

        # ---- 2. allocate new pages ----------------------------------------
        new = alive & (tier == TIER_NONE)
        fast_usage = by_tenant((tier == TIER_FAST).astype(jnp.int32))
        fast_free = n_fast - fast_usage.sum()
        # per-tenant upper bound gating of *fast* placement
        if mode in ("equilibria", "memtis") and cfg.enable_upper_bound:
            ranks = alloc_ranks(new)
            bound = pol.upper_bound[owner_j]
            under_bound = (bound == 0) | (fast_usage[owner_j] + ranks < bound)
        else:
            under_bound = jnp.ones((L,), bool)
        elig = new & under_bound
        grank = SEL.masked_rank(elig)
        go_fast = elig & (grank < jnp.maximum(fast_free - wmark, 0))
        tier = jnp.where(go_fast, TIER_FAST, jnp.where(new, TIER_SLOW, tier))
        alloc_t = by_tenant(new.astype(jnp.int32))
        stats = OS.record_fast_entries(stats, go_fast, t)

        # ---- 3. hotness / recency -----------------------------------------
        hot = jnp.where(alive, cfg.hot_decay * state.hot + accesses, 0.0)
        last_access = jnp.where(new | (accesses > 0), t, state.last_access)

        # ---- 4. contention ------------------------------------------------
        # Local memory is contended when free space cannot absorb both the
        # watermark and the pending promotion demand (kswapd-style: promotion
        # pressure drives background demotion, §IV-D).
        fast_usage = by_tenant((tier == TIER_FAST).astype(jnp.int32))
        fast_free = n_fast - fast_usage.sum()
        cand_pre = (tier == TIER_SLOW) & (hot >= cfg.promo_hot_threshold) & alive
        demand_t = jnp.minimum(by_tenant(cand_pre.astype(jnp.int32)), k_max)
        promo_demand = jnp.minimum(demand_t.sum(), k_max)
        contended = fast_free < wmark + promo_demand

        # ---- 5. demotion ---------------------------------------------------
        sync_quota = jnp.zeros((T,), jnp.int32)
        if mode == "equilibria":
            d_scan = P.eq1_demotion_scan(fast_usage, fast_usage, pol, contended)
            if not cfg.enable_protection:
                # ablation: proportional pressure without protection
                d_scan = jnp.where(contended, fast_usage.astype(jnp.float32), 0.0)
            # Eq.1 sets each tenant's *share* of reclaim work; the total is
            # kswapd-style demand-driven: free enough for the watermark plus
            # pending promotions, no more (work-conserving donation, §V-B3).
            # A tenant's OWN promotion demand never drives its own demotion
            # (that would be pure churn); only neighbors' demand evicts it.
            demand_other = jnp.minimum(promo_demand - demand_t, k_max)
            needed_t = jnp.maximum(wmark + demand_other - fast_free, 0)
            total_scan = jnp.maximum(d_scan.sum(), 1.0)
            share = jnp.ceil(d_scan * jnp.minimum(
                needed_t.astype(jnp.float32) / total_scan, 1.0)).astype(jnp.int32)
            if cfg.enable_upper_bound:
                sync_quota = P.upper_bound_demotion(fast_usage, pol)
            quota = jnp.minimum(share + sync_quota, k_max)
        elif mode == "tpp":
            needed = jnp.maximum(2 * wmark - fast_free, 0)
            quota = jnp.minimum(needed, k_max * T)  # global
        elif mode == "memtis":
            sync_quota = P.upper_bound_demotion(fast_usage, pol)
            quota = jnp.minimum(sync_quota, k_max)
        else:  # static
            quota = jnp.zeros((T,), jnp.int32)

        age = (t - last_access).astype(jnp.float32)
        cold_score = age * 1e3 - hot          # LRU order, hotness tiebreak
        fast_mask = tier == TIER_FAST
        if mode == "tpp":
            dsel = SEL.Selection(
                SEL.select_global(cold_score, fast_mask, quota, k_max * T),
                None, None, None)
        elif mode == "static":
            dsel = SEL.Selection(jnp.zeros((L,), bool), None, None, None)
        else:
            dsel = select_pt(cold_score, fast_mask, quota)
        demoted = dsel.mask
        demo_t = sel_counts(dsel)

        # thrash detection on demotions (§IV-F)
        thrash_new = sel_thrash(state.table, dsel)
        stats = sel_exits(stats, dsel)
        ring = sel_ring(state.ring, dsel, hot, OT.DIR_DEMOTE)
        tier = jnp.where(demoted, TIER_SLOW, tier)
        fast_usage = fast_usage - demo_t
        fast_free = n_fast - fast_usage.sum()

        # ---- 6. promotion ---------------------------------------------------
        # just-demoted pages are not promotion candidates this tick
        cand = (tier == TIER_SLOW) & (hot >= cfg.promo_hot_threshold) & alive & ~demoted
        cand_t = by_tenant(cand.astype(jnp.int32))
        throttled = jnp.zeros((T,), bool)
        if mode == "equilibria":
            p_base = jnp.full((T,), float(cfg.p_base), jnp.float32)
            if cfg.enable_promo_throttle:
                p_scan, throttled = P.eq2_promotion_scan(p_base, fast_usage,
                                                         pol, contended, cfg)
            else:
                p_scan = p_base
            p_scan = p_scan * state.promo_scale        # thrash mitigation
            p_quota = jnp.minimum(p_scan.astype(jnp.int32), k_max)
        elif mode in ("tpp", "memtis"):
            p_quota = jnp.full((T,), cfg.p_base, jnp.int32)  # unregulated
        else:
            p_quota = jnp.zeros((T,), jnp.int32)

        # never overfill: cap total promotions by free fast capacity.
        # NOTE: promotions may transiently exceed a tenant's upper bound —
        # the allocating thread then demotes synchronously in the same tick
        # (paper §IV-D); that promote->sync-demote cycle is exactly the
        # thrashing signature §IV-F detects.
        p_quota = jnp.minimum(p_quota, jnp.minimum(cand_t, k_max))
        headroom = jnp.maximum(fast_free - wmark, 0)
        total = p_quota.sum()
        scale = jnp.where(total > headroom,
                          headroom.astype(jnp.float32) / jnp.maximum(total, 1),
                          1.0)
        p_quota = jnp.floor(p_quota.astype(jnp.float32) * scale).astype(jnp.int32)

        if mode == "tpp":
            psel = SEL.Selection(
                SEL.select_global(hot, cand, p_quota.sum(), k_max * T),
                None, None, None)
        elif mode == "static":
            psel = SEL.Selection(jnp.zeros((L,), bool), None, None, None)
        else:
            psel = select_pt(hot, cand, p_quota)
        promoted = psel.mask
        promo_t = sel_counts(psel)
        tier = jnp.where(promoted, TIER_FAST, tier)
        table = sel_record_promos(state.table, psel)
        stats = OS.record_fast_entries(stats, promoted, t)
        ring = sel_ring(ring, psel, hot, OT.DIR_PROMOTE)

        # ---- 6b. synchronous upper-bound demotion (allocation path, §IV-D):
        # promotions that pushed a tenant past its bound are shed in the same
        # tick by the "allocating thread" — these demotions hit the thrash
        # table immediately when they evict recently-promoted pages.
        sync2_t = jnp.zeros((T,), jnp.int32)
        if mode in ("equilibria", "memtis") and cfg.enable_upper_bound:
            fast_usage2 = by_tenant((tier == TIER_FAST).astype(jnp.int32))
            over2 = jnp.where(pol.upper_bound > 0,
                              jnp.maximum(fast_usage2 - pol.upper_bound, 0), 0)
            over2 = jnp.minimum(over2, k_max)
            age2 = (t - last_access).astype(jnp.float32)
            cold2 = age2 * 1e3 - hot
            ssel = select_pt(cold2, tier == TIER_FAST, over2)
            sync_dem = ssel.mask
            thr2 = sel_thrash(table, ssel)
            thrash_new = thrash_new + thr2
            stats = sel_exits(stats, ssel)
            ring = sel_ring(ring, ssel, hot, OT.DIR_DEMOTE)
            tier = jnp.where(sync_dem, TIER_SLOW, tier)
            sync2_t = sel_counts(ssel)
            demo_t = demo_t + sync2_t

        # ---- 7. counters ----------------------------------------------------
        c = state.counters
        counters = Counters(
            promotions=c.promotions + promo_t,
            demotions=c.demotions + demo_t,
            attempted_promotions=c.attempted_promotions + cand_t,
            reclaims=c.reclaims + freed_t,
            allocations=c.allocations + alloc_t,
            thrash_events=c.thrash_events + thrash_new,
            sync_demotions=c.sync_demotions
            + jnp.minimum(sync_quota, demo_t) + sync2_t,
        )
        fast_usage = by_tenant((tier == TIER_FAST).astype(jnp.int32))
        slow_usage = by_tenant((tier == TIER_SLOW).astype(jnp.int32))

        # ---- 7b. observability (obs/, §IV-C) --------------------------------
        # tpp's quota is one global scan budget; split it evenly so
        # demo_success_ratio stays comparable across modes
        demo_att = (jnp.broadcast_to((quota + T - 1) // T, (T,))
                    if quota.ndim == 0 else quota)
        below_prot = OS.below_protection(fast_usage, slow_usage,
                                         pol.lower_protection)
        # sync upper-bound demotions (6b) bypass the step-5 quota; count them
        # on both sides so demo_success_ratio stays <= 1
        stats = OS.update_tick(
            stats, promo_attempts=cand_t, promo_success=promo_t,
            demo_attempts=jnp.minimum(demo_att, k_max) + sync2_t,
            demo_success=demo_t,
            thrash_new=thrash_new, contended=contended, throttled=throttled,
            below_protection=below_prot, decay=cfg.obs_window_decay)

        new_state = TierState(
            tier=tier.astype(jnp.int8), hot=hot, last_access=last_access,
            owner=state.owner,
            counters=counters, promo_scale=state.promo_scale,
            thrash_prev=state.thrash_prev, usage_prev=state.usage_prev,
            freed_since=state.freed_since + freed_t, steady=state.steady,
            mitigated_prev=state.mitigated_prev,
            table=table, stats=stats, ring=ring, t=t + 1)

        # ---- 8. periodic controller (§IV-F) ---------------------------------
        def run_ctrl(s: TierState) -> TierState:
            out = P.thrash_controller(s, fast_usage + slow_usage, cfg)
            return s._replace(promo_scale=out.promo_scale, steady=out.steady,
                              table=out.table, thrash_prev=out.thrash_prev,
                              usage_prev=out.usage_prev,
                              freed_since=out.freed_since,
                              mitigated_prev=out.mitigated_prev)

        new_state = jax.lax.cond(
            (t + 1) % cfg.controller_period == 0, run_ctrl, lambda s: s,
            new_state)

        # ---- 9. perf model ---------------------------------------------------
        a_fast = by_tenant(accesses * (tier == TIER_FAST))
        a_slow = by_tenant(accesses * (tier == TIER_SLOW))
        a_tot = a_fast + a_slow
        migrations = (promo_t + demo_t).sum().astype(jnp.float32)
        lat = jnp.where(
            a_tot > 0,
            (a_fast * cfg.lat_fast + a_slow * cfg.lat_slow) / jnp.maximum(a_tot, 1e-9),
            cfg.lat_fast) + migrations * cfg.migration_cost
        thru = jnp.where(a_tot > 0, a_tot / lat, 0.0)

        out = TickOutput(
            fast_usage=fast_usage, slow_usage=slow_usage,
            promotions=promo_t, demotions=demo_t,
            throughput=thru, latency=lat, promo_scale=new_state.promo_scale,
            thrash_events=counters.thrash_events,
            fast_free=n_fast - fast_usage.sum(),
            attempted_promotions=cand_t,
            pool_free=(tier == TIER_NONE).sum())
        return new_state, out

    return tick


def run_engine(cfg: TieringConfig, owner: np.ndarray, accesses: np.ndarray,
               alive: np.ndarray, mode: str = "equilibria",
               k_max: int = 256, impl: str = "batched") -> TickOutput:
    """Run the full trace (scan over ticks). accesses/alive: [ticks, L]."""
    tick = make_tick(cfg, owner, mode, k_max, impl=impl)
    state = init_state(cfg, owner.shape[0], owner=owner)

    @jax.jit
    def run(state, accesses, alive):
        return jax.lax.scan(tick, state, (accesses, alive))

    final, outs = run(state, jnp.asarray(accesses, jnp.float32),
                      jnp.asarray(alive, bool))
    return final, outs
