"""The static-ownership tiering engine: a thin adapter over the unified
tick core (core/tick.py) for fixed tenant rosters.

Modes select the policy:

  equilibria — the paper (Eq.1 + Eq.2 + upper bound + thrash mitigation)
  tpp        — baseline Linux/TPP: watermark-driven *global-LRU* demotion,
               hint-fault-style *global* promotion, no fairness
  memtis     — MEMTIS-like: upper limit only (allocation-time enforcement)
  static     — tier fixed at allocation, no migration

Page ownership is static (tenant i owns a fixed logical range); liveness and
tier are dynamic. The pipeline itself — allocation, hotness, regulated
demotion/promotion, thrash mitigation, §IV-C obs and the perf model — lives
in ``core.tick.make_tick_core``; this module only binds the static
ownership provider (``core.tick.static_ownership``), which selects the
fastest selection strategy for the trace-constant owner vector:

  * contiguous layouts (what ``build_trace`` produces): padded-row batched
    top_k + cumsum/boundary-gather reductions — trace time, jaxpr size and
    kernel count constant in T (T=64+, L=256k+ supported)
  * arbitrary permutations: composite-key sort + scatter-add reductions
  * ``impl="unrolled"``: the seed engine (per-tenant top_k loops + [T, L]
    one-hot matmuls), kept for the equivalence suite and as the scale
    benchmark's baseline
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TieringConfig
from repro.core.state import TierState, init_state
from repro.core.tick import (MODES, TickOutput, make_tick_core,
                             static_ownership)

IMPLS = ("batched", "unrolled", "jnp", "pallas", "pallas_interpret",
         "pallas_ref")

__all__ = ["MODES", "IMPLS", "TickOutput", "make_tick", "run_engine"]


def make_tick(cfg: TieringConfig, owner: np.ndarray, mode: str = "equilibria",
              k_max: int = 256, impl: str = "batched", detector=None,
              attrib=None, hotness=None):
    """Build the jittable tick. owner: [L] int (static tenant of each page).

    impl: "batched" (segmented selection + scatter-add reductions, trace-time
    constant in T; "jnp" is an alias), "unrolled" (the seed engine:
    per-tenant top_k loops and [T, L] one-hot matmuls — kept for equivalence
    tests and benchmarks), or "pallas"/"pallas_interpret"/"pallas_ref"
    (the selection core runs through the Pallas kernels in
    ``kernels/select`` + ``kernels/migrate``; interpret mode is bit-exact
    with "batched", "pallas_ref" compiles the kernels' jnp oracles — the
    kernel algorithm on backends without a Mosaic lowering).
    detector: optional ``obs.streaming.DetectorSpec`` — the state must then
    carry a matching DetectorState (``init_state(..., detector=...)``).
    attrib: optional ``obs.attribution.AttributionSpec`` — likewise paired
    with ``init_state(..., attrib=...)``.
    hotness: optional hotness-provider spec (core/hotness.py) — a name
    ("exact"/"sampled"/"sketch"/"neomem") or spec NamedTuple; stateful
    providers pair with ``init_state(..., hotness=...)``.
    """
    assert impl in IMPLS, impl
    provider = static_ownership(cfg, owner, k_max=k_max, impl=impl)
    return make_tick_core(cfg, provider, mode=mode, k_max=k_max,
                          detector=detector, attrib=attrib, hotness=hotness)


def run_engine(cfg: TieringConfig, owner: np.ndarray, accesses: np.ndarray,
               alive: np.ndarray, mode: str = "equilibria",
               k_max: int = 256, impl: str = "batched", detector=None,
               attrib=None, hotness=None) -> Tuple[TierState, TickOutput]:
    """Run the full trace (scan over ticks). accesses/alive: [ticks, L]."""
    tick = make_tick(cfg, owner, mode, k_max, impl=impl, detector=detector,
                     attrib=attrib, hotness=hotness)
    state = init_state(cfg, owner.shape[0], owner=owner, detector=detector,
                       attrib=attrib, hotness=hotness)

    @jax.jit
    def run(state, accesses, alive):
        return jax.lax.scan(tick, state, (accesses, alive))

    final, outs = run(state, jnp.asarray(accesses, jnp.float32),
                      jnp.asarray(alive, bool))
    return final, outs
