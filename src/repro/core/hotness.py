"""Hotness providers: how the unified tick learns which pages are hot.

Equilibria's control plane starts at hotness — and the exact engine
recomputes a dense [L] EWMA every tick, so tick cost grows linearly in the
page pool. This module makes hotness a SEAM of ``core.tick.make_tick_core``
(mirroring the ownership-provider / ``detect=`` / ``attrib=`` seams): a
provider owns a pytree-carried state plus the update/candidate ops tick
steps 3-6b consume, while selection quotas, Eq.1/Eq.2 regulation, obs,
attribution and churn run unchanged on top.

Providers (``hotness=`` on the tick builders / ``init_state``):

  exact    — today's dense EWMA; bit-exact with the pre-seam tick (the
             default; golden traces pass unregenerated).
  sampled  — dense EWMA fed by a rotating per-tick page subset with
             unbiased 1/frac scaling (the cheap-fidelity frontier point:
             same O(L) dense ops, sparser access instrumentation).
  sketch   — HybridTier direction: a decayed count-min sketch over hashed
             page ids (core/cms.py) fed by O(probe) sampled lanes, plus
             per-tenant top-N candidate/victim buffers, so the promotion-
             and demotion-candidate paths touch O(hot set), not O(L).
  neomem   — NeoMem direction: an emulated device-side tracker counts
             every access exactly and publishes a top-N hot-page report
             per tick; the OS-side promotion path consumes the report one
             tick LATE (hardware asynchrony), demotion keeps the OS's own
             LRU metadata.

The differential fidelity harness (tests/test_hotness_differential.py,
benchmarks/hotness.py) quantifies each provider's promotion-decision
agreement and fast-hit fidelity against ``exact``.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TieringConfig
from repro.core import cms as CM
from repro.core import select as SEL
from repro.core.state import TIER_SLOW

HOTNESS_PROVIDERS = ("exact", "sampled", "sketch", "neomem")


def cold_score(t: jax.Array, last_access: jax.Array,
               hot: jax.Array) -> jax.Array:
    """The ONE demotion/reclaim ranking score: LRU age in ticks, hotness as
    the tiebreak within an age class (higher = colder = demoted first).
    Every consumer — Eq.1 demotion, sync upper-bound demotion, churn
    reclaim, the sketch provider's victim buffer — must rank with this
    helper so the orderings can never drift apart again."""
    return (t - last_access).astype(jnp.float32) * 1e3 - hot


# ------------------------------------------------------------- the seam ----
class RowSpace(NamedTuple):
    """Tenant-local page addressing: row t lists tenant t's pages.

    The ownership provider supplies this lazily (``Prepared.rows`` is a
    thunk): static layouts bake it in at trace time, the dynamic provider
    scatters it from the live owner vector only when a hotness provider
    actually asks (the exact provider never does, so the default tick
    carries zero extra ops)."""
    page: jax.Array    # [T, S] int32 page id, -1 = empty slot
    valid: jax.Array   # [T, S] bool


class HotCtx(NamedTuple):
    """Everything tick step 3 hands the active hotness provider."""
    hstate: Any                    # provider state subtree (None = stateless)
    prev_hot: jax.Array            # [L] post-lifecycle hot from last tick
    accesses: jax.Array            # [L] f32 this tick
    alive: jax.Array               # [L] bool
    new: jax.Array                 # [L] bool pages allocated this tick
    tier: jax.Array                # [L] int32, post-allocation
    last_access: jax.Array         # [L] int32, post-recency-update
    owner: jax.Array               # [L] int32 (sentinel T = free)
    owner_c: jax.Array             # [L] int32 gather-safe owner
    t: jax.Array                   # scalar int32
    rows: Callable[[], RowSpace]   # lazy tenant rowspace (see RowSpace)
    strategy: SEL.Strategy         # the ownership provider's selection ops


class PromoCand(NamedTuple):
    """Promotion-candidate ops for tick step 6 (post-demotion tier view)."""
    cand_t: jax.Array                                  # [T] candidate count
    select: Callable[[jax.Array], SEL.Selection]       # quotas [T]
    select_global: Callable[[jax.Array], SEL.Selection]  # scalar budget (tpp)


class HotnessView(NamedTuple):
    """One tick's hotness products, consumed by tick steps 3-6b."""
    hstate: Any                    # carried into the next TierState
    hot: jax.Array                 # [L] dense hotness (state carry/telemetry)
    demand_t: jax.Array            # [T] promotion demand (step 4, pre-cap)
    promo_cand: Callable[[jax.Array, jax.Array], PromoCand]  # (tier, demoted)
    demote: Callable[[jax.Array, jax.Array], SEL.Selection]  # (fast_mask, q[T])
    demote_global: Callable[[jax.Array, jax.Array], SEL.Selection]  # (m, q)


class HotnessProvider(NamedTuple):
    name: str
    init: Callable[[], Any]        # build the state subtree (None = stateless)
    step: Callable[[HotCtx], HotnessView]


# ------------------------------------------------------- provider specs ----
class SampledSpec(NamedTuple):
    frac: float = 0.25    # fraction of pages instrumented per tick
    seed: int = 0


class SketchSpec(NamedTuple):
    depth: int = 2        # count-min rows
    width: int = 1 << 15  # buckets per row (power of two)
    n_cand: int = 128     # per-tenant promotion-candidate buffer
    n_cold: int = 128     # per-tenant demotion-victim buffer
    probe: int = 4096     # sampled access lanes per tick (split across T)
    seed: int = 0


class NeomemSpec(NamedTuple):
    n_report: int = 256   # hot pages per tenant in each device report


class SketchState(NamedTuple):
    cms: jax.Array        # [depth, width] f32 decayed counts
    cand_page: jax.Array  # [T, n_cand] int32, est-descending, -1 empty
    cold_page: jax.Array  # [T, n_cold] int32, cold-descending, -1 empty


class NeomemState(NamedTuple):
    report_page: jax.Array   # [T, n_report] int32 last tick's report
    report_hot: jax.Array    # [T, n_report] f32 reported hotness


# ------------------------------------------------- compact row selection ----
def _row_select(pages: jax.Array, take: jax.Array, quotas: jax.Array,
                n_pages: int) -> SEL.Selection:
    """Quota select over score-ordered buffer rows ([T, N], best lane
    first): per-tenant top-quota is an exclusive running count over the
    eligible lanes — no sort, no top_k, O(T*N) total."""
    order = jnp.cumsum(take.astype(jnp.int32), axis=1) - take
    sel = take & (order < quotas[:, None])
    flat = jnp.where(sel, pages, n_pages).reshape(-1)
    mask = jnp.zeros((n_pages,), bool).at[flat].set(True, mode="drop")
    return SEL.Selection(mask=mask, pages=pages, take=sel,
                         counts=sel.sum(axis=1).astype(jnp.int32))


def _flat_select(score: jax.Array, pages: jax.Array, take: jax.Array,
                 quota: jax.Array, k_cap: int, n_pages: int) -> SEL.Selection:
    """Tenant-blind top-quota over flattened buffer lanes (the tpp global
    scan, restricted to the provider's tracked candidates)."""
    s = jnp.where(take, score, -jnp.inf).reshape(-1)
    k = min(k_cap, s.shape[0])
    vals, idx = jax.lax.top_k(s, k)
    tk = (jnp.arange(k) < quota) & (vals > -jnp.inf)
    pg = pages.reshape(-1)[idx]
    mask = jnp.zeros((n_pages,), bool).at[
        jnp.where(tk, pg, n_pages)].set(True, mode="drop")
    return SEL.Selection(mask, None, None, None)


# ------------------------------------------------------------- providers ----
def _dense_view(cfg: TieringConfig, k_max: int, ctx: HotCtx,
                hot: jax.Array, hstate: Any) -> HotnessView:
    """The exact engine's candidate/selection ops over a dense hot vector —
    shared by ``exact`` (its own EWMA) and ``sampled`` (scaled-subset EWMA),
    and the demotion side of ``neomem``."""
    T = cfg.n_tenants
    thr = cfg.promo_hot_threshold
    strat = ctx.strategy
    cand_pre = (ctx.tier == TIER_SLOW) & (hot >= thr) & ctx.alive
    demand_t = strat.by_tenant(cand_pre.astype(jnp.int32), ctx.owner)
    cold = cold_score(ctx.t, ctx.last_access, hot)

    def demote(fast_mask, quotas):
        return strat.select(cold, ctx.owner, fast_mask, quotas)

    def demote_global(fast_mask, quota):
        return SEL.Selection(
            SEL.select_global(cold, fast_mask, quota, k_max * T),
            None, None, None)

    def promo_cand(tier, demoted):
        cand = (tier == TIER_SLOW) & (hot >= thr) & ctx.alive & ~demoted
        cand_t = strat.by_tenant(cand.astype(jnp.int32), ctx.owner)
        return PromoCand(
            cand_t,
            lambda quotas: strat.select(hot, ctx.owner, cand, quotas),
            lambda quota: SEL.Selection(
                SEL.select_global(hot, cand, quota, k_max * T),
                None, None, None))

    return HotnessView(hstate=hstate, hot=hot, demand_t=demand_t,
                       promo_cand=promo_cand, demote=demote,
                       demote_global=demote_global)


def exact_hotness(cfg: TieringConfig, n_pages: int,
                  k_max: int) -> HotnessProvider:
    """Today's dense EWMA — bit-exact with the pre-seam tick."""
    def step(ctx: HotCtx) -> HotnessView:
        hot = jnp.where(ctx.alive,
                        cfg.hot_decay * ctx.prev_hot + ctx.accesses, 0.0)
        return _dense_view(cfg, k_max, ctx, hot, None)

    return HotnessProvider("exact", lambda: None, step)


def sampled_hotness(cfg: TieringConfig, n_pages: int, k_max: int,
                    spec: SampledSpec) -> HotnessProvider:
    """Dense EWMA fed by a rotating page subset with unbiased scaling.

    The subset is a multiplicative-hash residue class shifted by the tick
    (page*A + t*B mod 2^20 < frac*2^20, A and B odd): deterministic, O(L)
    elementwise, every page is instrumented ``frac`` of ticks, and the
    1/frac scaling keeps E[hot] equal to the exact EWMA. Stateless — the
    schedule is a function of (page, t)."""
    M = 1 << 20
    thresh = np.int32(min(max(spec.frac, 0.0), 1.0) * M)
    A = np.int32(2 * ((spec.seed * 131) % 1024) + 1093)   # odd, < 2**12
    B = np.int32(2 * ((spec.seed * 37) % 1024) + 40503)   # odd, < 2**16
    inv = np.float32(1.0 / max(spec.frac, 1e-9))
    page_mix = None

    def step(ctx: HotCtx) -> HotnessView:
        nonlocal page_mix
        if page_mix is None:
            page_mix = jnp.arange(n_pages, dtype=jnp.int32) * A
        smask = ((page_mix + ctx.t * B) & (M - 1)) < thresh
        acc = jnp.where(smask, ctx.accesses * inv, 0.0)
        hot = jnp.where(ctx.alive, cfg.hot_decay * ctx.prev_hot + acc, 0.0)
        return _dense_view(cfg, k_max, ctx, hot, None)

    return HotnessProvider("sampled", lambda: None, step)


def sketch_hotness(cfg: TieringConfig, n_pages: int, k_max: int,
                   spec: SketchSpec) -> HotnessProvider:
    """Count-min hotness with per-tenant candidate/victim buffers.

    Per tick: probe ``probe`` tenant-rowspace lanes (full enumeration when
    a tenant's rowspace fits the per-tenant budget, so small presets are
    covered exactly), scatter their scaled accesses into the decayed
    sketch, then refresh two [T, N] buffers by merging last tick's entries
    with the fresh probes under one batched top_k per buffer — candidates
    ranked by estimate, victims by ``cold_score``. Steps 4-6b then select
    from the buffers with running-count quota cuts: the candidate path is
    O(probe + T*N) regardless of L.

    Probe lanes are presented in ascending page order (full enumeration is
    ``arange``; random probes are row-sorted), so top_k's lower-lane
    tie-break inherits the exact engine's lower-page-wins rule.
    """
    T = cfg.n_tenants
    L = n_pages
    thr = cfg.promo_hot_threshold
    params = CM.cms_params(spec.depth, spec.width, cfg.hot_decay, spec.seed)
    # hash int32 safety: see core/cms.py (pages + width) * mult < 2**31
    assert (L + spec.width) * CM.MULT_MAX < 2 ** 31, (L, spec.width)
    base_key = jax.random.PRNGKey(spec.seed)
    r = max(spec.probe // T, 1)

    def init() -> SketchState:
        return SketchState(
            cms=CM.make_cms(params),
            cand_page=jnp.full((T, spec.n_cand), -1, jnp.int32),
            cold_page=jnp.full((T, spec.n_cold), -1, jnp.int32))

    def step(ctx: HotCtx) -> HotnessView:
        st: SketchState = ctx.hstate
        rows = ctx.rows()
        S = rows.page.shape[1]
        row_t = jnp.arange(T, dtype=jnp.int32)[:, None]

        # ---- probe: sampled access lanes in tenant-local space ----------
        if r >= S:         # full coverage (small presets): exact stream
            u = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None, :], (T, S))
            dup_u = jnp.zeros((T, S), bool)
            scale = jnp.float32(1.0)
        else:              # with-replacement draws; E[hits] = r/S per page
            key = jax.random.fold_in(base_key, ctx.t)
            u = jnp.sort(jax.random.randint(key, (T, r), 0, S, jnp.int32),
                         axis=1)
            dup_u = jnp.concatenate(
                [jnp.zeros((T, 1), bool), u[:, 1:] == u[:, :-1]], axis=1)
            scale = jnp.float32(S) / jnp.float32(r)
        sp = jnp.take_along_axis(rows.page, u, axis=1)        # [T, rr]
        spc = jnp.maximum(sp, 0)
        in_row = jnp.take_along_axis(rows.valid, u, axis=1) & ~dup_u
        sv = in_row & ctx.alive[spc]
        if r >= S and L <= spec.width:
            # full coverage + injective hash (the whole pool is one
            # collision-free window): each page owns its buckets, so the
            # recurrence can be written per-lane in the exact engine's
            # ``where(alive, decay * prev + accesses, 0)`` form and
            # scatter-SET — estimates then track the dense EWMA bitwise
            # (a plain decay-then-scatter-add rounds differently and
            # threshold crossings drift by ticks). Dead lanes write 0:
            # the page-free counter reset.
            prev = CM.cms_estimate(params, st.cms, spc)
            val = jnp.where(sv, jnp.float32(params.decay) * prev
                            + ctx.accesses[spc], 0.0)
            sk = CM.cms_assign(params, st.cms, spc, val, in_row)
        else:
            amt = jnp.where(sv, ctx.accesses[spc] * scale, 0.0)
            sk = CM.cms_add(params, CM.cms_decay(params, st.cms), spc,
                            amt, sv)
            # probed DEAD pages reset their counters (the page-free
            # hook): the exact engine zeroes hot on death, and without
            # this a revived page inherits its previous life's residue
            # and outranks what the exact engine would promote. Deaths
            # are rare — cond-skip the scatter on all-alive probes (an
            # empty clear is a value no-op).
            dead = in_row & ~ctx.alive[spc]
            sk = jax.lax.cond(
                dead.any(),
                lambda c: CM.cms_clear(params, c, spc, dead),
                lambda c: c, sk)

        # ---- refresh the candidate/victim buffers -----------------------
        def merge(buf, n, score_of):
            if r >= S:
                # full coverage: the fresh probes already enumerate every
                # page in ascending order, so the buffer is a pure function
                # of the current sketch and top_k's lower-lane tie-break
                # reproduces the exact engine's lower-page-wins rule
                pool = jnp.where(sv, sp, -1)
            else:
                # keep last tick's entries so hot pages survive being
                # unsampled; a probe already resident in the buffer keeps
                # its buffer lane (rows never hold a page twice). The
                # membership test is a [T, r, N] broadcast compare —
                # constant in L, and cheaper than an [L] bitmap
                # scatter/gather round-trip (XLA CPU scatters serialize).
                resident = (sp[:, :, None] == buf[:, None, :]).any(axis=2)
                pool = jnp.concatenate(
                    [buf, jnp.where(sv & ~resident, sp, -1)], axis=1)
            pc = jnp.maximum(pool, 0)
            ok = (pool >= 0) & ctx.alive[pc] & (ctx.owner[pc] == row_t)
            est = CM.cms_estimate(params, sk, pc)
            return CM.topn_rows(score_of(pc, est), pool, ok, n)

        cand_page, cand_est = merge(st.cand_page, spec.n_cand,
                                    lambda pc, est: est)
        cold_page, cold_val = merge(
            st.cold_page, spec.n_cold,
            lambda pc, est: cold_score(ctx.t, ctx.last_access[pc], est))

        cp = jnp.maximum(cand_page, 0)
        cvalid = cand_page >= 0
        dp = jnp.maximum(cold_page, 0)
        dvalid = cold_page >= 0
        dest = CM.cms_estimate(params, sk, dp)

        # dense hot carry/telemetry: tracked estimates, 0 elsewhere (the
        # ring and the churn reclaim read it; untracked pages rank coldest)
        hot = jnp.zeros((L,), jnp.float32).at[
            jnp.concatenate([jnp.where(cvalid, cp, L),
                             jnp.where(dvalid, dp, L)], axis=1).reshape(-1)
        ].set(jnp.concatenate(
            [jnp.where(cvalid, cand_est, 0.0),
             jnp.where(dvalid, dest, 0.0)], axis=1).reshape(-1), mode="drop")

        is_cand = (cvalid & (ctx.tier[cp] == TIER_SLOW) & ctx.alive[cp]
                   & (cand_est >= thr))
        demand_t = is_cand.sum(axis=1).astype(jnp.int32)

        def promo_cand(tier, demoted):
            take = (cvalid & (tier[cp] == TIER_SLOW) & ctx.alive[cp]
                    & (cand_est >= thr) & ~demoted[cp])
            return PromoCand(
                take.sum(axis=1).astype(jnp.int32),
                lambda quotas: _row_select(cp, take, quotas, L),
                lambda quota: _flat_select(cand_est, cp, take, quota,
                                           k_max * T, L))

        def demote(fast_mask, quotas):
            take = dvalid & fast_mask[dp] & ctx.alive[dp]
            return _row_select(dp, take, quotas, L)

        def demote_global(fast_mask, quota):
            take = dvalid & fast_mask[dp] & ctx.alive[dp]
            return _flat_select(cold_val, dp, take, quota, k_max * T, L)

        return HotnessView(
            hstate=SketchState(cms=sk, cand_page=cand_page,
                               cold_page=cold_page),
            hot=hot, demand_t=demand_t, promo_cand=promo_cand,
            demote=demote, demote_global=demote_global)

    return HotnessProvider("sketch", init, step)


def neomem_hotness(cfg: TieringConfig, n_pages: int, k_max: int,
                   spec: NeomemSpec) -> HotnessProvider:
    """Emulated device-side hot-page tracker (NeoMem direction).

    The "device" counts every access exactly (it sits on the CXL path, so
    full-rate counting is free for the OS) and publishes a per-tenant
    top-N hot-page report each tick. The OS-side promotion pipeline
    consumes the report ONE TICK LATE — hardware/OS asynchrony is the
    semantic difference vs ``exact`` — while demotion keeps the OS's own
    dense LRU metadata (the device only sees CXL-side traffic)."""
    T = cfg.n_tenants
    L = n_pages
    thr = cfg.promo_hot_threshold

    def init() -> NeomemState:
        return NeomemState(
            report_page=jnp.full((T, spec.n_report), -1, jnp.int32),
            report_hot=jnp.zeros((T, spec.n_report), jnp.float32))

    def step(ctx: HotCtx) -> HotnessView:
        st: NeomemState = ctx.hstate
        hot = jnp.where(ctx.alive,
                        cfg.hot_decay * ctx.prev_hot + ctx.accesses, 0.0)
        view = _dense_view(cfg, k_max, ctx, hot, None)
        row_t = jnp.arange(T, dtype=jnp.int32)[:, None]

        # OS promotion path: last tick's report (reported hotness ranks and
        # gates; stale entries die on the alive/owner checks)
        rp = jnp.maximum(st.report_page, 0)
        rvalid = ((st.report_page >= 0) & ctx.alive[rp]
                  & (ctx.owner[rp] == row_t))
        rhot = st.report_hot
        is_cand = rvalid & (ctx.tier[rp] == TIER_SLOW) & (rhot >= thr)
        demand_t = is_cand.sum(axis=1).astype(jnp.int32)

        def promo_cand(tier, demoted):
            take = (rvalid & (tier[rp] == TIER_SLOW) & (rhot >= thr)
                    & ~demoted[rp])
            return PromoCand(
                take.sum(axis=1).astype(jnp.int32),
                lambda quotas: _row_select(rp, take, quotas, L),
                lambda quota: _flat_select(rhot, rp, take, quota,
                                           k_max * T, L))

        # this tick's device report, delivered next tick
        rows = ctx.rows()
        rpg = jnp.maximum(rows.page, 0)
        rok = rows.valid & ctx.alive[rpg]
        pages, vals = CM.topn_rows(hot[rpg], rows.page, rok, spec.n_report)
        hstate = NeomemState(report_page=pages,
                             report_hot=jnp.where(pages >= 0, vals, 0.0))
        return view._replace(hstate=hstate, demand_t=demand_t,
                             promo_cand=promo_cand)

    return HotnessProvider("neomem", init, step)


# ------------------------------------------------------ resolution / init ----
def _norm(spec):
    if isinstance(spec, str):
        if spec not in HOTNESS_PROVIDERS:
            raise ValueError(
                f"unknown hotness provider {spec!r}; "
                f"expected one of {HOTNESS_PROVIDERS}")
        return {"exact": None, "sampled": SampledSpec(),
                "sketch": SketchSpec(), "neomem": NeomemSpec()}[spec]
    return spec


def resolve_hotness(spec, cfg: TieringConfig, n_pages: int,
                    k_max: int) -> HotnessProvider:
    """Accepts None/"exact" (the default dense EWMA), a provider name, a
    spec NamedTuple, or a prebuilt HotnessProvider."""
    spec = _norm(spec)
    if spec is None:
        return exact_hotness(cfg, n_pages, k_max)
    if isinstance(spec, HotnessProvider):
        return spec
    if isinstance(spec, SampledSpec):
        return sampled_hotness(cfg, n_pages, k_max, spec)
    if isinstance(spec, SketchSpec):
        return sketch_hotness(cfg, n_pages, k_max, spec)
    if isinstance(spec, NeomemSpec):
        return neomem_hotness(cfg, n_pages, k_max, spec)
    raise TypeError(f"not a hotness provider spec: {spec!r}")


def init_hotness(spec, cfg: TieringConfig, n_pages: int):
    """The state subtree for ``init_state(..., hotness=...)``. None for
    stateless providers — states built without one keep their pre-existing
    tree structure, jaxprs and golden traces bit-exact (the det/attrib
    optional-subtree pattern)."""
    return resolve_hotness(spec, cfg, n_pages, k_max=256).init()


def static_rowspace(owner: np.ndarray, n_tenants: int) -> RowSpace:
    """Trace-time RowSpace for a static owner vector (any permutation)."""
    owner = np.asarray(owner)
    counts = np.bincount(owner, minlength=n_tenants)[:n_tenants]
    S = max(int(counts.max()) if counts.size else 1, 1)
    page = np.full((n_tenants, S), -1, np.int32)
    for ti in range(n_tenants):          # host-side, once per build
        ids = np.nonzero(owner == ti)[0]
        page[ti, :ids.size] = ids
    return RowSpace(page=jnp.asarray(page), valid=jnp.asarray(page >= 0))
