"""Training launcher: config -> mesh -> sharded train loop with fault
tolerance, checkpointing and (optionally) gradient compression.

  PYTHONPATH=src python -m repro.launch.train --arch llama32_1b --smoke \
      --steps 50 --batch 8 --seq 128

On real hardware the same entry point runs the production mesh
(--production); on this CPU container the smoke path exercises the full
stack end-to-end (loader -> step -> FT driver -> checkpoints).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import SyntheticLoader, synthetic_batch
from repro.ft.driver import FTConfig, TrainDriver
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.params import init_params, param_count
from repro.models.transformer import model_specs
from repro.optim.adamw import init_opt_state
from repro.sharding import rules as R
from repro.sharding.context import set_mesh_context
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32_1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--production", action="store_true",
                    help="use the 16x16 production mesh (TPU pod)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--remat", default="block")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tc = TrainConfig(learning_rate=args.lr, warmup_steps=10,
                     total_steps=args.steps, microbatches=args.microbatches,
                     grad_compression=args.grad_compression,
                     remat_policy=args.remat,
                     checkpoint_dir=args.ckpt_dir,
                     checkpoint_every=args.ckpt_every)

    mesh = make_production_mesh() if args.production else make_host_mesh()
    set_mesh_context(mesh)
    specs = model_specs(cfg)
    print(f"arch={cfg.name} params={param_count(specs):,} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    with mesh:
        pshard = R.param_shardings(specs, mesh, R.base_rules(False))
        params = init_params(jax.random.PRNGKey(tc.seed), specs)
        params = jax.tree_util.tree_map(jax.device_put, params, pshard)
        opt = init_opt_state(params)
        raw_step = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))

        def step_fn(state, batch):
            params, opt = state
            params, opt, metrics = raw_step(params, opt, batch)
            return (params, opt), metrics

        loader = SyntheticLoader(cfg, args.batch, args.seq, seed=tc.seed)
        ftc = FTConfig(checkpoint_dir=tc.checkpoint_dir,
                       checkpoint_every=tc.checkpoint_every)
        driver = TrainDriver(step_fn, ftc)
        state, start = driver.maybe_restore((params, opt))
        if start:
            print(f"resumed from checkpoint at step {start}")

        t0 = time.time()
        state, logs = driver.run(state, loader, start_step=start,
                                 num_steps=args.steps - start)
        dt = time.time() - t0
        losses = [float(m["loss"]) for m in logs]
        print(f"steps={len(logs)} loss {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"({dt / max(len(logs), 1):.2f}s/step, "
              f"stragglers={driver.stats.stragglers}, "
              f"retries={driver.stats.retries})")


if __name__ == "__main__":
    main()
