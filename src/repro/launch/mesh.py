"""Production mesh construction. A function (not a module-level constant) so
importing this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ("data", "model") = 256 chips.
    Multi-pod: (2, 16, 16) ("pod", "data", "model") = 512 chips; the "pod"
    axis carries data parallelism across the pod-interconnect (DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over the real local device (smoke tests, examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
