import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
# ^ MUST be the first lines, before any other import: jax locks the device
#   count on first initialization. Set ONLY here — smoke tests and benches
#   see the single real CPU device.

# Multi-pod dry-run: lower + compile every (arch x shape) cell on the
# production meshes and record memory/cost/collective analysis.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_32b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
#
# Results are cached as JSON under benchmarks/results/dryrun/.
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, shape_cells
from repro.configs.base import SHAPES, ShapeConfig, TieringConfig, TrainConfig
from repro.data.pipeline import input_specs
from repro.launch.mesh import make_production_mesh
from repro.models.params import abstract_params, param_count
from repro.models.transformer import model_specs
from repro.optim.adamw import abstract_opt_state
from repro.serve.decode import build_serve_step, init_serve_state
from repro.sharding import rules as R
from repro.train.step import make_prefill_step, make_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

# TPU v5e-class hardware model (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

_COLL_RE = re.compile(
    r"(\w+\[[0-9,a-z{}\[\]]*\]|\([^)]*\))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([0-9,]*)\]")

_DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
             "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
             "pred": 1}


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DT_BYTES.get(dt if dt in _DT_BYTES else dt[:3], 4)
    return total


# Ring-collective bytes-on-wire factors (per device, relative to result bytes)
_COLL_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives, parsed from post-SPMD HLO.
    Shapes in the partitioned module are already per-device; we weight by
    ring-algorithm factors ((N-1)/N ≈ 1)."""
    per_op = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(2).lower()
        b = _shape_bytes(m.group(1)) * _COLL_FACTOR.get(op, 1.0)
        per_op[op] = per_op.get(op, 0.0) + b
    per_op["total"] = float(sum(per_op.values()))
    return per_op


def build_cell(arch: str, shape: ShapeConfig, mesh, tc: TrainConfig,
               cfg=None):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    from repro.sharding.context import set_mesh_context
    set_mesh_context(mesh)
    cfg = cfg or get_config(arch)
    specs = model_specs(cfg)
    aparams = abstract_params(specs)
    pshard = R.param_shardings(specs, mesh, R.base_rules("pod" in mesh.axis_names))
    batch = input_specs(cfg, shape)
    bshard = R.batch_shardings(cfg, mesh, batch)

    if shape.kind == "train":
        aopt = abstract_opt_state(aparams)
        oshard = jax.tree_util.tree_map(
            lambda _: None, aopt, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        oshard = type(aopt)(m=pshard, v=pshard,
                            step=R.replicated(mesh))
        step = make_train_step(cfg, tc)
        fn = jax.jit(step,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        return fn, (aparams, aopt, batch)

    if shape.kind == "prefill":
        step = make_prefill_step(cfg, tc)
        fn = jax.jit(step, in_shardings=(pshard, bshard), out_shardings=None)
        return fn, (aparams, batch)

    # decode — keeps the layer SCAN. Both unrolled variants were tried and
    # measured WORSE on this backend (stacked ys: +0% temp, slow compiles;
    # in-place .at[l].set chain: +128% temp — XLA-CPU does not alias the
    # DUS chain). The scan's xs->ys costs ~0.7-1.2x pool temp and compiles
    # 6x faster. Full log: EXPERIMENTS.md §Perf B.
    from repro.models.unroll import set_unroll
    set_unroll(False)
    tcfg = TieringConfig(n_tenants=4, page_tokens=64)
    state = init_serve_state(cfg, tcfg, shape.global_batch, shape.seq_len,
                             abstract=True)
    sshard = R.serve_state_shardings(state, mesh)
    step = build_serve_step(cfg, tcfg, shape.global_batch, shape.seq_len)

    def step_batch(params, st, b):
        return step(params, st, b["tokens"])

    fn = jax.jit(step_batch,
                 in_shardings=(pshard, sshard, bshard),
                 out_shardings=(None, sshard),
                 donate_argnums=(1,))
    return fn, (aparams, state, batch)


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool,
                tc: TrainConfig | None = None, save: bool = True,
                tag: str = "", reduced_depth: int = 0) -> dict:
    from repro.configs import reduced_depth_config
    from repro.models.unroll import set_unroll
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if reduced_depth:
        cfg = reduced_depth_config(arch, reduced_depth)
        set_unroll(True)
    else:
        cfg = get_config(arch)
        set_unroll(False)
    tc = tc or TrainConfig()
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "multi_pod": multi_pod, "kind": shape.kind, "tag": tag,
           "reduced_depth": reduced_depth,
           "num_layers": cfg.num_layers,
           "params": param_count(model_specs(cfg)),
           "active_params": cfg.active_param_count()}
    try:
        with mesh:
            fn, args = build_cell(arch, shape, mesh, tc, cfg=cfg)
            lowered = fn.lower(*args)
            compiled = lowered.compile()
        from repro.models.unroll import unrolled
        rec["unrolled"] = unrolled()
        rec["lower_compile_s"] = round(time.time() - t0, 1)
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(ma, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes")
                if hasattr(ma, k)}
        except Exception as e:  # noqa: BLE001
            rec["memory_analysis"] = {"error": str(e)}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            rec["cost_analysis"] = {
                k: float(v) for k, v in ca.items()
                if k in ("flops", "bytes accessed", "transcendentals",
                         "utilization operand", "bytes accessed output")
                or k.startswith("bytes accessed")}
        except Exception as e:  # noqa: BLE001
            rec["cost_analysis"] = {"error": str(e)}
        try:
            hlo = compiled.as_text()
            rec["collectives"] = collective_bytes(hlo)
            rec["hlo_bytes"] = len(hlo)
        except Exception as e:  # noqa: BLE001
            rec["collectives"] = {"error": str(e)}
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        suffix = "multipod" if multi_pod else "pod"
        if reduced_depth:
            suffix += f"_red{reduced_depth}"
        name = f"{arch}_{shape_name}_{suffix}{('_' + tag) if tag else ''}.json"
        (RESULTS_DIR / name).write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--reduced", action="store_true",
                    help="also run the two unrolled reduced-depth cost probes")
    ap.add_argument("--reduced-only", action="store_true")
    args = ap.parse_args()

    from repro.configs import reduced_depths

    cells = []
    if args.all:
        pairs = [(a, sh.name) for a in ARCH_IDS for sh in shape_cells(a)]
    else:
        assert args.arch and args.shape
        pairs = [(args.arch, args.shape)]
    for arch, shape_name in pairs:
        for mp in ([False, True] if args.both_meshes else [args.multi_pod]):
            depths = [0]
            if args.reduced or args.reduced_only:
                if not mp:  # cost probes on the single-pod mesh only
                    depths = list(reduced_depths(arch)) + ([] if args.reduced_only else [0])
                    if args.reduced_only:
                        pass
                elif args.reduced_only:
                    continue
            for rd in depths:
                cells.append((arch, shape_name, mp, rd))

    for arch, shape_name, mp, rd in cells:
        suffix = ("multipod" if mp else "pod") + (f"_red{rd}" if rd else "")
        out = RESULTS_DIR / f"{arch}_{shape_name}_{suffix}{('_' + args.tag) if args.tag else ''}.json"
        if out.exists() and not args.force:
            rec = json.loads(out.read_text())
            if rec.get("ok"):
                print(f"SKIP {arch} {shape_name} {suffix} (cached ok)", flush=True)
                continue
        rec = dryrun_cell(arch, shape_name, mp, tag=args.tag, reduced_depth=rd)
        status = "OK " if rec["ok"] else "FAIL"
        flops = rec.get("cost_analysis", {}).get("flops", 0)
        print(f"{status} {arch:24s} {shape_name:12s} {suffix:8s} "
              f"{rec['total_s']:7.1f}s flops/dev={flops:.3e} "
              f"coll/dev={rec.get('collectives', {}).get('total', 0):.3e}B",
              flush=True)
        if not rec["ok"]:
            print(rec["error"], flush=True)


if __name__ == "__main__":
    main()
