"""Multi-tenant serving launcher: Equilibria-tiered paged-KV decode.

  PYTHONPATH=src python -m repro.launch.serve --arch llama32_1b --smoke \
      --tenants 4 --batch 8 --steps 64 --mode equilibria

Runs a continuous-batching decode loop: every sequence belongs to a tenant;
the Equilibria policy (lower protection / upper bound / Eq.1 / Eq.2 / thrash
mitigation) manages the shared fast-tier page budget inside the compiled
step. Prints the per-tenant cgroup-style tier_stat counters at the end.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import TieringConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.params import init_params
from repro.models.transformer import model_specs
from repro.serve.decode import build_serve_step, init_serve_state
from repro.sharding.context import set_mesh_context


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--page-tokens", type=int, default=4)
    ap.add_argument("--mode", default="equilibria",
                    choices=["equilibria", "tpp", "static"])
    ap.add_argument("--protection", type=int, default=8,
                    help="fast-tier lower protection per tenant (pages)")
    ap.add_argument("--bound", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TieringConfig(
        n_tenants=args.tenants, page_tokens=args.page_tokens,
        thrash_table_slots=256,
        lower_protection=(args.protection,) * args.tenants,
        upper_bound=(args.bound,) * args.tenants)
    mesh = make_production_mesh() if args.production else make_host_mesh()
    set_mesh_context(mesh)

    with mesh:
        params = init_params(jax.random.PRNGKey(0), model_specs(cfg))
        state = init_serve_state(cfg, tcfg, args.batch, args.steps)
        step = jax.jit(build_serve_step(cfg, tcfg, args.batch, args.steps,
                                        mode=args.mode))
        key = jax.random.PRNGKey(1)
        tokens = jax.random.randint(key, (args.batch, 1), 0, cfg.vocab_size)
        t0 = time.time()
        for i in range(args.steps):
            logits, state = step(params, state, tokens)
            tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(logits)
        dt = time.time() - t0

    print(f"arch={cfg.name} mode={args.mode} decoded "
          f"{args.steps} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s)")
    if "kv" in state:
        kv = state["kv"]
        print("\nper-tenant tier_stat (cgroup-style observability, §IV-C):")
        fast = np.zeros(args.tenants, int)
        slow = np.zeros(args.tenants, int)
        ten = np.asarray(kv.tenant)
        fp = np.asarray(kv.fast_page >= 0).sum(1)
        sp = np.asarray(kv.slow_page >= 0).sum(1)
        for b in range(args.batch):
            fast[ten[b]] += fp[b]
            slow[ten[b]] += sp[b]
        c = kv.counters
        for t in range(args.tenants):
            print(f"  tenant{t}: fast_pages={fast[t]} slow_pages={slow[t]} "
                  f"pgpromote={int(c.promotions[t])} "
                  f"pgdemote={int(c.demotions[t])} "
                  f"thrash={int(c.thrash_events[t])} "
                  f"promo_scale={float(kv.promo_scale[t]):.3f}")


if __name__ == "__main__":
    main()
