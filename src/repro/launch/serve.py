"""Multi-tenant serving launcher: Equilibria-tiered paged-KV decode.

  PYTHONPATH=src python -m repro.launch.serve --arch llama32_1b --smoke \
      --tenants 4 --batch 8 --steps 64 --mode equilibria

Runs a continuous-batching decode loop: every sequence belongs to a tenant;
the Equilibria policy (lower protection / upper bound / Eq.1 / Eq.2 / thrash
mitigation) manages the shared fast-tier page budget inside the compiled
step. Prints the per-tenant cgroup-style tier_stat counters at the end.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import TieringConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.params import init_params
from repro.models.transformer import model_specs
from repro.serve.decode import build_serve_step, init_serve_state
from repro.sharding.context import set_mesh_context


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--page-tokens", type=int, default=4)
    ap.add_argument("--mode", default="equilibria",
                    choices=["equilibria", "tpp", "static"])
    ap.add_argument("--protection", type=int, default=8,
                    help="fast-tier lower protection per tenant (pages)")
    ap.add_argument("--bound", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TieringConfig(
        n_tenants=args.tenants, page_tokens=args.page_tokens,
        thrash_table_slots=256,
        lower_protection=(args.protection,) * args.tenants,
        upper_bound=(args.bound,) * args.tenants)
    mesh = make_production_mesh() if args.production else make_host_mesh()
    set_mesh_context(mesh)

    with mesh:
        params = init_params(jax.random.PRNGKey(0), model_specs(cfg))
        state = init_serve_state(cfg, tcfg, args.batch, args.steps)
        step = jax.jit(build_serve_step(cfg, tcfg, args.batch, args.steps,
                                        mode=args.mode))
        key = jax.random.PRNGKey(1)
        tokens = jax.random.randint(key, (args.batch, 1), 0, cfg.vocab_size)
        t0 = time.time()
        for i in range(args.steps):
            logits, state = step(params, state, tokens)
            tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(logits)
        dt = time.time() - t0

    print(f"arch={cfg.name} mode={args.mode} decoded "
          f"{args.steps} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s)")
    if "kv" in state:
        from repro.obs.stats import format_tier_stat, stats_summary
        from repro.obs.trace import decode_ring
        kv = state["kv"]
        fast = np.zeros(args.tenants, int)
        slow = np.zeros(args.tenants, int)
        ten = np.asarray(kv.tenant)
        fp = np.asarray(kv.fast_page >= 0).sum(1)
        sp = np.asarray(kv.slow_page >= 0).sum(1)
        for b in range(args.batch):
            fast[ten[b]] += fp[b]
            slow[ten[b]] += sp[b]
        c = kv.counters
        from repro.memtier.kvcache import kv_layer_count
        # one page slot holds k+v for every KV layer (pools are [L, B, Mf, ...])
        page_bytes = (2 * args.page_tokens * cfg.num_kv_heads
                      * cfg.resolved_head_dim * 2 * kv_layer_count(cfg))
        stat = {
            "local_usage_bytes": fast * page_bytes,
            "cxl_usage_bytes": slow * page_bytes,
            "pgpromote": c.promotions, "pgdemote": c.demotions,
            "pgpromote_attempted": c.attempted_promotions,
            "pgalloc": c.allocations, "thrash_events": c.thrash_events,
        }
        summary = stats_summary(kv.stats)
        print("\nper-tenant tier_stat (cgroup-style observability, §IV-C):")
        for t in range(args.tenants):
            print(f"tenant{t} (promo_scale="
                  f"{float(kv.promo_scale[t]):.3f}):")
            print(format_tier_stat(stat, summary, t))
        events, dropped = decode_ring(kv.ring)
        print(f"\nmigration trace: {len(events)} events buffered "
              f"({dropped} older events overwritten); last 5:")
        for e in events[-5:]:
            d = "promote" if e["direction"] == 0 else "demote"
            print(f"  step={e['tick']} tenant={e['tenant']} "
                  f"page={e['page']} {d} hotness={e['hotness']:.3f}")


if __name__ == "__main__":
    main()
