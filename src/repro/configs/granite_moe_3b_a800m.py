"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]

vocab_true=49155 padded to 49408 (multiple of 256) for 16-way TP of the
embedding/vocab dimension.
"""
from repro.configs.base import ModelConfig, MoEConfig

VOCAB_TRUE = 49155

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49408,          # padded from 49155
    head_dim=64,
    tie_embeddings=True,
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=32, vocab_size=256, head_dim=16,
        tie_embeddings=True, moe=MoEConfig(num_experts=8, top_k=4, d_ff_expert=32))
