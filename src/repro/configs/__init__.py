"""Architecture registry: one module per assigned architecture.

Each arch module defines ``CONFIG`` (the exact assigned configuration) and
``smoke_config()`` (a reduced same-family config for CPU smoke tests).
Vocab sizes that don't divide the 16-way model axis are padded up to the next
multiple of 256 (``vocab_true`` records the paper value) — standard TPU
practice (MaxText does the same); padded logits are dead weight, never labels.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (MeshConfig, ModelConfig, MoEConfig, SSMConfig,
                                ShapeConfig, SHAPES, TieringConfig, TrainConfig)

ARCH_IDS = [
    "mixtral_8x22b",
    "granite_moe_3b_a800m",
    "qwen3_32b",
    "codeqwen15_7b",
    "h2o_danube_3_4b",
    "llama32_1b",
    "mamba2_130m",
    "whisper_tiny",
    "llama32_vision_90b",
    "zamba2_7b",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def reduced_depth_config(arch: str, n: int) -> ModelConfig:
    """Depth-reduced config for unrolled cost extraction (same widths,
    same sharding; only the stacked layer counts shrink)."""
    import dataclasses
    cfg = get_config(arch)
    if cfg.family == "vlm":
        n = max(cfg.cross_attn_every, (n // cfg.cross_attn_every)
                * cfg.cross_attn_every)
        return dataclasses.replace(cfg, num_layers=n)
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, num_layers=n, encoder_layers=n)
    if cfg.family == "hybrid":
        n = max(cfg.hybrid_attn_every, (n // cfg.hybrid_attn_every)
                * cfg.hybrid_attn_every)
        return dataclasses.replace(cfg, num_layers=n)
    return dataclasses.replace(cfg, num_layers=n)


def reduced_depths(arch: str) -> tuple:
    """Two unroll depths per arch for the linear cost fit."""
    cfg = get_config(arch)
    if cfg.family == "vlm":
        return (cfg.cross_attn_every, 2 * cfg.cross_attn_every)
    if cfg.family == "hybrid":
        return (cfg.hybrid_attn_every, 2 * cfg.hybrid_attn_every)
    return (2, 4)


def shape_cells(arch: str):
    """The assigned (shape) cells for one arch, with principled skips."""
    cfg = get_config(arch)
    cells = []
    for name, sh in SHAPES.items():
        if name == "long_500k" and not cfg.has_subquadratic_path:
            continue  # pure full-attention archs skip long-context decode
        cells.append(sh)
    return cells
