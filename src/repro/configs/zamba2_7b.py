"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + ONE weight-shared attention
block applied every 6 layers on concat(hidden, embeddings). [arXiv:2411.15242]
"""
from repro.configs.base import ModelConfig, SSMConfig


CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,            # mamba blocks; shared attn applied every 6
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,
    hybrid_attn_every=6,
    rope_theta=10_000.0,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  ngroups=1, chunk_size=256),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
        hybrid_attn_every=2,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4,
                      ngroups=1, chunk_size=8))
