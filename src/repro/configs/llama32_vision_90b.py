"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — cross-attn image layers every 5th layer (80 self + 20 gated
cross). Vision frontend is a STUB: input_specs() provides patch embeddings
[B, 1600, 8192]. [hf:meta-llama/Llama-3.2-11B-Vision family]"""
from repro.configs.base import ModelConfig


CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    cross_attn_every=5,
    num_image_tokens=1600,
    rope_theta=500_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-smoke", family="vlm", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        cross_attn_every=2, num_image_tokens=8)
