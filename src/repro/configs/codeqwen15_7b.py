"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (GQA kv=32, i.e. MHA)
d_ff=13440 vocab=92416 — qwen1.5 arch. [hf:Qwen/CodeQwen1.5-7B]"""
from repro.configs.base import ModelConfig


CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    head_dim=128,
    rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16)
