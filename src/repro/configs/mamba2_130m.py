"""mamba2-130m [ssm] — 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060]

TPU adaptations (DESIGN.md §2): vocab_true=50280 padded to 50432 (×256);
SSM head_dim=48 (⇒ 32 heads, divisible by the 16-way model axis) instead of
the GPU default 64 (⇒ 24 heads, which does not tile a 16-wide TP axis).
"""
from repro.configs.base import ModelConfig, SSMConfig

VOCAB_TRUE = 50280

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=1,              # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50432,         # padded from 50280
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=48, expand=2, conv_width=4,
                  ngroups=1, chunk_size=256),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm", num_layers=2, d_model=64,
        num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=256,
        tie_embeddings=True,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4,
                      ngroups=1, chunk_size=8))
