"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix, sliding-window attention. [arXiv:2401.16818]"""
from repro.configs.base import ModelConfig


CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    head_dim=120,
    sliding_window=4096,
    rope_theta=500_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="danube-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        sliding_window=32)
