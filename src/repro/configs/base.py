"""Configuration system for the Equilibria reproduction framework.

Plain dataclasses (no external deps). A ModelConfig fully describes one of the
assigned architectures; ShapeConfig describes one assigned input-shape cell;
TieringConfig carries the Equilibria fairness parameters (paper §IV).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128          # N: SSM state size
    head_dim: int = 64            # P: channels per SSM head
    expand: int = 2               # d_inner = expand * d_model
    conv_width: int = 4
    ngroups: int = 1
    chunk_size: int = 256         # Q: SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None        # default d_model // num_heads
    qk_norm: bool = False
    sliding_window: Optional[int] = None  # SWA window (tokens), None = full attn
    swa_pattern: int = 1                  # every n-th layer is SWA (1 = all)
    rope_theta: float = 500000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"                     # silu (SwiGLU) | gelu (fc1/fc2)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # --- hybrid (zamba2-style): mamba backbone + shared attention block ---
    hybrid_attn_every: int = 6            # shared attn block every N mamba blocks
    # --- enc-dec (whisper-style) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500               # fixed frame count from the (stub) frontend
    # --- vlm (llama3.2-vision-style): gated cross-attn every N layers ---
    cross_attn_every: int = 0             # 0 = no cross-attn layers
    num_image_tokens: int = 1600          # (stub) patch embeddings per sample
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_subquadratic_path(self) -> bool:
        """True if the arch can run long_500k (SSM / hybrid / SWA)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec (whisper)

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.resolved_head_dim
        qkv = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd)
        o = (self.num_heads * hd) * d
        attn = qkv + o
        if self.act == "silu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.family == "moe":
            assert self.moe is not None
            mlp = self.moe.num_experts * 3 * d * self.moe.d_ff_expert + d * self.moe.num_experts
        if self.family == "ssm":
            assert self.ssm is not None
            di = self.ssm.expand * d
            nh = di // self.ssm.head_dim
            inproj = d * (2 * di + 2 * self.ssm.ngroups * self.ssm.state_dim + nh)
            conv = (di + 2 * self.ssm.ngroups * self.ssm.state_dim) * self.ssm.conv_width
            per_layer = inproj + conv + di * d + 2 * nh + di
            emb = self.vocab_size * d
            return self.num_layers * per_layer + emb + (0 if self.tie_embeddings else emb)
        per_layer = attn + mlp + 2 * d
        if self.family == "hybrid":
            assert self.ssm is not None
            di = self.ssm.expand * d
            nh = di // self.ssm.head_dim
            inproj = d * (2 * di + 2 * self.ssm.ngroups * self.ssm.state_dim + nh)
            mamba_layer = inproj + di * d + di
            n_shared_applications = self.num_layers // self.hybrid_attn_every
            shared = attn + mlp + 2 * d * d  # one shared block + concat projections
            emb = self.vocab_size * d
            return self.num_layers * mamba_layer + shared + 2 * emb + n_shared_applications * 0
        n_layers = self.num_layers
        if self.family == "vlm" and self.cross_attn_every > 0:
            # num_layers counts self+cross; cross layers have attn (no kv grouping change) + mlp
            pass
        emb = self.vocab_size * d
        total = n_layers * per_layer + emb + (0 if self.tie_embeddings else emb)
        if self.family == "encdec":
            total += self.encoder_layers * (attn + mlp + 2 * d)
            total += self.num_layers * attn  # cross-attn in decoder layers
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family != "moe" or self.moe is None:
            return self.param_count()
        d = self.d_model
        dense_mlp_all = self.moe.num_experts * 3 * d * self.moe.d_ff_expert
        active_mlp = self.moe.top_k * 3 * d * self.moe.d_ff_expert
        return self.param_count() - self.num_layers * (dense_mlp_all - active_mlp)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class TieringConfig:
    """Equilibria fairness parameters (paper §IV). Page sizes are in 'pages'."""
    n_tenants: int = 4
    n_fast_pages: int = 4096          # fast-tier pool (local DRAM / HBM analogue)
    n_slow_pages: int = 4096          # slow-tier pool (CXL analogue)
    page_tokens: int = 64             # tokens per KV page (serving path)
    # per-tenant policy (paper §IV-B): lower protection and upper bound, in pages.
    lower_protection: Tuple[int, ...] = ()
    upper_bound: Tuple[int, ...] = () # 0 entries mean "no bound"
    # fair-share weights for churn-time policy re-partitioning: when active
    # tenants' protections oversubscribe the fast tier, heavier slots keep
    # more of their ask (empty = equal weights). Only the dynamic-ownership
    # engine (core/churn.py) consumes these.
    tenant_weights: Tuple[float, ...] = ()
    # demotion/promotion machinery
    watermark_free: float = 0.02      # keep this fraction of fast pages free
    p_base: int = 256                 # unthrottled promotion scan per tick (pages)
    promo_hot_threshold: float = 2.0  # hint-fault analogue: promote after ~2 accesses
    promo_floor: float = 1.0 / 16.0   # Eq.2 floor
    # thrashing mitigation (paper §IV-F)
    thrash_table_slots: int = 1024
    t_resident: int = 8               # ticks: promoted->demoted faster than this = thrash
    r_thrashing: float = 32.0         # thrash events / period threshold
    controller_period: int = 5        # ticks between controller runs (paper: 5 s)
    steady_active_delta: float = 0.05 # steady-state detector thresholds
    steady_free_rate: float = 0.05
    hot_decay: float = 0.85           # EWMA hotness decay per tick
    # perf model (simulator): latency units per access by tier (paper Fig.2 / §V-A:
    # CXL idle latency 252ns vs ~100ns local)
    lat_fast: float = 1.0
    lat_slow: float = 2.5
    migration_cost: float = 0.0005    # system-wide stall per migrated page (noisy neighbor)
    enable_protection: bool = True
    enable_upper_bound: bool = True
    enable_promo_throttle: bool = True
    enable_thrash_mitigation: bool = True
    # observability (obs/, paper §IV-C): in-graph stats + migration ring
    obs_ring_capacity: int = 4096     # migration events kept (newest wins)
    obs_resid_buckets: int = 16       # log2 residency-histogram buckets
    obs_window_decay: float = 0.9     # EWMA decay of windowed rates

    def with_(self, **kw) -> "TieringConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    # logical rules: name -> mesh axes (see sharding/rules.py)

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    microbatches: int = 1             # gradient accumulation
    remat_policy: str = "block"       # none | block | dots_saveable | full
    grad_compression: bool = False    # int8 error-feedback DP all-reduce
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
