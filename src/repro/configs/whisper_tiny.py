"""whisper-tiny [audio] — 4L(+4L enc) d_model=384 6H d_ff=1536 vocab=51865 —
enc-dec; conv/mel frontend is a STUB: input_specs() provides precomputed
frame embeddings [B, 1500, 384]. [arXiv:2212.04356]

vocab_true=51865 padded to 51968 (×128). 6 heads do not divide the 16-way
model axis — attention stays head-replicated for this 39M-param arch
(DESIGN.md §Arch-applicability); the MLP and vocab dims still shard.
"""
from repro.configs.base import ModelConfig

VOCAB_TRUE = 51865

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,             # decoder layers
    encoder_layers=4,
    encoder_seq=1500,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51968,         # padded from 51865
    head_dim=64,
    act="gelu",
    rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="encdec", num_layers=2, encoder_layers=2,
        encoder_seq=16, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=256, head_dim=16, act="gelu")
