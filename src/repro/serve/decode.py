"""Multi-tenant decode serving with Equilibria-tiered paged KV caches.

``build_serve_step(cfg, tcfg, batch, seq)`` returns (serve_step, init_state)
for any assigned architecture family. serve_step(params, state, tokens)
decodes one token for every sequence and runs the Equilibria tiering step
(hotness from attention mass, Eq.1/Eq.2-regulated migrations, thrash
mitigation) inside the same compiled program.

State is a dict: {"kv": TieredKVCache?, "mamba": MambaCache?, "cross_k/v"?}.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TieringConfig
from repro.core.state import TenantPolicy, make_policy
from repro.memtier import kvcache as KC
from repro.memtier.tiering import equilibria_kv_step
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as TF
from repro.models.unroll import scan_layers


def fast_budget_pages(cfg: ModelConfig, tcfg: TieringConfig, batch: int,
                      seq: int) -> int:
    """Global fast-tier budget: fast_frac of the total logical pages."""
    M, Mf, Ms = KC.cache_dims(cfg, seq, tcfg.page_tokens)
    return max(int(batch * M * 0.75), 1)


def init_serve_state(cfg: ModelConfig, tcfg: TieringConfig, batch: int,
                     seq: int, abstract: bool = False,
                     params=None) -> Dict[str, object]:
    state: Dict[str, object] = {}
    dt = jnp.dtype(cfg.dtype)
    K, D = cfg.num_kv_heads, cfg.resolved_head_dim

    def arr(shape, dtype):
        return (jax.ShapeDtypeStruct(shape, dtype) if abstract
                else jnp.zeros(shape, dtype))

    if cfg.family != "ssm":
        state["kv"] = KC.init_cache(cfg, tcfg, batch, seq, abstract=abstract)
    if cfg.family in ("ssm", "hybrid"):
        nl = cfg.num_layers
        mc = S.mamba_cache_specs(cfg, batch, nl)
        state["mamba"] = (mc if abstract else
                          jax.tree_util.tree_map(
                              lambda s: jnp.zeros(s.shape, s.dtype), mc))
    if cfg.family == "vlm":
        n_units = cfg.num_layers // cfg.cross_attn_every
        state["cross_k"] = arr((n_units, batch, cfg.num_image_tokens, K, D), dt)
        state["cross_v"] = arr((n_units, batch, cfg.num_image_tokens, K, D), dt)
    if cfg.family == "encdec":
        state["cross_k"] = arr((cfg.num_layers, batch, cfg.encoder_seq, K, D), dt)
        state["cross_v"] = arr((cfg.num_layers, batch, cfg.encoder_seq, K, D), dt)
    return state


def serve_exposition(state: Dict[str, object],
                     prefix: str = "equilibria_kv") -> str:
    """Prometheus text exposition of a serve state's KV tiering counters
    (``export.kv_exposition``). Raises ValueError for attention-free
    states (pure-SSM serving carries no paged KV cache to meter)."""
    from repro.obs.export import kv_exposition
    if "kv" not in state:
        raise ValueError("serve state has no tiered KV cache "
                         "(attention-free family)")
    return kv_exposition(state["kv"], prefix=prefix)


def compute_cross_kv(params, cfg: ModelConfig, enc: jax.Array):
    """Precompute per-layer cross-attention K/V from the encoder output
    (whisper) or stub image embeddings (vlm). enc: [B, T, D].
    Returns (ck, cv): [L_cross, B, T, K, D]."""
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "encdec":
        wk = params["decoder"]["xattn"]["wk"]        # [L, D, K, hd]
        wv = params["decoder"]["xattn"]["wv"]
    elif cfg.family == "vlm":
        wk = params["units"]["cross"]["attn"]["wk"]
        wv = params["units"]["cross"]["attn"]["wv"]
    else:
        raise ValueError(cfg.family)
    enc = enc.astype(dt)
    ck = jnp.einsum("btd,ldhk->lbthk", enc, wk.astype(dt))
    cv = jnp.einsum("btd,ldhk->lbthk", enc, wv.astype(dt))
    return ck, cv


def _cross_attend(p, x, ck, cv, cfg: ModelConfig):
    """Cross-attention against precomputed K/V. x: [B,1,D]; ck/cv: [B,T,K,D]."""
    dt = jnp.dtype(cfg.dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.rms_eps)
    attn = L.attn_decode(q, ck, cv)
    return L.attention_out(p, attn, cfg)


def build_serve_step(cfg: ModelConfig, tcfg: TieringConfig, batch: int,
                     seq: int, mode: str = "equilibria"):
    """Returns serve_step(params, state, tokens [B,1]) -> (logits, state)."""
    policy = make_policy(tcfg)
    budget = fast_budget_pages(cfg, tcfg, batch, seq) if cfg.family != "ssm" else 0
    window = cfg.sliding_window

    def attend_and_update(kv: KC.TieredKVCache, lpage, fast_valid, slow_valid,
                          pools, q, k, v):
        fk, fv, sk, sv = pools
        fk, fv, sk, sv = KC.append_token_kv(fk, fv, sk, sv, kv, lpage, k, v)
        out, mf, ms = KC.tiered_paged_attention(q, fk, fv, sk, sv,
                                                fast_valid, slow_valid)
        return out, (fk, fv, sk, sv), mf, ms

    # ------------------------------------------------------------- SSM ----
    if cfg.family == "ssm":
        def serve_step(params, state, tokens):
            x = TF.embed_tokens(params, tokens, cfg)
            mc = state["mamba"]

            def body(x, xs):
                lp, h, cx, cb, cc = xs
                cache = S.MambaCache(h, cx, cb, cc)
                x, cache = S.mamba_decode_step(lp, x, cache, cfg)
                return x, cache

            x, mc2 = scan_layers(
                body, x, (params["layers"], mc.h, mc.conv_x, mc.conv_B,
                          mc.conv_C))
            logits = TF.lm_logits(params, x, cfg)
            return logits, {**state, "mamba": S.MambaCache(*mc2)}

        return serve_step

    # ------------------------------------------- families with paged KV ----
    def tiering_epilogue(kv: KC.TieredKVCache, pools, mf, ms, n_kv_layers):
        kv = kv._replace(fast_k=pools[0], fast_v=pools[1],
                         slow_k=pools[2], slow_v=pools[3],
                         seq_len=kv.seq_len + 1)
        kv = equilibria_kv_step(kv, mf / n_kv_layers, ms / n_kv_layers,
                                tcfg, policy, budget, mode=mode)
        return kv

    if cfg.family in ("dense", "moe"):
        def serve_step(params, state, tokens):
            from repro.models.unroll import unrolled
            kv: KC.TieredKVCache = state["kv"]
            kv, lpage = KC.alloc_page_for_append(kv, tcfg, policy, budget)
            fast_valid, slow_valid = KC.token_validity(kv, window)
            x = TF.embed_tokens(params, tokens, cfg)
            pos = kv.seq_len[:, None]
            B, Mf = kv.fast_page.shape
            Ms = kv.slow_page.shape[1]
            acc0 = (x, jnp.zeros((B, Mf), jnp.float32),
                    jnp.zeros((B, Ms), jnp.float32))

            def body(carry, xs):
                x, amf, ams = carry
                lp, fk, fv, sk, sv = xs
                h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
                q, k, v = L.attention_qkv(lp["attn"], h, cfg, pos)
                out, pools, mf, ms = attend_and_update(
                    kv, lpage, fast_valid, slow_valid, (fk, fv, sk, sv), q, k, v)
                x = x + L.attention_out(lp["attn"], out, cfg)
                h = L.rms_norm(x, lp["ln2"], cfg.rms_eps)
                if cfg.family == "moe":
                    y = L.moe_block_decode(lp["moe"], h, cfg)
                else:
                    y = L.mlp(lp["mlp"], h, cfg)
                return (x + y, amf + mf, ams + ms), pools

            if unrolled():
                # in-place per-layer pool updates: a scan would route the
                # pools through xs->ys and double-buffer the whole KV
                # (measured ~1x extra pool temp; EXPERIMENTS.md §Perf B)
                fk, fv = kv.fast_k, kv.fast_v
                sk, sv = kv.slow_k, kv.slow_v
                carry = acc0
                for l in range(cfg.num_layers):
                    lp = jax.tree_util.tree_map(lambda a: a[l],
                                                params["layers"])
                    carry, pools_l = body(
                        carry, (lp, fk[l], fv[l], sk[l], sv[l]))
                    fk = fk.at[l].set(pools_l[0])
                    fv = fv.at[l].set(pools_l[1])
                    sk = sk.at[l].set(pools_l[2])
                    sv = sv.at[l].set(pools_l[3])
                x, amf, ams = carry
                pools = (fk, fv, sk, sv)
            else:
                (x, amf, ams), pools = scan_layers(
                    body, acc0, (params["layers"], kv.fast_k, kv.fast_v,
                                 kv.slow_k, kv.slow_v))
            kv = tiering_epilogue(kv, pools, amf, ams, cfg.num_layers)
            return TF.lm_logits(params, x, cfg), {**state, "kv": kv}

        return serve_step

    if cfg.family == "encdec":
        def serve_step(params, state, tokens):
            kv: KC.TieredKVCache = state["kv"]
            kv, lpage = KC.alloc_page_for_append(kv, tcfg, policy, budget)
            fast_valid, slow_valid = KC.token_validity(kv, window)
            x = TF.embed_tokens(params, tokens, cfg)
            pos = kv.seq_len[:, None]
            B, Mf = kv.fast_page.shape
            Ms = kv.slow_page.shape[1]
            acc0 = (x, jnp.zeros((B, Mf), jnp.float32),
                    jnp.zeros((B, Ms), jnp.float32))

            def body(carry, xs):
                x, amf, ams = carry
                lp, fk, fv, sk, sv, ck, cv = xs
                h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
                q, k, v = L.attention_qkv(lp["attn"], h, cfg, pos)
                out, pools, mf, ms = attend_and_update(
                    kv, lpage, fast_valid, slow_valid, (fk, fv, sk, sv), q, k, v)
                x = x + L.attention_out(lp["attn"], out, cfg)
                h = L.rms_norm(x, lp["ln_x"], cfg.rms_eps)
                x = x + _cross_attend(lp["xattn"], h, ck, cv, cfg)
                h = L.rms_norm(x, lp["ln2"], cfg.rms_eps)
                return (x + L.mlp(lp["mlp"], h, cfg), amf + mf, ams + ms), pools

            (x, amf, ams), pools = scan_layers(
                body, acc0, (params["decoder"], kv.fast_k, kv.fast_v,
                             kv.slow_k, kv.slow_v,
                             state["cross_k"], state["cross_v"]))
            kv = tiering_epilogue(kv, pools, amf, ams, cfg.num_layers)
            return TF.lm_logits(params, x, cfg), {**state, "kv": kv}

        return serve_step

    if cfg.family == "vlm":
        every = cfg.cross_attn_every
        n_units = cfg.num_layers // every

        def serve_step(params, state, tokens):
            kv: KC.TieredKVCache = state["kv"]
            kv, lpage = KC.alloc_page_for_append(kv, tcfg, policy, budget)
            fast_valid, slow_valid = KC.token_validity(kv, window)
            x = TF.embed_tokens(params, tokens, cfg)
            pos = kv.seq_len[:, None]
            B, Mf = kv.fast_page.shape
            Ms = kv.slow_page.shape[1]
            n_self = every - 1
            # reshape per-unit pools: [n_units, n_self, ...]
            def units(a):
                return a.reshape((n_units, n_self) + a.shape[1:])
            acc0 = (x, jnp.zeros((B, Mf), jnp.float32),
                    jnp.zeros((B, Ms), jnp.float32))

            def unit_body(carry, xs):
                up, fk_u, fv_u, sk_u, sv_u, ck, cv = xs

                def self_body(c, xs2):
                    x, amf, ams = c
                    lp, fk, fv, sk, sv = xs2
                    h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
                    q, k, v = L.attention_qkv(lp["attn"], h, cfg, pos)
                    out, pools, mf, ms = attend_and_update(
                        kv, lpage, fast_valid, slow_valid, (fk, fv, sk, sv),
                        q, k, v)
                    x = x + L.attention_out(lp["attn"], out, cfg)
                    h = L.rms_norm(x, lp["ln2"], cfg.rms_eps)
                    return (x + L.mlp(lp["mlp"], h, cfg), amf + mf, ams + ms), pools

                c, pools_u = scan_layers(
                    self_body, carry, (up["self"], fk_u, fv_u, sk_u, sv_u))
                x, amf, ams = c
                cp = up["cross"]
                h = L.rms_norm(x, cp["ln"], cfg.rms_eps)
                a = _cross_attend(cp["attn"], h, ck, cv, cfg)
                x = x + jnp.tanh(cp["gate"].astype(jnp.float32)).astype(x.dtype) * a
                h = L.rms_norm(x, cp["ln2"], cfg.rms_eps)
                y = L.mlp(cp["mlp"], h, cfg)
                x = x + jnp.tanh(cp["gate_mlp"].astype(jnp.float32)).astype(x.dtype) * y
                return (x, amf, ams), pools_u

            (x, amf, ams), pools = scan_layers(
                unit_body, acc0,
                (params["units"], units(kv.fast_k), units(kv.fast_v),
                 units(kv.slow_k), units(kv.slow_v),
                 state["cross_k"], state["cross_v"]))
            pools = tuple(p.reshape((n_units * n_self,) + p.shape[2:])
                          for p in pools)
            kv = tiering_epilogue(kv, pools, amf, ams, n_units * n_self)
            return TF.lm_logits(params, x, cfg), {**state, "kv": kv}

        return serve_step

    if cfg.family == "hybrid":
        every = cfg.hybrid_attn_every

        def serve_step(params, state, tokens):
            kv: KC.TieredKVCache = state["kv"]
            mc: S.MambaCache = state["mamba"]
            kv, lpage = KC.alloc_page_for_append(kv, tcfg, policy, budget)
            fast_valid, slow_valid = KC.token_validity(kv, window)
            x = TF.embed_tokens(params, tokens, cfg)
            emb0 = x
            pos = kv.seq_len[:, None]
            B, Mf = kv.fast_page.shape
            Ms = kv.slow_page.shape[1]
            sp = params["shared"]
            dt = jnp.dtype(cfg.dtype)
            acc0 = (x, kv.fast_k, kv.fast_v, kv.slow_k, kv.slow_v,
                    jnp.zeros((B, Mf), jnp.float32),
                    jnp.zeros((B, Ms), jnp.float32))

            def shared_app(x, pools4, j, amf, ams):
                fk = jax.lax.dynamic_index_in_dim(pools4[0], j, 0, False)
                fv = jax.lax.dynamic_index_in_dim(pools4[1], j, 0, False)
                sk = jax.lax.dynamic_index_in_dim(pools4[2], j, 0, False)
                sv = jax.lax.dynamic_index_in_dim(pools4[3], j, 0, False)
                h = jnp.concatenate([x, emb0], axis=-1)
                h = jnp.einsum("bse,ed->bsd", h, sp["in_proj"].astype(dt))
                a = L.rms_norm(h, sp["ln1"], cfg.rms_eps)
                q, k, v = L.attention_qkv(sp["attn"], a, cfg, pos)
                out, (fk, fv, sk, sv), mf, ms = attend_and_update(
                    kv, lpage, fast_valid, slow_valid, (fk, fv, sk, sv), q, k, v)
                h = h + L.attention_out(sp["attn"], out, cfg)
                a = L.rms_norm(h, sp["ln2"], cfg.rms_eps)
                h = h + L.mlp(sp["mlp"], a, cfg)
                x = x + jnp.einsum("bsd,de->bse", h, sp["out_proj"].astype(dt))
                pools4 = tuple(
                    jax.lax.dynamic_update_index_in_dim(p, u, j, 0)
                    for p, u in zip(pools4, (fk, fv, sk, sv)))
                return x, pools4, amf + mf, ams + ms

            def body(carry, xs):
                x, fk, fv, sk, sv, amf, ams = carry
                lp, h_l, cx_l, cb_l, cc_l, idx = xs
                j = idx // every

                def with_attn(args):
                    x, pools4, amf, ams = args
                    return shared_app(x, pools4, j, amf, ams)

                def no_attn(args):
                    x, pools4, amf, ams = args
                    return x, pools4, amf, ams

                x, (fk, fv, sk, sv), amf, ams = jax.lax.cond(
                    idx % every == 0, with_attn, no_attn,
                    (x, (fk, fv, sk, sv), amf, ams))
                mcache = S.MambaCache(h_l, cx_l, cb_l, cc_l)
                x, mcache = S.mamba_decode_step(lp, x, mcache, cfg)
                return (x, fk, fv, sk, sv, amf, ams), mcache

            (x, fk, fv, sk, sv, amf, ams), mc2 = scan_layers(
                body, acc0,
                (params["layers"], mc.h, mc.conv_x, mc.conv_B, mc.conv_C,
                 jnp.arange(cfg.num_layers)))
            n_kv = cfg.num_layers // every + 1
            kv = tiering_epilogue(kv, (fk, fv, sk, sv), amf, ams, n_kv)
            return TF.lm_logits(params, x, cfg), {
                **state, "kv": kv, "mamba": S.MambaCache(*mc2)}

        return serve_step

    raise ValueError(cfg.family)
