"""Jit'd wrapper for the SSD scan with impl dispatch."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan_tpu
from repro.kernels.ssd_scan.ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd_scan(x, a, b, c, *, chunk: int = 128, impl: str = "ref"):
    """Mamba2 SSD. x: [B,S,H,P]; a: [B,S,H]; b,c: [B,S,H,N]."""
    if impl == "ref":
        return ssd_scan_ref(x, a, b, c, chunk)
    return ssd_scan_tpu(x, a, b, c, chunk=chunk,
                        interpret=(impl == "pallas_interpret"))
