"""Pure-jnp oracle for the Mamba2 SSD scan kernel: the chunked SSD from
models/ssm.py restricted to a single (batch, head) — plus the full-array
wrapper used for allclose tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked, ssd_recurrent_ref  # noqa: F401


def ssd_scan_ref(x, a, b, c, chunk: int, h0=None):
    """x: [B,S,H,P]; a: [B,S,H]; b,c: [B,S,H,N] -> (y [B,S,H,P], h [B,H,P,N]).
    (Delegates to the framework implementation, which is itself validated
    against the O(S) recurrent form.)"""
    return ssd_chunked(x, a, b, c, chunk, h0=h0)
