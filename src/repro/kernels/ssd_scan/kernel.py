"""Mamba2 SSD chunked-scan kernel (Pallas TPU).

Grid = (B, H, n_chunks) with the chunk dimension innermost ("arbitrary"):
the inter-chunk SSM state h [P, N] lives in VMEM scratch and carries the
recurrence; each program computes one chunk's intra-chunk (dual, quadratic)
term and the state contribution — the two matmuls hit the MXU with
[Q, N] x [N, P] shapes. Chunk length Q defaults to 128/256: Q x Q decay
matrix and Q x max(N, P) operands stay comfortably inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_s, *,
                nc: int, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_s[...] = jnp.zeros_like(h_s)

    x = x_ref[0, 0].astype(jnp.float32)          # [Q, P]
    a = a_ref[0, 0].astype(jnp.float32)          # [Q]
    b = b_ref[0, 0].astype(jnp.float32)          # [Q, N]
    c = c_ref[0, 0].astype(jnp.float32)          # [Q, N]

    a_cum = jnp.cumsum(a)                        # [Q]
    # L[i,j] = exp(sum_{k=j+1..i} a_k) for i >= j
    diff = a_cum[:, None] - a_cum[None, :]
    q = a.shape[0]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)
    # intra-chunk: y_diag = ((C B^T) * L) X
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ()))) * L  # [Q,Q]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())))      # [Q,P]
    # inter-chunk: y_off = (C h^T) * exp(a_cum)
    h = h_s[...]                                                      # [P,N]
    y += jax.lax.dot_general(c, h, (((1,), (1,)), ((), ()))) \
        * jnp.exp(a_cum)[:, None]
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state update: h' = exp(A_chunk) h + X^T (B * decay)
    decay_states = jnp.exp(a_cum[-1] - a_cum)                          # [Q]
    contrib = jax.lax.dot_general(x, b * decay_states[:, None],
                                  (((0,), (0,)), ((), ())))            # [P,N]
    h_s[...] = jnp.exp(a_cum[-1]) * h + contrib

    @pl.when(ic == nc - 1)
    def _fin():
        hout_ref[0, 0] = h_s[...]


def ssd_scan_tpu(x, a, b, c, *, chunk: int = 128, interpret: bool = False):
    """x: [B,S,H,P]; a: [B,S,H]; b,c: [B,S,H,N] (groups pre-broadcast).
    Returns (y [B,S,H,P], h_final [B,H,P,N])."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    # layout: [B, H, S, *] so the chunk dim tiles cleanly
    xt = x.transpose(0, 2, 1, 3)
    at = a.transpose(0, 2, 1)
    bt = b.transpose(0, 2, 1, 3)
    ct = c.transpose(0, 2, 1, 3)

    kernel = functools.partial(_ssd_kernel, nc=nc, chunk=chunk)
    y, hf = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, chunk), lambda ib, ih, ic: (ib, ih, ic)),
            pl.BlockSpec((1, 1, chunk, N), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda ib, ih, ic: (ib, ih, ic, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, chunk, P), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, P, N), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=tpu_compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xt, at, bt, ct)
    return y.transpose(0, 2, 1, 3), hf
