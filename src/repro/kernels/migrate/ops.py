"""Jit'd wrapper for page migration with impl dispatch."""
from __future__ import annotations

import functools

import jax

from repro.kernels.migrate.kernel import migrate_pages_tpu
from repro.kernels.migrate.ref import migrate_pages_ref


@functools.partial(jax.jit, static_argnames=("impl",), donate_argnums=(1,))
def migrate_pages(src_pool, dst_pool, src_idx, dst_idx, sel, *,
                  impl: str = "ref"):
    if impl == "ref":
        return migrate_pages_ref(src_pool, dst_pool, src_idx, dst_idx, sel)
    return migrate_pages_tpu(src_pool, dst_pool, src_idx, dst_idx, sel,
                             interpret=(impl == "pallas_interpret"))
