"""Jit'd wrappers for the page-move kernels with impl dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.migrate.kernel import commit_moves_tpu, migrate_pages_tpu
from repro.kernels.migrate.ref import commit_moves_ref, migrate_pages_ref


@functools.partial(jax.jit, static_argnames=("impl", "page_block"),
                   donate_argnums=(1,))
def migrate_pages(src_pool, dst_pool, src_idx, dst_idx, sel, *,
                  impl: str = "ref", page_block: int = 8):
    if impl == "ref":
        return migrate_pages_ref(src_pool, dst_pool, src_idx, dst_idx, sel)
    return migrate_pages_tpu(src_pool, dst_pool, src_idx, dst_idx, sel,
                             page_block=page_block,
                             interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("direction", "to_tier", "impl"))
def commit_moves(tier, ring_data, head, pages, take, tenants, hot, t, *,
                 direction: int, to_tier: int, impl: str = "ref"):
    """Fused tier scatter + migration-ring append over a compact move
    stream. tier [L] i32; ring_data [C, 5] i32; head scalar i32;
    pages [N] i32 (sentinel L on non-taken lanes is fine); take [N] bool;
    tenants [N] i32; hot [N] f32 (hotness-at-move, ring-bitcast); t scalar
    tick. Returns (tier', ring_data', head') — bit-identical to the
    separate jnp tier ``where`` + ``obs/trace.ring_record``."""
    hot_bits = jax.lax.bitcast_convert_type(hot.astype(jnp.float32),
                                            jnp.int32)
    if impl == "ref":
        return commit_moves_ref(tier, ring_data, head, pages, take, tenants,
                                hot_bits, t, direction=direction,
                                to_tier=to_tier)
    # lane-pad the move stream to a multiple of 128: untaken pad lanes are
    # commit no-ops, and the fixed width keeps the kernel's prefix-scan
    # depth (and so the tick jaxpr) constant across stream sizes
    n = pages.shape[0]
    pad = -n % 128
    if pad:
        pages = jnp.pad(pages, (0, pad))
        take = jnp.pad(take, (0, pad))
        tenants = jnp.pad(tenants, (0, pad))
        hot_bits = jnp.pad(hot_bits, (0, pad))
    tier2, data2, head2 = commit_moves_tpu(
        tier[None].astype(jnp.int32), ring_data,
        head.astype(jnp.int32).reshape(1, 1),
        pages[None].astype(jnp.int32), take[None].astype(jnp.int32),
        tenants[None].astype(jnp.int32), hot_bits[None],
        t.astype(jnp.int32).reshape(1, 1),
        direction=direction, to_tier=to_tier,
        interpret=(impl == "pallas_interpret"))
    return tier2[0], data2, head2[0, 0]
