"""Pure-jnp oracle for the page-migration kernel (matches
memtier.tiering's `move`: one page per selected sequence, all layers)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def migrate_pages_ref(src_pool, dst_pool, src_idx, dst_idx, sel):
    """src/dst_pool: [L, B, Mp, pt, K, D]; src_idx/dst_idx: [B]; sel: [B].
    Returns dst_pool with page src_pool[:, b, src_idx[b]] written at
    dst_idx[b] for selected b."""
    L, B = src_pool.shape[:2]
    barange = jnp.arange(B)
    src = src_pool[:, barange, src_idx]
    cur = dst_pool[:, barange, dst_idx]
    out = jnp.where(sel[None, :, None, None, None], src, cur)
    return dst_pool.at[:, barange, dst_idx].set(out)


def commit_moves_ref(tier, ring_data, head, pages, take, tenants, hot_bits,
                     t, *, direction: int, to_tier: int):
    """jnp oracle for the tick's fused page-move commit. Bit-identical to
    the tick core's separate ``jnp.where`` tier update + ``ring_record``
    append (obs/trace.py): same newest-wins slot math, same packed row
    layout, same drop-mode scatters.

    tier [L] i32; ring_data [C, 5] i32; head scalar i32; pages/take/
    tenants/hot_bits [N] (hot scores pre-bitcast to i32). Returns
    (tier', ring_data', head')."""
    L = tier.shape[0]
    C = ring_data.shape[0]
    m = take
    offs = jnp.cumsum(m.astype(jnp.int32)) - 1
    total = offs[-1] + 1
    keep = m & (offs >= total - C)          # newest C events win
    idx = jnp.where(keep, (head + offs) % C, C)   # C = OOB -> dropped
    rows = jnp.stack([
        jnp.broadcast_to(t, m.shape).astype(jnp.int32),
        tenants.astype(jnp.int32),
        pages.astype(jnp.int32),
        jnp.full(m.shape, direction, jnp.int32),
        hot_bits,
    ], axis=-1)
    data = ring_data.at[idx].set(rows, mode="drop")
    tier2 = tier.at[jnp.where(m, pages, L)].set(to_tier, mode="drop")
    return tier2, data, head + m.sum()
