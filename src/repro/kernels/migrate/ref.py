"""Pure-jnp oracle for the page-migration kernel (matches
memtier.tiering's `move`: one page per selected sequence, all layers)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def migrate_pages_ref(src_pool, dst_pool, src_idx, dst_idx, sel):
    """src/dst_pool: [L, B, Mp, pt, K, D]; src_idx/dst_idx: [B]; sel: [B].
    Returns dst_pool with page src_pool[:, b, src_idx[b]] written at
    dst_idx[b] for selected b."""
    L, B = src_pool.shape[:2]
    barange = jnp.arange(B)
    src = src_pool[:, barange, src_idx]
    cur = dst_pool[:, barange, dst_idx]
    out = jnp.where(sel[None, :, None, None, None], src, cur)
    return dst_pool.at[:, barange, dst_idx].set(out)
