"""Page-migration kernel (Pallas TPU): batched promote/demote page copies.

The scalar-prefetch page table (src_idx, dst_idx, sel) drives the BlockSpec
index maps — the DMA engine streams exactly the selected [pt, K, D] page per
(layer, sequence) program, nothing else. The destination pool is
input/output-aliased so unselected sequences keep their data without any
copy. On real hardware this is the HBM<->host (CXL-analogue) transfer; the
same kernel covers both directions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _mig_kernel(src_idx_ref, dst_idx_ref, sel_ref, src_ref, dst_in_ref,
                dst_ref):
    b = pl.program_id(1)

    @pl.when(sel_ref[b] != 0)
    def _copy():
        dst_ref[...] = src_ref[...]

    @pl.when(sel_ref[b] == 0)
    def _keep():
        dst_ref[...] = dst_in_ref[...]


def migrate_pages_tpu(src_pool, dst_pool, src_idx, dst_idx, sel, *,
                      interpret: bool = False):
    """src/dst_pool: [L, B, Mp, pt, K, D]; src_idx/dst_idx: [B]; sel: [B]."""
    L, B, Ms_, pt, K, D = src_pool.shape
    Md = dst_pool.shape[2]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(L, B),
        in_specs=[
            pl.BlockSpec((1, 1, 1, pt, K, D),
                         lambda l, b, si, di, se: (l, b, si[b], 0, 0, 0)),
            pl.BlockSpec((1, 1, 1, pt, K, D),
                         lambda l, b, si, di, se: (l, b, di[b], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, pt, K, D),
                               lambda l, b, si, di, se: (l, b, di[b], 0, 0, 0)),
    )
    return pl.pallas_call(
        _mig_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_pool.shape, dst_pool.dtype),
        input_output_aliases={4: 0},   # dst_pool (3 scalars + src = idx 4) -> out
        compiler_params=tpu_compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.maximum(src_idx, 0), jnp.maximum(dst_idx, 0),
      sel.astype(jnp.int32), src_pool, dst_pool)
