"""Page-migration kernels (Pallas TPU).

``migrate_pages_tpu`` — batched promote/demote page copies for the KV
pools: the scalar-prefetch page table (src_idx, dst_idx, sel) drives the
BlockSpec index maps, so the DMA engine streams exactly the selected
[page_block·pt, K, D] slab per (layer-block, sequence) program, nothing
else. The destination pool is input/output-aliased so unselected sequences
keep their data without any copy. On real hardware this is the HBM<->host
(CXL-analogue) transfer; the same kernel covers both directions. The layer
axis is tiled by ``page_block`` (not the seed's hardcoded single-layer
blocks) so the grid is L/page_block × B instead of L × B — at real batch
sizes the per-program dispatch overhead dominated the copy itself.

``commit_moves_tpu`` — the tiering tick's fused move commit: one kernel
pass applies the promotion/demotion scatter to the [L] tier vector AND
appends the packed migration-ring events, replacing a drop-mode scatter
plus the five-column ring build/scatter of ``obs/trace.ring_record``. The
vector phase computes the ring slot of every taken lane (log-shift prefix
sum, newest-C-wins window, modular head offset — bit-identical to the jnp
ring math); the scalar phase walks the compact [N = T·k] lane stream and
commits both stores. ``tier`` and ``ring_data`` are input/output-aliased:
the commit is in-place, the way a real migration engine retires a move
queue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _mig_kernel(src_idx_ref, dst_idx_ref, sel_ref, src_ref, dst_in_ref,
                dst_ref):
    b = pl.program_id(1)

    @pl.when(sel_ref[b] != 0)
    def _copy():
        dst_ref[...] = src_ref[...]

    @pl.when(sel_ref[b] == 0)
    def _keep():
        dst_ref[...] = dst_in_ref[...]


def migrate_pages_tpu(src_pool, dst_pool, src_idx, dst_idx, sel, *,
                      page_block: int = 8, interpret: bool = False):
    """src/dst_pool: [L, B, Mp, pt, K, D]; src_idx/dst_idx: [B]; sel: [B].

    ``page_block`` layers are copied per program (clamped down to a divisor
    of L), amortizing grid dispatch over an 8x larger DMA slab by default.
    """
    L, B, Ms_, pt, K, D = src_pool.shape
    Md = dst_pool.shape[2]
    pb = max(min(page_block, L), 1)
    while L % pb:
        pb -= 1

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(L // pb, B),
        in_specs=[
            pl.BlockSpec((pb, 1, 1, pt, K, D),
                         lambda l, b, si, di, se: (l, b, si[b], 0, 0, 0)),
            pl.BlockSpec((pb, 1, 1, pt, K, D),
                         lambda l, b, si, di, se: (l, b, di[b], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((pb, 1, 1, pt, K, D),
                               lambda l, b, si, di, se: (l, b, di[b], 0, 0, 0)),
    )
    return pl.pallas_call(
        _mig_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_pool.shape, dst_pool.dtype),
        input_output_aliases={4: 0},   # dst_pool (3 scalars + src = idx 4) -> out
        compiler_params=tpu_compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.maximum(src_idx, 0), jnp.maximum(dst_idx, 0),
      sel.astype(jnp.int32), src_pool, dst_pool)


# ------------------------------------------------------- commit_moves ------
def _row_prefix(x):
    """Inclusive prefix sum along axis 1 (log-shift adds, int32)."""
    N = x.shape[1]
    inc = x
    off = 1
    while off < N:
        shifted = jnp.concatenate(
            [jnp.zeros((x.shape[0], off), jnp.int32), inc[:, :-off]], axis=1)
        inc = inc + shifted
        off *= 2
    return inc


def _moves_kernel(pages_ref, take_ref, ten_ref, hot_ref, t_ref, head_ref,
                  tier_in_ref, ring_in_ref, tier_ref, ring_ref, head_out_ref,
                  idx_ref, *, direction: int, to_tier: int):
    C = ring_ref.shape[0]
    N = pages_ref.shape[1]
    take = take_ref[...]                               # [1, N] i32
    incl = _row_prefix(take)
    offs = incl - 1                                    # slot among selected
    total = incl[0, -1]
    head = head_ref[0, 0]
    keep = (take != 0) & (offs >= total - C)           # newest C events win
    idx_ref[...] = jnp.where(keep, (head + offs) % C, C)   # C = OOB -> skip
    tier_ref[...] = tier_in_ref[...]
    ring_ref[...] = ring_in_ref[...]
    head_out_ref[0, 0] = head + total

    def commit(j, _):
        @pl.when(take_ref[0, j] != 0)
        def _tier():
            tier_ref[0, pages_ref[0, j]] = to_tier

        ii = idx_ref[0, j]

        @pl.when(ii < C)
        def _ring():
            ring_ref[ii, 0] = t_ref[0, 0]
            ring_ref[ii, 1] = ten_ref[0, j]
            ring_ref[ii, 2] = pages_ref[0, j]
            ring_ref[ii, 3] = direction
            ring_ref[ii, 4] = hot_ref[0, j]
        return 0

    jax.lax.fori_loop(0, N, commit, 0)


def commit_moves_tpu(tier, ring_data, head, pages, take, tenants, hot_bits,
                     t, *, direction: int, to_tier: int,
                     interpret: bool = False):
    """tier [1, L] i32; ring_data [C, 5] i32; head/t [1, 1] i32;
    pages/take/tenants/hot_bits [1, N] i32. Whole-array refs, no grid:
    the move stream is the compact [T·k] candidate lane space, small enough
    to sit in VMEM next to the tier vector."""
    L = tier.shape[1]
    C = ring_data.shape[0]
    N = pages.shape[1]
    return pl.pallas_call(
        functools.partial(_moves_kernel, direction=direction,
                          to_tier=to_tier),
        out_shape=[
            jax.ShapeDtypeStruct((1, L), jnp.int32),
            jax.ShapeDtypeStruct((C, 5), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        input_output_aliases={6: 0, 7: 1},   # tier, ring_data -> in place
        scratch_shapes=[pltpu.VMEM((1, N), jnp.int32)],
        compiler_params=tpu_compiler_params(()),
        interpret=interpret,
    )(pages, take, tenants, hot_bits, t, head, tier, ring_data)
