"""jnp reference oracles for the selection-core kernels.

These are the canonical semantics the Pallas kernels must reproduce
bit-exactly (the interpret-mode equivalence suite pins them), and they are
also the *compiled* selection-core algorithm on backends without a Mosaic
lowering: a batched masked ``top_k`` over padded [T, S] tenant rows is O(L)
where the generic composite-key sort path is O(L log L), so ``impl="ref"``
is already the fast path on CPU.

Semantics shared with the kernels:

* ``seg_topk``: lane j of row t holds that row's j-th best eligible column
  by (score desc, column asc) — the exact ``jax.lax.top_k`` "lower index
  wins" tie-break. Lanes at or beyond ``min(quota[t], k)`` (or beyond the
  row's eligible count) carry the sentinel column ``S`` and ``take=False``.
* ``seg_reduce``: per-row sum and exclusive prefix sum of the masked values.
  Integer-only by contract: integer addition is associative, so any kernel
  reduction order is bit-equal; floats must stay on the golden-pinned jnp
  association in ``core/select.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def seg_topk_ref(score: jax.Array, valid: jax.Array, quotas: jax.Array,
                 k: int):
    """score/valid: [T, S]; quotas: [T] int. Returns (cols, take, counts):
    cols [T, k] int32 (sentinel S on non-taken lanes), take [T, k] bool,
    counts [T] int32."""
    S = score.shape[1]
    elig = valid & jnp.isfinite(score)
    s = jnp.where(elig, score, -jnp.inf)
    vals, cols = jax.lax.top_k(s, k)
    take = (jnp.arange(k, dtype=jnp.int32)[None, :]
            < quotas.astype(jnp.int32)[:, None]) & (vals > -jnp.inf)
    cols = jnp.where(take, cols, S).astype(jnp.int32)
    return cols, take, take.sum(axis=1).astype(jnp.int32)


def seg_reduce_ref(x: jax.Array, valid: jax.Array):
    """x/valid: [T, S] (x integer). Returns (sums [T] int32,
    prefix [T, S] int32 exclusive prefix sum along axis 1)."""
    xm = jnp.where(valid, x, 0).astype(jnp.int32)
    cs = jnp.cumsum(xm, axis=1, dtype=jnp.int32)
    return cs[:, -1], cs - xm


def seg_sums_ref(x: jax.Array, valid: jax.Array) -> jax.Array:
    """Sum-only variant of ``seg_reduce_ref`` (no prefix output)."""
    return jnp.where(valid, x, 0).astype(jnp.int32).sum(
        axis=1, dtype=jnp.int32)
