"""Jit'd wrappers for the selection-core kernels with impl dispatch.

``impl`` ∈ {"ref", "pallas", "pallas_interpret"}: "ref" is the jnp oracle
(and the compiled fast path on backends without a Mosaic lowering),
"pallas" lowers to TPU, "pallas_interpret" runs the same kernel on the
Pallas interpreter (the CI equivalence gate).

The wrappers own the tile-alignment contract: rows are padded to a
multiple of ``block_rows`` (quota 0, all-invalid) and columns to a
multiple of 128 (the TPU lane width); outputs are sliced back and the
kernel's padded-width sentinel is renormalized to the logical ``S``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.select.kernel import (seg_reduce_tpu, seg_sums_tpu,
                                         seg_topk_tpu)
from repro.kernels.select.ref import (seg_reduce_ref, seg_sums_ref,
                                      seg_topk_ref)

_LANE = 128


def _pad_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _pad_rows(score, valid, block_rows):
    T, S = score.shape
    Tp, Sp = _pad_up(T, block_rows), _pad_up(S, _LANE)
    if (Tp, Sp) == (T, S):
        return score, valid
    score = jnp.pad(score, ((0, Tp - T), (0, Sp - S)))
    valid = jnp.pad(valid, ((0, Tp - T), (0, Sp - S)))
    return score, valid


@functools.partial(jax.jit, static_argnames=("k", "impl", "block_rows"))
def seg_topk(score, valid, quotas, k: int, *, impl: str = "ref",
             block_rows: int = 8):
    """Per-row quota-bounded top-k. score/valid: [T, S]; quotas: [T].
    Returns (cols [T, k] i32 — sentinel S on non-taken lanes,
    take [T, k] bool, counts [T] i32)."""
    T, S = score.shape
    k = max(min(k, S), 1)
    score = score.astype(jnp.float32)
    if impl == "ref":
        return seg_topk_ref(score, valid, quotas, k)
    elig = (valid & jnp.isfinite(score)).astype(jnp.int32)
    score_p, elig_p = _pad_rows(score, elig, block_rows)
    Tp = score_p.shape[0]
    q = jnp.zeros((Tp, 1), jnp.int32).at[:T, 0].set(quotas.astype(jnp.int32))
    cols, take, counts = seg_topk_tpu(
        score_p, elig_p, q, k=k, block_rows=block_rows,
        interpret=(impl == "pallas_interpret"))
    # padded-width sentinel (Sp) -> logical sentinel (S); real cols are < S
    cols = jnp.minimum(cols[:T], S)
    return cols, take[:T].astype(bool), counts[:T, 0]


@functools.partial(jax.jit, static_argnames=("impl", "block_rows"))
def seg_reduce(x, valid, *, impl: str = "ref", block_rows: int = 8):
    """Fused per-row sum + exclusive prefix sum (integers only).
    x/valid: [T, S]. Returns (sums [T] i32, prefix [T, S] i32)."""
    T, S = x.shape
    x = x.astype(jnp.int32)
    if impl == "ref":
        return seg_reduce_ref(x, valid)
    x_p, valid_p = _pad_rows(x, valid.astype(jnp.int32), block_rows)
    sums, pre = seg_reduce_tpu(x_p, valid_p, block_rows=block_rows,
                               interpret=(impl == "pallas_interpret"))
    return sums[:T, 0], pre[:T, :S]


@functools.partial(jax.jit, static_argnames=("impl", "block_rows"))
def seg_sums(x, valid, *, impl: str = "ref", block_rows: int = 8):
    """Per-row masked sum (integers only). x/valid: [T, S] -> [T] i32."""
    T, S = x.shape
    x = x.astype(jnp.int32)
    if impl == "ref":
        return seg_sums_ref(x, valid)
    x_p, valid_p = _pad_rows(x, valid.astype(jnp.int32), block_rows)
    sums = seg_sums_tpu(x_p, valid_p, block_rows=block_rows,
                        interpret=(impl == "pallas_interpret"))
    return sums[:T, 0]
