"""Selection-core Pallas TPU kernels: tiled segmented top-k + reductions.

Both kernels tile the [T, S] tenant-row space over row blocks of
``block_rows`` (grid = T/block_rows programs, each owning a [block_rows, S]
VMEM-resident tile), so VMEM pressure is bounded by the widest tenant row,
not by L, and the grid is embarrassingly parallel across tenants.

``seg_topk`` fuses the per-tenant masking, scoring and quota-bounded
selection that the jnp path spreads across a gather, a masked ``top_k``
and a take-compare: one pass of iterative max-extraction per tile. The
extraction loop runs ``min(max(quota), k)`` rounds — the *quota* bound, not
the row width — and each round is a row-max + row-argmin over the tile
(pure VPU work, no sort network, no cross-program traffic). Ties break as
(score desc, column asc), bit-matching ``jax.lax.top_k``'s "lower index
wins".

``seg_reduce`` replaces the length-L cumsum + boundary gathers of
``allocation_ranks_contiguous``/``by_tenant_contiguous`` with a per-row
Hillis-Steele log-shift scan: log2(S) shifted adds per tile, emitting the
per-row total and the exclusive prefix in one pass. Integer-only: integer
addition is associative so the reordered reduction is bit-equal to the jnp
cumsum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


# ------------------------------------------------------------- seg_topk ----
def _seg_topk_kernel(score_ref, valid_ref, quota_ref, cols_ref, take_ref,
                     cnt_ref, *, k: int):
    s = jnp.where(valid_ref[...] != 0, score_ref[...], -jnp.inf)  # [Bt, S]
    Bt, S = s.shape
    q = quota_ref[...][:, 0]                                      # [Bt] i32
    col_iota = jax.lax.broadcasted_iota(jnp.int32, (Bt, S), 1)
    lane_iota = jax.lax.broadcasted_iota(jnp.int32, (Bt, k), 1)
    # Quota-bounded round count: rows that exhaust their quota (or run out
    # of eligible columns) keep looping but stop committing lanes.
    rounds = jnp.minimum(jnp.max(jnp.maximum(q, 0)), k)

    def round_(j, carry):
        s, cols, take = carry
        m = jnp.max(s, axis=1)                       # row max  [Bt]
        hit = s == m[:, None]
        c = jnp.min(jnp.where(hit, col_iota, S), axis=1)   # lowest max col
        ok = (m > -jnp.inf) & (j < q)
        lane = lane_iota == j
        commit = lane & ok[:, None]
        cols = jnp.where(commit, c[:, None], cols)
        take = jnp.where(commit, 1, take)
        s = jnp.where(col_iota == c[:, None], -jnp.inf, s)  # consume winner
        return s, cols, take

    cols0 = jnp.full((Bt, k), S, jnp.int32)
    take0 = jnp.zeros((Bt, k), jnp.int32)
    _, cols, take = jax.lax.fori_loop(0, rounds, round_, (s, cols0, take0))
    cols_ref[...] = cols
    take_ref[...] = take
    cnt_ref[...] = take.sum(axis=1, dtype=jnp.int32)[:, None]


def seg_topk_tpu(score, valid, quotas, *, k: int, block_rows: int = 8,
                 interpret: bool = False):
    """score [T, S] f32, valid [T, S] int32, quotas [T, 1] int32; T must be
    a multiple of ``block_rows`` (ops wrapper pads). Returns
    (cols [T, k] i32 with sentinel S, take [T, k] i32, counts [T, 1] i32)."""
    T, S = score.shape
    Bt = block_rows
    grid = (T // Bt,)
    return pl.pallas_call(
        functools.partial(_seg_topk_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bt, S), lambda i: (i, 0)),
            pl.BlockSpec((Bt, S), lambda i: (i, 0)),
            pl.BlockSpec((Bt, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((Bt, k), lambda i: (i, 0)),
            pl.BlockSpec((Bt, k), lambda i: (i, 0)),
            pl.BlockSpec((Bt, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, k), jnp.int32),
            jax.ShapeDtypeStruct((T, k), jnp.int32),
            jax.ShapeDtypeStruct((T, 1), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(("parallel",)),
        interpret=interpret,
    )(score, valid, quotas)


# ----------------------------------------------------------- seg_reduce ----
def _row_scan(x):
    """Inclusive prefix sum along axis 1 (log-shift adds, int32)."""
    S = x.shape[1]
    inc = x
    off = 1
    while off < S:
        shifted = jnp.concatenate(
            [jnp.zeros((x.shape[0], off), jnp.int32), inc[:, :-off]], axis=1)
        inc = inc + shifted
        off *= 2
    return inc


def _seg_reduce_kernel(x_ref, valid_ref, sum_ref, pre_ref):
    x = jnp.where(valid_ref[...] != 0, x_ref[...], 0)
    inc = _row_scan(x)
    sum_ref[...] = inc[:, -1:]
    pre_ref[...] = inc - x


def _seg_sums_kernel(x_ref, valid_ref, sum_ref):
    x = jnp.where(valid_ref[...] != 0, x_ref[...], 0)
    sum_ref[...] = x.sum(axis=1, dtype=jnp.int32)[:, None]


def seg_reduce_tpu(x, valid, *, block_rows: int = 8,
                   interpret: bool = False):
    """x/valid [T, S] int32, T a multiple of ``block_rows``. Returns
    (sums [T, 1] i32, prefix [T, S] i32)."""
    T, S = x.shape
    Bt = block_rows
    return pl.pallas_call(
        _seg_reduce_kernel,
        grid=(T // Bt,),
        in_specs=[
            pl.BlockSpec((Bt, S), lambda i: (i, 0)),
            pl.BlockSpec((Bt, S), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((Bt, 1), lambda i: (i, 0)),
            pl.BlockSpec((Bt, S), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, 1), jnp.int32),
            jax.ShapeDtypeStruct((T, S), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(("parallel",)),
        interpret=interpret,
    )(x, valid)


def seg_sums_tpu(x, valid, *, block_rows: int = 8,
                 interpret: bool = False):
    """Sum-only variant (skips the [T, S] prefix write for by_tenant)."""
    T, S = x.shape
    Bt = block_rows
    return pl.pallas_call(
        _seg_sums_kernel,
        grid=(T // Bt,),
        in_specs=[
            pl.BlockSpec((Bt, S), lambda i: (i, 0)),
            pl.BlockSpec((Bt, S), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((Bt, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, 1), jnp.int32),
        compiler_params=tpu_compiler_params(("parallel",)),
        interpret=interpret,
    )(x, valid)
