"""Pure-jnp oracle for the tiered paged-attention kernel.

Computes a *pool-partial* attention: online-softmax statistics plus per-page
attention mass over ONE pool (fast or slow). Two partials merge into the
final output (ops.py), mirroring memtier.kvcache.tiered_paged_attention.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def pool_attention_partial_ref(q, pool_k, pool_v, slot_page, seq_len, *,
                               window: Optional[int] = None,
                               sm_scale: Optional[float] = None):
    """q: [B,H,D]; pool_k/v: [B,Mp,pt,K,D]; slot_page: [B,Mp] (absolute page
    id, -1 free); seq_len: [B] (current position, inclusive).

    Returns (acc [B,H,D] f32 — UNNORMALIZED, m [B,H], l [B,H],
             mass [B,H,Mp] — per-head unnormalized page attention mass).
    """
    B, Mp, pt, K, D = pool_k.shape
    H = q.shape[1]
    G = H // K
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(D)
    ke = jnp.repeat(pool_k, G, axis=3).reshape(B, Mp * pt, H, D)
    ve = jnp.repeat(pool_v, G, axis=3).reshape(B, Mp * pt, H, D)
    s = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32) * scale,
                   ke.astype(jnp.float32))
    tok = (slot_page.astype(jnp.int32) * pt)[:, :, None] + jnp.arange(pt)
    ok = (slot_page >= 0)[:, :, None] & (tok <= seq_len[:, None, None])
    if window is not None:
        ok &= tok > (seq_len[:, None, None] - window)
    ok = ok.reshape(B, 1, Mp * pt)
    s = jnp.where(ok, s, NEG_INF)
    m = s.max(axis=-1)                                     # [B,H]
    p = jnp.where(ok, jnp.exp(s - m[..., None]), 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bht,bthd->bhd", p, ve.astype(jnp.float32))
    mass = p.reshape(B, H, Mp, pt).sum(axis=-1)            # [B,H,Mp]
    return acc, m, l, mass


def merge_partials_ref(q_dtype, partials):
    """Merge pool partials [(acc,m,l,mass), ...] -> (out [B,H,D], masses)."""
    ms = jnp.stack([p[1] for p in partials])               # [P,B,H]
    m = ms.max(axis=0)
    outs, masses, l_tot = None, [], None
    for acc, mp, lp, mass in partials:
        c = jnp.exp(mp - m)                                # [B,H]
        l_tot = lp * c if l_tot is None else l_tot + lp * c
        outs = acc * c[..., None] if outs is None else outs + acc * c[..., None]
        masses.append(mass * c[:, :, None])
    out = outs / jnp.maximum(l_tot[..., None], 1e-30)
    denom = jnp.maximum(l_tot.sum(axis=1), 1e-30)          # [B]
    page_masses = [(mm.sum(axis=1) / denom[:, None]) for mm in masses]
    return out.astype(q_dtype), page_masses
