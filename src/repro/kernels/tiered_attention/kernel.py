"""Tiered paged-attention decode kernel (Pallas TPU) — the paper's hot path.

One invocation computes the pool-partial attention of a single tier's page
pool (fast = HBM pages, slow = CXL/host-class pages; on real hardware the
slow pool ref lives in pinned_host memory and Mosaic streams it via DMA).
Each (b, block) program:
  * loads `page_block` pages [page_block*pt tokens, K, D] into VMEM,
  * computes masked scores for all H = K*G query heads (GQA by static K
    loop — no KV expansion, each kv head read once),
  * online-softmax accumulates (acc, m, l) in VMEM scratch,
  * emits the per-page attention mass — the paper's hotness signal ("NUMA
    hint faults" == softmax weights) — with a per-block stabilizer so ops.py
    can renormalize exactly.

Grid = (B, nblk), nblk innermost/"arbitrary": scratch persists, outputs
(acc, m, l) written on the last block.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _kernel(seq_ref, q_ref, k_ref, v_ref, page_ref,
            acc_ref, m_ref, l_ref, mass_ref, mstab_ref,
            acc_s, m_s, l_s, *,
            sm_scale: float, window: Optional[int], K: int, G: int,
            pt: int, page_block: int, nblk: int):
    ib = pl.program_id(1)
    H = K * G
    T = page_block * pt

    @pl.when(ib == 0)
    def _init():
        acc_s[...] = jnp.zeros_like(acc_s)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0].astype(jnp.float32) * sm_scale            # [H, D]
    kblk = k_ref[0].astype(jnp.float32)                    # [page_block, pt, K, D]
    vblk = v_ref[0].astype(jnp.float32)
    pages = page_ref[0]                                    # [page_block] int32
    seq = seq_ref[0]

    kf = kblk.reshape(T, K, -1)
    vf = vblk.reshape(T, K, -1)

    # scores for all heads, kv-head at a time (GQA without expansion)
    s_rows = []
    for kk in range(K):
        qk = q.reshape(K, G, -1)[kk]                       # [G, D]
        s_rows.append(jax.lax.dot_general(
            qk, kf[:, kk, :], (((1,), (1,)), ((), ()))))   # [G, T]
    s = jnp.concatenate(s_rows, axis=0)                    # [H, T]

    # validity: absolute token id from the page's absolute page number
    tok = (pages.astype(jnp.int32) * pt)[:, None] + jax.lax.broadcasted_iota(
        jnp.int32, (page_block, pt), 1)
    ok = (pages >= 0)[:, None] & (tok <= seq)
    if window is not None:
        ok &= tok > (seq - window)
    okf = ok.reshape(1, T)
    s = jnp.where(okf, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))             # [H]
    p = jnp.where(okf, jnp.exp(s - m_new[:, None]), 0.0)   # [H, T]
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p.sum(axis=1)
    # acc update per kv-head (GQA mapping exact, each kv head read once)
    pvs = []
    for kk in range(K):
        pvs.append(jax.lax.dot_general(
            p[kk * G:(kk + 1) * G], vf[:, kk, :],
            (((1,), (0,)), ((), ()))))                     # [G, D]
    acc_s[...] = acc_s[...] * corr[:, None] + jnp.concatenate(pvs, axis=0)
    m_s[...] = m_new

    # per-page mass with this block's stabilizer (renormalized in ops.py)
    mass_ref[0] = p.reshape(H, page_block, pt).sum(axis=2)  # [H, page_block]
    mstab_ref[0] = m_new[:, None]                           # [H, 1]

    @pl.when(ib == nblk - 1)
    def _finalize():
        acc_ref[0] = acc_s[...]
        m_ref[0] = m_s[...]
        l_ref[0] = l_s[...]


def pool_attention_partial_tpu(q, pool_k, pool_v, slot_page, seq_len, *,
                               window: Optional[int] = None,
                               sm_scale: Optional[float] = None,
                               page_block: int = 8,
                               interpret: bool = False):
    """q: [B,H,D]; pool_k/v: [B,Mp,pt,K,D]; slot_page: [B,Mp]; seq_len: [B].

    Returns (acc [B,H,D] f32, m [B,H], l [B,H], mass [B,H,Mp] — mass carries
    a per-block stabilizer, also returned: mstab [B,H,nblk])."""
    B, Mp, pt, K, D = pool_k.shape
    H = q.shape[1]
    G = H // K
    page_block = min(page_block, Mp)
    assert Mp % page_block == 0
    nblk = Mp // page_block
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _kernel, sm_scale=scale, window=window, K=K, G=G, pt=pt,
        page_block=page_block, nblk=nblk)

    out_shapes = (
        jax.ShapeDtypeStruct((B, H, D), jnp.float32),       # acc
        jax.ShapeDtypeStruct((B, H), jnp.float32),          # m
        jax.ShapeDtypeStruct((B, H), jnp.float32),          # l
        jax.ShapeDtypeStruct((B, H, Mp), jnp.float32),      # mass
        jax.ShapeDtypeStruct((B, H, nblk), jnp.float32),    # mstab
    )
    acc, m, l, mass, mstab = pl.pallas_call(
        kernel,
        grid=(B, nblk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, ib: (b,)),                   # seq_len
            pl.BlockSpec((1, H, D), lambda b, ib: (b, 0, 0)),         # q
            pl.BlockSpec((1, page_block, pt, K, D),
                         lambda b, ib: (b, ib, 0, 0, 0)),             # k
            pl.BlockSpec((1, page_block, pt, K, D),
                         lambda b, ib: (b, ib, 0, 0, 0)),             # v
            pl.BlockSpec((1, page_block), lambda b, ib: (b, ib)),     # pages
        ],
        out_specs=(
            pl.BlockSpec((1, H, D), lambda b, ib: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, ib: (b, 0)),
            pl.BlockSpec((1, H), lambda b, ib: (b, 0)),
            pl.BlockSpec((1, H, page_block), lambda b, ib: (b, 0, ib)),
            pl.BlockSpec((1, H, 1), lambda b, ib: (b, 0, ib)),
        ),
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((H, D), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(seq_len, q, pool_k, pool_v, slot_page)
    return acc, m, l, mass, mstab
