"""Jit'd wrapper: run the pool-partial kernel on both tiers and merge.

``tiered_attention(...)`` is a drop-in for
memtier.kvcache.tiered_paged_attention (same outputs) with impl dispatch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.tiered_attention.kernel import pool_attention_partial_tpu
from repro.kernels.tiered_attention.ref import (merge_partials_ref,
                                                pool_attention_partial_ref)


def _renorm_mass(mass, mstab, m_merged, page_block):
    """mass [B,H,Mp] with per-block stabilizers mstab [B,H,nblk] ->
    unnormalized mass relative to m_merged [B,H]."""
    B, H, Mp = mass.shape
    nblk = mstab.shape[-1]
    stab = jnp.repeat(mstab, Mp // nblk, axis=-1)            # [B,H,Mp]
    return mass * jnp.exp(stab - m_merged[..., None])


@functools.partial(jax.jit, static_argnames=("window", "impl", "page_block"))
def tiered_attention(q, fast_k, fast_v, slow_k, slow_v, fast_page, slow_page,
                     seq_len, *, window: Optional[int] = None,
                     impl: str = "ref", page_block: int = 8):
    """q: [B,1,H,D]; pools: [B,Mp,pt,K,D]; *_page: [B,Mp] absolute page ids
    (-1 free); seq_len: [B]. Returns (out [B,1,H,D], fast_mass [B,Mf],
    slow_mass [B,Ms]) — identical semantics to the XLA serving path."""
    B, _, H, D = q.shape
    q2 = q[:, 0]
    if impl == "ref":
        pf = pool_attention_partial_ref(q2, fast_k, fast_v, fast_page,
                                        seq_len, window=window)
        ps = pool_attention_partial_ref(q2, slow_k, slow_v, slow_page,
                                        seq_len, window=window)
        out, (mf, ms) = merge_partials_ref(q.dtype, [pf, ps])
        return out[:, None], mf, ms

    interpret = impl == "pallas_interpret"
    af, mf_, lf, massf, stabf = pool_attention_partial_tpu(
        q2, fast_k, fast_v, fast_page, seq_len, window=window,
        page_block=page_block, interpret=interpret)
    as_, ms_, ls, masss, stabs = pool_attention_partial_tpu(
        q2, slow_k, slow_v, slow_page, seq_len, window=window,
        page_block=page_block, interpret=interpret)
    m = jnp.maximum(mf_, ms_)
    cf = jnp.exp(mf_ - m)
    cs = jnp.exp(ms_ - m)
    l = lf * cf + ls * cs
    out = (af * cf[..., None] + as_ * cs[..., None]) / jnp.maximum(
        l[..., None], 1e-30)
    denom = jnp.maximum(l.sum(axis=1), 1e-30)[:, None]
    fast_mass = _renorm_mass(massf, stabf, m, page_block).sum(axis=1) / denom
    slow_mass = _renorm_mass(masss, stabs, m, page_block).sum(axis=1) / denom
    return out[:, None].astype(q.dtype), fast_mass, slow_mass
