# Custom Pallas kernels for the compute hot-spots the paper optimizes
# (tiered attention, page migration, flash attention, SSD scan), plus
# shared TPU-lowering compatibility shims.
"""Kernel package utilities shared by all Pallas kernels."""
from jax.experimental.pallas import tpu as pltpu

# jax renamed ``TPUCompilerParams`` to ``CompilerParams`` (jax >= 0.5);
# resolve whichever this jax exposes so kernels build on both.
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None)
if _COMPILER_PARAMS_CLS is None:
    _COMPILER_PARAMS_CLS = pltpu.TPUCompilerParams


def tpu_compiler_params(dimension_semantics, **kw):
    """Version-portable ``compiler_params`` for ``pl.pallas_call``."""
    return _COMPILER_PARAMS_CLS(dimension_semantics=dimension_semantics, **kw)
