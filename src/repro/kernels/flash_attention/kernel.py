"""Blockwise flash attention for TPU (Pallas): GQA, causal, sliding window.

Layout [B, H, S, D]. Grid = (B, H, nq, nk) with the kv dimension innermost
and "arbitrary" semantics: VMEM scratch (acc, m, l) persists across the nk
iterations of one (b, h, iq) program family; the output block is written on
the last visited kv block. Causal/SWA blocks outside the band are skipped
with @pl.when (zero work on TPU — unlike the XLA reference, which executes
masked blocks).

GQA needs no KV expansion: the kv-head BlockSpec index_map folds h -> h // G,
so each q head streams its own kv head's blocks straight from HBM to VMEM.
MXU alignment: block_q/block_k default 512/512 with D padded to a multiple
of 128 by ops.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               sm_scale: float, causal: bool, window: Optional[int],
               block_q: int, block_k: int, nk: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    q_start = iq * block_q + q_offset          # absolute position of q block
    k_start = ik * block_k

    # band check: does this kv block intersect the visible range?
    q_lo, q_hi = q_start, q_start + block_q - 1
    visible = True
    if causal:
        visible = jnp.asarray(k_start <= q_hi)
    if window is not None:
        visible = jnp.logical_and(visible,
                                  k_start + block_k - 1 > q_lo - window)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale      # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
        if causal or window is not None:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            mask = jnp.ones((block_q, block_k), bool)
            if causal:
                mask &= kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(p.astype(v.dtype), v,
                                              (((1,), (0,)), ((), ()))))
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_tpu(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        block_q: int = 512, block_k: int = 512,
                        sm_scale: Optional[float] = None,
                        interpret: bool = False) -> jax.Array:
    """q: [B, H, Sq, D]; k, v: [B, K, Skv, D]. Returns [B, H, Sq, D]."""
    b, h, sq, d = q.shape
    kh, skv = k.shape[1], k.shape[2]
    g = h // kh
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    nq, nk = sq // block_q, skv // block_k
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    q_offset = skv - sq                      # right-aligned queries

    kernel = functools.partial(
        _fa_kernel, sm_scale=sm_scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk, q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
