"""Pure-jnp oracle for the flash attention kernel (GQA, causal, SWA)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q: [B, H, Sq, D]; k, v: [B, K, Skv, D] (K divides H). -> [B, H, Sq, D]."""
    b, h, sq, d = q.shape
    kh, skv = k.shape[1], k.shape[2]
    g = h // kh
    ke = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    ve = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    scale = 1.0 / np.sqrt(d)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, ke)
    qpos = jnp.arange(sq) + (skv - sq)   # right-aligned queries
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None] > qpos[:, None] - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, ve).astype(q.dtype)
