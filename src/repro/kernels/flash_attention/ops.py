"""Jit'd public wrapper: layout handling, head-dim padding, impl dispatch."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_tpu
from repro.kernels.flash_attention.ref import flash_attention_ref


def _pad_d(x: jax.Array, mult: int = 128):
    d = x.shape[-1]
    pad = (-d) % mult
    if pad == 0:
        return x, d
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]), d


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    impl: str = "ref", block_q: int = 512,
                    block_k: int = 512) -> jax.Array:
    """Flash attention over [B, H|K, S, D] tensors.

    impl: "ref" (pure jnp, runs anywhere) | "pallas" (TPU) |
          "pallas_interpret" (kernel body executed on CPU for validation).
    """
    if impl == "ref":
        return flash_attention_ref(q, k, v, causal=causal, window=window)
    qp, d0 = _pad_d(q)
    kp, _ = _pad_d(k)
    vp, _ = _pad_d(v)
    out = flash_attention_tpu(qp, kp, vp, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              sm_scale=1.0 / (d0 ** 0.5),
                              interpret=(impl == "pallas_interpret"))
    return out[..., :d0]
