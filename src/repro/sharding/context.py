"""Ambient sharding context for activation constraints.

GSPMD resolves the FSDP conflict (weights sharded over "data" on the
contracting dim vs activations batch-sharded over "data") by whichever side
is cheaper *locally* — which silently unshards the batch and replicates all
activation compute across the data axis (measured: ~4.4x FLOPs/device, see
EXPERIMENTS.md §Perf iteration 1). Pinning activations at block boundaries
forces the all-gather onto the (much smaller) weights — true FSDP.

Model code calls ``constrain_batch`` / ``constrain``; outside a mesh context
they are no-ops, so smoke tests and the simulator never see a mesh.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None
_BATCH_AXES: Tuple[str, ...] = ("data",)


def set_mesh_context(mesh: Optional[Mesh]) -> None:
    global _MESH, _BATCH_AXES
    _MESH = mesh
    if mesh is not None:
        _BATCH_AXES = (("pod", "data") if "pod" in mesh.axis_names
                       else ("data",))


def mesh_context() -> Optional[Mesh]:
    return _MESH


def batch_spec() -> Tuple[str, ...]:
    return _BATCH_AXES


def constrain(x: jax.Array, *parts) -> jax.Array:
    """with_sharding_constraint under the ambient mesh (no-op without one)."""
    if _MESH is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*parts)))


def constrain_batch(x: jax.Array, model_dim: Optional[int] = None) -> jax.Array:
    """Shard dim 0 over the batch axes; optionally one dim over "model"."""
    if _MESH is None:
        return x
    ba = _BATCH_AXES if len(_BATCH_AXES) > 1 else _BATCH_AXES[0]
    parts = [ba] + [None] * (x.ndim - 1)
    if model_dim is not None:
        parts[model_dim] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*parts)))
