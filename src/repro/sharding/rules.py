"""Logical-axis sharding rules (MaxText-style) → NamedSharding.

The production mesh is ("data", "model") = (16, 16) per pod, with a leading
"pod" axis (=2) for the multi-pod dry-run. Parallelism strategy:
  * batch       → ("pod", "data")   data parallelism across pods+data axis
  * weights     → "embed"-class dims FSDP-sharded over "data";
                  heads / mlp / vocab / expert-ff / ssm-inner TP over "model"
  * KV pools    → batch over "data", page dim over "model" (sharded-KV
                  attention; softmax partials combine with XLA collectives)

Rules are *per-config*: dims that don't divide the mesh axis fall back to
replication (e.g. whisper-tiny's 6 heads on a 16-wide model axis).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec, logical_axes, tree_map_specs


def base_rules(multi_pod: bool) -> Dict[str, Optional[Tuple[str, ...]]]:
    return {
        "vocab": ("model",),
        "embed": ("data",),         # FSDP
        "embed_tbl": None,          # embed table model-dim: see embed_specs
        "embed_x2": ("data",),
        "embed_out": None,
        "heads": ("model",),        # TP
        "kv_heads": None,           # small; replicated (GQA)
        "head_dim": None,
        "mlp": ("model",),
        "experts": None,
        "experts_dim": None,
        "expert_mlp": ("model",),
        "ssm_inner": ("model",),
        "layers": None,
        None: None,
    }


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def spec_for(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
             rules: Dict, mesh: Mesh) -> P:
    parts = []
    used = set()
    for ax, dim in zip(axes, shape):
        r = rules.get(ax, None)
        if r is None:
            parts.append(None)
            continue
        r = tuple(a for a in r if a not in used)
        total = int(np.prod([mesh_axis_size(mesh, a) for a in r])) if r else 1
        if not r or dim % total != 0:
            parts.append(None)      # non-divisible -> replicate (no padding)
        else:
            parts.append(r if len(r) > 1 else r[0])
            used.update(r)
    return P(*parts)


def param_shardings(specs, mesh: Mesh, rules: Dict):
    """ParamSpec tree -> NamedSharding tree."""
    return tree_map_specs(
        lambda s: NamedSharding(mesh, spec_for(s.axes, s.shape, rules, mesh)),
        specs)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fitted_batch_axes(mesh: Mesh, dim: int) -> Optional[Tuple[str, ...]]:
    """Largest prefix of the batch axes that divides `dim` (None if none)."""
    ba = batch_axes(mesh)
    best = None
    total = 1
    for i in range(len(ba)):
        total *= mesh_axis_size(mesh, ba[i])
        if dim % total == 0:
            best = ba[:i + 1]
    return best


def data_sharding(mesh: Mesh, shape: Tuple[int, ...],
                  batch_dim: int = 0) -> NamedSharding:
    parts: list = [None] * len(shape)
    ba = fitted_batch_axes(mesh, shape[batch_dim])
    if ba:
        parts[batch_dim] = ba if len(ba) > 1 else ba[0]
    return NamedSharding(mesh, P(*parts))


def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch: Dict) -> Dict:
    """Shardings for a train/prefill input batch (dict of arrays)."""
    return {k: data_sharding(mesh, v.shape) for k, v in batch.items()}


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def kv_pool_sharding(mesh: Mesh) -> NamedSharding:
    """[L, B, M, pt, K, D]: batch over data axes, pages over model."""
    ba = batch_axes(mesh)
    return NamedSharding(mesh, P(None, ba if len(ba) > 1 else ba[0], "model"))


def tree_sharding_like(tree, mesh: Mesh, leaf_fn):
    return jax.tree_util.tree_map(leaf_fn, tree)


def serve_state_shardings(state, mesh: Mesh):
    """Shardings for the serve state dict (tiered KV cache + extras)."""

    def bspec_for(dim: int):
        ba = fitted_batch_axes(mesh, dim)
        if not ba:
            return None
        return ba if len(ba) > 1 else ba[0]

    def model_for(dim: int):
        return "model" if dim % mesh_axis_size(mesh, "model") == 0 else None

    def leaf(path, x):
        keys = [getattr(p, "name", getattr(p, "key", None)) for p in path]
        name = next((k for k in keys if isinstance(k, str)), "")
        shp = x.shape
        if name in ("fast_k", "fast_v", "slow_k", "slow_v", "cross_k",
                    "cross_v"):
            # [L, B, M|T, pt?, K, D]: batch over data, pages/tokens over model
            return NamedSharding(mesh, P(None, bspec_for(shp[1]),
                                         model_for(shp[2])))
        if name in ("fast_page", "slow_page", "fast_hot", "slow_hot",
                    "page_tier", "page_idx", "seq_len", "tenant"):
            return NamedSharding(mesh, P(bspec_for(shp[0])))
        if name in ("h", "conv_x", "conv_B", "conv_C"):   # mamba cache [L,B,...]
            return NamedSharding(mesh, P(None, bspec_for(shp[1])))
        return NamedSharding(mesh, P())  # counters, tables, scalars

    return jax.tree_util.tree_map_with_path(leaf, state)
