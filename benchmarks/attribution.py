"""Attribution smoke (scripts/check.sh): the slowdown-attribution ledger's
four load-bearing properties, end to end.

  1. Conservation at fleet scale: a 128-host rollout carries the ledger in
     the chunked scan and every host's components sum to the counter
     identity bit-exact, while the rollout sustains the host-tick rate gate
     (the ledger must be observability, not a tax).
  2. Counterfactual sanity: on a clean pressured host every tenant's
     interference index (isolated minus stacked fast-hit fraction) is
     >= 0; injecting the §V-B5 thrasher drives the victim's index
     strictly up.
  3. Sketch accuracy: fleet-merged stall percentiles from the fixed-size
     histogram sketch stay within 2% rank error of the exact empirical
     percentile over 128 hosts of synthetic stall data.
  4. Trace-size constancy: the attribution+detector tick's jaxpr has the
     same equation count at horizon 100 and 1000 and at T=3 and T=6 —
     components are data, not structure.

  PYTHONPATH=src python -m benchmarks.attribution --smoke  # CI gate
  PYTHONPATH=src python -m benchmarks.attribution          # + attribution.json
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

SMOKE_HOSTS = 128
SMOKE_TICKS = 4_000
SMOKE_CHUNK = 2_000
RATE_GATE = 8_500.0          # host-ticks/s with the ledger carried
SKETCH_HOSTS = 128
SKETCH_RANK_ERR = 0.02
SMOKE_BUDGET_S = 420.0
RESULTS = os.path.join(os.path.dirname(__file__), "results",
                       "attribution.json")


# ------------------------------------------------- pressured single host ----
def _pressured(noisy: bool, ticks: int = 120):
    """A 4-tenant host whose footprints oversubscribe the fast tier ~2.2x,
    so stall attribution has something to attribute. ``noisy=True`` swaps
    the late-arriving 4th tenant for the §V-B5 thrasher."""
    from repro.configs.base import TieringConfig
    from repro.core.workloads import (ChurnSlot, build_churn_schedule,
                                      cache_like, spark_like,
                                      suggest_churn_policy, thrasher,
                                      web_like)
    slots = [ChurnSlot(web_like(40), [(0, ticks)]),
             ChurnSlot(cache_like(40), [(0, ticks)]),
             ChurnSlot(spark_like(32), [(4, ticks)])]
    mk = (lambda: ChurnSlot(thrasher(32, fast_share=10),
                            [(ticks // 5, ticks)])) if noisy else \
        (lambda: ChurnSlot(web_like(32), [(ticks // 5, ticks)]))
    slots.append(mk())
    prot, bound = suggest_churn_policy(slots)
    cfg = TieringConfig(n_tenants=4, n_fast_pages=64, n_slow_pages=128,
                        lower_protection=prot, upper_bound=bound, p_base=16)
    return cfg, build_churn_schedule(slots, ticks)


def _rate_rollout(H: int, ticks: int, chunk: int):
    """The fleet_sweep mixed fleet with the attribution ledger carried."""
    from benchmarks.fleet_sweep import _build_fleet, _config
    from repro.obs.fleet import fleet_rollout
    want, rates = _build_fleet(min(500, ticks))
    host_arch = np.arange(H) % want.shape[0]
    cfg = _config()
    return cfg, fleet_rollout(cfg, want, rates, ticks, host_arch=host_arch,
                              chunk=chunk, k_max=16, warmup=True)


# ------------------------------------------------------- sketch accuracy ----
def _sketch_rank_error(n_hosts: int = SKETCH_HOSTS, per_host: int = 512,
                       qs=(0.5, 0.9, 0.95, 0.99)):
    """Max rank error of merged-sketch percentiles vs the exact empirical
    rank, over synthetic per-host stall samples (bulk in the exact linear
    range, a heavy tail through the quarter-log2 buckets)."""
    import jax
    import jax.numpy as jnp
    from repro.obs.sketch import (init_sketch, sketch_add, sketch_merge,
                                  sketch_percentile)
    rng = np.random.default_rng(7)
    bulk = rng.integers(0, 100, size=(n_hosts, per_host * 9 // 10))
    tail = np.minimum(rng.lognormal(6.0, 1.2, size=(n_hosts,
                                                    per_host // 10)), 5e4)
    values = np.concatenate([bulk, tail.astype(np.int64)], axis=1)

    counts = jax.jit(jax.vmap(sketch_add))(
        init_sketch((n_hosts,)), jnp.asarray(values, jnp.float32))
    merged = sketch_merge(counts)
    flat = np.sort(values.reshape(-1))
    N = flat.size
    worst = 0.0
    for q in qs:
        v = float(sketch_percentile(merged, q))
        lo = float(np.searchsorted(flat, v, side="left"))
        hi = float(np.searchsorted(flat, v, side="right"))
        target = q * N
        err = max(0.0, lo - target, target - hi) / N
        worst = max(worst, err)
    return worst


# ------------------------------------------------------ jaxpr constancy ----
def _tick_eqns(ticks: int, T: int, L: int = 40, S: int = 12) -> int:
    """Equation count of the fully-loaded (detector + attribution) churn
    tick's jaxpr for a given horizon and tenant count."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import TieringConfig
    from repro.core.churn import make_churn_tick
    from repro.core.state import init_state
    from repro.obs.attribution import make_attribution
    from repro.obs.streaming import make_detector
    cfg = TieringConfig(n_tenants=T, n_fast_pages=16, n_slow_pages=24,
                        lower_protection=(3,) * min(T, 2),
                        upper_bound=(0,) * min(T, 2))
    det = make_detector(ticks, T, cfg.lower_protection)
    att = make_attribution(T, cfg.lat_fast)
    tick = make_churn_tick(cfg, L, k_max=16, detector=det, attrib=att)
    state = init_state(cfg, L, detector=det, attrib=att)
    return len(jax.make_jaxpr(tick)(
        state, (jnp.zeros((T, S), jnp.float32),
                jnp.zeros((T,), jnp.int32))).eqns)


def _run_checks(include_rate: bool = True) -> dict:
    from repro.obs.counterfactual import counterfactual_run

    out: dict = {}

    # 1. fleet-scale conservation + rate gate
    if include_rate:
        cfg, roll = _rate_rollout(SMOKE_HOSTS, SMOKE_TICKS, SMOKE_CHUNK)
        rup = roll.attribution_rollup()
        out["rate"] = {
            "hosts": SMOKE_HOSTS, "ticks": SMOKE_TICKS,
            "chunk": roll.chunk, "sharded": roll.sharded,
            "host_ticks_per_s": round(roll.host_ticks_per_s, 1),
            "gate": RATE_GATE,
            "conserved": rup["conserved"],
            "stall_units_total": rup["stall_units_total"],
            "component_shares": {k: round(v, 4) for k, v
                                 in rup["component_shares"].items()},
            "stall_p99": rup["stall_p99"],
            "ok": bool(roll.host_ticks_per_s >= RATE_GATE
                       and rup["conserved"]
                       and rup["stall_units_total"] > 0),
        }

    # 2. counterfactual interference, clean vs noisy neighbor
    cf = {}
    for label, noisy in (("clean", False), ("noisy", True)):
        cfg, sched = _pressured(noisy)
        res = counterfactual_run(cfg, sched, k_max=32)
        cf[label] = res
    clean, noisy = cf["clean"], cf["noisy"]
    victim = int(np.argmax(noisy.interference - clean.interference))
    out["counterfactual"] = {
        "clean_interference": [round(float(x), 4)
                               for x in clean.interference],
        "noisy_interference": [round(float(x), 4)
                               for x in noisy.interference],
        "clean_min": round(float(clean.interference.min()), 5),
        "victim": victim,
        "victim_delta": round(float(noisy.interference[victim]
                                    - clean.interference[victim]), 4),
        "conserved": bool(
            clean.stacked_state.attrib is not None
            and noisy.stacked_state.attrib is not None),
        "ok": bool(clean.interference.min() >= -1e-6
                   and noisy.interference[victim] > 0.01
                   and noisy.interference[victim]
                   > clean.interference[victim] + 0.05),
    }

    # 3. sketch percentile accuracy
    err = _sketch_rank_error()
    out["sketch"] = {"hosts": SKETCH_HOSTS, "max_rank_error": round(err, 5),
                     "bound": SKETCH_RANK_ERR,
                     "ok": bool(err <= SKETCH_RANK_ERR)}

    # 4. jaxpr size constant in horizon and tenant count
    e_base = _tick_eqns(100, 3)
    e_long = _tick_eqns(1000, 3)
    e_wide = _tick_eqns(100, 6)
    out["jaxpr"] = {"eqns_t100_T3": e_base, "eqns_t1000_T3": e_long,
                    "eqns_t100_T6": e_wide,
                    "ok": bool(e_base == e_long == e_wide)}
    return out


def main() -> int:
    smoke = "--smoke" in sys.argv
    t0 = time.perf_counter()
    out = _run_checks(include_rate=True)
    if not out["rate"]["ok"] and out["rate"]["conserved"]:
        # timing gates are noisy on shared CI cores: one re-measure
        _, roll = _rate_rollout(SMOKE_HOSTS, SMOKE_TICKS, SMOKE_CHUNK)
        rate = roll.host_ticks_per_s
        out["rate"]["host_ticks_per_s"] = round(
            max(rate, out["rate"]["host_ticks_per_s"]), 1)
        out["rate"]["ok"] = bool(
            out["rate"]["host_ticks_per_s"] >= RATE_GATE
            and out["rate"]["stall_units_total"] > 0)
    elapsed = time.perf_counter() - t0

    r = out["rate"]
    print(f"attribution smoke: {r['hosts']} hosts x {r['ticks']} ticks "
          f"(chunk={r['chunk']}, sharded={r['sharded']}) -> "
          f"{r['host_ticks_per_s']:,.0f} host-ticks/s "
          f"(gate {RATE_GATE:,.0f}); conserved={r['conserved']} "
          f"stall_units={r['stall_units_total']:,} "
          f"shares={r['component_shares']}")
    c = out["counterfactual"]
    print(f"  counterfactual: clean={c['clean_interference']} "
          f"(min {c['clean_min']}), noisy={c['noisy_interference']}, "
          f"victim tenant {c['victim']} delta +{c['victim_delta']}")
    s = out["sketch"]
    print(f"  sketch: max rank error {s['max_rank_error']:.4f} over "
          f"{s['hosts']} hosts (bound {s['bound']})")
    j = out["jaxpr"]
    print(f"  jaxpr eqns: t=100/T=3 {j['eqns_t100_T3']}, "
          f"t=1000/T=3 {j['eqns_t1000_T3']}, t=100/T=6 {j['eqns_t100_T6']}")
    ok = (all(out[k]["ok"] for k in ("rate", "counterfactual", "sketch",
                                    "jaxpr"))
          and elapsed < SMOKE_BUDGET_S)
    print(f"  total={elapsed:.1f}s budget={SMOKE_BUDGET_S:.0f}s "
          f"-> {'OK' if ok else 'FAIL'}")

    if not smoke:
        from benchmarks.fleet_sweep import _config
        from benchmarks.run import write_result
        os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
        payload = {"meta": {"note": "slowdown-attribution ledger: fleet "
                            "conservation + rate gate, counterfactual "
                            "interference, sketch accuracy, jaxpr "
                            "constancy"}}
        payload.update(out)
        write_result(RESULTS, payload, config=_config())
        print(f"wrote {RESULTS}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
