# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   PYTHONPATH=src python -m benchmarks.run            # paper benchmarks
#   PYTHONPATH=src python -m benchmarks.run --roofline # + roofline summary
import sys


def main() -> None:
    from benchmarks.paper_figs import ALL_BENCHES

    print("name,us_per_call,derived")
    failures = 0
    for bench in ALL_BENCHES:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.0f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{bench.__name__},0,ERROR:{type(e).__name__}:{e}",
                  flush=True)

    if "--roofline" in sys.argv:
        from benchmarks.roofline import full_table
        for r in full_table():
            print(f"roofline_{r.arch}_{r.shape},0,"
                  f"dominant={r.dominant};frac={r.roofline_frac:.3f};"
                  f"useful={r.useful_ratio:.2f}", flush=True)

    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
