# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV. Also home of the shared result-stamping helper: every JSON under
# benchmarks/results/ carries a common ``meta`` block (git sha, UTC date,
# config hash, suite version) so results from different checkouts are
# diffable artifacts.
#
#   PYTHONPATH=src python -m benchmarks.run            # paper benchmarks
#   PYTHONPATH=src python -m benchmarks.run --roofline # + roofline summary
import dataclasses
import datetime
import hashlib
import json
import subprocess
import sys

# Bump when the schema of any results/*.json payload changes shape.
SUITE_VERSION = 2


def git_sha() -> str:
    """Current commit sha, or "unknown" outside a git checkout."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, check=True).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def config_hash(config=None) -> str:
    """Short stable hash of the benchmark's config (a dataclass such as
    TieringConfig, or any JSON-serializable mapping). "none" when the
    benchmark has no single governing config."""
    if config is None:
        return "none"
    if dataclasses.is_dataclass(config) and not isinstance(config, type):
        config = dataclasses.asdict(config)
    blob = json.dumps(config, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


def result_meta(config=None) -> dict:
    return {
        "git_sha": git_sha(),
        "date_utc": datetime.datetime.now(datetime.timezone.utc)
        .strftime("%Y-%m-%dT%H:%M:%SZ"),
        "config_hash": config_hash(config),
        "suite_version": SUITE_VERSION,
    }


def write_result(path, payload: dict, config=None) -> dict:
    """Stamp ``payload`` with the common meta block and write it to
    ``path``. Benchmark-specific meta keys (backend, notes, ...) in
    ``payload["meta"]`` are kept; the common stamp keys always win (a
    retro-stamped or stale stamp never survives a rewrite)."""
    meta = dict(payload.get("meta") or {})
    meta.update(result_meta(config))
    stamped = {"meta": meta}
    stamped.update({k: v for k, v in payload.items() if k != "meta"})
    with open(path, "w") as f:
        json.dump(stamped, f, indent=1, default=float)
    return stamped


def main() -> None:
    from benchmarks.paper_figs import ALL_BENCHES

    print("name,us_per_call,derived")
    failures = 0
    for bench in ALL_BENCHES:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.0f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{bench.__name__},0,ERROR:{type(e).__name__}:{e}",
                  flush=True)

    if "--roofline" in sys.argv:
        from benchmarks.roofline import bench_select, bench_tick, T0, L0
        for impl in ("jnp_sort", "jnp_rows", "kernel_ref"):
            r = bench_select(T0, L0, impl, n_iters=8)
            print(f"roofline_select_{impl},{r['select_ms'] * 1e3:.0f},"
                  f"T={T0};L={L0}", flush=True)
        for impl in ("jnp", "pallas_ref"):
            r = bench_tick(T0, L0, impl, n_ticks=8)
            print(f"roofline_tick_{impl},{r['tick_ms'] * 1e3:.0f},"
                  f"T={T0};L={L0}", flush=True)

    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
