"""Churn sweep: the dynamic-ownership engine (core/churn.py) across churn
intensity x mode — tick wall-time, compile time, lifecycle event totals,
conservation checks, and the fairness outcome for the stable tenants that
share the host with the churning roster.

  PYTHONPATH=src python -m benchmarks.churn_sweep          # full sweep -> churn.json
  PYTHONPATH=src python -m benchmarks.churn_sweep --smoke  # CI budget + invariants

One compiled tick serves every schedule: churn events are scan *data*, so
jaxpr size is constant in the number of arrivals/departures (the sweep
records the trace equation count at each intensity to prove it). The smoke
run asserts the acceptance properties: >= 50 lifecycle events through one
tick, page-count conservation every tick, and zero pages owned by departed
tenants — inside a CI time budget.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

SMOKE_BUDGET_S = 120.0
SMOKE_MIN_EVENTS = 50
RESULTS = os.path.join(os.path.dirname(__file__), "results", "churn.json")

# churn intensity: multiplier on arrival rate / inverse lifetime of the
# non-stable slots (0 = static roster baseline)
INTENSITIES = (0.0, 0.5, 1.0, 2.0)
MODES = ("equilibria", "tpp")


def _roster(intensity: float, ticks: int):
    from repro.core.workloads import (ChurnSlot, cache_like, churn_stacked,
                                      poisson_churn, serverless_bursts,
                                      web_like)
    if intensity == 0.0:
        kinds = (web_like, cache_like)
        return [ChurnSlot(kinds[i % 2](64 + 8 * (i % 3)), [(3 * i, ticks)])
                for i in range(16)]
    slots = [ChurnSlot((web_like if i % 2 == 0 else cache_like)(64 + 8 * (i % 3)),
                       [(3 * i, ticks)]) for i in range(6)]
    slots += poisson_churn(6, ticks, arrival_rate=0.05 * intensity,
                           mean_life=max(45.0 / intensity, 8.0),
                           base_footprint=48, seed=0)
    slots += serverless_bursts(4, ticks, mean_life=max(6.0 / intensity, 2.0),
                               mean_gap=max(8.0 / intensity, 2.0),
                               footprint=56, seed=1)
    return slots


def _build(intensity: float, ticks: int):
    from repro.core.simulator import churn_roster_config
    from repro.core.workloads import build_churn_schedule
    slots = _roster(intensity, ticks)
    return churn_roster_config(slots), build_churn_schedule(slots, ticks)


def bench(intensity: float, mode: str, ticks: int = 240) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.core.churn import churn_events, make_churn_tick
    from repro.core.state import init_state
    cfg, schedule = _build(intensity, ticks)
    arrivals, departures = churn_events(schedule.want)
    L = cfg.n_fast_pages + cfg.n_slow_pages

    tick = make_churn_tick(cfg, L, mode=mode)
    run = jax.jit(lambda s, r, w: jax.lax.scan(tick, s, (r, w)))
    state = init_state(cfg, L)
    rates = jnp.asarray(schedule.rates, jnp.float32)
    want = jnp.asarray(schedule.want, jnp.int32)

    t0 = time.perf_counter()
    final, outs = run(state, rates, want)
    jax.block_until_ready(outs.fast_usage)
    first_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    final, outs = run(state, rates, want)   # cached: run time only
    jax.block_until_ready(outs.fast_usage)
    run_s = time.perf_counter() - t0

    eqns = len(jax.make_jaxpr(tick)(
        state, (rates[0], want[0])).jaxpr.eqns)

    fast = np.asarray(outs.fast_usage)
    slow = np.asarray(outs.slow_usage)
    pool = np.asarray(outs.pool_free)
    active = schedule.want > 0
    conserved = bool((fast.sum(1) + slow.sum(1) + pool == L).all())
    departed_clean = bool(((fast + slow)[~active] == 0).all())
    # fairness outcome: mean steady throughput of the tenants resident for
    # the whole steady window (the stable cohort sharing the host with the
    # churn; stable slots have staggered arrivals, so gate on the window)
    w = slice(ticks // 2, ticks)
    stable = [i for i in range(cfg.n_tenants) if bool(active[w, i].all())]
    stable_thru = float(np.asarray(outs.throughput)[w][:, stable].mean()) \
        if stable else 0.0
    return {"intensity": intensity, "mode": mode, "ticks": ticks,
            "tenants": cfg.n_tenants, "pages": L,
            "arrivals": arrivals, "departures": departures,
            "compile_s": round(max(first_s - run_s, 0.0), 3),
            "tick_ms": round(run_s / ticks * 1e3, 3), "jaxpr_eqns": eqns,
            "conserved": conserved, "departed_clean": departed_clean,
            "stable_cohort": len(stable),
            "stable_mean_throughput": round(stable_thru, 3)}


def smoke() -> int:
    t0 = time.perf_counter()
    r = bench(1.0, "equilibria", ticks=200)
    elapsed = time.perf_counter() - t0
    events = r["arrivals"] + r["departures"]
    ok = (elapsed < SMOKE_BUDGET_S and events >= SMOKE_MIN_EVENTS
          and r["conserved"] and r["departed_clean"])
    print(f"churn smoke: {events} lifecycle events through one compiled "
          f"tick (jaxpr {r['jaxpr_eqns']} eqns), tick={r['tick_ms']:.2f}ms, "
          f"conserved={r['conserved']} departed_clean={r['departed_clean']} "
          f"total={elapsed:.1f}s budget={SMOKE_BUDGET_S:.0f}s "
          f"-> {'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


def main() -> int:
    if "--smoke" in sys.argv:
        return smoke()
    import jax
    sweep = []
    for mode in MODES:
        for i in INTENSITIES:
            r = bench(i, mode)
            sweep.append(r)
            print(f"{mode:10s} intensity={i:3.1f} "
                  f"events={r['arrivals'] + r['departures']:4d} "
                  f"compile={r['compile_s']:6.2f}s "
                  f"tick={r['tick_ms']:7.3f}ms eqns={r['jaxpr_eqns']} "
                  f"stable_thru={r['stable_mean_throughput']:8.3f} "
                  f"({r['stable_cohort']} stable) "
                  f"conserved={r['conserved']}", flush=True)
    eqn_set = {r["jaxpr_eqns"] for r in sweep if r["mode"] == "equilibria"}
    out = {
        "meta": {"backend": jax.default_backend(),
                 "note": "dynamic-ownership engine across churn intensity; "
                         "jaxpr_eqns constant across intensities = trace is "
                         "constant in the number of lifecycle events",
                 "jaxpr_constant_in_events": len(eqn_set) == 1},
        "sweep": sweep,
    }
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    from benchmarks.run import write_result
    write_result(RESULTS, out,
                 config={"intensities": INTENSITIES, "modes": MODES})
    print(f"wrote {RESULTS}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
