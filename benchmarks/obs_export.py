"""Exporter smoke (scripts/check.sh): roll out a small mixed fleet with an
injected thrasher, export the migration rings as Chrome-trace JSON and the
fleet counters as Prometheus text exposition, and validate both artifacts —
the trace parses and has monotone per-track timestamps, the exposition
matches the text-format grammar with consistent histogram series. The
streamed detectors must flag the injected chronic thrasher.

  PYTHONPATH=src python -m benchmarks.obs_export --smoke   # CI gate
  PYTHONPATH=src python -m benchmarks.obs_export           # same, keeps files
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

SMOKE_HOSTS = 4
SMOKE_TICKS = 160
SMOKE_BUDGET_S = 180.0


def main() -> int:
    from repro.obs.dashboard import demo_fleet
    from repro.obs.export import (rollout_exposition, validate_chrome_trace,
                                  validate_exposition, write_chrome_trace)

    smoke = "--smoke" in sys.argv
    t0 = time.perf_counter()
    cfg, roll = demo_fleet(SMOKE_HOSTS, SMOKE_TICKS, noisy=True)

    outdir = (tempfile.mkdtemp(prefix="obs_export_") if smoke
              else os.path.join(os.path.dirname(__file__), "results"))
    os.makedirs(outdir, exist_ok=True)
    trace_path = os.path.join(outdir, "fleet.trace.json")
    prom_path = os.path.join(outdir, "fleet.prom")

    events = {h: roll.host_migrations(h)[0] for h in range(roll.n_hosts)}
    trace = write_chrome_trace(trace_path, events,
                               t_resident=cfg.t_resident,
                               horizon=SMOKE_TICKS)
    with open(trace_path) as f:
        n_trace = validate_chrome_trace(json.load(f))   # round-trips as JSON

    text = rollout_exposition(roll)
    with open(prom_path, "w") as f:
        f.write(text)
    n_prom = validate_exposition(text)

    counts = roll.pathology_counts()
    flagged = counts.get("chronic_thrashing", 0) >= 1
    elapsed = time.perf_counter() - t0
    ok = (n_trace > 0 and n_prom > 0 and flagged
          and elapsed < SMOKE_BUDGET_S)
    print(f"obs export smoke: {SMOKE_HOSTS} hosts x {SMOKE_TICKS} ticks, "
          f"{sum(len(e) for e in events.values())} ring events")
    print(f"  chrome trace: {n_trace} events validated -> {trace_path}")
    print(f"  exposition:   {n_prom} samples validated -> {prom_path}")
    print(f"  pathology counts: {counts} (thrasher flagged: {flagged}); "
          f"total={elapsed:.1f}s budget={SMOKE_BUDGET_S:.0f}s "
          f"-> {'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
