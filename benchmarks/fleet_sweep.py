"""Fleet sweep: hosts x horizon through the unified tick's chunked rollout
(obs/fleet.fleet_rollout) — mixed static+churn fleets, schedule archetypes
gathered in-graph, donated carries, pmap-sharded when devices allow.

  PYTHONPATH=src python -m benchmarks.fleet_sweep          # full sweep -> fleet.json
  PYTHONPATH=src python -m benchmarks.fleet_sweep --smoke  # CI gate (128 hosts x 10k)

The smoke is the PR-5 acceptance run: a 128-host fleet mixing static and
churned rosters advances a 10,000-tick horizon through the chunked rollout,
and its host-tick rate must be no worse than the pre-refactor
``scale_sweep`` baseline's tick rate (benchmarks/results/scale.json,
equilibria/batched at T=16, L=16k): the fleet harness must deliver
simulated host-ticks at least as fast as the prior single-host engine
delivered ticks, or batching has regressed. Conservation (fast + slow +
free == L on every host) is asserted on the final fleet state.

When only one device is visible, the smoke re-execs itself with
``--xla_force_host_platform_device_count`` so the pmap-sharded path runs in
CI (on CPU the forced devices share cores; the speedup is modest but the
code path is exercised).
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

SMOKE_HOSTS = 128
SMOKE_TICKS = 10_000
SMOKE_CHUNK = 500
SMOKE_BUDGET_S = 420.0
HOSTS = (8, 32, 128)
HORIZONS = (1_000, 10_000)
RESULTS = os.path.join(os.path.dirname(__file__), "results", "fleet.json")
SCALE_RESULTS = os.path.join(os.path.dirname(__file__), "results",
                             "scale.json")


def _archetypes(period: int):
    """Tiny mixed rosters (T=3 slots per host): two static archetypes
    (single-episode slots — the degenerate schedule) and two churned ones
    (random lifecycle episodes). Small footprints keep the CI smoke's
    128 x 10k host-tick volume inside budget on CPU."""
    from repro.core.workloads import (ChurnSlot, as_churn_slots, cache_like,
                                      spark_like, stream_like, web_like)

    def churn_roster(seed: int):
        rng = np.random.default_rng(seed)
        kinds = (web_like, cache_like, spark_like)
        slots = []
        for i in range(3):
            w = kinds[(i + seed) % 3](6 + 2 * i)
            w.ramp = 2
            eps, t = [], int(rng.integers(0, 10))
            while t < period:
                life = 8 + int(rng.integers(0, 30))
                eps.append((t, min(t + life, period)))
                t += life + 1 + int(rng.integers(2, 12))
            slots.append(ChurnSlot(w, eps))
        return slots

    static = [as_churn_slots([web_like(6), cache_like(8), stream_like(10)],
                             period),
              as_churn_slots([cache_like(6), web_like(10), spark_like(8)],
                             period)]
    churned = [churn_roster(0), churn_roster(1)]
    return static + churned


def _config():
    from repro.configs.base import TieringConfig
    # protections fit fast - wmark; a bound on slot 2 exercises the sync path
    return TieringConfig(n_tenants=3, n_fast_pages=16, n_slow_pages=24,
                         lower_protection=(3, 3, 0), upper_bound=(0, 0, 6))


def _build_fleet(period: int):
    from repro.core.workloads import build_churn_schedule
    from repro.obs.fleet import stack_schedules
    archs = _archetypes(period)
    want, rates = stack_schedules(
        [build_churn_schedule(slots, period) for slots in archs])
    return want, rates


def _baseline_tick_rate() -> float:
    """ticks/s of the pre-refactor scale_sweep baseline (equilibria,
    batched, T=16, L=16384). Falls back to measuring it if scale.json is
    missing."""
    try:
        with open(SCALE_RESULTS) as f:
            for r in json.load(f)["sweep"]:
                if (r["mode"] == "equilibria" and r["impl"] == "batched"
                        and r["T"] == 16 and r["L"] == 16384):
                    return 1e3 / r["tick_ms"]
    except (OSError, KeyError, ValueError):
        pass
    from benchmarks.scale_sweep import bench_tick
    return 1e3 / bench_tick(16, 16384, "equilibria", n_ticks=20)["tick_ms"]


def _rollout(H: int, ticks: int, chunk: int, warmup: bool = True):
    from repro.core.churn import churn_events
    from repro.obs.fleet import fleet_rollout
    period = min(SMOKE_CHUNK, ticks)
    want, rates = _build_fleet(period)
    A = want.shape[0]
    host_arch = np.arange(H) % A
    cfg = _config()
    summary = fleet_rollout(cfg, want, rates, ticks, host_arch=host_arch,
                            chunk=chunk, k_max=16, warmup=warmup)
    per_arch = [sum(churn_events(want[a])) for a in range(A)]
    events = sum(per_arch[a] for a in host_arch)
    return cfg, summary, events


def _conserved(cfg, summary) -> bool:
    """fast + slow + free == L on every host of the final fleet state."""
    from repro.core.state import TIER_FAST, TIER_SLOW
    tier = np.asarray(summary.final_state.tier)
    owner = np.asarray(summary.final_state.owner)
    L = tier.shape[1]
    fast = (tier == TIER_FAST).sum(axis=1)
    slow = (tier == TIER_SLOW).sum(axis=1)
    free = (owner == cfg.n_tenants).sum(axis=1)
    return bool((fast + slow + free == L).all())


def _fork_for_devices() -> None:
    """Re-exec with forced host devices so the pmap-sharded path runs even
    on a single-device CPU install (no-op if already multi-device)."""
    if os.environ.get("REPRO_FLEET_NO_FORK"):
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return
    # largest power of two <= min(cores, 8): always divides the 128-host
    # smoke fleet, so the pmap-sharded path really runs (fleet_rollout only
    # shards when H % devices == 0)
    n = 1 << (min(os.cpu_count() or 1, 8).bit_length() - 1)
    if n < 2:
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = (flags + " "
                        f"--xla_force_host_platform_device_count={n}").strip()
    env["REPRO_FLEET_NO_FORK"] = "1"
    os.execve(sys.executable,
              [sys.executable, "-m", "benchmarks.fleet_sweep"] + sys.argv[1:],
              env)


def smoke() -> int:
    _fork_for_devices()
    import jax
    t0 = time.perf_counter()
    base_rate = _baseline_tick_rate()
    cfg, summary, events = _rollout(SMOKE_HOSTS, SMOKE_TICKS, SMOKE_CHUNK)
    elapsed = time.perf_counter() - t0
    L = cfg.n_fast_pages + cfg.n_slow_pages
    rate = summary.host_ticks_per_s
    conserved = _conserved(cfg, summary)
    # streaming pathology telemetry rode along in the fleet carry: flag
    # counters at any horizon with O(H * T) memory, never [ticks, ...]
    detected = summary.detector is not None
    flags = summary.pathology_flag_ticks() if detected else None
    ok = (rate >= base_rate and conserved and elapsed < SMOKE_BUDGET_S
          and events > 0 and detected)
    print(f"fleet smoke: {SMOKE_HOSTS} mixed hosts (static+churn, "
          f"{events} lifecycle events) x {SMOKE_TICKS} ticks, "
          f"chunk={summary.chunk}, sharded={summary.sharded} "
          f"({jax.local_device_count()} devices)")
    if detected:
        from repro.obs.streaming import KINDS
        per_kind = {k: int(flags[:, :, i].sum())
                    for i, k in enumerate(KINDS)}
        hosts_flagged = int((flags.sum(axis=(1, 2)) > 0).sum())
        print(f"  pathology flag-ticks (streamed, {flags.shape} counters): "
              f"{per_kind}; hosts with any flag: {hosts_flagged}; "
              f"end-of-run counts: {summary.pathology_counts()}")
    print(f"  rollout {summary.elapsed_s:.1f}s steady -> "
          f"{rate:,.0f} host-ticks/s "
          f"({rate * L:,.0f} page-ticks/s), baseline {base_rate:,.1f} "
          f"ticks/s; conserved={conserved} "
          f"total={elapsed:.1f}s budget={SMOKE_BUDGET_S:.0f}s "
          f"-> {'OK' if ok else 'FAIL'}")
    if not summary.sharded:
        print("  note: single device visible — the pmap-sharded path was "
              "NOT exercised this run")
    return 0 if ok else 1


def main() -> int:
    if "--smoke" in sys.argv:
        return smoke()
    _fork_for_devices()
    import jax
    base_rate = _baseline_tick_rate()
    sweep = []
    for H in HOSTS:
        for ticks in HORIZONS:
            cfg, summary, events = _rollout(H, ticks, SMOKE_CHUNK)
            L = cfg.n_fast_pages + cfg.n_slow_pages
            r = {"hosts": H, "ticks": ticks, "chunk": summary.chunk,
                 "sharded": summary.sharded,
                 "lifecycle_events": events,
                 "steady_s": round(summary.elapsed_s, 2),
                 "host_ticks_per_s": round(summary.host_ticks_per_s, 1),
                 "page_ticks_per_s": round(summary.host_ticks_per_s * L, 1),
                 "fleet_tick_ms": round(
                     summary.elapsed_s / ticks * 1e3, 3),
                 "conserved": _conserved(cfg, summary)}
            sweep.append(r)
            print(f"H={H:4d} ticks={ticks:6d} sharded={r['sharded']!s:5s} "
                  f"tick={r['fleet_tick_ms']:7.2f}ms "
                  f"host-ticks/s={r['host_ticks_per_s']:10,.0f} "
                  f"conserved={r['conserved']}", flush=True)
    out = {
        "meta": {"backend": jax.default_backend(),
                 "devices": jax.local_device_count(),
                 "baseline_ticks_per_s": round(base_rate, 2),
                 "note": "mixed static+churn fleets through the unified "
                         "tick's chunked rollout; host_ticks_per_s is the "
                         "gate metric vs the scale_sweep single-host "
                         "baseline tick rate"},
        "sweep": sweep,
    }
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    from benchmarks.run import write_result
    write_result(RESULTS, out, config=cfg)
    print(f"wrote {RESULTS}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
