"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh):
  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / ICI_BW

XLA's HloCostAnalysis counts while bodies once, so per-cell FLOPs/bytes/
collective traffic are reconstructed from the two unrolled reduced-depth
probes by a linear fit in num_layers:
  cost(L) = base + L * per_layer     (exact: the unrolled HLO has no loops)
MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill/decode) with N = active params.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced_depths, shape_cells
from repro.configs.base import SHAPES

RESULTS_DIR = Path(__file__).resolve().parent / "results" / "dryrun"

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link / chip


def _load(arch: str, shape: str, suffix: str, tag: str = "") -> Optional[dict]:
    name = f"{arch}_{shape}_{suffix}{('_' + tag) if tag else ''}.json"
    p = RESULTS_DIR / name
    if not p.exists():
        return None
    rec = json.loads(p.read_text())
    return rec if rec.get("ok") else None


def _cost(rec: dict) -> Dict[str, float]:
    ca = rec.get("cost_analysis", {})
    coll = rec.get("collectives", {})
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll.get("total", 0.0)),
            "layers": rec["num_layers"]}


def extrapolate(arch: str, shape: str, tag: str = "") -> Optional[Dict[str, float]]:
    """Linear-fit reduced-depth unrolled probes to the production depth."""
    cfg = get_config(arch)
    d1, d2 = reduced_depths(arch)
    r1 = _load(arch, shape, f"pod_red{d1}", tag)
    r2 = _load(arch, shape, f"pod_red{d2}", tag)
    if r1 is None or r2 is None:
        return None
    c1, c2 = _cost(r1), _cost(r2)
    out = {}
    for k in ("flops", "bytes", "coll"):
        per_layer = (c2[k] - c1[k]) / max(c2["layers"] - c1["layers"], 1)
        out[k] = c1[k] + (cfg.num_layers - c1["layers"]) * per_layer
        out[k + "_per_layer"] = per_layer
    return out


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    flops_dev: float
    bytes_dev: float
    coll_dev: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float            # MODEL_FLOPS / (HLO_FLOPs * chips)
    roofline_frac: float           # ideal compute time / dominant term
    note: str

    def as_dict(self):
        return self.__dict__.copy()


def _model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    n = cfg.active_param_count()
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n * tokens
    if sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * sh.global_batch       # decode: one token per sequence


_NOTES = {
    "compute": ("compute-bound: raise MFU via remat policy (save dots), "
                "fuse softmax/elementwise, larger per-device batch"),
    "memory": ("HBM-bound: shrink bytes/step — fewer f32 intermediates, "
               "fused attention kernel (no score materialization), "
               "narrower pool slack"),
    "collective": ("ICI-bound: reshard to cut all-gathers (FSDP gather "
                   "amortization, TP only where dims divide), overlap "
                   "collectives with compute, int8-compress DP grads"),
}


def analyze_cell(arch: str, shape: str, mesh_suffix: str = "pod",
                 tag: str = "") -> Optional[RooflineRow]:
    full = _load(arch, shape, mesh_suffix, tag)
    if full is None:
        return None
    chips = 512 if full["multi_pod"] else 256
    if full.get("unrolled"):
        # decode cells compile fully unrolled: cost analysis is exact
        c = _cost(full)
        ext = {"flops": c["flops"], "bytes": c["bytes"], "coll": c["coll"]}
    else:
        ext = extrapolate(arch, shape, tag)
    if ext is None:       # fall back to (undercounted) full-compile numbers
        c = _cost(full)
        ext = {"flops": c["flops"], "bytes": c["bytes"], "coll": c["coll"]}
    compute = ext["flops"] / PEAK_FLOPS
    memory = ext["bytes"] / HBM_BW
    coll = ext["coll"] / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = _model_flops(arch, shape)
    useful = mf / max(ext["flops"] * chips, 1e-9)
    ideal = mf / (chips * PEAK_FLOPS)
    frac = ideal / max(terms[dominant], 1e-12)
    return RooflineRow(
        arch=arch, shape=shape, mesh=full["mesh"],
        flops_dev=ext["flops"], bytes_dev=ext["bytes"], coll_dev=ext["coll"],
        compute_s=compute, memory_s=memory, collective_s=coll,
        dominant=dominant, model_flops=mf, useful_ratio=useful,
        roofline_frac=min(frac, 1.0), note=_NOTES[dominant])


def full_table(tag: str = "") -> List[RooflineRow]:
    rows = []
    for arch in ARCH_IDS:
        for sh in shape_cells(arch):
            r = analyze_cell(arch, sh.name, "pod", tag)
            if r:
                rows.append(r)
    return rows


def skipped_cells() -> List[tuple]:
    out = []
    for arch in ARCH_IDS:
        names = {s.name for s in shape_cells(arch)}
        for s in SHAPES:
            if s not in names:
                out.append((arch, s, "long_500k needs sub-quadratic attention"
                            " (pure full-attention arch; DESIGN.md §4)"))
    return out


def markdown_table(rows: List[RooflineRow]) -> str:
    hdr = ("| arch | shape | flops/dev | bytes/dev | coll/dev | compute(s) | "
           "memory(s) | collective(s) | dominant | 6ND/HLO | roofline frac |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r.arch} | {r.shape} | {r.flops_dev:.2e} | {r.bytes_dev:.2e} "
            f"| {r.coll_dev:.2e} | {r.compute_s:.2e} | {r.memory_s:.2e} "
            f"| {r.collective_s:.2e} | **{r.dominant}** | {r.useful_ratio:.2f} "
            f"| {r.roofline_frac:.1%} |")
    for arch, shape, why in skipped_cells():
        lines.append(f"| {arch} | {shape} | SKIP | | | | | | — | | ({why}) |")
    return "\n".join(lines)


def main():
    from benchmarks.run import write_result
    rows = full_table()
    print(markdown_table(rows))
    out = Path(__file__).resolve().parent / "results" / "roofline.json"
    write_result(out, {"cells": [r.as_dict() for r in rows]},
                 config={"archs": list(ARCH_IDS), "shapes": list(SHAPES)})
    print(f"\n{len(rows)} cells analyzed -> {out}")


if __name__ == "__main__":
    main()
