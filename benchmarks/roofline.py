"""Selection-core roofline: the Pallas segmented top-k kernels
(kernels/select) vs the jnp selection paths at fleet scale, emitted to
benchmarks/results/roofline.json.

  PYTHONPATH=src python -m benchmarks.roofline          # full matrix
  PYTHONPATH=src python -m benchmarks.roofline --smoke  # CI gates

Two measurements at T=64, L=262144 (the scale_sweep scenario PR 9
measured a ~12.6 ms structural tick floor on):

  select_ms — the isolated selection core: per-tenant masked,
      quota-bounded top-k over the [L] page array, jitted alone.
      * jnp_sort       — ``select.select_top_quota``: the composite-key
                         ``lax.sort`` + gather path (O(L log L); what the
                         dynamic provider and permuted owners pay).
      * jnp_rows       — ``select.select_top_quota_rows``: the batched
                         padded-row ``lax.top_k`` default for contiguous
                         traces (the "batched"/"jnp" engine impl).
      * kernel_ref     — the segmented top-k kernel's algorithm compiled
                         by XLA (``kernels/select`` ops with impl="ref"
                         via the ``pallas_ref`` strategy): tiled [T, S]
                         rowspace, masking/scoring/quota fused per tile.
      * pallas_interpret — the same kernel on the Pallas interpreter
                         (bit-exactness witness; carries emulation
                         overhead, not a performance number).
  tick_ms — the full engine tick per impl ("jnp" vs "pallas_ref" vs
      "pallas_interpret"), same scenario as benchmarks/hotness.py's
      bench_tick so the numbers are directly comparable.

On a machine without a TPU the compiled backend for the kernels is the
ref oracle's XLA-CPU lowering — same algorithm, same tiling semantics;
``impl="pallas"`` lowers the identical kernel through Mosaic on TPU. The
interpret row documents that the Pallas kernel itself is bit-exact.

CI gates (--smoke, wired into scripts/check.sh):
  * the selection-core micro-bench is recorded in results/roofline.json
    and kernel_ref beats jnp_sort by >= SELECT_SPEEDUP_MIN;
  * the jnp default tick has not regressed: fresh tick_ms <=
    TICK_REGRESSION_MAX x the exact-provider tick_ms recorded in
    results/hotness.json at the same T/L;
  * a quick interpret-vs-jnp tick equivalence assert (bitwise).
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

T0, L0 = 64, 262144
K_MAX = 256
SELECT_SPEEDUP_MIN = 1.2   # kernel_ref vs jnp_sort, T=64/L=262144
TICK_REGRESSION_MAX = 1.6  # jnp tick_ms vs results/hotness.json exact row
SMOKE_BUDGET_S = 300.0
RESULTS = os.path.join(os.path.dirname(__file__), "results", "roofline.json")
HOTNESS_RESULTS = os.path.join(os.path.dirname(__file__), "results",
                               "hotness.json")

SELECT_IMPLS = ("jnp_sort", "jnp_rows", "kernel_ref", "pallas_interpret")
TICK_IMPLS = ("jnp", "pallas_ref", "pallas_interpret")


# ------------------------------------------------------------- scenario ----
def _select_inputs(T: int, L: int, seed: int = 0):
    """The scale_sweep selection scenario: contiguous owner, 30% hot pages,
    demotion-style quotas."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    owner = np.repeat(np.arange(T, dtype=np.int32), L // T)
    score = jnp.asarray(np.where(rng.random(L) < 0.3, 4.0, 0.1)
                        .astype(np.float32) + rng.random(L).astype(np.float32))
    active = jnp.asarray(rng.random(L) < 0.5)
    quotas = jnp.asarray(rng.integers(0, K_MAX + 1, T).astype(np.int32))
    return owner, score, active, quotas


def _select_fn(impl: str, owner: np.ndarray, T: int, k_max: int):
    """A jitted ``(score, active, quotas) -> [L] mask`` for one impl."""
    import jax
    import jax.numpy as jnp
    from repro.core import select as S

    owner_j = jnp.asarray(owner)
    if impl == "jnp_sort":
        def f(score, active, quotas):
            return S.select_top_quota(score, owner_j, active, quotas, T,
                                      k_max)
    elif impl == "jnp_rows":
        strat = S.static_strategy(owner, T, k_max, impl="batched")

        def f(score, active, quotas):
            return strat.select(score, owner_j, active, quotas).mask
    else:
        simpl = {"kernel_ref": "pallas_ref"}.get(impl, impl)
        strat = S.static_strategy(owner, T, k_max, impl=simpl)

        def f(score, active, quotas):
            return strat.select(score, owner_j, active, quotas).mask
    return jax.jit(f)


def bench_select(T: int, L: int, impl: str, n_iters: int = 20) -> dict:
    """Isolated selection-core wall time (jitted alone, steady state)."""
    import jax
    owner, score, active, quotas = _select_inputs(T, L)
    f = _select_fn(impl, owner, T, K_MAX)
    t0 = time.perf_counter()
    out = f(score, active, quotas)
    jax.block_until_ready(out)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(n_iters):
        out = f(score, active, quotas)
    jax.block_until_ready(out)
    ms = (time.perf_counter() - t0) / n_iters * 1e3
    return {"impl": impl, "T": T, "L": L, "select_ms": round(ms, 3),
            "compile_s": round(compile_s, 3), "n_iters": n_iters}


def bench_tick(T: int, L: int, impl: str, n_ticks: int = 15) -> dict:
    """Full engine tick per selection impl (benchmarks/hotness.py's
    bench_tick scenario, so rows are comparable across results files)."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import TieringConfig
    from repro.core.engine import make_tick
    from repro.core.state import init_state

    share = L // (4 * T)
    cfg = TieringConfig(
        n_tenants=T, n_fast_pages=L // 4, n_slow_pages=L,
        lower_protection=(max(share // 2, 1),) * T,
        upper_bound=(2 * share,) * T)
    owner = np.repeat(np.arange(T, dtype=np.int32), L // T)
    eimpl = "batched" if impl == "jnp" else impl
    tick = jax.jit(make_tick(cfg, owner, "equilibria", k_max=K_MAX,
                             impl=eimpl))
    state = init_state(cfg, L, owner=owner)
    rng = np.random.default_rng(0)
    accesses = np.where(rng.random(L) < 0.3, 4.0, 0.1).astype(np.float32)
    inputs = (jnp.asarray(accesses), jnp.ones((L,), bool))
    t0 = time.perf_counter()
    state, out = tick(state, inputs)
    jax.block_until_ready(out)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(n_ticks):
        state, out = tick(state, inputs)
    jax.block_until_ready(out)
    tick_ms = (time.perf_counter() - t0) / n_ticks * 1e3
    return {"impl": impl, "T": T, "L": L, "tick_ms": round(tick_ms, 3),
            "compile_s": round(compile_s, 3), "n_ticks": n_ticks}


# ---------------------------------------------------------- equivalence ----
def quick_equivalence() -> bool:
    """Bitwise interpret-vs-jnp tick agreement on a small scenario (the
    full matrix lives in tests/test_select_kernels.py)."""
    from repro.configs.base import TieringConfig
    from repro.core.engine import run_engine
    from repro.core.workloads import build_trace, ci_like, microbenchmark

    cfg = TieringConfig(n_tenants=3, n_fast_pages=256, n_slow_pages=256,
                        lower_protection=(96, 96, 0), upper_bound=(0, 120, 0))
    tenants = [microbenchmark(150), microbenchmark(140, arrival=10),
               ci_like(120, phase_len=20)]
    owner, acc, alive = build_trace(tenants, 15)
    _, a = run_engine(cfg, owner, acc, alive, k_max=64, impl="batched")
    _, b = run_engine(cfg, owner, acc, alive, k_max=64,
                      impl="pallas_interpret")
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
               for f in a._fields)


def _hotness_baseline_ms(T: int, L: int):
    """The exact provider's tick_ms recorded by benchmarks/hotness.py at
    (T, L), or None if hotness.json is absent."""
    if not os.path.exists(HOTNESS_RESULTS):
        return None
    rec = json.loads(open(HOTNESS_RESULTS).read())
    for row in rec.get("tick_ms", []):
        if (row.get("provider") == "exact" and row.get("T") == T
                and row.get("L") == L):
            return float(row["tick_ms"])
    return None


def _write(select_rows, tick_rows, equiv_ok, note: str) -> None:
    import jax
    from benchmarks.run import write_result

    ref = {r["impl"]: r["select_ms"] for r in select_rows}
    summary = {}
    if "jnp_sort" in ref and "kernel_ref" in ref:
        summary["select_speedup_kernel_vs_sort"] = round(
            ref["jnp_sort"] / ref["kernel_ref"], 2)
    if "jnp_rows" in ref and "kernel_ref" in ref:
        summary["select_speedup_kernel_vs_rows"] = round(
            ref["jnp_rows"] / ref["kernel_ref"], 2)
    tick = {r["impl"]: r["tick_ms"] for r in tick_rows}
    if "jnp" in tick and "pallas_ref" in tick:
        summary["tick_speedup_kernel_vs_jnp"] = round(
            tick["jnp"] / tick["pallas_ref"], 2)
    out = {
        "meta": {"backend": jax.default_backend(), "note": note},
        "select_ms": select_rows,
        "tick_ms": tick_rows,
        "interpret_bit_exact": bool(equiv_ok),
        "summary": summary,
        "gates": {"select_speedup_min": SELECT_SPEEDUP_MIN,
                  "tick_regression_max": TICK_REGRESSION_MAX},
    }
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    write_result(RESULTS, out, config={
        "T": T0, "L": L0, "k_max": K_MAX,
        "select_impls": SELECT_IMPLS, "tick_impls": TICK_IMPLS})
    print(f"wrote {RESULTS}")


# ----------------------------------------------------------------- entry ----
def smoke() -> int:
    """Budgeted CI gate (see module docstring). Measures fresh but never
    rewrites results/roofline.json — the committed artifact comes from a
    full ``python -m benchmarks.roofline`` run (mirrors hotness --smoke)."""
    t0 = time.perf_counter()
    rows = [bench_select(T0, L0, i, n_iters=8)
            for i in ("jnp_sort", "kernel_ref")]
    for r in rows:
        print(f"select_ms T={T0} L={L0} {r['impl']:16s} "
              f"{r['select_ms']:8.3f}ms", flush=True)
    tick_jnp = bench_tick(T0, L0, "jnp", n_ticks=8)
    print(f"tick_ms   T={T0} L={L0} {'jnp':16s} "
          f"{tick_jnp['tick_ms']:8.3f}ms", flush=True)
    equiv = quick_equivalence()

    ms = {r["impl"]: r["select_ms"] for r in rows}
    speedup = ms["jnp_sort"] / ms["kernel_ref"]
    ok_rec = False
    if os.path.exists(RESULTS):
        rec = json.loads(open(RESULTS).read())
        ok_rec = any(r["impl"] == "kernel_ref" and r["T"] == T0
                     and r["L"] == L0 for r in rec.get("select_ms", []))
    ok_sel = speedup >= SELECT_SPEEDUP_MIN
    base = _hotness_baseline_ms(T0, L0)
    ok_tick = (base is None
               or tick_jnp["tick_ms"] <= TICK_REGRESSION_MAX * base)
    elapsed = time.perf_counter() - t0
    ok_b = elapsed < SMOKE_BUDGET_S
    print(f"roofline smoke: micro-bench recorded in results/roofline.json "
          f"-> {'OK' if ok_rec else 'FAIL'}")
    print(f"roofline smoke: kernel_ref vs jnp_sort speedup={speedup:.2f}x "
          f"(gate>={SELECT_SPEEDUP_MIN}) -> {'OK' if ok_sel else 'FAIL'}")
    if base is None:
        print("roofline smoke: results/hotness.json absent -> tick "
              "regression gate skipped")
    else:
        print(f"roofline smoke: jnp tick={tick_jnp['tick_ms']:.1f}ms vs "
              f"hotness.json exact={base:.1f}ms "
              f"(gate<={TICK_REGRESSION_MAX}x) "
              f"-> {'OK' if ok_tick else 'FAIL'}")
    print(f"roofline smoke: interpret bit-exact -> "
          f"{'OK' if equiv else 'FAIL'}")
    print(f"roofline smoke: total={elapsed:.1f}s budget={SMOKE_BUDGET_S:.0f}s"
          f" -> {'OK' if ok_b else 'OVER BUDGET'}")
    return 0 if (ok_rec and ok_sel and ok_tick and equiv and ok_b) else 1


def main() -> int:
    if "--smoke" in sys.argv:
        return smoke()
    select_rows = []
    for impl in SELECT_IMPLS:
        n = 3 if impl == "pallas_interpret" else 20
        r = bench_select(T0, L0, impl, n_iters=n)
        select_rows.append(r)
        print(f"select_ms T={T0} L={L0} {impl:16s} {r['select_ms']:9.3f}ms "
              f"(compile={r['compile_s']:.2f}s)", flush=True)
    tick_rows = []
    for impl in TICK_IMPLS:
        n = 3 if impl == "pallas_interpret" else 15
        r = bench_tick(T0, L0, impl, n_ticks=n)
        tick_rows.append(r)
        print(f"tick_ms   T={T0} L={L0} {impl:16s} {r['tick_ms']:9.3f}ms "
              f"(compile={r['compile_s']:.2f}s)", flush=True)
    equiv = quick_equivalence()
    print(f"interpret bit-exact: {equiv}")
    _write(select_rows, tick_rows, equiv,
           "selection-core roofline: Pallas segmented top-k kernels vs the "
           "jnp selection paths; kernel_ref = the kernel algorithm's XLA "
           "lowering (the compiled path off-TPU), pallas_interpret = "
           "bit-exactness witness with emulation overhead")
    return 0


if __name__ == "__main__":
    sys.exit(main())
