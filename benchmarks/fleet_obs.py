"""Fleet observability sweep: vmap the tiering engine across N simulated
hosts, roll telemetry up fleet-wide, and show the pathology detectors
catching an injected noisy neighbor that a clean fleet does not flag.

  PYTHONPATH=src python -m benchmarks.fleet_obs                 # 32 hosts
  PYTHONPATH=src python -m benchmarks.fleet_obs --smoke         # 4 hosts, CI

Two sweeps run over the same heterogeneous tenant mixes:
  clean — stable web/cache/ci/spark/micro mixes
  noisy — tenant 0 replaced mid-run by a §V-B5 thrasher (hot pages never
          re-accessed before demotion) squeezed under a small upper bound

and the exit code asserts the acceptance property: the noisy fleet flags
tenant 0 (chronic thrashing + protection violation) on every injected host,
the clean fleet flags nothing.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.configs.base import TieringConfig
from repro.obs.fleet import (heterogeneous_mixes, inject_noisy_neighbor,
                             run_fleet)


def _print_rollup(tag: str, roll: dict) -> None:
    print(f"\n[{tag}] fleet rollup "
          f"({roll['hosts']} hosts x {roll['tenants']} tenants x "
          f"{roll['ticks']} ticks):")
    print(f"  latency p50/p99           "
          f"{roll['latency_p50']:.3f} / {roll['latency_p99']:.3f} "
          f"(worst-host p99 {roll['latency_worst_host_p99']:.3f})")
    print(f"  mean throughput           {roll['throughput_mean']:.1f}")
    print(f"  migrations per tick       {roll['migrations_per_tick']:.2f}")
    print(f"  thrash events (total)     {roll['thrash_total']}")
    print(f"  hosts with pathologies    {roll['hosts_with_pathology']}")
    print(f"  pathology counts          {roll['pathology_counts'] or '{}'}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hosts", type=int, default=32)
    ap.add_argument("--ticks", type=int, default=200)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--mode", default="equilibria",
                    choices=["equilibria", "tpp", "memtis", "static"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (--hosts 4 --ticks 120)")
    args = ap.parse_args()
    if args.smoke:
        args.hosts, args.ticks = min(args.hosts, 4), min(args.ticks, 120)

    T = args.tenants
    footprints = [160, 160] + [120] * (T - 2) if T >= 2 else [160]
    # fast tier sized so the worst-case *stable* mix (every tenant hot)
    # fits with headroom — a clean fleet must be clean, not quietly squeezed
    n_fast = max(int(sum(footprints) * 1.15), 256)
    cfg = TieringConfig(
        n_tenants=T, n_fast_pages=n_fast, n_slow_pages=n_fast,
        lower_protection=(96,) * T, upper_bound=(0,) * T,
        migration_cost=0.005)

    mixes = heterogeneous_mixes(footprints, args.hosts, seed=args.seed)

    t0 = time.time()
    clean = run_fleet(cfg, mixes, args.ticks, mode=args.mode)
    t_clean = time.time() - t0
    _print_rollup(f"clean mode={args.mode} {t_clean:.1f}s", clean.rollup())

    # noisy sweep: tenant 0 becomes a thrasher pinned under a 24-page bound
    # (bound < protection — the misconfiguration §IV-C observability exists
    # to expose), arriving after a clean baseline window
    noisy_mixes = inject_noisy_neighbor(mixes, tenant=0, fast_share=24,
                                        arrival=max(args.ticks // 4, 10))
    t0 = time.time()
    noisy = run_fleet(cfg.with_(upper_bound=(24,) + (0,) * (T - 1)),
                      noisy_mixes, args.ticks, mode=args.mode)
    t_noisy = time.time() - t0
    _print_rollup(f"noisy mode={args.mode} {t_noisy:.1f}s", noisy.rollup())

    print("\nper-host pathologies (noisy sweep, first 8 hosts):")
    for h, ps in enumerate(noisy.pathologies[:8]):
        for p in ps:
            print(f"  host{h}: {p}")

    s0 = noisy.stats[0]
    print("\nhost0 tenant0 tier_stat excerpt (noisy):")
    print(f"  resid_p50 {s0['resid_p50'][0]:.0f} ticks, "
          f"resid_p99 {s0['resid_p99'][0]:.0f} ticks")
    print(f"  promo_success_ratio {s0['promo_success_ratio'][0]:.3f}, "
          f"thrash_rate {s0['thrash_rate'][0]:.1f}")
    ev, dropped = noisy.host_migrations(0)
    print(f"  migration ring: {len(ev)} events ({dropped} overwritten)")

    if args.mode != "equilibria":
        return 0  # acceptance property is only asserted for the paper policy

    # acceptance: noisy flags tenant 0 for thrash AND protection violation
    # on every host; the clean fleet is silent
    ok = True
    if clean.tenants_flagged():
        print(f"FAIL: clean fleet flagged {clean.tenants_flagged()}")
        ok = False
    for kind in ("chronic_thrashing", "protection_violation"):
        hosts_flagged = {h for h, t in noisy.tenants_flagged(kind) if t == 0}
        if len(hosts_flagged) < args.hosts:
            print(f"FAIL: {kind} flagged tenant0 on only "
                  f"{len(hosts_flagged)}/{args.hosts} hosts")
            ok = False
    print("\nACCEPTANCE", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
