"""Scale sweep for the tiering engine: tick wall-time and compile time
across T (tenants) x L (pages) x mode, batched vs the seed's unrolled
engine — the repo's perf trajectory baseline (benchmarks/results/scale.json).

  PYTHONPATH=src python -m benchmarks.scale_sweep          # full sweep -> scale.json
  PYTHONPATH=src python -m benchmarks.scale_sweep --smoke  # CI: T=16, L=16k budget check

The batched engine's trace is T-independent (one segmented sort per
selection site, scatter-add reductions), so one compiled tick serves any
tenant count; the unrolled baseline pays one top_k per tenant per selection
site. The sweep records both so future PRs have a number to beat.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

TS = (4, 16, 64)
LS = (16384, 65536, 262144)
MODES = ("equilibria", "tpp", "memtis", "static")
SMOKE_BUDGET_S = 120.0          # compile + 50 ticks, T=16, L=16k (CI gate)
RESULTS = os.path.join(os.path.dirname(__file__), "results", "scale.json")


def _build(T: int, L: int, mode: str, impl: str):
    import jax.numpy as jnp
    from repro.configs.base import TieringConfig
    from repro.core.engine import make_tick
    from repro.core.state import init_state

    share = L // (4 * T)        # fast tier is L/4 pages; share = fair split
    cfg = TieringConfig(
        n_tenants=T, n_fast_pages=L // 4, n_slow_pages=L,
        lower_protection=(max(share // 2, 1),) * T,
        upper_bound=(2 * share,) * T)   # exercises Eq.1/Eq.2 + sync path
    owner = np.repeat(np.arange(T, dtype=np.int32), L // T)
    tick = make_tick(cfg, owner, mode, k_max=256, impl=impl)
    state = init_state(cfg, L)
    rng = np.random.default_rng(0)
    accesses = np.where(rng.random(L) < 0.3, 4.0, 0.1).astype(np.float32)
    inputs = (jnp.asarray(accesses), jnp.ones((L,), bool))
    return tick, state, inputs


def bench_tick(T: int, L: int, mode: str, impl: str = "batched",
               n_ticks: int = 100) -> dict:
    import jax
    tick, state, inputs = _build(T, L, mode, impl)
    tick = jax.jit(tick)
    t0 = time.perf_counter()
    state, out = tick(state, inputs)
    jax.block_until_ready(out)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(n_ticks):
        state, out = tick(state, inputs)
    jax.block_until_ready(out)
    tick_ms = (time.perf_counter() - t0) / n_ticks * 1e3
    return {"mode": mode, "T": T, "L": L, "impl": impl,
            "compile_s": round(compile_s, 3), "tick_ms": round(tick_ms, 3),
            "n_ticks": n_ticks}


def trace_eqns(T: int, L: int, mode: str, impl: str) -> int:
    """Jaxpr equation count of one tick (trace only, no compile)."""
    import jax
    tick, state, inputs = _build(T, L, mode, impl)
    return len(jax.make_jaxpr(tick)(state, inputs).jaxpr.eqns)


def smoke() -> int:
    """CI gate: compile + 50 ticks at T=16, L=16k inside the budget."""
    t0 = time.perf_counter()
    r = bench_tick(16, 16384, "equilibria", "batched", n_ticks=50)
    elapsed = time.perf_counter() - t0
    ok = elapsed < SMOKE_BUDGET_S
    print(f"scale smoke: T=16 L=16384 compile={r['compile_s']:.2f}s "
          f"tick={r['tick_ms']:.2f}ms total={elapsed:.1f}s "
          f"budget={SMOKE_BUDGET_S:.0f}s -> {'OK' if ok else 'OVER BUDGET'}")
    return 0 if ok else 1


def main() -> int:
    if "--smoke" in sys.argv:
        return smoke()
    import jax
    sweep = []
    n_for = {16384: 100, 65536: 50, 262144: 25}
    for mode in MODES:
        for T in TS:
            for L in LS:
                r = bench_tick(T, L, mode, n_ticks=n_for[L])
                sweep.append(r)
                print(f"{mode:10s} T={T:3d} L={L:6d} batched   "
                      f"compile={r['compile_s']:7.2f}s tick={r['tick_ms']:8.3f}ms",
                      flush=True)
    # unrolled baseline at T=64 (the seed engine; fewer ticks, it's slow)
    speedup = {}
    for L in LS:
        u = bench_tick(64, L, "equilibria", impl="unrolled", n_ticks=20)
        sweep.append(u)
        b = next(r for r in sweep
                 if r["impl"] == "batched" and r["mode"] == "equilibria"
                 and r["T"] == 64 and r["L"] == L)
        speedup[f"T=64,L={L}"] = round(u["tick_ms"] / b["tick_ms"], 2)
        print(f"equilibria T= 64 L={L:6d} unrolled  "
              f"compile={u['compile_s']:7.2f}s tick={u['tick_ms']:8.3f}ms "
              f"-> speedup {speedup[f'T=64,L={L}']}x", flush=True)
    eqns = {f"T={T}": trace_eqns(T, 16384, "equilibria", "batched")
            for T in TS}
    out = {
        "meta": {"backend": jax.default_backend(), "k_max": 256,
                 "note": "tick wall-time (ms) and compile time (s) per "
                         "(mode, T, L); speedup = unrolled/batched tick_ms "
                         "at T=64; jaxpr_eqns shows trace T-independence"},
        "jaxpr_eqns_batched": eqns,
        "speedup_vs_unrolled": speedup,
        "sweep": sweep,
    }
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    from benchmarks.run import write_result
    write_result(RESULTS, out,
                 config={"modes": MODES, "TS": TS, "LS": LS, "k_max": 256})
    print(f"wrote {RESULTS}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
