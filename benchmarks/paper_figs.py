"""One benchmark per paper table/figure (DESIGN.md §5 index).

Each function runs the scenario on the tiering engine (Equilibria + the TPP
baseline where the paper compares), validates the paper's claim, and returns
(name, us_per_call, derived) rows for the CSV plus a JSON detail record.
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Dict, List, Tuple

import numpy as np

from repro.configs.base import TieringConfig
from repro.core.simulator import SimResult, compare_modes, simulate
from repro.core.workloads import (cache_like, ci_like, microbenchmark,
                                  spark_like, tao_like, thrasher, web_like)

RESULTS = Path(__file__).resolve().parent / "results"
Row = Tuple[str, float, str]


def _timed(fn, *a, **kw):
    t0 = time.time()
    out = fn(*a, **kw)
    return out, (time.time() - t0) * 1e6


def _save(name: str, detail: Dict, config=None):
    from benchmarks.run import write_result
    RESULTS.mkdir(parents=True, exist_ok=True)
    write_result(RESULTS / f"{name}.json", detail, config=config)


# ---------------------------------------------------------------- Fig. 3 ----
def fig3_hotness_unfairness() -> List[Row]:
    """Hotter Container A takes (almost) all local memory under system-level
    tiering; the colder B gets ~half its footprint (paper Fig. 3)."""
    cfg = TieringConfig(n_tenants=2, n_fast_pages=512, n_slow_pages=512,
                        lower_protection=(256, 256), upper_bound=(0, 0))
    tenants = [microbenchmark(400, hotness=2.0), microbenchmark(400, hotness=1.0)]
    res, us = _timed(compare_modes, cfg, tenants, 200)
    tpp, eq = res["tpp"], res["equilibria"]
    a_frac = tpp.fast_usage[-1, 0] / 400
    b_frac = tpp.fast_usage[-1, 1] / 400
    _save("fig3", {"tpp_fast": tpp.fast_usage[-1].tolist(),
                   "eq_fast": eq.fast_usage[-1].tolist(),
                   "tpp_fast_series": tpp.fast_usage[::5].tolist()})
    return [("fig3_tpp_hot_tenant_fast_frac", us, f"{a_frac:.2f}"),
            ("fig3_tpp_cold_tenant_fast_frac", us, f"{b_frac:.2f}"),
            ("fig3_eq_cold_tenant_fast_frac", us,
             f"{eq.fast_usage[-1, 1] / 400:.2f}")]


# ------------------------------------------------- §III-F launch order ----
def launch_order() -> List[Row]:
    """Late-arriving identical tenant is permanently impaired under TPP
    (paper: 28% lower throughput); equalized by Equilibria."""
    cfg = TieringConfig(n_tenants=2, n_fast_pages=512, n_slow_pages=512,
                        lower_protection=(256, 256), upper_bound=(0, 0))
    tenants = [microbenchmark(300), microbenchmark(300, arrival=30)]
    res, us = _timed(compare_modes, cfg, tenants, 250)
    tpp, eq = res["tpp"], res["equilibria"]
    gap_tpp = 1 - tpp.mean_throughput()[1] / tpp.mean_throughput()[0]
    gap_eq = 1 - eq.mean_throughput()[1] / eq.mean_throughput()[0]
    _save("launch_order", {"tpp_thr": tpp.mean_throughput().tolist(),
                           "eq_thr": eq.mean_throughput().tolist()})
    return [("launch_order_tpp_late_tenant_loss", us, f"{gap_tpp:.1%}"),
            ("launch_order_eq_late_tenant_loss", us, f"{gap_eq:.1%}")]


# ---------------------------------------------------------------- Fig. 5 ----
def fig5_protection() -> List[Row]:
    """Footprints 120/90/90GB, protection 80GB: fast usage converges to the
    protections; A spills ~40GB, B/C ~10GB (1 page = 0.25GB)."""
    cfg = TieringConfig(n_tenants=3, n_fast_pages=1024, n_slow_pages=512,
                        lower_protection=(320, 320, 320), upper_bound=(0, 0, 0))
    tenants = [microbenchmark(480), microbenchmark(360), microbenchmark(360)]
    r, us = _timed(simulate, cfg, tenants, 250, "equilibria")
    final = r.fast_usage[-25:].mean(0)
    _save("fig5", {"fast_series": r.fast_usage[::5].tolist(),
                   "slow_series": r.slow_usage[::5].tolist(),
                   "demotions": r.demotions.sum(0).tolist()})
    return [("fig5_converged_fast_gb", us,
             "/".join(f"{v / 4:.0f}" for v in final)),
            ("fig5_spilled_gb", us,
             "/".join(f"{v / 4:.0f}" for v in r.slow_usage[-25:].mean(0)))]


# ---------------------------------------------------------------- Fig. 6 ----
def fig6_promotion_throttle() -> List[Row]:
    """Over-protection Container A's promotion rate is suppressed while
    converging (paper Fig. 6)."""
    cfg = TieringConfig(n_tenants=3, n_fast_pages=1024, n_slow_pages=512,
                        lower_protection=(320, 320, 320), upper_bound=(0, 0, 0))
    tenants = [microbenchmark(480), microbenchmark(360), microbenchmark(360)]
    tpp = simulate(cfg, tenants, 250, mode="tpp")
    r, us = _timed(simulate, cfg, tenants, 250, "equilibria")
    # during convergence, A's promotion rate is intentionally suppressed
    # (Fig. 6 blue line) although it has the most CXL promotion candidates
    conv = slice(20, 120)
    a_promo = r.promotions[conv, 0].mean()
    a_promo_tpp = tpp.promotions[conv, 0].mean()
    suppression = 1 - a_promo / max(a_promo_tpp, 1e-9)
    _save("fig6", {"promotions": r.promotions[::5].tolist(),
                   "demotions": r.demotions[::5].tolist(),
                   "promotions_tpp": tpp.promotions[::5].tolist()})
    return [("fig6_overage_tenant_promo_rate_eq", us, f"{a_promo:.1f}"),
            ("fig6_overage_tenant_promo_rate_unregulated", us,
             f"{a_promo_tpp:.1f}"),
            ("fig6_promotion_suppression", us, f"{suppression:.0%}")]


# ------------------------------------------------------- §V-B validation ----
def validation_suite() -> List[Row]:
    rows: List[Row] = []
    base = dict(n_tenants=3, n_fast_pages=1024, n_slow_pages=512,
                lower_protection=(320, 320, 320), upper_bound=(0, 0, 0))
    # V-B1 local preferred
    cfg = TieringConfig(**base)
    r, us = _timed(simulate, cfg, [microbenchmark(480), microbenchmark(160),
                                   microbenchmark(160)], 120, "equilibria")
    rows.append(("vb1_all_resident_fast", us,
                 str(bool((r.slow_usage[-1] == 0).all()))))
    # V-B3 donation
    r, us = _timed(simulate, cfg, [microbenchmark(480),
                                   microbenchmark(280, arrival=40),
                                   microbenchmark(280, arrival=40)], 250,
                   "equilibria")
    rows.append(("vb3_donated_pages_to_A", us,
                 f"{r.fast_usage[-25:, 0].mean() - 320:.0f}"))
    # V-B4 upper bound
    cfg = TieringConfig(**{**base, "upper_bound": (320, 0, 0)})
    r, us = _timed(simulate, cfg, [microbenchmark(480), microbenchmark(160),
                                   microbenchmark(160)], 150, "equilibria")
    rows.append(("vb4_bound_respected", us,
                 str(bool(r.fast_usage[-25:, 0].max() <= 320))))
    return rows


# ------------------------------------------------------- §V-B5 thrashing ----
def fig_thrashing() -> List[Row]:
    """Thrashing tenant: migrations cut by orders of magnitude; neighbors
    regain ~7% throughput (paper §V-B5 / §III-F)."""
    tenants = [thrasher(400, fast_share=16), microbenchmark(200),
               microbenchmark(200)]
    # migration_cost calibrated so unmitigated thrashing costs neighbors ~7%
    # (the paper's measured interference)
    cfg = TieringConfig(n_tenants=3, n_fast_pages=1024, n_slow_pages=512,
                        lower_protection=(0, 256, 256), upper_bound=(16, 0, 0),
                        migration_cost=0.0003, t_resident=10, r_thrashing=8.0,
                        controller_period=15)
    t0 = time.time()
    on = simulate(cfg, tenants, 300, mode="equilibria")
    off = simulate(cfg.with_(enable_thrash_mitigation=False), tenants, 300,
                   mode="equilibria")
    us = (time.time() - t0) * 1e6
    w = slice(200, 300)
    mig_on = float((on.promotions[w, 0] + on.demotions[w, 0]).mean())
    mig_off = float((off.promotions[w, 0] + off.demotions[w, 0]).mean())
    thr_gain = (on.mean_throughput(w)[1:].sum()
                / max(off.mean_throughput(w)[1:].sum(), 1e-9) - 1)
    _save("thrashing", {"mig_on": mig_on, "mig_off": mig_off,
                        "promo_scale": on.promo_scale[::10, 0].tolist(),
                        "thrash_events": on.thrash_events[::10, 0].tolist()})
    return [("thrash_migrations_unmitigated", us, f"{mig_off:.1f}/tick"),
            ("thrash_migrations_mitigated", us, f"{mig_on:.1f}/tick"),
            ("thrash_neighbor_throughput_gain", us, f"{thr_gain:.1%}")]


# ------------------------------------------------ Fig. 7 / §V-C DCPerf ----
def fig7_heterogeneous() -> List[Row]:
    """3x TaoBench + 1x SparkBench on the large server (192GB upper bound
    each = the server split four ways; 1 page = 0.25GB: bound 768 pages,
    fast 3072 = 768GB local, slow 1024 = 256GB CXL). Paper: 1.7x SparkBench
    throughput on Equilibria vs TPP."""
    fast, slow, bound = 3072, 1024, 768
    tenants = [spark_like(1200), tao_like(900, arrival=10),
               tao_like(900, arrival=20), tao_like(900, arrival=30)]
    # p_base scaled to the real promotion-bandwidth : hot-set ratio — the
    # mechanism is allocation-time placement + promotion headroom (paper:
    # "preserving free local memory for the short-lived bursty SparkBench")
    # lat_slow=3.0: the paper's *loaded* CXL latency (Fig. 2 — loaded rises
    # well above the 252ns idle point; TaoBench keeps the bus busy here)
    cfg = TieringConfig(n_tenants=4, n_fast_pages=fast, n_slow_pages=slow,
                        lower_protection=(0, 0, 0, 0), p_base=12,
                        upper_bound=(bound, bound, bound, bound),
                        lat_slow=3.0)
    t0 = time.time()
    eq = simulate(cfg, tenants, 400, mode="equilibria", k_max=128)
    tpp = simulate(cfg.with_(upper_bound=(0, 0, 0, 0)), tenants, 400,
                   mode="tpp", k_max=128)
    us = (time.time() - t0) * 1e6
    # SparkBench runs in a loop; the paper reports queries/hour = completion
    # rate during its *active* (high-footprint) analytics phases.
    ticks = np.arange(400)
    active = ((ticks // 30) % 2 == 0) & (ticks >= 200)
    spark_qph_eq = eq.throughput[active, 0].mean()
    spark_qph_tpp = tpp.throughput[active, 0].mean()
    spark_gain = spark_qph_eq / max(spark_qph_tpp, 1e-9)
    w = slice(200, 400)
    tao_ratio = (eq.mean_throughput(w)[1:].mean()
                 / max(tpp.mean_throughput(w)[1:].mean(), 1e-9))
    _save("fig7", {"eq_fast": eq.fast_usage[::8].tolist(),
                   "tpp_fast": tpp.fast_usage[::8].tolist(),
                   "spark_qph_eq": float(spark_qph_eq),
                   "spark_qph_tpp": float(spark_qph_tpp)})
    return [("fig7_sparkbench_speedup_eq_vs_tpp", us, f"{spark_gain:.2f}x"),
            ("fig7_taobench_ratio_eq_vs_tpp", us, f"{tao_ratio:.2f}x")]


# -------------------------------------------------------- §V-D1 Cache ----
def prod_cache() -> List[Row]:
    """Two homogeneous Cache instances: TPP splits local memory unevenly
    (paper: 90% vs 70% resident, up to 3.3x P99 gap, 65% throughput drop on
    a burst); Equilibria (prot 70%, bound 75%) equalizes."""
    # large server: 3072 fast + 1024 slow pages; footprints fill it
    foot = 2000
    prot, bound = int(foot * 0.70), int(foot * 0.75)
    tenants = [cache_like(foot), cache_like(foot, arrival=5)]
    cfg_eq = TieringConfig(n_tenants=2, n_fast_pages=3072, n_slow_pages=1024,
                           lower_protection=(prot, prot),
                           upper_bound=(bound, bound))
    cfg_tpp = cfg_eq.with_(lower_protection=(0, 0), upper_bound=(0, 0))
    t0 = time.time()
    eq = simulate(cfg_eq, tenants, 300, mode="equilibria", k_max=512)
    tpp = simulate(cfg_tpp, tenants, 300, mode="tpp", k_max=512)
    us = (time.time() - t0) * 1e6
    w = slice(150, 300)
    eq_resident = eq.fast_usage[w].mean(0) / foot
    tpp_resident = tpp.fast_usage[w].mean(0) / foot
    p99_gap_tpp = tpp.p99_latency(w)[1] / tpp.p99_latency(w)[0]
    p99_gap_eq = eq.p99_latency(w)[1] / eq.p99_latency(w)[0]
    _save("prod_cache", {
        "eq_resident": eq_resident.tolist(),
        "tpp_resident": tpp_resident.tolist(),
        "p99_gap": [float(p99_gap_tpp), float(p99_gap_eq)]})
    return [("cache_tpp_resident_split", us,
             f"{tpp_resident[0]:.0%}/{tpp_resident[1]:.0%}"),
            ("cache_eq_resident_split", us,
             f"{eq_resident[0]:.0%}/{eq_resident[1]:.0%}"),
            ("cache_p99_gap_tpp_vs_eq", us,
             f"{p99_gap_tpp:.2f}->{p99_gap_eq:.2f}")]


def prod_cache_burst() -> List[Row]:
    """Noisy-neighbor burst (§V-D1): B's usage jumps 0->90% in a minute; on
    TPP A loses local share and throughput collapses; Equilibria absorbs."""
    foot = 2000
    prot, bound = 1400, 1500
    tenants = [cache_like(foot),
               cache_like(foot, arrival=150)]  # burst: B ramps at t=150
    cfg_eq = TieringConfig(n_tenants=2, n_fast_pages=3072, n_slow_pages=1024,
                           lower_protection=(prot, prot),
                           upper_bound=(bound, bound))
    cfg_tpp = cfg_eq.with_(lower_protection=(0, 0), upper_bound=(0, 0))
    t0 = time.time()
    eq = simulate(cfg_eq, tenants, 300, mode="equilibria", k_max=512)
    tpp = simulate(cfg_tpp, tenants, 300, mode="tpp", k_max=512)
    us = (time.time() - t0) * 1e6
    pre, post = slice(100, 150), slice(160, 220)
    drop_tpp = 1 - tpp.throughput[post, 0].mean() / tpp.throughput[pre, 0].mean()
    drop_eq = 1 - eq.throughput[post, 0].mean() / eq.throughput[pre, 0].mean()
    _save("prod_cache_burst", {"drop": [float(drop_tpp), float(drop_eq)]})
    return [("cache_burst_victim_drop_tpp", us, f"{drop_tpp:.1%}"),
            ("cache_burst_victim_drop_eq", us, f"{drop_eq:.1%}")]


# ----------------------------------------------------------- §V-D2 CI ----
def prod_ci() -> List[Row]:
    """Four spiky CI builds; protection=192GB (=768 pages) derived by the
    simple capacity-ratio policy. Late starter must get >90% fast residency
    on Equilibria (paper Fig. 8)."""
    prot = 768
    tenants = [ci_like(1000), ci_like(1000, arrival=10),
               ci_like(1000, arrival=20), ci_like(1000, arrival=60)]
    cfg = TieringConfig(n_tenants=4, n_fast_pages=3072, n_slow_pages=1024,
                        lower_protection=(prot,) * 4, upper_bound=(0,) * 4)
    t0 = time.time()
    eq = simulate(cfg, tenants, 300, mode="equilibria", k_max=512)
    tpp = simulate(cfg.with_(lower_protection=(0,) * 4), tenants, 300,
                   mode="tpp", k_max=512)
    us = (time.time() - t0) * 1e6
    w = slice(80, 160)  # during D's ramp-up
    d_res_eq = (eq.fast_usage[w, 3] /
                np.maximum(eq.fast_usage[w, 3] + eq.slow_usage[w, 3], 1)).mean()
    d_res_tpp = (tpp.fast_usage[w, 3] /
                 np.maximum(tpp.fast_usage[w, 3] + tpp.slow_usage[w, 3], 1)).mean()
    thr_gain = eq.mean_throughput()[0:].sum() / tpp.mean_throughput()[0:].sum()
    _save("prod_ci", {"eq_fast": eq.fast_usage[::8].tolist(),
                      "d_resident": [float(d_res_eq), float(d_res_tpp)]})
    return [("ci_late_starter_fast_residency_eq", us, f"{d_res_eq:.0%}"),
            ("ci_late_starter_fast_residency_tpp", us, f"{d_res_tpp:.0%}"),
            ("ci_total_throughput_eq_vs_tpp", us, f"{thr_gain:.3f}x")]


# ---------------------------------------------------------- §V-D3 Web ----
def prod_web() -> List[Row]:
    """Five Web instances (two partitions), protection from a hot-footprint
    profile (28GB = 112 pages @0.25GB). On TPP the partition-B instances'
    local share decays; Equilibria holds every instance at >= protection."""
    prot = 112
    tenants = [web_like(240, hot_pages=112), web_like(240, hot_pages=112),
               web_like(240, hot_pages=112), web_like(240, hot_pages=112),
               web_like(240, hot_pages=112)]
    # A & D serve partition B: less-hot, and the JIT re-specializes over
    # time (slowly rotating hot set) — their pages "manifest as less hot".
    # Partition-A instances keep warm non-hot pages (request-mix churn).
    for i in (1, 2, 4):
        tenants[i].cold_rate = 0.6
    for i in (0, 3):
        tenants[i].hot_rate = 2.2
        tenants[i].rotate_hot_every = 50
    cfg = TieringConfig(n_tenants=5, n_fast_pages=1024, n_slow_pages=256,
                        lower_protection=(prot,) * 5, upper_bound=(0,) * 5,
                        p_base=24)
    t0 = time.time()
    eq = simulate(cfg, tenants, 300, mode="equilibria")
    tpp = simulate(cfg.with_(lower_protection=(0,) * 5), tenants, 300,
                   mode="tpp")
    us = (time.time() - t0) * 1e6
    w = slice(150, 300)
    min_fast_eq = eq.fast_usage[w].min(0)
    partB = [0, 3]
    decay_tpp = tpp.fast_usage[60, partB].mean() - tpp.fast_usage[-1, partB].mean()
    slowdown_eq = 1 - (eq.mean_throughput(w)[partB].mean()
                       / max(tpp.mean_throughput(w).max(), 1e-9))
    _save("prod_web", {"eq_min_fast": min_fast_eq.tolist(),
                       "tpp_partB_decay": float(decay_tpp)})
    return [("web_protection_held_eq", us,
             str(bool((min_fast_eq[partB] >= prot - 4).all()))),
            ("web_partitionB_local_decay_tpp_pages", us, f"{decay_tpp:.0f}")]


# --------------------------------------------------------- Table I/III ----
def table1_bandwidth() -> List[Row]:
    """Capacity-bound tenants keep most of the slow tier's capacity in use
    while driving a small fraction of accesses to it (paper Table I)."""
    tenants = [cache_like(800), web_like(700), ci_like(700)]
    cfg = TieringConfig(n_tenants=3, n_fast_pages=1536, n_slow_pages=1024,
                        lower_protection=(512, 512, 512), upper_bound=(0,) * 3)
    r, us = _timed(simulate, cfg, tenants, 300, "equilibria")
    w = slice(150, 300)
    rows = []
    detail = {}
    for i, name in enumerate(["AppA", "AppB", "AppC"]):
        slow_cap = r.slow_usage[w, i].mean()
        # slow-tier access share ~ CXL bandwidth share
        lat = r.latency[w, i].mean()
        # lat = f_fast*1 + f_slow*2.5 -> f_slow = (lat-1)/1.5
        f_slow = max((lat - 1.0) / 1.5, 0.0)
        rows.append((f"table1_{name}_slow_capacity_pages", us,
                     f"{slow_cap:.0f}"))
        rows.append((f"table1_{name}_slow_access_share", us, f"{f_slow:.1%}"))
        detail[name] = {"slow_pages": float(slow_cap), "slow_share": f_slow}
    _save("table1", detail)
    return rows


ALL_BENCHES = [
    fig3_hotness_unfairness, launch_order, fig5_protection,
    fig6_promotion_throttle, validation_suite, fig_thrashing,
    fig7_heterogeneous, prod_cache, prod_cache_burst, prod_ci, prod_web,
    table1_bandwidth,
]
