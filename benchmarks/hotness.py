"""Differential fidelity harness for the hotness providers: the
fidelity/speed frontier of exact vs sampled vs sketch vs neomem
(core/hotness.py), emitted to benchmarks/results/hotness.json.

  PYTHONPATH=src python -m benchmarks.hotness          # full matrix -> hotness.json
  PYTHONPATH=src python -m benchmarks.hotness --smoke  # CI gates (see below)

Three measurements:

  agreement — paired-tick promotion-decision agreement. The EXACT engine
      advances the trajectory; each tick, every provider's tick runs
      counterfactually from the same pre-tick state (with the provider's
      own carried sketch/report state substituted in) and the two
      promotion sets (tier SLOW -> FAST transitions) are compared. Pooled
      Jaccard over the run — 1.0 means the provider made identical
      promotion decisions at every tick. Measured per provider x policy
      mode x ownership provider (static = stacked16, dynamic = churn16).
  fidelity — free-running per-tenant fast-hit fraction (recovered from
      the perf model's latency output) vs the exact run on the same
      preset; reported as max/mean absolute per-tenant delta.
  tick_ms / path_ms — wall-time vs L at T=64 (the scale_sweep scenario),
      per provider. ``tick_ms`` is the full tick; ``path_ms`` isolates the
      hotness path (provider step + the tick's three selection calls) —
      the part the sketch provider makes O(hot set) instead of O(L). The
      full tick also carries a shared floor both providers pay identically
      (perf-model reductions whose f32 association is golden-pinned,
      observability ring/histogram scatters, controller), so the
      end-to-end ratio is diluted; both numbers are reported.

CI gates (--smoke, wired into scripts/check.sh), all at T=64/L=262144:
sketch agreement on stacked16 >= AGREEMENT_MIN, hotness-path speedup
(exact path_ms / sketch path_ms) >= PATH_SPEEDUP_MIN, and full-tick
speedup >= TICK_SPEEDUP_MIN.
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

AGREEMENT_MIN = 0.95      # sketch vs exact, stacked16 (acceptance gate)
PATH_SPEEDUP_MIN = 2.0    # exact/sketch hotness-path ms at T=64, L=262144
TICK_SPEEDUP_MIN = 1.3    # exact/sketch full-tick ms (floor; ~1.6 measured)
SMOKE_BUDGET_S = 300.0
SMOKE_TICKS = 120
RESULTS = os.path.join(os.path.dirname(__file__), "results", "hotness.json")

PROVIDERS = ("exact", "sampled", "sketch", "neomem")
AGREE_MODES = ("equilibria", "tpp", "memtis")
BENCH_LS = (16384, 65536, 262144)


# ------------------------------------------------------------- agreement ----
def _promoted(before_tier, after_tier) -> np.ndarray:
    from repro.core.state import TIER_FAST, TIER_SLOW
    return np.asarray((np.asarray(before_tier) == TIER_SLOW)
                      & (np.asarray(after_tier) == TIER_FAST))


def _paired_agreement(exact_tick, provider_ticks, state, hstates,
                      inputs_seq) -> dict:
    """Advance the exact trajectory; per tick run each provider's tick
    counterfactually from the same pre-tick state and pool the Jaccard of
    the promotion sets. Returns {provider: {"agreement", "union"}}."""
    import jax

    inter = {p: 0 for p in provider_ticks}
    union = {p: 0 for p in provider_ticks}
    for inp in inputs_seq:
        before = state.tier
        new_exact, _ = exact_tick(state, inp)
        promo_e = _promoted(before, new_exact.tier)
        for p, ptick in provider_ticks.items():
            ns, _ = ptick(state._replace(hotness=hstates[p]), inp)
            promo_p = _promoted(before, ns.tier)
            inter[p] += int((promo_e & promo_p).sum())
            union[p] += int((promo_e | promo_p).sum())
            hstates[p] = ns.hotness
        state = new_exact
    jax.block_until_ready(state.tier)
    return {p: {"agreement": (inter[p] / union[p]) if union[p] else 1.0,
                "union": union[p]} for p in provider_ticks}


def agreement_static(preset: str, providers, mode: str, ticks: int,
                     k_max: int = 128) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.core.engine import make_tick
    from repro.core.hotness import init_hotness
    from repro.core.simulator import PRESETS
    from repro.core.state import init_state
    from repro.core.workloads import build_trace

    cfg, tenants = PRESETS[preset]()
    owner, accesses, alive = build_trace(tenants, ticks)
    cfg = cfg.with_(n_tenants=len(tenants))
    exact_tick = jax.jit(make_tick(cfg, owner, mode, k_max))
    pticks = {p: jax.jit(make_tick(cfg, owner, mode, k_max, hotness=p))
              for p in providers}
    hstates = {p: init_hotness(p, cfg, owner.shape[0]) for p in providers}
    state = init_state(cfg, owner.shape[0], owner=owner)
    acc = jnp.asarray(accesses, jnp.float32)
    alv = jnp.asarray(alive, bool)
    return _paired_agreement(exact_tick, pticks, state, hstates,
                             [(acc[t], alv[t]) for t in range(ticks)])


def agreement_churn(preset: str, providers, mode: str, ticks: int,
                    k_max: int = 128) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.core.churn import make_churn_tick
    from repro.core.hotness import init_hotness
    from repro.core.simulator import CHURN_PRESETS
    from repro.core.state import init_state
    from repro.core.workloads import build_churn_schedule

    cfg, slots = CHURN_PRESETS[preset]()
    cfg = cfg.with_(n_tenants=len(slots))
    schedule = build_churn_schedule(slots, ticks)
    L = cfg.n_fast_pages + cfg.n_slow_pages
    exact_tick = jax.jit(make_churn_tick(cfg, L, mode=mode, k_max=k_max))
    pticks = {p: jax.jit(make_churn_tick(cfg, L, mode=mode, k_max=k_max,
                                         hotness=p))
              for p in providers}
    hstates = {p: init_hotness(p, cfg, L) for p in providers}
    state = init_state(cfg, L)
    rates = jnp.asarray(schedule.rates, jnp.float32)
    want = jnp.asarray(schedule.want, jnp.int32)
    return _paired_agreement(exact_tick, pticks, state, hstates,
                             [(rates[t], want[t]) for t in range(ticks)])


# --------------------------------------------------------------- fidelity ----
def _fast_hit(res, cfg) -> np.ndarray:
    """Per-tenant steady-window fast-hit fraction, recovered from the perf
    model: lat = f*lat_fast + (1-f)*lat_slow + migrations*migration_cost."""
    mig = (res.promotions + res.demotions).sum(axis=1, keepdims=True)
    lat_pure = res.latency - mig * cfg.migration_cost
    f = (cfg.lat_slow - lat_pure) / (cfg.lat_slow - cfg.lat_fast)
    return np.clip(f, 0.0, 1.0)[res.steady_window()].mean(axis=0)


def fidelity(preset: str, providers, ticks: int = 300,
             mode: str = "equilibria") -> list:
    from repro.core.simulator import PRESETS, simulate_preset

    cfg, _ = PRESETS[preset]()
    base = _fast_hit(simulate_preset(preset, ticks, mode=mode), cfg)
    rows = []
    for p in providers:
        fh = _fast_hit(simulate_preset(preset, ticks, mode=mode, hotness=p),
                       cfg)
        d = np.abs(fh - base)
        rows.append({"provider": p, "preset": preset, "mode": mode,
                     "max_abs_fast_hit_delta": round(float(d.max()), 4),
                     "mean_abs_fast_hit_delta": round(float(d.mean()), 4)})
    return rows


# ------------------------------------------------------------------ speed ----
def bench_hotness_path(T: int, L: int, hotness, n_ticks: int = 30) -> dict:
    """The provider's per-tick cost in isolation: ``step`` plus the three
    selection calls the tick makes on its view (Eq.1 demotion, promotion
    select, sync upper-bound demotion), jitted as one program on the
    bench_tick scenario. This is the path the sketch provider makes
    O(hot set) instead of O(L) — the tentpole claim — measured without the
    shared tick floor (perf model, observability scatters, controller)
    that both providers pay identically."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import TieringConfig
    from repro.core import hotness as HOT
    from repro.core import select as SEL
    from repro.core.state import TIER_FAST, TIER_SLOW

    share = L // (4 * T)
    cfg = TieringConfig(
        n_tenants=T, n_fast_pages=L // 4, n_slow_pages=L,
        lower_protection=(max(share // 2, 1),) * T,
        upper_bound=(2 * share,) * T)
    owner_np = np.repeat(np.arange(T, dtype=np.int32), L // T)
    owner_j = jnp.asarray(owner_np)
    provider = HOT.resolve_hotness(hotness, cfg, L, k_max=256)
    strat = SEL.static_strategy(owner_np, T, 256)
    rows = HOT.static_rowspace(owner_np, T)
    rng = np.random.default_rng(0)
    accesses = jnp.asarray(np.where(rng.random(L) < 0.3, 4.0, 0.1)
                           .astype(np.float32))
    alive = jnp.ones((L,), bool)
    new = jnp.zeros((L,), bool)
    tier_np = np.full(L, TIER_SLOW, np.int8)
    tier_np[rng.permutation(L)[:L // 4]] = TIER_FAST
    d_quota = jnp.full((T,), 8, jnp.int32)
    s_quota = jnp.full((T,), 4, jnp.int32)

    def path(hstate, prev_hot, tier, t):
        hview = provider.step(HOT.HotCtx(
            hstate=hstate, prev_hot=prev_hot, accesses=accesses,
            alive=alive, new=new, tier=tier,
            last_access=jnp.full((L,), t, jnp.int32), owner=owner_j,
            owner_c=owner_j, t=t, rows=lambda: rows, strategy=strat))
        dsel = hview.demote(tier == TIER_FAST, d_quota)
        tier = jnp.where(dsel.mask, TIER_SLOW, tier)
        pcand = hview.promo_cand(tier, dsel.mask)
        psel = pcand.select(jnp.minimum(pcand.cand_t, 256))
        tier = jnp.where(psel.mask, TIER_FAST, tier)
        ssel = hview.demote(tier == TIER_FAST, s_quota)
        return (hview.hstate, hview.hot,
                jnp.where(ssel.mask, TIER_SLOW, tier),
                hview.demand_t)

    f = jax.jit(path)
    carry = (provider.init(), jnp.zeros((L,), jnp.float32),
             jnp.asarray(tier_np), jnp.int32(1))
    t0 = time.perf_counter()
    hstate, hot, tier, _ = f(*carry)
    jax.block_until_ready(tier)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for i in range(n_ticks):
        hstate, hot, tier, _ = f(hstate, hot, tier, jnp.int32(2 + i))
    jax.block_until_ready(tier)
    path_ms = (time.perf_counter() - t0) / n_ticks * 1e3
    name = "exact" if hotness is None else hotness
    return {"provider": name, "T": T, "L": L,
            "compile_s": round(compile_s, 3),
            "path_ms": round(path_ms, 3), "n_ticks": n_ticks}


def bench_tick(T: int, L: int, hotness, n_ticks: int = 50,
               mode: str = "equilibria") -> dict:
    """scale_sweep's scenario (contiguous owner, fast = L/4, 30% hot pages)
    with a hotness provider threaded through."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import TieringConfig
    from repro.core.engine import make_tick
    from repro.core.state import init_state

    share = L // (4 * T)
    cfg = TieringConfig(
        n_tenants=T, n_fast_pages=L // 4, n_slow_pages=L,
        lower_protection=(max(share // 2, 1),) * T,
        upper_bound=(2 * share,) * T)
    owner = np.repeat(np.arange(T, dtype=np.int32), L // T)
    tick = jax.jit(make_tick(cfg, owner, mode, k_max=256, hotness=hotness))
    state = init_state(cfg, L, owner=owner, hotness=hotness)
    rng = np.random.default_rng(0)
    accesses = np.where(rng.random(L) < 0.3, 4.0, 0.1).astype(np.float32)
    inputs = (jnp.asarray(accesses), jnp.ones((L,), bool))
    t0 = time.perf_counter()
    state, out = tick(state, inputs)
    jax.block_until_ready(out)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(n_ticks):
        state, out = tick(state, inputs)
    jax.block_until_ready(out)
    tick_ms = (time.perf_counter() - t0) / n_ticks * 1e3
    name = ("exact" if hotness is None else hotness
            if isinstance(hotness, str) else type(hotness).__name__)
    return {"provider": name,
            "T": T, "L": L, "compile_s": round(compile_s, 3),
            "tick_ms": round(tick_ms, 3), "n_ticks": n_ticks}


# ------------------------------------------------------------------ entry ----
def smoke() -> int:
    """CI gates at T=64, L=262144: sketch agreement >= AGREEMENT_MIN on
    stacked16, hotness-path speedup >= PATH_SPEEDUP_MIN, full-tick
    speedup >= TICK_SPEEDUP_MIN."""
    t0 = time.perf_counter()
    ag = agreement_static("stacked16", ("sketch",), "equilibria",
                          SMOKE_TICKS)["sketch"]
    pe = bench_hotness_path(64, 262144, None)
    ps = bench_hotness_path(64, 262144, "sketch")
    be = bench_tick(64, 262144, None, n_ticks=15)
    bs = bench_tick(64, 262144, "sketch", n_ticks=15)
    path_x = pe["path_ms"] / ps["path_ms"]
    tick_x = be["tick_ms"] / bs["tick_ms"]
    elapsed = time.perf_counter() - t0
    ok_a = ag["agreement"] >= AGREEMENT_MIN
    ok_p = path_x >= PATH_SPEEDUP_MIN
    ok_t = tick_x >= TICK_SPEEDUP_MIN
    ok_b = elapsed < SMOKE_BUDGET_S
    print(f"hotness smoke: sketch agreement={ag['agreement']:.4f} "
          f"(union={ag['union']}, gate>={AGREEMENT_MIN}) "
          f"-> {'OK' if ok_a else 'FAIL'}")
    print(f"hotness smoke: hotness path exact={pe['path_ms']:.1f}ms "
          f"sketch={ps['path_ms']:.1f}ms speedup={path_x:.2f}x "
          f"(gate>={PATH_SPEEDUP_MIN}) -> {'OK' if ok_p else 'FAIL'}")
    print(f"hotness smoke: full tick exact={be['tick_ms']:.1f}ms "
          f"sketch={bs['tick_ms']:.1f}ms speedup={tick_x:.2f}x "
          f"(gate>={TICK_SPEEDUP_MIN}) -> {'OK' if ok_t else 'FAIL'}")
    print(f"hotness smoke: total={elapsed:.1f}s budget={SMOKE_BUDGET_S:.0f}s "
          f"-> {'OK' if ok_b else 'OVER BUDGET'}")
    return 0 if (ok_a and ok_p and ok_t and ok_b) else 1


def main() -> int:
    if "--smoke" in sys.argv:
        return smoke()
    import jax

    providers = [p for p in PROVIDERS if p != "exact"]
    agreement = []
    # "exact" rides along as a harness sanity row (must come out 1.0)
    for mode in AGREE_MODES:
        rows = agreement_static("stacked16", PROVIDERS, mode, 240)
        for p, r in rows.items():
            agreement.append({"provider": p, "mode": mode,
                              "ownership": "static", "preset": "stacked16",
                              "agreement": round(r["agreement"], 4),
                              "union": r["union"]})
            print(f"agreement stacked16 {mode:10s} {p:8s} "
                  f"{r['agreement']:.4f} (union={r['union']})", flush=True)
    for mode in ("equilibria",):
        rows = agreement_churn("churn16", PROVIDERS, mode, 240)
        for p, r in rows.items():
            agreement.append({"provider": p, "mode": mode,
                              "ownership": "dynamic", "preset": "churn16",
                              "agreement": round(r["agreement"], 4),
                              "union": r["union"]})
            print(f"agreement churn16   {mode:10s} {p:8s} "
                  f"{r['agreement']:.4f} (union={r['union']})", flush=True)

    fid = fidelity("stacked16", providers)
    for r in fid:
        print(f"fidelity  {r['preset']} {r['provider']:8s} "
              f"max|d fast-hit|={r['max_abs_fast_hit_delta']:.4f}",
              flush=True)

    speed = []
    n_for = {16384: 100, 65536: 50, 262144: 25}
    for p in PROVIDERS:
        for L in BENCH_LS:
            r = bench_tick(64, L, None if p == "exact" else p,
                           n_ticks=n_for[L])
            r["provider"] = p
            speed.append(r)
            print(f"tick_ms   T=64 L={L:6d} {p:8s} "
                  f"compile={r['compile_s']:6.2f}s tick={r['tick_ms']:8.3f}ms",
                  flush=True)

    path = []
    for p in ("exact", "sketch"):
        for L in BENCH_LS:
            r = bench_hotness_path(64, L, None if p == "exact" else p)
            path.append(r)
            print(f"path_ms   T=64 L={L:6d} {p:8s} "
                  f"path={r['path_ms']:8.3f}ms", flush=True)

    exact_ms = {r["L"]: r["tick_ms"] for r in speed
                if r["provider"] == "exact"}
    sketch_ms = {r["L"]: r["tick_ms"] for r in speed
                 if r["provider"] == "sketch"}
    exact_path = {r["L"]: r["path_ms"] for r in path
                  if r["provider"] == "exact"}
    sketch_path = {r["L"]: r["path_ms"] for r in path
                   if r["provider"] == "sketch"}
    frontier = {
        "tick_speedup_sketch_vs_exact": {
            f"T=64,L={L}": round(exact_ms[L] / sketch_ms[L], 2)
            for L in BENCH_LS},
        "path_speedup_sketch_vs_exact": {
            f"T=64,L={L}": round(exact_path[L] / sketch_path[L], 2)
            for L in BENCH_LS},
        "agreement_sketch_stacked16_equilibria": next(
            a["agreement"] for a in agreement
            if a["provider"] == "sketch" and a["mode"] == "equilibria"
            and a["ownership"] == "static"),
        "gates": {"agreement_min": AGREEMENT_MIN,
                  "path_speedup_min": PATH_SPEEDUP_MIN,
                  "tick_speedup_min": TICK_SPEEDUP_MIN},
    }
    out = {
        "meta": {"backend": jax.default_backend(),
                 "note": "promotion-decision agreement (pooled Jaccard of "
                         "paired-tick SLOW->FAST sets vs the exact "
                         "trajectory), per-tenant fast-hit deltas and "
                         "tick wall-time per hotness provider"},
        "agreement": agreement,
        "fidelity": fid,
        "tick_ms": speed,
        "path_ms": path,
        "frontier": frontier,
    }
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    from benchmarks.run import write_result
    write_result(RESULTS, out, config={
        "providers": PROVIDERS, "modes": AGREE_MODES, "LS": BENCH_LS,
        "agreement_ticks": 240})
    print(f"wrote {RESULTS}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
