#!/usr/bin/env bash
# CI entry point: tier-1 test suite (default marks: slow excluded, ~3 min)
# plus a fast fleet-observability smoke (clean fleet silent, injected noisy
# neighbor flagged — the obs/ acceptance property).
#
#   scripts/check.sh          # default suite + obs smoke
#   scripts/check.sh --full   # include slow-marked tests (full matrix)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MARK=(-m "not slow")
if [[ "${1:-}" == "--full" ]]; then
    MARK=(-m "")
fi

echo "== tier-1 tests =="
python -m pytest -x -q "${MARK[@]}"

echo "== static analysis gate (jaxpr passes + repo lint vs committed baseline) =="
python -m repro.analysis --gate

echo "== obs fleet smoke (4 hosts) =="
python -m benchmarks.fleet_obs --smoke

echo "== obs exporter smoke (Chrome trace + Prometheus exposition) =="
python -m benchmarks.obs_export --smoke

echo "== scale smoke (T=16, L=16k, 50 ticks) =="
python -m benchmarks.scale_sweep --smoke

echo "== churn smoke (dynamic ownership, >=50 lifecycle events) =="
python -m benchmarks.churn_sweep --smoke

echo "== fleet smoke (128 mixed static+churn hosts, 10k-tick chunked rollout) =="
python -m benchmarks.fleet_sweep --smoke

echo "== attribution smoke (conservation, counterfactuals, sketch, jaxpr gate) =="
python -m benchmarks.attribution --smoke

echo "== hotness smoke (sketch agreement >= 0.95, hotness-path speedup >= 2x) =="
python -m benchmarks.hotness --smoke

echo "== roofline smoke (kernel select speedup >= 1.2x, tick vs hotness baseline, interpret equivalence) =="
python -m benchmarks.roofline --smoke
